"""Approximate-first frontier snapshot: construction speedup × quality.

Combines the paper's Figure 8 (approximate construction time vs sample
count — ``bench_approx_construction``) and Figures 9/10 (best modularity,
ARI vs the exact clustering at its modularity-maximizing (μ*, ε*), and
core-set precision/recall — ``bench_approx_quality``) into one section,
and commits the result as the repo-root ``BENCH_approx.json`` — the
speed/quality frontier tracked per PR exactly like construction
(``BENCH_construction.json``) and updates (``BENCH_update.json``) are.

Reading the snapshot: ``fig8/*`` rows carry ``speedup_vs_exact`` (the
ingest-latency win approximate-first serving banks); ``fig9_10/*`` rows
carry what that speed costs — ``ari_vs_exact`` / ``core_precision`` /
``core_recall`` at the exact index's best setting and ``best_modularity``
for the approximate index's own grid optimum. Rising sample counts move
rows toward (1.0 ARI, 1× speedup); the useful operating points are the
ones that keep ARI high while the speedup is still large.
"""
from __future__ import annotations

import pathlib

from benchmarks.common import write_snapshot

SNAPSHOT = pathlib.Path(__file__).resolve().parents[1] / "BENCH_approx.json"


def run():
    from benchmarks import bench_approx_construction, bench_approx_quality

    lines = bench_approx_construction.run() + bench_approx_quality.run()
    write_snapshot(SNAPSHOT, "approx", lines)
    return lines
