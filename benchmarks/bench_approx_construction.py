"""Paper Figure 8 — approximate index construction time vs sample count.

LSH pays off on the dense graph and not on the sparse one — the same
qualitative shape as the paper's cochlea-vs-Orkut contrast.
"""
from __future__ import annotations

import jax

from repro.core import build_index
from benchmarks.common import GRAPHS, load_graph, timeit, emit

SAMPLES = (32, 64, 128, 256)


def run():
    lines = []
    for gname in ("sparse-8k", "dense-2k"):
        g = load_graph(gname)
        t_exact = timeit(lambda: build_index(g, "cosine"), trials=2)
        lines.append(emit(f"fig8/exact/{gname}", t_exact, f"m={g.m}"))
        for k in SAMPLES:
            t = timeit(lambda: build_index(
                g, "cosine", approx="simhash", samples=k,
                key=jax.random.PRNGKey(k)), trials=2)
            lines.append(emit(
                f"fig8/simhash/{gname}/k={k}", t,
                f"speedup_vs_exact={t_exact / t:.2f}x"))
        if not GRAPHS[gname]["weighted"]:
            for k in SAMPLES:
                t = timeit(lambda: build_index(
                    g, "jaccard", approx="kpartition", samples=k,
                    key=jax.random.PRNGKey(k)), trials=2)
                lines.append(emit(
                    f"fig8/kpartition/{gname}/k={k}", t,
                    f"speedup_vs_exact={t_exact / t:.2f}x"))
    return lines
