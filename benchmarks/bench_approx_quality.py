"""Paper Figures 9 & 10 — approximation quality vs construction cost.

Figure 9: best modularity over a (μ, ε) grid for each sample count.
Figure 10: ARI of the approximate clustering against the exact-σ clustering
at the exact-σ modularity-maximizing parameters, plus core-set
precision/recall there — the §5 guarantees are classification guarantees,
so core-set fidelity is the direct readout of what they buy.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.core import (adjusted_rand_index, build_index,
                        core_precision_recall, modularity, query)
from benchmarks.common import load_graph, timeit, emit

# miniature Σ grid (paper eq. 1 uses {2,4,…,2^18} × {.01,…,.99})
MUS = (2, 4, 8, 16)
EPSS = tuple(np.round(np.arange(0.15, 0.96, 0.1), 2))
SAMPLES = (32, 64, 128, 256)


def best_modularity(g, idx):
    best = (-2.0, None)
    for mu in MUS:
        for eps in EPSS:
            res = query(idx, g, mu, float(eps))
            q = modularity(g, np.asarray(res.labels))
            if q > best[0]:
                best = (q, (mu, float(eps), np.asarray(res.labels),
                            np.asarray(res.is_core)))
    return best


def run():
    lines = []
    for gname in ("planted-4k", "dense-2k"):
        g = load_graph(gname)
        idx_exact = build_index(g, "cosine")
        t_exact = timeit(lambda: build_index(g, "cosine"), trials=1)
        q_exact, (mu_star, eps_star, labels_exact, cores_exact) = \
            best_modularity(g, idx_exact)
        lines.append(emit(
            f"fig9/exact/{gname}", t_exact,
            f"best_modularity={q_exact:.4f};mu*={mu_star};eps*={eps_star}"))
        for k in SAMPLES:
            t = timeit(lambda: build_index(
                g, "cosine", approx="simhash", samples=k,
                key=jax.random.PRNGKey(k)), trials=1)
            idx_a = build_index(g, "cosine", approx="simhash", samples=k,
                                key=jax.random.PRNGKey(k))
            q_a, _ = best_modularity(g, idx_a)
            res_at_star = query(idx_a, g, mu_star, eps_star)
            ari = adjusted_rand_index(labels_exact,
                                      np.asarray(res_at_star.labels))
            prec, rec = core_precision_recall(
                np.asarray(res_at_star.is_core), cores_exact)
            lines.append(emit(
                f"fig9_10/simhash/{gname}/k={k}", t,
                f"best_modularity={q_a:.4f};ari_vs_exact={ari:.4f};"
                f"core_precision={prec:.4f};core_recall={rec:.4f};"
                f"speedup_vs_exact={t_exact / t:.2f}x"))
    return lines
