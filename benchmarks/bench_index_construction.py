"""Paper Figure 5 — index construction time (exact similarities).

Reports seconds and edges/sec for cosine and jaccard on each suite graph,
plus the similarity-pass / order-pass split (the paper's two phases).
"""
from __future__ import annotations

from repro.core import build_index, compute_similarities
from benchmarks.common import GRAPHS, load_graph, timeit, emit


def run():
    lines = []
    for gname in GRAPHS:
        g = load_graph(gname)
        measures = ["cosine"] if GRAPHS[gname]["weighted"] else ["cosine", "jaccard"]
        for measure in measures:
            t_sim = timeit(lambda: compute_similarities(g, measure))
            sims = compute_similarities(g, measure)
            t_idx = timeit(lambda: build_index(g, measure, sims=sims))
            t_full = timeit(lambda: build_index(g, measure))
            eps = g.m / t_full
            lines.append(emit(
                f"fig5/index_construction/{gname}/{measure}", t_full,
                f"edges_per_s={eps:.0f};sim_pass_s={t_sim:.3f};"
                f"order_pass_s={t_idx:.3f};m={g.m}"))
    return lines
