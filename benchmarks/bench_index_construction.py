"""Paper Figure 5 — index construction time (exact similarities).

Two sections:

* the uniform suite (fig5 continuity): seconds and edges/sec for cosine
  and jaccard on each suite graph, plus the similarity-pass / order-pass
  split (the paper's two phases) — all on the degree-bucketed engine;
* the skewed suite: bucketed vs the legacy dense-padded layout on
  power-law / hub-ring graphs, where one hub used to inflate the dense
  operand to O(n·Δ). Rows report the similarity-pass and end-to-end
  construction speedups and the peak similarity-operand-memory ratio;
* the lane suite: the same similarity pass forced down each execution
  lane (``REPRO_LANE`` — read per call, so flipping the env between
  timings pins every kernel). Rows carry a ``bit_identical_vs_ref``
  column: on the unweighted lane graph every lane must reproduce the
  ref lane's σ bit-for-bit (the backend contract, enforced here on the
  real construction path and in ``tests/test_backend.py``).

Every run also snapshots its rows to ``BENCH_construction.json`` at the
repo root — the construction perf trajectory that CI uploads per commit
(same pattern as the serve/update artifacts).
"""
from __future__ import annotations

import os
import pathlib

import numpy as np

from repro.core import build_index, compute_similarities, random_graph
from repro.core.similarity import (compute_similarities_densepad,
                                   densepad_operand_bytes, plan_for)
from benchmarks.common import (GRAPHS, SKEWED_GRAPHS, load_graph, timeit,
                               emit, write_snapshot)

SNAPSHOT = pathlib.Path(__file__).resolve().parents[1] / \
    "BENCH_construction.json"


def _uniform_rows():
    lines = []
    for gname in GRAPHS:
        g = load_graph(gname)
        measures = ["cosine"] if GRAPHS[gname]["weighted"] else ["cosine", "jaccard"]
        for measure in measures:
            t_sim = timeit(lambda: compute_similarities(g, measure))
            sims = compute_similarities(g, measure)
            t_idx = timeit(lambda: build_index(g, measure, sims=sims))
            t_full = timeit(lambda: build_index(g, measure))
            eps = g.m / t_full
            lines.append(emit(
                f"fig5/index_construction/{gname}/{measure}", t_full,
                f"edges_per_s={eps:.0f};sim_pass_s={t_sim:.3f};"
                f"order_pass_s={t_idx:.3f};m={g.m}"))
    return lines


def _skew_rows():
    """Bucketed vs dense-padded on skewed graphs (cosine; jaccard runs the
    same kernels with different epilogue math)."""
    lines = []
    for gname in SKEWED_GRAPHS:
        g = load_graph(gname)
        plan = plan_for(g)
        mem_bucket = plan.operand_bytes()
        mem_dense = densepad_operand_bytes(g)
        t_bucket = timeit(lambda: compute_similarities(g, "cosine"),
                          trials=2)
        t_dense = timeit(lambda: compute_similarities_densepad(g, "cosine"),
                         trials=2)
        sims = compute_similarities(g, "cosine")
        t_order = timeit(lambda: build_index(g, "cosine", sims=sims),
                         trials=2)
        t_build = timeit(lambda: build_index(g, "cosine"), trials=2)
        speedup_sim = t_dense / t_bucket
        speedup_build = (t_dense + t_order) / t_build
        max_deg = int(np.asarray(g.degrees()).max())
        lines.append(emit(
            f"fig5/skew_construction/{gname}/cosine", t_build,
            f"m={g.m};max_deg={max_deg};"
            f"sim_bucketed_s={t_bucket:.3f};sim_densepad_s={t_dense:.3f};"
            f"sim_speedup={speedup_sim:.2f}x;"
            f"construction_speedup={speedup_build:.2f}x;"
            f"mem_bucketed_bytes={mem_bucket};mem_densepad_bytes={mem_dense};"
            f"mem_ratio={mem_dense / mem_bucket:.1f}x"))
    return lines


# small on purpose: pallas-interpret runs the kernel body per grid step in
# python, so a 2k graph keeps the lane leg under a minute while still
# exercising multiple degree classes
LANE_GRAPH = ("lane-2k", dict(n=2048, avg_degree=16.0, weighted=False,
                              seed=9))
LANES = ("ref", "pallas-interpret")


def _lane_rows():
    gname, spec = LANE_GRAPH
    g = random_graph(**spec)
    lines = []
    prior = os.environ.get("REPRO_LANE")
    sims = {}
    try:
        for lane in LANES:
            os.environ["REPRO_LANE"] = lane
            t = timeit(lambda: compute_similarities(g, "cosine"), trials=2)
            sims[lane] = np.asarray(compute_similarities(g, "cosine"))
            identical = bool(np.array_equal(sims[lane], sims["ref"]))
            lines.append(emit(
                f"fig5/lane/{gname}/{lane}", t,
                f"m={g.m};edges_per_s={g.m / t:.0f};"
                f"bit_identical_vs_ref={int(identical)}"))
            if not identical:
                raise AssertionError(
                    f"lane {lane} diverged from ref on unweighted σ")
    finally:
        if prior is None:
            os.environ.pop("REPRO_LANE", None)
        else:
            os.environ["REPRO_LANE"] = prior
    return lines


def run():
    lines = _uniform_rows() + _skew_rows() + _lane_rows()
    write_snapshot(
        SNAPSHOT, "index_construction", lines,
        {"graphs": {**{k: dict(v) for k, v in GRAPHS.items()},
                    **{k: dict(v) for k, v in SKEWED_GRAPHS.items()}}})
    return lines
