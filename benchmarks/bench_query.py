"""Paper Figures 6 & 7 — clustering (query) time across (μ, ε).

Figure 6: μ=5, ε ∈ {.1 … .9}.  Figure 7: ε=0.6, μ ∈ {2,4,…,2^⌊log max_deg⌋}.
Also reports the direct (non-index) query cost — the ppSCAN-style
全-recompute baseline — so the index-vs-direct asymmetry the paper claims
is visible on this hardware too.
"""
from __future__ import annotations

import numpy as np

from repro.core import build_index, compute_similarities, query, query_batch
from benchmarks.common import GRAPHS, load_graph, timeit, emit


def run():
    lines = []
    for gname in ("sparse-8k", "planted-4k"):
        g = load_graph(gname)
        idx = build_index(g, "cosine")

        # fig 6: sweep ε at μ=5
        for eps in (0.1, 0.3, 0.5, 0.7, 0.9):
            t = timeit(lambda: query(idx, g, 5, eps))
            res = query(idx, g, 5, eps)
            lines.append(emit(
                f"fig6/query_eps/{gname}/eps={eps}", t,
                f"clusters={int(res.n_clusters)}"))

        # fig 7: sweep μ at ε=0.6
        max_deg = int(np.asarray(g.degrees()).max())
        mu = 2
        while mu <= max(max_deg, 2):
            t = timeit(lambda: query(idx, g, mu, 0.6))
            res = query(idx, g, mu, 0.6)
            lines.append(emit(
                f"fig7/query_mu/{gname}/mu={mu}", t,
                f"clusters={int(res.n_clusters)}"))
            mu *= 4

        # batched sweep: a 4×4 (μ, ε) grid answered as ONE vmapped call
        # (the serve-layer amortization; compare against per_query_s above)
        mus = np.asarray([m for m in (2, 3, 4, 5) for _ in range(4)],
                         dtype=np.int32)
        epss = np.asarray([0.2, 0.4, 0.6, 0.8] * 4, dtype=np.float32)
        t_grid = timeit(lambda: query_batch(idx, g, mus, epss))
        t_one = timeit(lambda: query(idx, g, 5, 0.6))
        lines.append(emit(
            f"fig6/query_batched_sweep/{gname}/settings={len(mus)}", t_grid,
            f"per_setting_s={t_grid / len(mus):.4f};"
            f"vs_sequential={t_one * len(mus) / t_grid:.1f}x"))

        # direct (index-free) baseline: similarities recomputed per query
        def direct():
            sims = compute_similarities(g, "cosine")
            idx2 = build_index(g, "cosine", sims=sims)
            return query(idx2, g, 5, 0.5)

        t_direct = timeit(direct, trials=2)
        t_index = timeit(lambda: query(idx, g, 5, 0.5))
        lines.append(emit(
            f"fig6/direct_vs_index/{gname}", t_direct,
            f"indexed_query_s={t_index:.4f};speedup={t_direct / t_index:.1f}x"))
    return lines
