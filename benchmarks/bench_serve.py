"""Serving-layer benchmark: batched-vs-sequential sweeps, sharded sweeps,
the micro-batching engine, and the multi-index router.

Sections per graph:
  * ``sweep_seq``    — G sequential ``query`` calls over a (μ, ε) grid;
  * ``sweep_batch``  — the same grid as ONE vmapped ``query_batch`` call
    (the amortization the serve layer is built on) + speedup;
  * ``sweep_shard``  — the same grid through ``query_batch_sharded`` on a
    mesh over every visible device (rows appear when >1 device is visible —
    run via ``python -m benchmarks.run serve --shards 8``);
  * ``engine``       — queries/sec through the async micro-batching engine
    with cold cache, and again fully cached.

Cross-graph sections:
  * ``router``       — mixed-fingerprint traffic for two indexes through
    ONE engine (per-index buckets + cache partitions);
  * ``router_walk``  — grid-walking traffic, where sweep-ahead warming
    turns neighbor requests into cache hits.

Seed-set (local-query) sections, on ``powerlaw-8k`` (the skewed
acceptance graph):
  * ``seed_direct``    — one ``query_seeds`` device batch answering B
    (seed, μ, ε) requests through the fixed-shape frontier kernel, vs
    ``seed_fullbatch`` — the same B (μ, ε) settings as full ``query_batch``
    clusterings (the pre-seed-path way to answer a seed request); the
    ``speedup`` column is the acceptance ratio (seeds/s vs q/s);
  * ``seed_engine_cold`` / ``seed_engine_cached`` — ``query_seed``
    traffic through the micro-batching engine (seed buckets + the
    seed-keyed cache), with ``engine.seed_e2e``-derived latency columns;
  * ``seed_live``      — seed traffic racing a live edit stream through
    ``LiveIndexService``: entries survive deltas via frontier migration
    (``migrated`` / ``dropped`` columns).

Replicated-fleet sections (``planted-4k``):
  * ``fleet``        — aggregate q/s + p99 through the
    writer-+ N-replica ``Fleet`` (consistent-hash router, hedged
    failover) for replicas=1/2/3 under a skewed client mix (two hot
    clients, one hot index name), 50/50 global/seed traffic;
  * ``fleet …/crash=1`` — the same wave with one replica chaos-crashed
    mid-traffic: q/s degrades instead of collapsing, and the ``errors``
    column counts the *typed* failures clients actually saw.

Engine/router rows carry p50/p90/p99 queue-wait and end-to-end latency
columns read from the engine's own ``repro.obs`` histograms
(``engine.queue_wait`` / ``engine.e2e``), with :func:`hist_delta`
isolating each traffic wave out of the cumulative counts (fleet rows
read the *merged* fleet snapshot, so their latency columns span every
replica). The full row set is committed at the repo root as
``BENCH_serve.json`` (the ``BENCH_update.json`` /
``BENCH_construction.json`` pattern).
"""
from __future__ import annotations

import asyncio
import pathlib
import time

import numpy as np

from repro.core import build_index, query, query_batch
from repro.obs import hist_delta, hist_quantile
from repro.serve import EngineConfig, MicroBatchEngine
from benchmarks.common import load_graph, timeit, emit, write_snapshot

GRID_MUS = (2, 3, 4, 5)
GRID_EPS = (0.2, 0.4, 0.6, 0.8)

SNAPSHOT = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"

_LAT_HISTS = (("engine.e2e", "e2e"), ("engine.queue_wait", "wait"))
_SEED_LAT_HISTS = (("engine.seed_e2e", "e2e"),
                   ("engine.seed_queue_wait", "wait"))


def _hists(engine) -> dict:
    """Current latency-histogram snapshots from the engine's registry."""
    return engine.registry.snapshot()["histograms"]


def _wave(now: dict, before: dict, hists=_LAT_HISTS) -> dict:
    """Latency histograms for one traffic wave: ``now - before``."""
    out = {}
    for key, _ in hists:
        if key in now:
            out[key] = (hist_delta(now[key], before[key])
                        if key in before else now[key])
    return out


def _lat_cols(wave: dict, hists=_LAT_HISTS) -> str:
    """Derived columns ``e2e_p50_ms=…;…;wait_p99_ms=…`` for one wave."""
    parts = []
    for key, label in hists:
        snap = wave.get(key)
        if not snap or not snap["count"]:
            continue
        for q, ql in ((0.5, "p50"), (0.9, "p90"), (0.99, "p99")):
            parts.append(
                f"{label}_{ql}_ms={hist_quantile(snap, q) * 1e3:.3f}")
    return ";".join(parts)


def run():
    import jax

    lines = []
    built = {}
    for gname in ("sparse-8k", "planted-4k"):
        g = load_graph(gname)
        idx = build_index(g, "cosine")
        built[gname] = (idx, g)
        mus = np.asarray([m for m in GRID_MUS for _ in GRID_EPS], np.int32)
        epss = np.asarray(list(GRID_EPS) * len(GRID_MUS), np.float32)
        n_set = len(mus)

        def seq():
            return [query(idx, g, int(m), float(e)) for m, e in zip(mus, epss)]

        def batched():
            return query_batch(idx, g, mus, epss)

        t_seq = timeit(seq, trials=2)
        t_batch = timeit(batched, trials=2)
        lines.append(emit(
            f"serve/sweep_seq/{gname}/settings={n_set}", t_seq,
            f"per_query_s={t_seq / n_set:.4f}"))
        lines.append(emit(
            f"serve/sweep_batch/{gname}/settings={n_set}", t_batch,
            f"per_query_s={t_batch / n_set:.4f};"
            f"speedup={t_seq / t_batch:.2f}x"))

        # ---- sharded sweep (giant-graph posture; needs a multi-device
        # host, e.g. benchmarks.run serve --shards 8) ----
        n_dev = jax.device_count()
        if n_dev > 1:
            from repro.core import ShardedQueryPlan, query_mesh
            # plan built once (pad + device_put), like the engine does —
            # the timed loop measures the steady-state sharded call only
            plan = ShardedQueryPlan(idx, g, query_mesh())

            def sharded():
                return plan(mus, epss)

            t_shard = timeit(sharded, trials=2)
            lines.append(emit(
                f"serve/sweep_shard/{gname}/shards={n_dev}", t_shard,
                f"per_query_s={t_shard / n_set:.4f};"
                f"vs_batch={t_batch / t_shard:.2f}x"))

        # ---- micro-batching engine under concurrent clients ----
        cfg = EngineConfig(max_batch=16, flush_ms=2.0)
        pool = [(int(m), float(e)) for m, e in zip(mus, epss)]

        async def traffic(n_clients: int, n_requests: int):
            engine = MicroBatchEngine(idx, g, config=cfg)
            async with engine:
                await engine.query(*pool[0])          # compile warmup
                base = _hists(engine)
                t0 = time.time()
                rng = np.random.default_rng(0)

                async def client():
                    for _ in range(n_requests):
                        await engine.query(*pool[rng.integers(len(pool))])
                        await asyncio.sleep(0)

                await asyncio.gather(*[client() for _ in range(n_clients)])
                dt = time.time() - t0
                after_cold = _hists(engine)
                # fully-cached second wave
                t1 = time.time()
                await asyncio.gather(*[client() for _ in range(n_clients)])
                dt_hot = time.time() - t1
                after_hot = _hists(engine)
            return (dt, dt_hot, engine.batch_stats(),
                    _wave(after_cold, base), _wave(after_hot, after_cold))

        n_clients, n_requests = 8, 16
        dt, dt_hot, st, cold_lat, hot_lat = asyncio.run(
            traffic(n_clients, n_requests))
        total = n_clients * n_requests
        lines.append(emit(
            f"serve/engine_cold/{gname}/clients={n_clients}", dt / total,
            f"qps={total / dt:.1f};device_calls={st['device_queries']};"
            f"avg_batch={st['avg_batch']:.1f};{_lat_cols(cold_lat)}"))
        lines.append(emit(
            f"serve/engine_cached/{gname}/clients={n_clients}", dt_hot / total,
            f"qps={total / dt_hot:.1f};hit_rate={st['cache_hit_rate']:.2f};"
            f"{_lat_cols(hot_lat)}"))

    # ---- multi-index router: both indexes behind one engine ----
    cfg = EngineConfig(max_batch=16, flush_ms=2.0)
    engine = MicroBatchEngine(config=cfg)
    fps = [engine.register(idx, g) for idx, g in built.values()]
    pool = [(int(m), float(e)) for m in GRID_MUS for e in GRID_EPS]

    async def router_traffic(n_clients: int, n_requests: int):
        async with engine:
            for fp in fps:                            # compile warmup
                await engine.query(*pool[0], fingerprint=fp)
            base = _hists(engine)
            rng = np.random.default_rng(1)
            t0 = time.time()

            async def client():
                for _ in range(n_requests):
                    fp = fps[rng.integers(len(fps))]
                    await engine.query(*pool[rng.integers(len(pool))],
                                       fingerprint=fp)
                    await asyncio.sleep(0)

            await asyncio.gather(*[client() for _ in range(n_clients)])
            return (time.time() - t0, engine.batch_stats(),
                    _wave(_hists(engine), base))

    n_clients, n_requests = 8, 16
    dt, st, rt_lat = asyncio.run(router_traffic(n_clients, n_requests))
    total = n_clients * n_requests
    lines.append(emit(
        f"serve/router/indexes={len(fps)}/clients={n_clients}", dt / total,
        f"qps={total / dt:.1f};device_calls={st['device_queries']};"
        f"buckets={st['batches']};warmed={st['warmed']};"
        f"partitions={st['cache_partitions']};{_lat_cols(rt_lat)}"))

    # ---- grid-walking clients: warming converts neighbors to hits ----
    walk_engine = MicroBatchEngine(config=EngineConfig(
        max_batch=16, flush_ms=2.0, warm_ahead=True, warm_eps_step=0.05))
    wfps = [walk_engine.register(idx, g) for idx, g in built.values()]

    async def walk_traffic(n_clients: int, n_steps: int):
        async with walk_engine:
            for fp in wfps:
                await walk_engine.query(3, 0.5, fingerprint=fp)
            base = _hists(walk_engine)
            rng = np.random.default_rng(2)
            t0 = time.time()

            async def client(i):
                fp = wfps[i % len(wfps)]
                mu, eps = 3, 0.5
                for _ in range(n_steps):
                    mu = max(2, mu + int(rng.integers(-1, 2)))
                    eps = float(np.clip(
                        eps + 0.05 * int(rng.integers(-1, 2)), 0.0, 1.0))
                    await walk_engine.query(mu, eps, fingerprint=fp)
                    await asyncio.sleep(0)

            await asyncio.gather(*[client(i) for i in range(n_clients)])
            return (time.time() - t0, walk_engine.batch_stats(),
                    _wave(_hists(walk_engine), base))

    dt, st, wk_lat = asyncio.run(walk_traffic(8, 16))
    total = 8 * 16
    lines.append(emit(
        f"serve/router_walk/indexes={len(wfps)}/clients=8", dt / total,
        f"qps={total / dt:.1f};hit_rate={st['cache_hit_rate']:.2f};"
        f"warmed={st['warmed']};device_calls={st['device_queries']};"
        f"{_lat_cols(wk_lat)}"))

    lines.extend(_seed_sections())
    lines.extend(_fleet_sections())
    write_snapshot(SNAPSHOT, "serve", lines)
    return lines


def _fleet_sections():
    """Replicated read fleet: q/s scaling vs replica count, with and
    without one chaos-crashed replica mid-wave."""
    import tempfile

    from repro.serve import (EngineConfig, Fleet, FleetExhausted,
                             Overloaded, RouterConfig)

    lines = []
    gname = "planted-4k"
    g = load_graph(gname)
    idx = build_index(g, "cosine")
    cfg = EngineConfig(max_batch=16, flush_ms=2.0, seed_batch=16)
    pool = [(int(m), float(e)) for m in GRID_MUS for e in GRID_EPS]
    names = ["g0", "g1", "g2"]
    # skewed mix: two hot clients carry half the load, and half of all
    # requests hit one hot index name (the consistent-hash owner of the
    # hot name becomes the pressured replica; hedging/spill is what lets
    # extra replicas absorb that skew)
    n_clients, n_requests = 8, 16
    weights = np.asarray([4.0, 4.0] + [1.0] * (n_clients - 2))
    reqs_per = np.maximum(np.round(
        weights / weights.sum() * n_clients * n_requests), 1).astype(int)
    name_share = (0.5, 0.3, 0.2)

    async def one_wave(n_replicas: int, crash: bool):
        fleet = Fleet(tempfile.mkdtemp(prefix="bench_fleet_"),
                      n_replicas=n_replicas, writer_config=cfg,
                      router_config=RouterConfig(timeout_s=10.0,
                                                 hedge_after_s=1.0),
                      poll_s=0.01)
        errors = 0
        done = 0
        async with fleet:
            for name in names:
                fleet.create(name, g, index=idx)
                await fleet.converged(name, timeout_s=30.0)
            for rep in fleet.replicas:       # compile warmup everywhere
                for name in names:
                    await rep.query(name, *pool[0])
                    await rep.query_seed(name, 0, *pool[0])
            base = fleet.metrics_snapshot()["histograms"]
            rng = np.random.default_rng(3)

            async def client(i):
                nonlocal errors, done
                for _ in range(int(reqs_per[i])):
                    name = names[int(rng.choice(len(names), p=name_share))]
                    mu, eps = pool[rng.integers(len(pool))]
                    try:
                        if rng.random() < 0.5:
                            await fleet.query_seed(
                                name, int(rng.integers(g.n)), mu, eps)
                        else:
                            await fleet.query(name, mu, eps)
                    except (Overloaded, FleetExhausted,
                            asyncio.TimeoutError):
                        errors += 1
                    done += 1
                    await asyncio.sleep(0)

            async def killer():
                if crash:
                    await asyncio.sleep(0.2)
                    await fleet.replicas[-1].crash()

            t0 = time.time()
            await asyncio.gather(
                killer(), *[client(i) for i in range(n_clients)])
            dt = time.time() - t0
            snap = fleet.metrics_snapshot()
            lat = _wave(snap["histograms"], base)
            c = snap["counters"]
        return dt, done, errors, c, lat

    for n_replicas in (1, 2, 3):
        for crash in (False, True):
            if crash and n_replicas == 1:
                continue  # crashing the only replica just measures zeros
            dt, done, errors, c, lat = asyncio.run(
                one_wave(n_replicas, crash))
            tag = "/crash=1" if crash else ""
            lines.append(emit(
                f"serve/fleet/{gname}/replicas={n_replicas}{tag}",
                dt / done,
                f"qps={done / dt:.1f};errors={errors};"
                f"failovers={c.get('fleet.failovers', 0)};"
                f"hedges={c.get('fleet.hedges', 0)};"
                f"hedge_wins={c.get('fleet.hedge_wins', 0)};"
                f"{_lat_cols(lat)}"))
    return lines


def _seed_sections():
    """Seed-set (local query) rows on the skewed acceptance graph."""
    from repro.core import query_seeds
    from repro.core.update import random_delta
    from repro.serve import LiveIndexService

    lines = []
    gname = "powerlaw-8k"
    g = load_graph(gname)
    idx = build_index(g, "cosine")

    # B seed requests at mixed (μ, ε) settings, drawn once and reused by
    # every section so direct / engine / full-batch rows are comparable
    rng = np.random.default_rng(5)
    n_seeds = 64
    pool = [(int(m), float(e)) for m in GRID_MUS for e in (0.4, 0.6, 0.8)]
    picks = rng.integers(len(pool), size=n_seeds)
    smus = np.asarray([pool[i][0] for i in picks], np.int32)
    sepss = np.asarray([pool[i][1] for i in picks], np.float32)
    seeds = rng.integers(g.n, size=n_seeds).astype(np.int32)

    # ---- direct kernel vs the full-clustering way to answer the same
    # requests: B (μ, ε) rows of query_batch, each clustering all of g ----
    def direct():
        return query_seeds(idx, g, seeds, smus, sepss)

    def fullb():
        return query_batch(idx, g, smus, sepss)

    t_seed = timeit(direct, trials=2)
    t_full = timeit(fullb, trials=2)
    spilled = int(direct().spilled.sum())
    lines.append(emit(
        f"serve/seed_direct/{gname}/batch={n_seeds}", t_seed,
        f"seeds_per_s={n_seeds / t_seed:.1f};spilled={spilled};"
        f"speedup_vs_full={t_full / t_seed:.2f}x"))
    lines.append(emit(
        f"serve/seed_fullbatch/{gname}/settings={n_seeds}", t_full,
        f"qps={n_seeds / t_full:.1f}"))

    # ---- query_seed through the engine: cold wave, then fully cached ----
    cfg = EngineConfig(max_batch=16, flush_ms=2.0, seed_batch=16)
    reqs = [(int(s), int(m), float(e))
            for s, m, e in zip(seeds, smus, sepss)]
    n_clients = 8
    per_client = len(reqs) // n_clients

    async def seed_traffic():
        engine = MicroBatchEngine(idx, g, config=cfg)
        async with engine:
            await engine.query_seed(*reqs[0])     # compile warmup
            base = _hists(engine)
            t0 = time.time()

            async def client(i):
                for s, m, e in reqs[i * per_client:(i + 1) * per_client]:
                    await engine.query_seed(s, m, e)
                    await asyncio.sleep(0)

            await asyncio.gather(*[client(i) for i in range(n_clients)])
            dt = time.time() - t0
            after_cold = _hists(engine)
            t1 = time.time()                      # same requests → cache
            await asyncio.gather(*[client(i) for i in range(n_clients)])
            dt_hot = time.time() - t1
            after_hot = _hists(engine)
        return (dt, dt_hot, engine.batch_stats(),
                _wave(after_cold, base, _SEED_LAT_HISTS),
                _wave(after_hot, after_cold, _SEED_LAT_HISTS))

    dt, dt_hot, st, cold_lat, hot_lat = asyncio.run(seed_traffic())
    total = n_clients * per_client
    lines.append(emit(
        f"serve/seed_engine_cold/{gname}/clients={n_clients}", dt / total,
        f"seed_qps={total / dt:.1f};"
        f"device_calls={st['seed_device_queries']};"
        f"buckets={st['seed_batches']};spills={st['seed_spills']};"
        f"{_lat_cols(cold_lat, _SEED_LAT_HISTS)}"))
    lines.append(emit(
        f"serve/seed_engine_cached/{gname}/clients={n_clients}",
        dt_hot / total,
        f"seed_qps={total / dt_hot:.1f};"
        f"cache_hits={st['seed_cache_hits']};"
        f"{_lat_cols(hot_lat, _SEED_LAT_HISTS)}"))

    # ---- seed traffic racing a live edit stream: cache entries ride
    # through each hot-swap via frontier migration ----
    import tempfile

    svc = LiveIndexService(tempfile.mkdtemp(prefix="bench_seed_live_"),
                           config=EngineConfig(max_batch=16, flush_ms=2.0,
                                               seed_batch=16),
                           measure="cosine")
    svc.create("live", g, index=idx)
    n_updates, update_batch, n_requests = 4, 8, 16

    async def live_seed_traffic():
        async with svc:
            await svc.query_seed("live", *reqs[0])
            drng = np.random.default_rng(7)
            t0 = time.time()

            async def editor():
                for _ in range(n_updates):
                    delta = random_delta(svc.graph("live"),
                                         update_batch, drng)
                    await svc.apply("live", delta)
                    await asyncio.sleep(0)

            async def client(i):
                crng = np.random.default_rng(100 + i)
                for _ in range(n_requests):
                    m, e = pool[crng.integers(len(pool))]
                    await svc.query_seed("live",
                                         int(crng.integers(g.n)), m, e)
                    await asyncio.sleep(0)

            await asyncio.gather(
                editor(), *[client(i) for i in range(n_clients)])
            return time.time() - t0

    dt = asyncio.run(live_seed_traffic())
    reg = svc.engine.registry
    total = n_clients * n_requests
    lines.append(emit(
        f"serve/seed_live/{gname}/updates={n_updates}"
        f"/clients={n_clients}", dt / total,
        f"seed_qps={total / dt:.1f};"
        f"migrated={reg.counter('live.seed_entries_migrated').value};"
        f"dropped={reg.counter('live.seed_entries_dropped').value};"
        f"rewarm_failures={reg.counter('live.rewarm_failures').value}"))
    return lines
