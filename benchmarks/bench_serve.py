"""Serving-layer benchmark: batched-vs-sequential sweeps and the
micro-batching engine under concurrent synthetic traffic.

Three sections per graph:
  * ``sweep_seq``    — G sequential ``query`` calls over a (μ, ε) grid;
  * ``sweep_batch``  — the same grid as ONE vmapped ``query_batch`` call
    (the amortization the serve layer is built on) + speedup;
  * ``engine``       — queries/sec through the async micro-batching engine
    with cold cache, and again fully cached.
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

from repro.core import build_index, query, query_batch
from repro.serve import EngineConfig, MicroBatchEngine
from benchmarks.common import load_graph, timeit, emit

GRID_MUS = (2, 3, 4, 5)
GRID_EPS = (0.2, 0.4, 0.6, 0.8)


def run():
    lines = []
    for gname in ("sparse-8k", "planted-4k"):
        g = load_graph(gname)
        idx = build_index(g, "cosine")
        mus = np.asarray([m for m in GRID_MUS for _ in GRID_EPS], np.int32)
        epss = np.asarray(list(GRID_EPS) * len(GRID_MUS), np.float32)
        n_set = len(mus)

        def seq():
            return [query(idx, g, int(m), float(e)) for m, e in zip(mus, epss)]

        def batched():
            return query_batch(idx, g, mus, epss)

        t_seq = timeit(seq, trials=2)
        t_batch = timeit(batched, trials=2)
        lines.append(emit(
            f"serve/sweep_seq/{gname}/settings={n_set}", t_seq,
            f"per_query_s={t_seq / n_set:.4f}"))
        lines.append(emit(
            f"serve/sweep_batch/{gname}/settings={n_set}", t_batch,
            f"per_query_s={t_batch / n_set:.4f};"
            f"speedup={t_seq / t_batch:.2f}x"))

        # ---- micro-batching engine under concurrent clients ----
        cfg = EngineConfig(max_batch=16, flush_ms=2.0)
        pool = [(int(m), float(e)) for m, e in zip(mus, epss)]

        async def traffic(n_clients: int, n_requests: int):
            engine = MicroBatchEngine(idx, g, config=cfg)
            async with engine:
                await engine.query(*pool[0])          # compile warmup
                t0 = time.time()
                rng = np.random.default_rng(0)

                async def client():
                    for _ in range(n_requests):
                        await engine.query(*pool[rng.integers(len(pool))])
                        await asyncio.sleep(0)

                await asyncio.gather(*[client() for _ in range(n_clients)])
                dt = time.time() - t0
                # fully-cached second wave
                t1 = time.time()
                await asyncio.gather(*[client() for _ in range(n_clients)])
                dt_hot = time.time() - t1
            return dt, dt_hot, engine.batch_stats()

        n_clients, n_requests = 8, 16
        dt, dt_hot, st = asyncio.run(traffic(n_clients, n_requests))
        total = n_clients * n_requests
        lines.append(emit(
            f"serve/engine_cold/{gname}/clients={n_clients}", dt / total,
            f"qps={total / dt:.1f};device_calls={st['device_queries']};"
            f"avg_batch={st['avg_batch']:.1f}"))
        lines.append(emit(
            f"serve/engine_cached/{gname}/clients={n_clients}", dt_hot / total,
            f"qps={total / dt_hot:.1f};hit_rate={st['cache_hit_rate']:.2f}"))
    return lines
