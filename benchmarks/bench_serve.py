"""Serving-layer benchmark: batched-vs-sequential sweeps, sharded sweeps,
the micro-batching engine, and the multi-index router.

Sections per graph:
  * ``sweep_seq``    — G sequential ``query`` calls over a (μ, ε) grid;
  * ``sweep_batch``  — the same grid as ONE vmapped ``query_batch`` call
    (the amortization the serve layer is built on) + speedup;
  * ``sweep_shard``  — the same grid through ``query_batch_sharded`` on a
    mesh over every visible device (rows appear when >1 device is visible —
    run via ``python -m benchmarks.run serve --shards 8``);
  * ``engine``       — queries/sec through the async micro-batching engine
    with cold cache, and again fully cached.

Cross-graph sections:
  * ``router``       — mixed-fingerprint traffic for two indexes through
    ONE engine (per-index buckets + cache partitions);
  * ``router_walk``  — grid-walking traffic, where sweep-ahead warming
    turns neighbor requests into cache hits.

Engine/router rows carry p50/p90/p99 queue-wait and end-to-end latency
columns read from the engine's own ``repro.obs`` histograms
(``engine.queue_wait`` / ``engine.e2e``), with :func:`hist_delta`
isolating each traffic wave out of the cumulative counts. The full row
set is committed at the repo root as ``BENCH_serve.json`` (the
``BENCH_update.json`` / ``BENCH_construction.json`` pattern).
"""
from __future__ import annotations

import asyncio
import pathlib
import time

import numpy as np

from repro.core import build_index, query, query_batch
from repro.obs import hist_delta, hist_quantile
from repro.serve import EngineConfig, MicroBatchEngine
from benchmarks.common import load_graph, timeit, emit, write_snapshot

GRID_MUS = (2, 3, 4, 5)
GRID_EPS = (0.2, 0.4, 0.6, 0.8)

SNAPSHOT = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"

_LAT_HISTS = (("engine.e2e", "e2e"), ("engine.queue_wait", "wait"))


def _hists(engine) -> dict:
    """Current latency-histogram snapshots from the engine's registry."""
    return engine.registry.snapshot()["histograms"]


def _wave(now: dict, before: dict) -> dict:
    """Latency histograms for one traffic wave: ``now - before``."""
    out = {}
    for key, _ in _LAT_HISTS:
        if key in now:
            out[key] = (hist_delta(now[key], before[key])
                        if key in before else now[key])
    return out


def _lat_cols(wave: dict) -> str:
    """Derived columns ``e2e_p50_ms=…;…;wait_p99_ms=…`` for one wave."""
    parts = []
    for key, label in _LAT_HISTS:
        snap = wave.get(key)
        if not snap or not snap["count"]:
            continue
        for q, ql in ((0.5, "p50"), (0.9, "p90"), (0.99, "p99")):
            parts.append(
                f"{label}_{ql}_ms={hist_quantile(snap, q) * 1e3:.3f}")
    return ";".join(parts)


def run():
    import jax

    lines = []
    built = {}
    for gname in ("sparse-8k", "planted-4k"):
        g = load_graph(gname)
        idx = build_index(g, "cosine")
        built[gname] = (idx, g)
        mus = np.asarray([m for m in GRID_MUS for _ in GRID_EPS], np.int32)
        epss = np.asarray(list(GRID_EPS) * len(GRID_MUS), np.float32)
        n_set = len(mus)

        def seq():
            return [query(idx, g, int(m), float(e)) for m, e in zip(mus, epss)]

        def batched():
            return query_batch(idx, g, mus, epss)

        t_seq = timeit(seq, trials=2)
        t_batch = timeit(batched, trials=2)
        lines.append(emit(
            f"serve/sweep_seq/{gname}/settings={n_set}", t_seq,
            f"per_query_s={t_seq / n_set:.4f}"))
        lines.append(emit(
            f"serve/sweep_batch/{gname}/settings={n_set}", t_batch,
            f"per_query_s={t_batch / n_set:.4f};"
            f"speedup={t_seq / t_batch:.2f}x"))

        # ---- sharded sweep (giant-graph posture; needs a multi-device
        # host, e.g. benchmarks.run serve --shards 8) ----
        n_dev = jax.device_count()
        if n_dev > 1:
            from repro.core import ShardedQueryPlan, query_mesh
            # plan built once (pad + device_put), like the engine does —
            # the timed loop measures the steady-state sharded call only
            plan = ShardedQueryPlan(idx, g, query_mesh())

            def sharded():
                return plan(mus, epss)

            t_shard = timeit(sharded, trials=2)
            lines.append(emit(
                f"serve/sweep_shard/{gname}/shards={n_dev}", t_shard,
                f"per_query_s={t_shard / n_set:.4f};"
                f"vs_batch={t_batch / t_shard:.2f}x"))

        # ---- micro-batching engine under concurrent clients ----
        cfg = EngineConfig(max_batch=16, flush_ms=2.0)
        pool = [(int(m), float(e)) for m, e in zip(mus, epss)]

        async def traffic(n_clients: int, n_requests: int):
            engine = MicroBatchEngine(idx, g, config=cfg)
            async with engine:
                await engine.query(*pool[0])          # compile warmup
                base = _hists(engine)
                t0 = time.time()
                rng = np.random.default_rng(0)

                async def client():
                    for _ in range(n_requests):
                        await engine.query(*pool[rng.integers(len(pool))])
                        await asyncio.sleep(0)

                await asyncio.gather(*[client() for _ in range(n_clients)])
                dt = time.time() - t0
                after_cold = _hists(engine)
                # fully-cached second wave
                t1 = time.time()
                await asyncio.gather(*[client() for _ in range(n_clients)])
                dt_hot = time.time() - t1
                after_hot = _hists(engine)
            return (dt, dt_hot, engine.batch_stats(),
                    _wave(after_cold, base), _wave(after_hot, after_cold))

        n_clients, n_requests = 8, 16
        dt, dt_hot, st, cold_lat, hot_lat = asyncio.run(
            traffic(n_clients, n_requests))
        total = n_clients * n_requests
        lines.append(emit(
            f"serve/engine_cold/{gname}/clients={n_clients}", dt / total,
            f"qps={total / dt:.1f};device_calls={st['device_queries']};"
            f"avg_batch={st['avg_batch']:.1f};{_lat_cols(cold_lat)}"))
        lines.append(emit(
            f"serve/engine_cached/{gname}/clients={n_clients}", dt_hot / total,
            f"qps={total / dt_hot:.1f};hit_rate={st['cache_hit_rate']:.2f};"
            f"{_lat_cols(hot_lat)}"))

    # ---- multi-index router: both indexes behind one engine ----
    cfg = EngineConfig(max_batch=16, flush_ms=2.0)
    engine = MicroBatchEngine(config=cfg)
    fps = [engine.register(idx, g) for idx, g in built.values()]
    pool = [(int(m), float(e)) for m in GRID_MUS for e in GRID_EPS]

    async def router_traffic(n_clients: int, n_requests: int):
        async with engine:
            for fp in fps:                            # compile warmup
                await engine.query(*pool[0], fingerprint=fp)
            base = _hists(engine)
            rng = np.random.default_rng(1)
            t0 = time.time()

            async def client():
                for _ in range(n_requests):
                    fp = fps[rng.integers(len(fps))]
                    await engine.query(*pool[rng.integers(len(pool))],
                                       fingerprint=fp)
                    await asyncio.sleep(0)

            await asyncio.gather(*[client() for _ in range(n_clients)])
            return (time.time() - t0, engine.batch_stats(),
                    _wave(_hists(engine), base))

    n_clients, n_requests = 8, 16
    dt, st, rt_lat = asyncio.run(router_traffic(n_clients, n_requests))
    total = n_clients * n_requests
    lines.append(emit(
        f"serve/router/indexes={len(fps)}/clients={n_clients}", dt / total,
        f"qps={total / dt:.1f};device_calls={st['device_queries']};"
        f"buckets={st['batches']};warmed={st['warmed']};"
        f"partitions={st['cache_partitions']};{_lat_cols(rt_lat)}"))

    # ---- grid-walking clients: warming converts neighbors to hits ----
    walk_engine = MicroBatchEngine(config=EngineConfig(
        max_batch=16, flush_ms=2.0, warm_ahead=True, warm_eps_step=0.05))
    wfps = [walk_engine.register(idx, g) for idx, g in built.values()]

    async def walk_traffic(n_clients: int, n_steps: int):
        async with walk_engine:
            for fp in wfps:
                await walk_engine.query(3, 0.5, fingerprint=fp)
            base = _hists(walk_engine)
            rng = np.random.default_rng(2)
            t0 = time.time()

            async def client(i):
                fp = wfps[i % len(wfps)]
                mu, eps = 3, 0.5
                for _ in range(n_steps):
                    mu = max(2, mu + int(rng.integers(-1, 2)))
                    eps = float(np.clip(
                        eps + 0.05 * int(rng.integers(-1, 2)), 0.0, 1.0))
                    await walk_engine.query(mu, eps, fingerprint=fp)
                    await asyncio.sleep(0)

            await asyncio.gather(*[client(i) for i in range(n_clients)])
            return (time.time() - t0, walk_engine.batch_stats(),
                    _wave(_hists(walk_engine), base))

    dt, st, wk_lat = asyncio.run(walk_traffic(8, 16))
    total = 8 * 16
    lines.append(emit(
        f"serve/router_walk/indexes={len(wfps)}/clients=8", dt / total,
        f"qps={total / dt:.1f};hit_rate={st['cache_hit_rate']:.2f};"
        f"warmed={st['warmed']};device_calls={st['device_queries']};"
        f"{_lat_cols(wk_lat)}"))
    write_snapshot(SNAPSHOT, "serve", lines)
    return lines
