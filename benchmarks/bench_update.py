"""Incremental-vs-rebuild crossover: maintenance cost by edit-batch size.

For each graph, an existing index absorbs one :class:`EdgeDelta` of K
edits (half inserts of fresh edges, half deletes of existing ones) two
ways:

  * ``incremental`` — ``apply_delta``: frontier-only σ recompute through
    the *incrementally maintained* ``SimilarityPlan`` (touched blocks
    patched, untouched blocks reused — no O(m) operand rebuild per
    batch), local NO re-sort + CO merge (the live-serve maintenance
    path);
  * ``rebuild``     — ``build_index`` from scratch on the post-edit graph
    (graph assembly excluded, i.e. the rebuild is measured generously).

The ``crossover`` row reports the batch size where rebuilding becomes
cheaper — the number a ``LiveIndexService`` operator uses to pick between
applying a burst as deltas or scheduling a rebuild/compaction. Rows also
carry the plan-maintenance counters (``plan_rows`` block tile rows
rewritten, ``plan_classes`` class blocks not reused) so the
work-proportionality claim is visible in the artifact.

Every run also snapshots its rows to ``BENCH_update.json`` at the repo
root (same pattern as ``BENCH_construction.json``) — the update-path perf
trajectory CI uploads per commit.
"""
from __future__ import annotations

import dataclasses
import pathlib

import numpy as np

from repro.core import build_index, random_graph
from repro.core.update import apply_delta, random_delta
from benchmarks.common import timeit, emit, write_snapshot

BATCH_SIZES = (4, 16, 64, 256, 1024, 4096)
UPDATE_GRAPHS = {
    "sparse-8k": dict(n=8192, avg_degree=16.0, weighted=False, seed=1),
    "dense-1k": dict(n=1024, avg_degree=96.0, weighted=True, seed=3),
}

SNAPSHOT = pathlib.Path(__file__).resolve().parents[1] / "BENCH_update.json"


def run():
    lines = []
    for gname, spec in UPDATE_GRAPHS.items():
        g = random_graph(**spec)
        idx = build_index(g, "cosine")
        rng = np.random.default_rng(0)
        crossover = None
        for k in BATCH_SIZES:
            delta = random_delta(g, k, rng)
            # post-edit graph assembled once; rebuild timing excludes it.
            # This warm call also seeds the maintained plan for g, so the
            # timed incremental runs measure the resident steady state.
            _, g2, info = apply_delta(idx, g, delta)

            t_inc = timeit(lambda: apply_delta(idx, g, delta)[0], trials=2)
            # the rebuild baseline must NOT inherit a cached SimilarityPlan
            # (apply_delta adopted one for g2, and a timed build would
            # cache one for its own graph) — rebuild a distinct graph
            # object per call so every trial pays the full operand build,
            # exactly like a real from-scratch rebuild would
            t_reb = timeit(
                lambda: build_index(dataclasses.replace(g2), "cosine"),
                trials=2)
            speedup = t_reb / t_inc
            if crossover is None and speedup < 1.0:
                crossover = k
            lines.append(emit(
                f"update/incremental/{gname}/batch={k}", t_inc,
                f"rebuild_s={t_reb:.4f};speedup={speedup:.2f}x;"
                f"frontier={info.n_frontier};touched={info.n_touched};"
                f"plan_rows={info.n_plan_rows};"
                f"plan_classes={info.n_plan_classes}"))
        lines.append(emit(
            f"update/crossover/{gname}/m={g.m}", 0.0,
            f"batch={crossover if crossover is not None else 'none'};"
            f"max_tested={BATCH_SIZES[-1]}"))
    write_snapshot(
        SNAPSHOT, "update", lines,
        {"graphs": {k: dict(v) for k, v in UPDATE_GRAPHS.items()},
         "batch_sizes": list(BATCH_SIZES)})
    return lines
