"""Incremental-vs-rebuild crossover: maintenance cost by edit-batch size.

For each graph, an existing index absorbs one :class:`EdgeDelta` of K
edits (half inserts of fresh edges, half deletes of existing ones) two
ways:

  * ``incremental`` — ``apply_delta``: frontier-only σ recompute + local
    NO re-sort + CO merge (the live-serve maintenance path);
  * ``rebuild``     — ``build_index`` from scratch on the post-edit graph
    (graph assembly excluded, i.e. the rebuild is measured generously).

The ``crossover`` row reports the batch size where rebuilding becomes
cheaper — the number a ``LiveIndexService`` operator uses to pick between
applying a burst as deltas or scheduling a rebuild/compaction.
"""
from __future__ import annotations

import numpy as np

from repro.core import build_index, random_graph
from repro.core.update import apply_delta, random_delta
from benchmarks.common import timeit, emit

BATCH_SIZES = (4, 16, 64, 256, 1024)
UPDATE_GRAPHS = {
    "sparse-8k": dict(n=8192, avg_degree=16.0, weighted=False, seed=1),
    "dense-1k": dict(n=1024, avg_degree=96.0, weighted=True, seed=3),
}


def run():
    lines = []
    for gname, spec in UPDATE_GRAPHS.items():
        g = random_graph(**spec)
        idx = build_index(g, "cosine")
        rng = np.random.default_rng(0)
        crossover = None
        for k in BATCH_SIZES:
            delta = random_delta(g, k, rng)
            # post-edit graph assembled once; rebuild timing excludes it
            _, g2, info = apply_delta(idx, g, delta)

            t_inc = timeit(lambda: apply_delta(idx, g, delta)[0], trials=2)
            t_reb = timeit(lambda: build_index(g2, "cosine"), trials=2)
            speedup = t_reb / t_inc
            if crossover is None and speedup < 1.0:
                crossover = k
            lines.append(emit(
                f"update/incremental/{gname}/batch={k}", t_inc,
                f"rebuild_s={t_reb:.4f};speedup={speedup:.2f}x;"
                f"frontier={info.n_frontier};touched={info.n_touched}"))
        lines.append(emit(
            f"update/crossover/{gname}/m={g.m}", 0.0,
            f"batch={crossover if crossover is not None else 'none'};"
            f"max_tested={BATCH_SIZES[-1]}"))
    return lines
