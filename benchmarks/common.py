"""Shared benchmark helpers: timing + the graph suite.

The paper's experiments (table 2) run on 10⁸–10⁹-edge graphs on 48 cores;
this container is one CPU core, so the suite is scaled to keep every
benchmark minutes-long while preserving the *structure* of each figure
(same axes, same derived quantities). Densities mirror the paper's mix:
sparse social-like graphs and dense weighted graphs (where LSH should win).
"""
from __future__ import annotations

import json
import time
from typing import Callable, Dict

import jax
import numpy as np

from repro.core import hub_ring_graph, power_law_graph, random_graph

GRAPHS: Dict[str, dict] = {
    # social-like, sparse (Orkut/Friendster stand-ins)
    "sparse-8k": dict(n=8192, avg_degree=16.0, weighted=False, seed=1),
    # clustered graph (ground-truth-ish structure)
    "planted-4k": dict(n=4096, avg_degree=24.0, weighted=False, seed=2,
                       planted_clusters=16),
    # dense weighted (blood-vessel/cochlea stand-ins — LSH territory)
    "dense-2k": dict(n=2048, avg_degree=192.0, weighted=True, seed=3),
}

# Skewed suite: real-world graphs are power-law, and construction speed on
# them is the paper's headline claim. These drive the bucketed-vs-dense
# comparison in bench_index_construction (a global-width padded layout pays
# O(n·Δ) for the hub; the bucketed engine pays O(m + n)).
SKEWED_GRAPHS: Dict[str, dict] = {
    # α≈2.1 power law with one forced deg-2048 hub (the acceptance case)
    "powerlaw-8k": dict(kind="power_law", n=8192, alpha=2.1, avg_degree=8.0,
                        seed=7, hub_degree=2048),
    # adversarial skew: ring of deg-2 vertices + one deg-1024 hub
    "hubring-4k": dict(kind="hub_ring", n=4096, hub_degree=1024, seed=8),
}


def load_graph(name: str):
    if name in GRAPHS:
        return random_graph(**GRAPHS[name])
    spec = dict(SKEWED_GRAPHS[name])
    kind = spec.pop("kind")
    if kind == "power_law":
        return power_law_graph(**spec)
    if kind == "hub_ring":
        return hub_ring_graph(**spec)
    raise KeyError(name)


def timeit(fn: Callable, *, trials: int = 3, warmup: int = 1) -> float:
    """Median wall seconds; blocks on jax outputs."""
    for _ in range(warmup):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(trials):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = "") -> str:
    line = f"{name},{seconds * 1e6:.1f},{derived}"
    print(line, flush=True)
    return line


def write_snapshot(path, bench: str, lines, extra_meta=None) -> None:
    """Write a bench section's rows as the repo-root JSON snapshot
    (``BENCH_construction.json`` / ``BENCH_update.json`` pattern): the
    perf trajectory committed per PR and uploaded by CI per run."""
    from benchmarks.run import _parse_line

    payload = {
        "meta": {"bench": bench, "unix_time": int(time.time()),
                 **(extra_meta or {})},
        "rows": [_parse_line(ln) for ln in lines],
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {len(lines)} rows to {path}", flush=True)
