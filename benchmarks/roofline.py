"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/<mesh>/*.json and prints a markdown table with the
three terms (compute / memory / collective, seconds), the dominant term,
MODEL_FLOPS, the useful-compute ratio, and the roofline fraction.
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(mesh_dir: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(mesh_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_ms(s):
    return f"{s * 1e3:.2f}" if s is not None else "-"


def table(recs, *, only_baseline=True):
    rows = []
    header = ("| arch | shape | status | compute ms | memory ms | coll ms | "
              "dominant | MODEL_GF/dev | useful | roofline frac |")
    sep = "|" + "---|" * 10
    rows.append(header)
    rows.append(sep)
    for r in recs:
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP ({r['reason'][:40]}…) "
                        "| - | - | - | - | - | - | - |")
            continue
        if r["status"] == "error":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | - | "
                        "- | - | - | - |")
            continue
        t = r["roofline"]["terms_s"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {fmt_ms(t['compute_s'])} | {fmt_ms(t['memory_s'])} "
            f"| {fmt_ms(t['collective_s'])} | {r['roofline']['dominant'].replace('_s','')} "
            f"| {r['roofline']['model_flops_per_device'] / 1e9:.1f} "
            f"| {r['roofline']['useful_ratio']:.3f} "
            f"| {r['roofline']['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def run(out_dir: str = "experiments/dryrun"):
    lines = []
    for mesh in ("pod16x16", "pod2x16x16"):
        d = os.path.join(out_dir, mesh)
        if not os.path.isdir(d):
            continue
        recs = [r for r in load(d)
                if "__" in os.path.basename(r.get("arch", "") or "x")
                or True]
        # keep only untagged baseline artifacts
        base = [r for r in recs if r.get("status")]
        print(f"\n### mesh {mesh} ({len(base)} cells)\n")
        print(table(base))
        ok = [r for r in base if r["status"] == "ok"]
        for r in ok:
            lines.append(
                f"roofline/{mesh}/{r['arch']}/{r['shape']},"
                f"{max(r['roofline']['terms_s'].values()) * 1e6:.1f},"
                f"dominant={r['roofline']['dominant']};"
                f"frac={r['roofline']['roofline_fraction']:.4f}")
    for l in lines:
        print(l)
    return lines


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
