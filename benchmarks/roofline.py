"""Roofline tables (EXPERIMENTS.md §Roofline).

Two sections:

* the dry-run table: reads experiments/dryrun/<mesh>/*.json and prints the
  three terms (compute / memory / collective, seconds), the dominant term,
  MODEL_FLOPS, the useful-compute ratio, and the roofline fraction;
* the similarity-pass table: achieved vs peak for the bucketed similarity
  engine on live suite graphs. Bytes and flops are modeled from the
  SimilarityPlan's group shapes — per half-edge the kernel gathers a
  pe-wide probe row and a te-wide target row (ids + weights, 8 bytes per
  element) and runs pe binary searches over te targets plus the σ
  multiply-accumulate epilogue. Peaks are nominal single-socket CPU
  numbers; override with REPRO_PEAK_GFLOPS / REPRO_PEAK_GBPS for your
  machine (or a device backend).
"""
from __future__ import annotations

import glob
import json
import os
import sys

import numpy as np


def load(mesh_dir: str):
    recs = []
    for path in sorted(glob.glob(os.path.join(mesh_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_ms(s):
    return f"{s * 1e3:.2f}" if s is not None else "-"


def table(recs, *, only_baseline=True):
    rows = []
    header = ("| arch | shape | status | compute ms | memory ms | coll ms | "
              "dominant | MODEL_GF/dev | useful | roofline frac |")
    sep = "|" + "---|" * 10
    rows.append(header)
    rows.append(sep)
    for r in recs:
        if r["status"] == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | SKIP ({r['reason'][:40]}…) "
                        "| - | - | - | - | - | - | - |")
            continue
        if r["status"] == "error":
            rows.append(f"| {r['arch']} | {r['shape']} | ERROR | - | - | - | "
                        "- | - | - | - |")
            continue
        t = r["roofline"]["terms_s"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {fmt_ms(t['compute_s'])} | {fmt_ms(t['memory_s'])} "
            f"| {fmt_ms(t['collective_s'])} | {r['roofline']['dominant'].replace('_s','')} "
            f"| {r['roofline']['model_flops_per_device'] / 1e9:.1f} "
            f"| {r['roofline']['useful_ratio']:.3f} "
            f"| {r['roofline']['roofline_fraction']:.4f} |")
    return "\n".join(rows)


# nominal single-socket CPU peaks; env-overridable so the fraction column
# is meaningful on whatever actually runs the bench
PEAK_GFLOPS = float(os.environ.get("REPRO_PEAK_GFLOPS", 50.0))
PEAK_GBPS = float(os.environ.get("REPRO_PEAK_GBPS", 20.0))

SIM_GRAPHS = ("sparse-8k", "powerlaw-8k")


def sim_pass_model(plan, eu, ev):
    """(bytes, flops) one bucketed similarity pass moves/executes, modeled
    from the plan's per-edge group shapes: pe = probe tiles^ × probe class
    width, te likewise for the target side."""
    from repro.backend.padding import np_pow2ceil

    pu, pv, _ = plan.route(np.asarray(eu, np.int64), np.asarray(ev, np.int64))
    widths = np.asarray(plan.widths, np.int64)
    pe = np_pow2ceil(plan.vtiles[pu]).astype(np.int64) * \
        widths[plan.vclass[pu]]
    te = np_pow2ceil(plan.vtiles[pv]).astype(np.int64) * \
        widths[plan.vclass[pv]]
    # ids (int32) + weights (f32) for both rows
    model_bytes = int(8 * (pe + te).sum())
    # pe binary searches of depth log2(te) + the 2·pe dot-product MACs
    compares = (pe * np.ceil(np.log2(np.maximum(te, 2)))).sum()
    flops = int(compares + 2 * pe.sum())
    return model_bytes, flops


def similarity_section():
    from benchmarks.common import load_graph, timeit
    from repro.core import compute_similarities
    from repro.core.similarity import plan_for
    from repro.backend.policy import default_policy

    lines = []
    pol = default_policy()
    print(f"\n### similarity pass (platform {pol.platform()}, "
          f"peaks {PEAK_GFLOPS:.0f} GFLOP/s / {PEAK_GBPS:.0f} GB/s)\n")
    print("| graph | lane | m | GB | GFLOP | ms | GB/s | GFLOP/s "
          "| AI F/B | dominant | frac |")
    print("|" + "---|" * 11)
    for gname in SIM_GRAPHS:
        g = load_graph(gname)
        plan = plan_for(g)
        model_bytes, flops = sim_pass_model(
            plan, np.asarray(g.edge_u), np.asarray(g.nbrs))
        t = timeit(lambda: compute_similarities(g, "cosine"), trials=2)
        widest = int(np.asarray(plan.widths, np.int64).max())
        lane = pol.lane("bucket_probe", width=widest)
        gbps = model_bytes / t / 1e9
        gflops = flops / t / 1e9
        t_mem = model_bytes / (PEAK_GBPS * 1e9)
        t_cmp = flops / (PEAK_GFLOPS * 1e9)
        dominant = "memory" if t_mem >= t_cmp else "compute"
        frac = max(t_mem, t_cmp) / t
        print(f"| {gname} | {lane} | {g.m} | {model_bytes / 1e9:.3f} "
              f"| {flops / 1e9:.3f} | {t * 1e3:.1f} | {gbps:.2f} "
              f"| {gflops:.2f} | {flops / model_bytes:.2f} | {dominant} "
              f"| {frac:.4f} |")
        lines.append(
            f"roofline/simpass/{gname},{t * 1e6:.1f},"
            f"lane={lane};m={g.m};model_gb={model_bytes / 1e9:.3f};"
            f"model_gflop={flops / 1e9:.3f};achieved_gbps={gbps:.2f};"
            f"achieved_gflops={gflops:.2f};dominant={dominant};"
            f"roofline_frac={frac:.4f}")
    return lines


def run(out_dir: str = "experiments/dryrun"):
    lines = similarity_section()
    for mesh in ("pod16x16", "pod2x16x16"):
        d = os.path.join(out_dir, mesh)
        if not os.path.isdir(d):
            continue
        recs = [r for r in load(d)
                if "__" in os.path.basename(r.get("arch", "") or "x")
                or True]
        # keep only untagged baseline artifacts
        base = [r for r in recs if r.get("status")]
        print(f"\n### mesh {mesh} ({len(base)} cells)\n")
        print(table(base))
        ok = [r for r in base if r["status"] == "ok"]
        for r in ok:
            lines.append(
                f"roofline/{mesh}/{r['arch']}/{r['shape']},"
                f"{max(r['roofline']['terms_s'].values()) * 1e6:.1f},"
                f"dominant={r['roofline']['dominant']};"
                f"frac={r['roofline']['roofline_fraction']:.4f}")
    for l in lines:
        print(l)
    return lines


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun")
