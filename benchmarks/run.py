"""Benchmark entry point — one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [section ...] \
           [--json out.json] [--shards K]
Sections: fig5 fig6 approx serve update roofline (default: all; ``approx``
subsumes the old fig8/fig9 aliases and commits ``BENCH_approx.json``).
Output: ``name,us_per_call,derived`` CSV lines on stdout; ``--json`` also
writes the same rows as structured JSON (the artifact CI uploads per run,
so regressions are diffable across commits). ``--shards K`` forces K host
platform devices *before* jax initializes, so the sharded-query rows in
the ``serve`` section run on a real K-way mesh:

    XLA-free shorthand for
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.run serve      ==      --shards 8
"""
from __future__ import annotations

import argparse
import json
import sys

SECTIONS = ("fig5", "fig6", "approx", "serve", "update", "roofline")
# the approx section subsumes the paper's fig8 (construction) and fig9/10
# (quality) and commits the combined BENCH_approx.json snapshot
ALIASES = {"fig7": "fig6", "fig8": "approx", "fig9": "approx",
           "fig10": "approx"}


def parse_args(argv):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("sections", nargs="*", default=list(SECTIONS),
                    help=f"subset of {SECTIONS} (default: all)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write rows as JSON (CI artifact)")
    ap.add_argument("--shards", type=int, default=0,
                    help="force K host devices for the sharded serve rows")
    return ap.parse_args(argv)


def _parse_line(line: str) -> dict:
    name, us, derived = line.split(",", 2)
    row = {"name": name, "us_per_call": float(us)}
    for kv in filter(None, derived.split(";")):
        if "=" in kv:
            k, v = kv.split("=", 1)
            num = v[:-1] if v.endswith("x") else v   # speedup=3.42x → 3.42
            try:
                row[k] = (float(num) if "." in num or "e" in num.lower()
                          else int(num))
            except ValueError:
                row[k] = v
    return row


def main() -> None:
    args = parse_args(sys.argv[1:])
    sections = [ALIASES.get(s, s) for s in args.sections]
    unknown = set(sections) - set(SECTIONS)
    if unknown:
        raise SystemExit(f"unknown sections: {sorted(unknown)}")
    if args.shards > 1:
        if set(sections) != {"serve"}:
            # splitting the host into K emulated devices throttles every
            # section's intra-op threading; numbers from other sections
            # would be silently non-comparable with unsharded runs
            raise SystemExit(
                "--shards only applies to the serve section; run "
                "`benchmarks.run serve --shards K` (other sections would "
                "be silently measured on K-way-split host compute)")
        # must land before jax's backend initializes (first device query)
        from repro.core.distributed import force_host_devices
        force_host_devices(args.shards)

    print("name,us_per_call,derived")
    lines: list[str] = []
    if "fig5" in sections:
        from benchmarks import bench_index_construction
        lines += bench_index_construction.run()
    if "fig6" in sections:
        from benchmarks import bench_query
        lines += bench_query.run()
    if "approx" in sections:
        from benchmarks import bench_approx
        lines += bench_approx.run()
    if "serve" in sections:
        from benchmarks import bench_serve
        lines += bench_serve.run()
    if "update" in sections:
        from benchmarks import bench_update
        lines += bench_update.run()
    if "roofline" in sections:
        from benchmarks import roofline
        lines += roofline.run()

    if args.json:
        rows = [_parse_line(ln) for ln in lines]
        meta = {"sections": sections, "shards": args.shards}
        with open(args.json, "w") as f:
            json.dump({"meta": meta, "rows": rows}, f, indent=2)
        print(f"# wrote {len(rows)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
