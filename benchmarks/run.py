"""Benchmark entry point — one section per paper table/figure.

Usage: PYTHONPATH=src python -m benchmarks.run [section ...]
Sections: fig5 fig6 fig8 fig9 serve roofline (default: all).
Output: ``name,us_per_call,derived`` CSV lines.
"""
from __future__ import annotations

import sys


def main() -> None:
    sections = sys.argv[1:] or ["fig5", "fig6", "fig8", "fig9", "serve",
                                "roofline"]
    print("name,us_per_call,derived")
    if "fig5" in sections:
        from benchmarks import bench_index_construction
        bench_index_construction.run()
    if "fig6" in sections or "fig7" in sections:
        from benchmarks import bench_query
        bench_query.run()
    if "fig8" in sections:
        from benchmarks import bench_approx_construction
        bench_approx_construction.run()
    if "fig9" in sections or "fig10" in sections:
        from benchmarks import bench_approx_quality
        bench_approx_quality.run()
    if "serve" in sections:
        from benchmarks import bench_serve
        bench_serve.run()
    if "roofline" in sections:
        from benchmarks import roofline
        roofline.run()


if __name__ == "__main__":
    main()
