"""Root pytest config: per-test timeout guard.

CI installs ``pytest-timeout`` (requirements-dev.txt), which honors the
``timeout`` value in pytest.ini so a hung XLA compile fails that test fast
instead of eating the whole job. Containers without the plugin fall back to
the SIGALRM shim below — same ini value, best-effort delivery (the alarm
fires on the next Python bytecode boundary, which is good enough to kill a
hung host-side loop or a subprocess wait, the common hang modes here).
"""
from __future__ import annotations

import signal

import pytest


def pytest_addoption(parser):
    # pytest-timeout owns the "timeout" ini key when present; only register
    # the fallback definition if nobody else has, so pytest doesn't warn
    # about an unknown option in plugin-less containers.
    if "timeout" not in getattr(parser, "_inidict", {}):
        parser.addini(
            "timeout",
            "per-test timeout in seconds (SIGALRM shim when pytest-timeout "
            "is not installed)",
            default="0")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    config = item.config
    if (config.pluginmanager.hasplugin("timeout")
            or not hasattr(signal, "SIGALRM")):
        yield
        return
    try:
        limit = float(config.getini("timeout") or 0)
    except (TypeError, ValueError):
        limit = 0.0
    if limit <= 0:
        yield
        return

    def on_alarm(signum, frame):
        raise TimeoutError(
            f"{item.nodeid} exceeded {limit:.0f}s (conftest SIGALRM shim)")

    old = signal.signal(signal.SIGALRM, on_alarm)
    signal.setitimer(signal.ITIMER_REAL, limit)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0)
        signal.signal(signal.SIGALRM, old)
