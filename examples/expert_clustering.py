"""SCAN ↔ MoE bridge: cluster the expert co-activation graph of a trained
MoE router with the paper's index, and SCAN-dedup the training corpus.

1. Train a small MoE for a few steps; collect routing statistics.
2. Build the expert co-activation graph (edge weight = how often two
   experts fire on the same token) and SCAN-cluster it — clusters are
   candidate expert placement groups for EP sharding (co-activated experts
   on nearby chips), hubs are generalist experts.
3. Build a document-similarity graph over a data batch and SCAN it for
   near-duplicate detection (data curation pass).

    PYTHONPATH=src python examples/expert_clustering.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.core import build_index, from_edge_list, hubs_outliers, query
from repro.data.pipeline import SyntheticLM, doc_similarity_graph
from repro.models import model as mdl
from repro.models import layers as L
from repro.optim import adamw
from repro.train.train_step import make_train_step


def main():
    cfg = get_config("deepseek-v2-lite-16b").scaled(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        n_experts=16, top_k=4, d_ff=32, d_ff_dense=96, first_dense_layers=1,
        kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
        vocab=512, dtype="float32", capacity_factor=4.0, q_chunk=32)
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=64, global_batch=8, accum=1)
    hp = adamw.AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=60)
    opt = adamw.init(params)
    step = jax.jit(make_train_step(cfg, hp, accum=1))
    for i in range(30):
        batch = jax.tree.map(lambda x: jnp.asarray(x)[None], data.batch(i))
        params, opt, metrics = step(params, opt, batch)
    print(f"trained 30 steps, ce={float(metrics['ce']):.3f}")

    # ---- routing statistics → expert co-activation graph ----
    batch = jax.tree.map(jnp.asarray, data.batch(99))
    x = params["emb"][batch["tokens"]]
    moe_p = params["layers"][1]["ffn"]          # layer 1 is the MoE layer
    xin = L.rmsnorm(x, params["layers"][1]["ln2"], cfg.norm_eps)
    logits = xin.reshape(-1, cfg.d_model) @ moe_p["router"]
    _, top_i = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    top_i = np.asarray(top_i)                   # [T, k]
    e = cfg.n_experts
    co = np.zeros((e, e))
    for row in top_i:
        for a in row:
            for b in row:
                if a != b:
                    co[a, b] += 1
    iu, iv = np.nonzero(np.triu(co, 1))
    w = co[iu, iv] / co.max()
    g = from_edge_list(e, np.stack([iu, iv], 1), w.astype(np.float32))
    print(f"co-activation graph: {e} experts, {g.m} edges")

    idx = build_index(g, "cosine")
    res = query(idx, g, mu=2, eps=0.3)
    labels = np.asarray(res.labels)
    hubs, _ = hubs_outliers(g, res.labels)
    groups = {}
    for ex, lab in enumerate(labels):
        groups.setdefault(int(lab), []).append(ex)
    print("expert placement groups (SCAN clusters):")
    for lab, members in sorted(groups.items()):
        kind = "unclustered" if lab == -1 else f"group {lab}"
        print(f"  {kind}: experts {members}")
    print("generalist (hub) experts:", np.nonzero(np.asarray(hubs))[0].tolist())

    # ---- SCAN dedup over the data batch ----
    docs = np.asarray(batch["tokens"])
    docs = np.concatenate([docs, docs[:2]])     # inject 2 duplicates
    dg = doc_similarity_graph(docs, shingle=3, min_shared=2)
    didx = build_index(dg, "jaccard")
    dres = query(didx, dg, mu=2, eps=0.5)
    dl = np.asarray(dres.labels)
    print("\ndedup pass: doc cluster labels:", dl.tolist())
    dup_pairs = [(i, j) for i in range(len(dl)) for j in range(i + 1, len(dl))
                 if dl[i] >= 0 and dl[i] == dl[j]]
    print("near-duplicate pairs:", dup_pairs)
    assert (len(docs) - 2, 0) in dup_pairs or (0, len(docs) - 2) in dup_pairs


if __name__ == "__main__":
    main()
