"""Quickstart: build a SCAN index, query clusterings, approximate with LSH.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (
    build_index, compute_similarities, from_edge_list, hubs_outliers,
    modularity, query, random_graph, adjusted_rand_index,
)


def main():
    # --- the paper's Figure-1 graph (1-indexed in the paper) ---
    edges = [(1, 2), (1, 3), (1, 4), (2, 3), (2, 4), (3, 4), (4, 5), (5, 6),
             (6, 7), (6, 8), (7, 8), (7, 11), (8, 11), (7, 9), (8, 10)]
    g = from_edge_list(11, [(u - 1, v - 1) for u, v in edges])
    index = build_index(g, measure="cosine")
    res = query(index, g, mu=3, eps=0.6)
    hubs, outliers = hubs_outliers(g, res.labels)
    print("figure-1 labels :", np.asarray(res.labels))
    print("cores           :", np.nonzero(np.asarray(res.is_core))[0] + 1)
    print("hubs            :", np.nonzero(np.asarray(hubs))[0] + 1)

    # --- a planted-partition graph: sweep parameters from ONE index ---
    g = random_graph(2000, 24.0, seed=7, planted_clusters=8)
    index = build_index(g, measure="cosine")
    print("\n(μ, ε) sweep from one index:")
    best = (-1.0, None)
    for mu in (2, 4, 8):
        for eps in (0.2, 0.4, 0.6):
            res = query(index, g, mu, eps)
            q = modularity(g, np.asarray(res.labels))
            best = max(best, (q, (mu, eps)))
            print(f"  mu={mu} eps={eps:.1f}: clusters={int(res.n_clusters):4d} "
                  f"modularity={q:.3f}")
    print("best:", best[1], f"modularity={best[0]:.3f}")

    # --- LSH-approximate index (paper §5) ---
    idx_apx = build_index(g, measure="cosine", approx="simhash", samples=256,
                          key=jax.random.PRNGKey(0))
    mu, eps = best[1]
    exact_labels = np.asarray(query(index, g, mu, eps).labels)
    approx_labels = np.asarray(query(idx_apx, g, mu, eps).labels)
    print("\nLSH(simhash,k=256) vs exact at best params: "
          f"ARI={adjusted_rand_index(exact_labels, approx_labels):.3f}")


if __name__ == "__main__":
    main()
