"""SCAN-as-a-service: persist an index, reload it, sweep parameters in one
vmapped call, serve concurrent clients through the micro-batch engine —
then the giant-graph/production postures: the same sweep *sharded* over an
8-way device mesh, and two indexes routed through one engine with
per-index cache partitions and sweep-ahead warming.

    PYTHONPATH=src python examples/scan_service.py
"""
# the sharded-serve section below wants multiple devices; force 8 host
# devices BEFORE jax's backend initializes (importing is fine, device use
# is not; harmless when real accelerators exist). Host compute is split
# 8 ways for the WHOLE demo, so the timings printed below illustrate the
# flow, not single-device performance — benchmarks/bench_serve.py is the
# measured story.
from repro.core.distributed import force_host_devices

force_host_devices(8)

import asyncio
import tempfile
import time

import numpy as np

from repro.core import build_index, query, query_batch, random_graph
from repro.serve import (EngineConfig, IndexStore, MicroBatchEngine,
                         sweep_stats)


def main():
    # --- build once, persist (the GS*-Index amortization story) ---
    g = random_graph(4000, 24.0, seed=7, planted_clusters=8)
    t0 = time.time()
    index = build_index(g, measure="cosine")
    print(f"index built in {time.time() - t0:.2f}s (n={g.n}, m={g.m})")

    with tempfile.TemporaryDirectory() as d:
        store = IndexStore(d)
        store.save(index, g)
        index, g, fp = store.load()     # a fresh process would start here
        print(f"reloaded version {store.latest_version()}, "
              f"fingerprint {fp[:12]}…")

        # --- explore settings: one compiled call for the whole grid ---
        rows = sweep_stats(index, g, [2, 4, 8], [0.2, 0.4, 0.6])
        best = max(rows, key=lambda r: r["modularity"])
        for r in rows:
            print(f"  mu={r['mu']} eps={r['eps']:.1f}: "
                  f"clusters={r['n_clusters']:4d} "
                  f"modularity={r['modularity']:.3f}")
        print(f"best: mu={best['mu']} eps={best['eps']:.1f}")

        # --- concurrent single queries, coalesced on the device ---
        engine = MicroBatchEngine(index, g, fingerprint=fp,
                                  config=EngineConfig(max_batch=8))

        async def client(mu, eps):
            res = await engine.query(mu, eps)
            return int(res.n_clusters)

        async def serve():
            async with engine:
                reqs = [(mu, eps) for mu in (2, 4, 8)
                        for eps in (0.2, 0.3, 0.4, 0.5, 0.6)]
                counts = await asyncio.gather(
                    *[client(mu, eps) for mu, eps in reqs])
                return counts

        counts = asyncio.run(serve())
        st = engine.batch_stats()
        print(f"{st['requests']} concurrent queries → "
              f"{st['device_queries']} device calls "
              f"(avg batch {st['avg_batch']:.1f}); "
              f"cluster counts {sorted(set(counts))}")

        # engine answers match direct queries
        r = query(index, g, best["mu"], best["eps"])
        assert int(r.n_clusters) == best["n_clusters"]
        print("consistency with direct query: OK")

    # ------------------------------------------------------------------
    # sharded serve: the giant-graph posture
    # ------------------------------------------------------------------
    # When one device can't hold the O(m) edge arrays, the same queries run
    # with the half-edge and CO-slot arrays partitioned over the mesh
    # 'data' axis; connectivity finishes with all-reduced label
    # propagation. Results are bit-identical to the single-device path.
    import jax
    from repro.core import query_batch_sharded, query_mesh

    k = min(8, jax.device_count())
    mesh = query_mesh(k)
    mus = np.asarray([2, 4, 8], np.int32)
    epss = np.asarray([0.3, 0.5, 0.7], np.float32)
    ref = query_batch(index, g, mus, epss)
    out = query_batch_sharded(index, g, mus, epss, mesh=mesh)
    exact = all(
        np.array_equal(np.asarray(getattr(out, f)),
                       np.asarray(getattr(ref, f)))
        for f in ("labels", "is_core", "n_clusters"))
    print(f"sharded sweep over {k} devices: bit-exact match = {exact}")
    assert exact

    # ------------------------------------------------------------------
    # multi-index routing: one engine, many graphs
    # ------------------------------------------------------------------
    # Requests carry an index fingerprint; the collector buckets by
    # fingerprint and flushes each bucket as its own fixed-shape device
    # call. Each index gets its own LRU cache partition, and padding slots
    # pre-warm the (μ±1, ε±δ) neighborhood of observed traffic.
    router = MicroBatchEngine(config=EngineConfig(max_batch=8, flush_ms=2.0,
                                                  warm_ahead=True))
    fps = []
    for seed in (7, 8):
        gk = random_graph(1500, 16.0, seed=seed, planted_clusters=6)
        fps.append(router.register(build_index(gk, "cosine"), gk))

    async def routed():
        async with router:
            reqs = [(fps[i % 2], 3, 0.3 + 0.05 * (i % 5))
                    for i in range(24)]
            outs = await asyncio.gather(
                *[router.query(mu, eps, fingerprint=fpk)
                  for fpk, mu, eps in reqs])
            return outs

    asyncio.run(routed())
    st = router.batch_stats()
    print(f"routed {st['requests']} requests across {st['indexes']} indexes"
          f" → {st['device_queries']} device calls, "
          f"{st['cache_hits']} cache hits, {st['warmed']} warmed, "
          f"{st['cache_partitions']} cache partitions")

    # ------------------------------------------------------------------
    # live updates: the resident update+query process
    # ------------------------------------------------------------------
    # Real graphs change. LiveIndexService applies edge-edit batches to a
    # resident index *incrementally* (σ recomputed only on the frontier of
    # touched endpoints — bit-identical to a full rebuild), hot-swaps the
    # result into the router atomically (in-flight queries finish on the
    # old index), persists every delta as a crash-safe chain, and compacts
    # the chain into a full snapshot periodically.
    from repro.core import build_index as rebuild_index
    from repro.core.update import EdgeDelta
    from repro.serve import LiveIndexService

    with tempfile.TemporaryDirectory() as d:
        svc = LiveIndexService(d, config=EngineConfig(max_batch=8),
                               compact_every=2)
        gl = random_graph(1200, 12.0, seed=11, planted_clusters=5)
        svc.create("social", gl)

        async def live_demo():
            async with svc:
                before = await svc.query("social", 3, 0.4)
                info = await svc.apply("social", EdgeDelta.make(
                    inserts=[(0, 600), (1, 700), (2, 800)],
                    weights=[0.9, 0.8, 0.7],
                    deletes=[(int(gl.edge_u[0]), int(gl.nbrs[0]))]))
                after = await svc.query("social", 3, 0.4)
                # second batch crosses compact_every=2 → full snapshot
                await svc.apply("social", EdgeDelta.make(
                    inserts=[(5, 900)], weights=[0.5]))
                return before, after, info

        before, after, info = asyncio.run(live_demo())
        status = svc.status("social")
        print(f"live update: {info.n_inserted} ins + {info.n_deleted} del "
              f"→ σ recomputed for {info.n_frontier}/"
              f"{2 * status['m']} half-edges (clusters "
              f"{int(before.n_clusters)} → {int(after.n_clusters)})")

        # the maintained index is bit-identical to a from-scratch rebuild
        rebuilt = rebuild_index(svc.graph("social"), "cosine")
        assert np.array_equal(np.asarray(rebuilt.no_sims),
                              np.asarray(svc.index("social").no_sims))
        print("incremental == rebuild (bit-identical): OK")

        # compaction snapshotted at the live fingerprint; a fresh process
        # restores straight from it
        assert (svc.catalog.store("social").latest_version()
                == status["seq"])
        svc2 = LiveIndexService(d)
        assert svc2.load("social") == status["fingerprint"]
        print(f"restored v{status['seq']} after compaction, fingerprint "
              f"{status['fingerprint'][:12]}… matches: OK")


if __name__ == "__main__":
    main()
