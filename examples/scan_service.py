"""SCAN-as-a-service: persist an index, reload it, sweep parameters in one
vmapped call, and serve concurrent clients through the micro-batch engine.

    PYTHONPATH=src python examples/scan_service.py
"""
import asyncio
import tempfile
import time

import numpy as np

from repro.core import build_index, query, random_graph
from repro.serve import (EngineConfig, IndexStore, MicroBatchEngine,
                         sweep_stats)


def main():
    # --- build once, persist (the GS*-Index amortization story) ---
    g = random_graph(4000, 24.0, seed=7, planted_clusters=8)
    t0 = time.time()
    index = build_index(g, measure="cosine")
    print(f"index built in {time.time() - t0:.2f}s (n={g.n}, m={g.m})")

    with tempfile.TemporaryDirectory() as d:
        store = IndexStore(d)
        store.save(index, g)
        index, g, fp = store.load()     # a fresh process would start here
        print(f"reloaded version {store.latest_version()}, "
              f"fingerprint {fp[:12]}…")

        # --- explore settings: one compiled call for the whole grid ---
        rows = sweep_stats(index, g, [2, 4, 8], [0.2, 0.4, 0.6])
        best = max(rows, key=lambda r: r["modularity"])
        for r in rows:
            print(f"  mu={r['mu']} eps={r['eps']:.1f}: "
                  f"clusters={r['n_clusters']:4d} "
                  f"modularity={r['modularity']:.3f}")
        print(f"best: mu={best['mu']} eps={best['eps']:.1f}")

        # --- concurrent single queries, coalesced on the device ---
        engine = MicroBatchEngine(index, g, fingerprint=fp,
                                  config=EngineConfig(max_batch=8))

        async def client(mu, eps):
            res = await engine.query(mu, eps)
            return int(res.n_clusters)

        async def serve():
            async with engine:
                reqs = [(mu, eps) for mu in (2, 4, 8)
                        for eps in (0.2, 0.3, 0.4, 0.5, 0.6)]
                counts = await asyncio.gather(
                    *[client(mu, eps) for mu, eps in reqs])
                return counts

        counts = asyncio.run(serve())
        st = engine.batch_stats()
        print(f"{st['requests']} concurrent queries → "
              f"{st['device_queries']} device calls "
              f"(avg batch {st['avg_batch']:.1f}); "
              f"cluster counts {sorted(set(counts))}")

        # engine answers match direct queries
        r = query(index, g, best["mu"], best["eps"])
        assert int(r.n_clusters) == best["n_clusters"]
        print("consistency with direct query: OK")


if __name__ == "__main__":
    main()
