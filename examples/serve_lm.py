"""Batched serving example: prefill a batch of prompts, stream greedy
decode steps from the KV cache (the decode_32k cell's step, miniature).

    PYTHONPATH=src python examples/serve_lm.py [--arch deepseek-v2-lite-16b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import model as mdl
from repro.train.serve_step import greedy_generate


def reduced(arch: str):
    cfg = get_config(arch)
    over = dict(n_layers=4, d_model=128, d_ff=256, vocab=1024,
                dtype="float32", q_chunk=64, attn_impl="auto")
    if cfg.family == "moe":
        over.update(n_heads=4, n_kv_heads=4, head_dim=32, n_experts=8,
                    top_k=2, d_ff=96, d_ff_dense=256, capacity_factor=4.0)
        if cfg.use_mla:
            over.update(kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16,
                        v_head_dim=32)
    elif cfg.family == "ssm":
        over.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    elif cfg.family == "hybrid":
        over.update(n_heads=4, n_kv_heads=2, head_dim=32, ssm_state=8,
                    ssm_head_dim=32, ssm_chunk=16, global_layers=(0,),
                    window=32, meta_tokens=8)
    else:
        over.update(n_heads=4, n_kv_heads=2, head_dim=32)
    return cfg.scaled(**over)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v2-lite-16b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced(args.arch)
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    max_len = args.prompt_len + args.gen + 1

    t0 = time.time()
    out = greedy_generate(cfg, params, {"tokens": prompts}, steps=args.gen,
                          max_len=max_len)
    jax.block_until_ready(out)
    dt = time.time() - t0
    print(f"arch={args.arch} (reduced) batch={args.batch} "
          f"prompt={args.prompt_len} generated={args.gen}")
    print(f"wall {dt:.2f}s → {args.batch * args.gen / dt:.1f} tok/s")
    print("sample generations:")
    for row in np.asarray(out[:3]):
        print("  ", row.tolist())
    # sanity: deterministic across runs
    out2 = greedy_generate(cfg, params, {"tokens": prompts}, steps=args.gen,
                           max_len=max_len)
    assert np.array_equal(np.asarray(out), np.asarray(out2))
    print("deterministic: ok")


if __name__ == "__main__":
    main()
