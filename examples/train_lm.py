"""End-to-end training driver: a ~100M-param granite-family model for a few
hundred steps under the fault-tolerant supervisor, with checkpointing and
the stateless data pipeline.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--arch granite-8b]

The model is the assigned granite-8b config scaled down to ~100M params
(same family/shape rules); loss decreases visibly within a few hundred
steps on the synthetic induction-mix data.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticLM
from repro.dist.fault_tolerance import Supervisor, SupervisorConfig
from repro.models import model as mdl
from repro.optim import adamw
from repro.train.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M-param variant of the assigned arch family
    cfg = get_config(args.arch).scaled(
        n_layers=6, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
        d_ff=2048, vocab=8192, dtype="float32", q_chunk=128,
        attn_impl="auto")
    n = mdl.count_params(cfg)
    print(f"arch={cfg.arch_id} (reduced) params={n/1e6:.1f}M")

    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    hp = adamw.AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    opt = adamw.init(params)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                       global_batch=args.batch, accum=1, seed=0)
    step_fn = jax.jit(make_train_step(cfg, hp, accum=1))

    losses = []

    def on_step(step, metrics):
        losses.append(float(metrics["ce"]))
        if step % 20 == 0:
            print(f"step {step:4d} ce={losses[-1]:.4f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)

    sup = Supervisor(SupervisorConfig(ckpt_dir=args.ckpt_dir, ckpt_every=100))
    sup.install_signal_handlers()

    def get_batch(step):
        return jax.tree.map(lambda x: jnp.asarray(x)[None],
                            data.batch(step))

    t0 = time.time()
    state = sup.run({"params": params, "opt_state": opt, "step": 0},
                    step_fn, get_batch, total_steps=args.steps,
                    hooks={"on_step": on_step})
    dt = time.time() - t0
    print(f"\ndone: {int(state['step'])} steps in {dt:.1f}s "
          f"({args.batch * args.seq * args.steps / dt:.0f} tok/s)")
    print(f"loss: first10={np.mean(losses[:10]):.4f} "
          f"last10={np.mean(losses[-10:]):.4f}")
    assert np.mean(losses[-10:]) < np.mean(losses[:10]), "loss did not drop"


if __name__ == "__main__":
    main()
