"""Fill EXPERIMENTS.md DRYRUN/ROOFLINE table placeholders from artifacts."""
import glob
import json
import os

ARCHS = ['deepseek-v2-lite-16b', 'granite-20b', 'granite-34b', 'granite-8b',
         'hymba-1.5b', 'mamba2-780m', 'moonshot-v1-16b-a3b', 'pixtral-12b',
         'whisper-small', 'yi-34b']
SHAPES = ['train_4k', 'prefill_32k', 'decode_32k', 'long_500k']


def load(mesh, arch, shape):
    p = f'experiments/dryrun/{mesh}/{arch}__{shape}.json'
    if not os.path.exists(p):
        return None
    return json.load(open(p))


def dryrun_table():
    rows = ["| arch | shape | pod16x16 | pod2x16x16 | bytes/dev (GiB, arg) | collectives (1-pod) |",
            "|---|---|---|---|---|---|"]
    for a in ARCHS:
        for s in SHAPES:
            r1 = load('pod16x16', a, s)
            r2 = load('pod2x16x16', a, s)

            def st(r):
                if r is None:
                    return "—"
                if r['status'] == 'skip':
                    return "skip†"
                if r['status'] == 'error':
                    return "ERR"
                return f"ok ({r['compile_s']:.0f}s)"
            arg = (f"{r1['memory']['argument_GiB']:.2f}"
                   if r1 and r1['status'] == 'ok' else "—")
            coll = (f"{r1['collective_count']} ops, "
                    f"{r1['collective_link_bytes_per_device']/1e9:.1f} GB"
                    if r1 and r1['status'] == 'ok' else "—")
            rows.append(f"| {a} | {s} | {st(r1)} | {st(r2)} | {arg} | {coll} |")
    rows.append("")
    rows.append("† long_500k on full-attention archs: documented skip "
                "(assignment rule).")
    return "\n".join(rows)


def roofline_table():
    rows = ["| arch | shape | compute ms | memory ms | coll ms | dominant | "
            "MODEL GF/dev | useful | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for a in ARCHS:
        for s in SHAPES:
            r = load('pod16x16', a, s)
            if r is None or r['status'] != 'ok':
                continue
            t = r['roofline']['terms_s']
            rows.append(
                f"| {a} | {s} | {t['compute_s']*1e3:.1f} | "
                f"{t['memory_s']*1e3:.1f} | {t['collective_s']*1e3:.1f} | "
                f"{r['roofline']['dominant'].replace('_s','')} | "
                f"{r['roofline']['model_flops_per_device']/1e9:.1f} | "
                f"{r['roofline']['useful_ratio']:.2f} | "
                f"{r['roofline']['roofline_fraction']:.4f} |")
    return "\n".join(rows)


s = open('EXPERIMENTS.md').read()
s = s.replace("<!-- DRYRUN_TABLE -->", dryrun_table())
s = s.replace("<!-- ROOFLINE_TABLE -->", roofline_table())
open('EXPERIMENTS.md', 'w').write(s)
print("tables inserted")
