"""Backend execution layer: one dispatch policy for every kernel.

* :mod:`repro.backend.policy` — :class:`ExecutionPolicy`: per-call
  platform detection, the op → lane registry (``ref`` /
  ``pallas-interpret`` / ``pallas-compiled``), forced-lane override
  (``REPRO_LANE`` env, ``EngineConfig(lane=...)``, ``scan_serve --lane``),
  ``backend.lane.*`` counters.
* :mod:`repro.backend.profile` — :class:`AutotuneProfile` calibrated
  thresholds (default = the legacy constants) + the one-shot
  :func:`autotune` microbenchmark; profiles persist as a manifest leaf in
  ``IndexStore`` snapshots.
* :mod:`repro.backend.padding` — the shared padding / pow2 shape helpers.
"""
from repro.backend.padding import (  # noqa: F401
    np_log2, np_pow2ceil, pad1, pad_to, pow2_bucket, pow2ceil,
)
from repro.backend.policy import (  # noqa: F401
    ENV_LANE, LANE_COMPILED, LANE_INTERPRET, LANE_REF, LANES, OPS,
    ExecutionPolicy, default_policy, set_default_policy,
)
from repro.backend.profile import (  # noqa: F401
    DEFAULT_PROFILE, PROFILE_VERSION, AutotuneProfile, autotune,
)
