"""Shared padding / power-of-two shape helpers.

Every fixed-shape trick in the repo — pow2 degree classes, pow2 chunk
buckets, block-multiple kernel operands — reduces to the same handful of
helpers. They used to live twice (``_pad_to`` in ``kernels/ops.py``,
``_pow2ceil``/``_pow2_bucket``/``_pad1`` in ``core/similarity.py``); this
module is the single home. ``core.similarity`` re-exports them under the
old underscore names for back-compat.

All helpers are shape-static (padding amounts derive from ``.shape``), so
the jnp ones are safe inside ``jax.jit`` traces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pow2ceil(x: int, floor: int = 1) -> int:
    """Smallest power of two ≥ max(x, floor)."""
    v = max(int(x), floor, 1)
    return 1 << (v - 1).bit_length()


def pow2_bucket(total: int, floor: int = 64) -> int:
    """Smallest power-of-two ≥ ``total`` (≥ ``floor``) — the fixed chunk
    shapes that let repeated subset passes share compiled kernels."""
    b = floor
    while b < total:
        b <<= 1
    return b


def np_pow2ceil(x: np.ndarray) -> np.ndarray:
    """Elementwise :func:`pow2ceil` (floor 1), int64."""
    x = np.maximum(np.asarray(x, np.int64), 1)
    return 1 << np.ceil(np.log2(x)).astype(np.int64)


def np_log2(x: np.ndarray) -> np.ndarray:
    """Elementwise exact log2 of power-of-two int arrays, int64."""
    return np.log2(np.asarray(x, np.int64)).astype(np.int64)


def pad1(a: np.ndarray, pad: int, fill) -> np.ndarray:
    """Append ``pad`` copies of ``fill`` to a 1-d numpy array."""
    if pad == 0:
        return a
    return np.concatenate([a, np.full(pad, fill, dtype=a.dtype)])


def pad_to(x: jax.Array, mult: int, axes) -> jax.Array:
    """Zero-pad ``axes`` of ``x`` up to the next multiple of ``mult``."""
    pads = [(0, 0)] * x.ndim
    for ax in axes:
        rem = (-x.shape[ax]) % mult
        pads[ax] = (0, rem)
    return jnp.pad(x, pads)
