"""The :class:`ExecutionPolicy`: one dispatch decision for every hot op.

Before this subsystem, backend choice was frozen at import time
(``_ON_TPU``/``_INTERPRET`` module constants in ``kernels/ops.py``) and
lane decisions (dense gram vs CSR searchsorted vs Pallas probe) were
hard-coded at each call site. The policy centralizes all of it:

* **platform detection per call** — ``platform()`` queries
  ``jax.default_backend()`` every time, so ``JAX_PLATFORMS`` set after
  import (as the subprocess mesh tests do) is honored, and importing this
  module never initializes the jax backend;
* **a kernel registry** — each hot op (``bucket_probe``, ``simhash``,
  ``hamming``, ``triangle_count``, plus ``attention`` and the pure-jnp
  ``query`` path) maps to its available lanes: ``ref`` (pure-jnp oracle),
  ``pallas-interpret`` (kernel body emulated on host), and
  ``pallas-compiled`` (real accelerator dispatch);
* **calibrated thresholds** — an :class:`~repro.backend.profile
  .AutotuneProfile` of block shapes and class-dispatch cutoffs
  (default = the legacy constants);
* **a forced-lane override** — the ``REPRO_LANE`` environment variable
  (read per call, so tests and subprocesses can pin a lane) or an
  explicit ``forced_lane=`` (``EngineConfig(lane=...)`` / ``scan_serve
  --lane``). A forced lane clamps to each op's available lanes (ops with
  only a ``ref`` lane stay on it).

The **bit-identity contract** makes lane choice safe: every lane of every
hot op reproduces the ``ref`` lane bit-for-bit on unweighted σ (to ULP on
weighted), enforced by the lane-matrix oracle test in
``tests/test_backend.py`` — swapping lanes can never change an index
fingerprint.

Every decision is observable: ``note()`` bumps a
``backend.lane.<op>.<lane>`` counter on the policy's registry, and
``describe()`` returns the block ``LiveIndexService.status()`` exposes.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.backend.profile import AutotuneProfile, DEFAULT_PROFILE

LANE_REF = "ref"
LANE_INTERPRET = "pallas-interpret"
LANE_COMPILED = "pallas-compiled"
LANES = (LANE_REF, LANE_INTERPRET, LANE_COMPILED)

ENV_LANE = "REPRO_LANE"

# the kernel registry: hot op → lanes that can answer it
OPS = {
    "bucket_probe": LANES,
    "simhash": LANES,
    "hamming": LANES,
    "triangle_count": LANES,
    "attention": LANES,
    "query": (LANE_REF,),       # (μ, ε) sweep path is pure jnp today
}


def _check_lane(lane: str) -> str:
    if lane not in LANES:
        raise ValueError(f"unknown lane {lane!r}; expected one of {LANES}")
    return lane


class ExecutionPolicy:
    """Per-call lane resolution + thresholds + lane counters.

    ``registry`` is an optional :class:`repro.obs.MetricsRegistry`; when
    present every resolved dispatch counts under
    ``backend.lane.<op>.<lane>``.
    """

    def __init__(self, profile: Optional[AutotuneProfile] = None,
                 forced_lane: Optional[str] = None, registry=None) -> None:
        self.profile = profile if profile is not None else DEFAULT_PROFILE
        self._forced = _check_lane(forced_lane) if forced_lane else None
        self.registry = registry

    # -- per-call resolution (never cached) ---------------------------------
    def platform(self) -> str:
        """The jax backend *right now* — resolved per call, never frozen."""
        import jax
        return jax.default_backend()

    def forced_lane(self) -> Optional[str]:
        """The pinned lane, if any: ``REPRO_LANE`` env (read per call)
        beats the constructor/``EngineConfig`` override."""
        env = os.environ.get(ENV_LANE)
        if env:
            return _check_lane(env)
        return self._forced

    def pallas_lane(self) -> str:
        """Which Pallas flavor this platform runs: compiled on TPU,
        interpret (host emulation of the same kernel body) elsewhere."""
        return LANE_COMPILED if self.platform() == "tpu" else LANE_INTERPRET

    def lane(self, op: str, *, width: Optional[int] = None) -> str:
        """Routing-site decision: which lane answers ``op``.

        Forced lane wins (clamped to the op's registered lanes). Otherwise
        on TPU the Pallas kernel takes groups at least
        ``profile.probe_min_width`` wide; everything else — including every
        non-TPU platform — runs the jnp reference engine.
        """
        avail = OPS.get(op, (LANE_REF,))
        forced = self.forced_lane()
        if forced is not None:
            return forced if forced in avail else LANE_REF
        if self.platform() == "tpu" and LANE_COMPILED in avail:
            if width is not None and width < self.profile.probe_min_width:
                return LANE_REF
            return LANE_COMPILED
        return LANE_REF

    def kernel_lane(self, op: str) -> str:
        """Entry-point decision for the explicit kernel wrappers in
        ``kernels/ops.py``: callers who reached a wrapper asked for the
        Pallas path, so the default is the platform's Pallas flavor; a
        forced lane (clamped to the op's lanes) still wins."""
        avail = OPS.get(op, (LANE_REF,))
        forced = self.forced_lane()
        if forced is not None:
            return forced if forced in avail else LANE_REF
        return self.pallas_lane() if LANE_INTERPRET in avail else LANE_REF

    @staticmethod
    def interpret(lane: str) -> bool:
        """The ``interpret=`` flag a Pallas call needs under ``lane``."""
        return lane != LANE_COMPILED

    # -- observability ------------------------------------------------------
    def note(self, op: str, lane: str, count: int = 1) -> None:
        """Record one (or ``count``) dispatch decisions."""
        if self.registry is not None and count:
            self.registry.inc(f"backend.lane.{op}.{lane}", count)

    def describe(self) -> dict:
        """The ``backend`` status block: platform, forced lane, the lane
        each op resolves to right now, and the active profile."""
        import dataclasses
        return {
            "platform": self.platform(),
            "forced_lane": self.forced_lane(),
            "lanes": {op: self.lane(op) for op in OPS},
            "profile": dataclasses.asdict(self.profile),
        }


_DEFAULT: Optional[ExecutionPolicy] = None


def default_policy() -> ExecutionPolicy:
    """The process-wide policy used when a call site is given none. Holds
    its own registry so ``backend.lane.*`` counters always land somewhere
    inspectable (``default_policy().registry.snapshot()``)."""
    global _DEFAULT
    if _DEFAULT is None:
        from repro.obs import MetricsRegistry
        _DEFAULT = ExecutionPolicy(registry=MetricsRegistry())
    return _DEFAULT


def set_default_policy(policy: Optional[ExecutionPolicy]) -> None:
    """Replace (or with ``None``, reset) the process-wide default policy."""
    global _DEFAULT
    _DEFAULT = policy
