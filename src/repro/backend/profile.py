"""Calibrated dispatch thresholds: the :class:`AutotuneProfile`.

Every knob the execution policy consults when routing a hot op — the hub
storage tile, the class-dispatch cutoff, the per-kernel block shapes —
lives in one frozen dataclass. The **default instance is bit-for-bit
today's constants** (``HUB_TILE=2048``, gram/simhash blocks 128, probe
blocks 256, hamming block 1024): an old checkpoint without a persisted
profile, or a policy that never autotuned, behaves exactly like the code
did before this subsystem existed.

Profiles persist as a versioned JSON manifest leaf next to the index
(``repro.serve.store.IndexStore``), so a served index remembers the
thresholds it was tuned with; :func:`autotune` produces a fresh profile
from a one-shot microbenchmark sweep under a ``backend.autotune`` span.

The profile only moves *shapes* (padding, tiling, chunking), never math:
the bit-identity contract (unweighted σ bit-for-bit, weighted to ULP)
holds under any profile, which is what makes retuning safe.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

PROFILE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class AutotuneProfile:
    """Dispatch thresholds for one platform. Defaults = legacy constants."""

    version: int = PROFILE_VERSION
    platform: str = "default"     # backend the sweep ran on ("default" = untuned)
    # -- similarity-plan shape (core.similarity) ---------------------------
    hub_tile: int = 2048          # storage tile width for hub rows
    # -- class-dispatch cutoff ---------------------------------------------
    # minimum probe-row element count before the auto policy (TPU) routes a
    # similarity group to the Pallas probe kernel instead of the jnp engine
    probe_min_width: int = 256
    # -- kernel block shapes ------------------------------------------------
    gram_block: int = 128         # masked_gram bm/bn/bk (triangle_count op)
    probe_be: int = 256           # bucket_probe edge-block
    probe_bt: int = 256           # bucket_probe target-tile stream width
    simhash_block: int = 128      # simhash_pack bm/bk (bs fixed at 128)
    hamming_block: int = 1024     # hamming_cosine edge-block
    # interpret-mode grids unroll at trace time, so the interpret lane caps
    # similarity chunks to keep compile time bounded (compiled lane ignores)
    probe_interpret_chunk: int = 512

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, payload: str) -> "AutotuneProfile":
        data = json.loads(payload)
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in data.items() if k in names})


DEFAULT_PROFILE = AutotuneProfile()

# candidate grids for the one-shot sweep; single-valued entries are taken
# without timing (tests shrink these to keep the sweep cheap)
DEFAULT_CANDIDATES = {
    "gram_block": (64, 128),
    "probe_block": ((128, 128), (256, 256)),   # (be, bt) pairs
    "hamming_block": (512, 1024),
    "simhash_block": (128,),                   # bs must stay 128-aligned
    "hub_tile": (2048,),                       # plan rebuild too costly to sweep
}


def _median_seconds(fn, trials: int) -> float:
    import time

    import jax

    fn()                                       # warmup (compile)
    times = []
    for _ in range(max(trials, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def autotune(policy=None, *, candidates: Optional[dict] = None,
             trials: int = 1) -> AutotuneProfile:
    """One-shot microbenchmark sweep → a fresh :class:`AutotuneProfile`.

    Times each candidate block shape on small synthetic operands through
    the lane the given policy would actually dispatch (its kernel lane on
    this platform), picks the argmin per knob, and stamps the platform.
    Runs under a ``backend.autotune`` span on the policy's registry.
    Single-valued candidate grids skip timing entirely.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.backend.policy import LANE_REF, default_policy
    from repro.obs import Tracer

    pol = policy if policy is not None else default_policy()
    cand = dict(DEFAULT_CANDIDATES)
    cand.update(candidates or {})
    tracer = Tracer(pol.registry)

    chosen = dataclasses.asdict(pol.profile)
    chosen["platform"] = pol.platform()
    chosen["version"] = PROFILE_VERSION

    with tracer.span("backend.autotune", platform=chosen["platform"],
                     trials=trials):
        lane = pol.kernel_lane("bucket_probe")
        interpret = lane != "pallas-compiled"
        timed = 0

        if len(cand["gram_block"]) > 1 and lane != LANE_REF:
            from repro.kernels.triangle_count import masked_gram
            n = 256
            w = jnp.asarray(np.random.default_rng(0).standard_normal(
                (n, n)), jnp.float32)
            mask = jnp.ones((n, n), jnp.float32)
            best = min(
                cand["gram_block"],
                key=lambda b: _median_seconds(
                    lambda: masked_gram(w, mask, bm=b, bn=b, bk=b,
                                        interpret=interpret), trials))
            chosen["gram_block"] = int(best)
            timed += len(cand["gram_block"])

        if len(cand["probe_block"]) > 1 and lane != LANE_REF:
            from repro.kernels.bucket_probe import bucket_probe
            rng = np.random.default_rng(1)
            e, p, t = 256, 64, 256
            ids_p = jnp.asarray(rng.integers(0, 1 << 20, (e, p)), jnp.int32)
            ids_t = jnp.asarray(rng.integers(0, 1 << 20, (e, t)), jnp.int32)
            w_p = jnp.ones((e, p), jnp.float32)
            w_t = jnp.ones((e, t), jnp.float32)
            best = min(
                cand["probe_block"],
                key=lambda bb: _median_seconds(
                    lambda: bucket_probe(ids_p, w_p, ids_t, w_t,
                                         be=min(bb[0], e), bt=min(bb[1], t),
                                         interpret=interpret), trials))
            chosen["probe_be"], chosen["probe_bt"] = int(best[0]), int(best[1])
            timed += len(cand["probe_block"])

        if len(cand["hamming_block"]) > 1 and lane != LANE_REF:
            from repro.kernels.hamming import hamming_cosine
            rng = np.random.default_rng(2)
            e, words = 2048, 8
            sk = jnp.asarray(
                rng.integers(0, 1 << 32, (2, e, words), dtype=np.uint64)
                .astype(np.uint32))
            best = min(
                cand["hamming_block"],
                key=lambda b: _median_seconds(
                    lambda: hamming_cosine(sk[0], sk[1], samples=words * 32,
                                           be=min(b, e),
                                           interpret=interpret), trials))
            chosen["hamming_block"] = int(best)
            timed += len(cand["hamming_block"])

        if len(cand["simhash_block"]) == 1:
            chosen["simhash_block"] = int(cand["simhash_block"][0])
        if len(cand["hub_tile"]) == 1:
            chosen["hub_tile"] = int(cand["hub_tile"][0])

        if pol.registry is not None:
            pol.registry.inc("backend.autotune_runs")
            pol.registry.inc("backend.autotune_candidates_timed", timed)

    return AutotuneProfile(**chosen)
