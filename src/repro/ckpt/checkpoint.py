"""Atomic, mesh-agnostic checkpointing.

Layout: one directory per step —
    <dir>/step_<k>.tmp/          (written first)
        manifest.json            (tree structure, shapes, dtypes)
        arr_<i>.npy              (one file per leaf, float32/int32 on disk)
    <dir>/step_<k>/              (atomic rename = commit)

Properties the 1000-node posture needs:
* **atomic commit** — a crash mid-write never corrupts the latest ckpt
  (readers only ever see fully renamed directories);
* **durable commit** — every file is fsynced and the directory entries
  (tmp dir before the rename, parent after) are fsynced too, so a power
  loss after :func:`save` returns cannot surface a committed-but-torn
  step (``os.rename`` alone orders against readers, not against disk);
* **mesh-agnostic restore** — leaves are stored unsharded (gathered); on
  restore they are device_put with the *current* mesh's shardings, so an
  elastic resize (e.g. 512 → 256 chips) is just a restore;
* **self-describing** — the manifest carries the treedef, so restore needs
  no reference pytree (but can validate against one).

On a real multi-host pod each host writes its addressable shards
(`shard_suffix`); this container is single-process so the gathered path is
exercised end-to-end and the sharded path is unit-tested structurally.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def fsync_dir(path: str) -> None:
    """fsync a *directory*: durably commit its entry table (file names,
    and on the parent, the rename that commits a checkpoint)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def fsync_file_then_dir(path: str) -> None:
    """Durably commit one written file: fsync its contents, then fsync
    the containing directory so the name itself survives power loss.

    ``os.rename`` alone only orders the commit against *readers*; without
    these fsyncs a crash can "commit" a step directory whose manifest or
    array files are torn or empty (data pages never reached disk). Shared
    by :func:`save` and every external chain built on it (the serve-layer
    ``DeltaLog`` appends ride through :func:`save`)."""
    with open(path, "rb") as f:
        os.fsync(f.fileno())
    fsync_dir(os.path.dirname(path) or ".")


def step_dir(directory: str, step: int, shard_suffix: str = "") -> str:
    """The committed directory for one step — the single home of the
    ``step_<k>`` naming convention (external chains like the serve-layer
    DeltaLog build on it instead of re-parsing)."""
    return os.path.join(directory, f"step_{step:08d}{shard_suffix}")


def steps(directory: str, shard_suffix: str = "") -> list:
    """All committed step numbers under ``directory``, ascending
    (``.tmp`` wreckage from interrupted writes is ignored)."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            core = name[len("step_"):]
            if shard_suffix:
                if not core.endswith(shard_suffix):
                    continue
                core = core[: -len(shard_suffix)]
            if core.isdigit():
                out.append(int(core))
    return sorted(out)


def save(directory: str, step: int, tree: Any, *, keep: int = 3,
         shard_suffix: str = "") -> str:
    """Write a checkpoint; returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = step_dir(directory, step, shard_suffix)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    paths, leaves, _ = _leaf_paths(tree)
    manifest = {"step": step, "leaves": []}
    for i, (path, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if arr.dtype not in (np.float64, np.float32, np.float16, np.int64,
                             np.int32, np.int16, np.int8, np.uint32,
                             np.uint8, np.bool_):
            arr = arr.astype(np.float32)   # bf16 etc: widen on disk
        fname = f"arr_{i:05d}.npy"
        fpath = os.path.join(tmp, fname)
        np.save(fpath, arr)
        with open(fpath, "rb") as f:
            os.fsync(f.fileno())     # data pages down before the rename
        manifest["leaves"].append(
            {"path": path, "file": fname, "dtype": logical_dtype,
             "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # manifest contents + the tmp dir's entry table down before the
    # rename (arrays were fsynced as written); then the rename itself is
    # made durable via the parent — without these a power loss can
    # "commit" a step whose manifest or arrays are torn or empty
    fsync_file_then_dir(os.path.join(tmp, "manifest.json"))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)            # atomic commit
    fsync_dir(directory)
    _gc(directory, keep, shard_suffix)
    return final


def latest_step(directory: str, shard_suffix: str = "") -> Optional[int]:
    committed = steps(directory, shard_suffix)
    return committed[-1] if committed else None


def leaf_key(*parts: str) -> str:
    """The manifest path string for a nested-dict leaf, e.g.
    ``leaf_key("index", "no_sims") == "['index']/['no_sims']"`` — matches
    how ``_leaf_paths`` serializes ``jax.tree_util.DictKey`` paths."""
    return "/".join(f"['{p}']" for p in parts)


def load_leaves(directory: str, step: int,
                shard_suffix: str = "") -> dict:
    """Reference-free restore: the manifest is self-describing, so return
    ``{leaf path string: numpy array}`` without a template pytree. Callers
    that know their tree's keys rebuild structures via :func:`leaf_key`."""
    path = step_dir(directory, step, shard_suffix)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    return {e["path"]: np.load(os.path.join(path, e["file"]))
            for e in manifest["leaves"]}


def verify_step(directory: str, step: int, shard_suffix: str = "") -> bool:
    """Whether a committed step directory is *intact*: manifest present
    and parseable, every leaf file loadable at its manifest shape.

    A pre-durability writer (or bitrot) can leave a renamed-but-torn
    step; chain consumers (the serve-layer ``DeltaLog``) use this to
    distinguish "not yet delivered / torn" from "committed" instead of
    exploding mid-replay."""
    path = step_dir(directory, step, shard_suffix)
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        for e in manifest["leaves"]:
            arr = np.load(os.path.join(path, e["file"]))
            if list(arr.shape) != list(e["shape"]):
                return False
    except Exception:  # torn bytes raise all kinds: treat alike
        return False
    return True


def restore(directory: str, step: int, like: Any, *, shardings=None,
            shard_suffix: str = "") -> Any:
    """Restore into the structure of ``like`` (shape/dtype validated).

    ``shardings``: optional matching pytree of NamedSharding — leaves are
    device_put with them (elastic re-mesh path)."""
    path = step_dir(directory, step, shard_suffix)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    paths, leaves, treedef = _leaf_paths(like)
    by_path = {e["path"]: e for e in manifest["leaves"]}
    shard_leaves = (jax.tree.leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    out = []
    for p, leaf, sh in zip(paths, leaves, shard_leaves):
        entry = by_path[p]
        arr = np.load(os.path.join(path, entry["file"]))
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"ckpt leaf {p}: shape {arr.shape} != {want_shape}")
        val = jnp.asarray(arr).astype(leaf.dtype)   # jnp handles bf16 casts
        out.append(jax.device_put(val, sh) if sh is not None else val)
    return jax.tree_util.tree_unflatten(treedef, out)


def _gc(directory: str, keep: int, shard_suffix: str):
    for s in steps(directory, shard_suffix)[:-keep]:
        shutil.rmtree(step_dir(directory, s, shard_suffix),
                      ignore_errors=True)
