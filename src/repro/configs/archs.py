"""Import side-effect module: registers all 10 assigned architectures."""
from repro.configs import whisper_small      # noqa: F401
from repro.configs import pixtral_12b        # noqa: F401
from repro.configs import granite_20b        # noqa: F401
from repro.configs import yi_34b             # noqa: F401
from repro.configs import granite_34b        # noqa: F401
from repro.configs import granite_8b         # noqa: F401
from repro.configs import mamba2_780m        # noqa: F401
from repro.configs import deepseek_v2_lite_16b  # noqa: F401
from repro.configs import moonshot_v1_16b_a3b   # noqa: F401
from repro.configs import hymba_1_5b         # noqa: F401
