"""Model configuration dataclass + registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0             # 0 → d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    first_dense_layers: int = 0   # leading layers with a dense FFN
    d_ff_dense: int = 0           # dense-FFN width for those layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001
    moe_impl: str = "pjit"        # pjit (auto-sharded dispatch) | ep (shard_map)
    # --- MLA (deepseek-style) ---
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    mla_cache_mode: str = "full"  # full | latent (absorbed decode)
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    d_conv: int = 4
    # --- hybrid (hymba) ---
    global_layers: Tuple[int, ...] = ()
    window: int = 0               # sliding-window size for non-global layers
    meta_tokens: int = 0
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    n_frames: int = 1500
    frontend: str = "none"        # none | audio_stub | vision_stub
    # --- numerics / execution ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    vocab_pad_to: int = 128
    tie_embeddings: bool = True
    act: str = "silu"             # silu | gelu
    q_chunk: int = 2048           # chunked-attention q block
    attn_impl: str = "auto"       # auto | dense | chunked
    remat: bool = True
    remat_policy: str = "full"    # full (save layer inputs) | dots (save dot outputs)
    softmax_dtype: str = "f32"    # f32 | bf16 (reduced-precision score bufs)
    ce_chunk: int = 0             # >0: chunked cross-entropy (no [B,S,V] logits)
    unroll_layers: bool = True    # python-loop layers (exact FLOP accounting)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def vocab_padded(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab + p - 1) // p * p

    @property
    def d_inner(self) -> int:   # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def n_dec_layers(self) -> int:
        return self.n_layers

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def scaled(self, **overrides) -> "ModelConfig":
        return dataclasses.replace(self, **overrides)


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    import repro.configs.archs  # noqa: F401  (populate registry)

    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def all_arch_ids():
    import repro.configs.archs  # noqa: F401

    return sorted(_REGISTRY)
