"""deepseek-v2-lite-16b [moe] — 27L, d=2048, 16H, MLA kv_lora=512
(qk_nope=128, qk_rope=64, v=128), 64 routed experts top-6 + 2 shared,
expert d_ff=1408, first layer dense (d_ff=10944), vocab=102400.
[arXiv:2405.04434]  (assignment note: the '160 routed' aside matches
DeepSeek-V2-236B; the Lite spec used here has 64 routed experts.)"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,              # per-expert width
    vocab=102400,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    first_dense_layers=1,
    d_ff_dense=10944,
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    tie_embeddings=False,
))
