"""granite-20b [dense code] — 52L, d=6144, 48H (MQA kv=1), d_ff=24576,
vocab=49152. llama-arch. [arXiv:2405.04324]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
))
