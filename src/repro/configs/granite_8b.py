"""granite-8b [dense code] — 36L, d=4096, 32H (GQA kv=8), d_ff=14336,
vocab=49152. llama-arch. [arXiv:2405.04324]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=49152,
))
