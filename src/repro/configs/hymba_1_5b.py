"""hymba-1.5b [hybrid] — 32L, d=1600, 25H (GQA kv=5) parallel attn+mamba
heads, d_ff=5504, ssm_state=16, vocab=32001. 3 global-attention layers
(first/middle/last), sliding window 1024 elsewhere, 128 meta tokens.
[arXiv:2411.13676]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    ssm_state=16,
    ssm_expand=1,
    ssm_head_dim=64,
    ssm_chunk=256,
    d_conv=4,
    global_layers=(0, 15, 31),
    window=1024,
    meta_tokens=128,
))
