"""mamba2-780m [ssm] — 48L, d=1536, attn-free SSD (state-space duality),
d_inner=3072, 48 ssm heads × 64, d_state=128, vocab=50280.
[arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    d_conv=4,
))
