"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) [moe] — 48L(? per assignment),
d=2048, 16H (GQA kv=16), 64 routed experts top-6 + 2 shared, expert
d_ff=1408, vocab=163840. Standard GQA attention per the assigned spec.
[hf:moonshotai/Moonlight-16B-A3B]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    first_dense_layers=1,
    d_ff_dense=11264,
    tie_embeddings=False,
))
