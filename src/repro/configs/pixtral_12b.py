"""pixtral-12b [vlm] backbone — 40L, d=5120, 32H (GQA kv=8), head_dim=128,
d_ff=14336, vocab=131072. Vision encoder is a stub: input_specs() provides
patch embeddings. [hf:mistralai/Pixtral-12B-2409]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="pixtral-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=131072,
    rope_theta=1e9,
    frontend="vision_stub",
    tie_embeddings=False,
))
