"""Assigned input-shape set (identical across the 10 LM archs).

``long_500k`` requires sub-quadratic attention: it runs only for the
SSM/hybrid archs (mamba2-780m, hymba-1.5b); the eight pure full-attention
archs skip it — recorded per-cell by launch/dryrun.py and in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_runs(family: str, shape_name: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch-family, shape) cell."""
    if shape_name == "long_500k" and family not in SUBQUADRATIC_FAMILIES:
        return False, "long_500k needs sub-quadratic attention (full-attention arch)"
    return True, ""
