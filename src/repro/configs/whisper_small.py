"""whisper-small [audio] enc-dec — 12L enc + 12L dec, d=768, 12H MHA,
d_ff=3072, vocab=51865. Conv/log-mel frontend is a stub: input_specs()
provides precomputed 1500-frame embeddings. [arXiv:2212.04356]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="whisper-small",
    family="encdec",
    n_layers=12,            # decoder layers
    n_enc_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,          # MHA
    d_ff=3072,
    vocab=51865,
    n_frames=1500,
    frontend="audio_stub",
    act="gelu",
    tie_embeddings=True,
))
