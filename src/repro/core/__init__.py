"""SCAN engine — the paper's contribution as a composable JAX module."""
from repro.core.graph import (
    CSRGraph,
    from_edge_list,
    graph_from_dense,
    hub_ring_graph,
    power_law_graph,
    random_graph,
    to_dense,
)
from repro.core.similarity import (
    SimilarityPlan,
    compute_similarities,
    compute_similarities_dense,
    compute_similarities_densepad,
    edge_similarities_subset,
    plan_for,
    triangle_counts,
)
from repro.core.index import ScanIndex, build_index, co_core_prefix, get_cores
from repro.core.query import ClusterResult, query, query_batch, hubs_outliers
from repro.core.local import (SeedBatchResult, SeedResult, query_seeds,
                              query_seeds_device)
from repro.core.lsh import (
    approximate_similarities,
    simhash_sketches,
    simhash_edge_similarity,
    minhash_sketches,
    minhash_edge_similarity,
    kpartition_sketches,
    kpartition_edge_similarity,
)
from repro.core.approx import (
    EXACT_PROVENANCE,
    ApproxIndexBuilder,
    ApproxParams,
    IndexProvenance,
    build_approx_index,
)
from repro.core.update import EdgeDelta, UpdateInfo, apply_delta
from repro.core.quality import (adjusted_rand_index, core_precision_recall,
                                modularity)
from repro.core.connectivity import (
    connected_components,
    connected_components_allreduce,
)
from repro.core.distributed import (
    ShardedQueryPlan,
    force_host_devices,
    query_batch_sharded,
    query_mesh,
)
