"""Approximate-first index construction (paper §5–§6.3) with provenance.

The paper's LSH pass makes index *construction* cheap: sketch every closed
neighborhood once, estimate σ per edge by sketch comparison, and fall back
to exact σ only on edges with a low-degree endpoint (§6.3 degree
heuristic — those route to the small degree-class kernels of the bucketed
similarity engine, so the exact pass never touches a hub-width kernel).
The resulting :class:`~repro.core.index.ScanIndex` is *queryable
immediately* and provably close (Theorems 5.2/5.3), which is what the
approximate-first serve lifecycle exploits: register the sketched index,
answer traffic from it, and refine to the exact index in the background
(:meth:`repro.serve.live.LiveIndexService.register_approximate` /
``refine``).

Because an approximate index is *content-wise* a different artifact from
the exact index of the same graph (its ``edge_sims`` differ, so its
fingerprint differs), every index carries an :class:`IndexProvenance`
tag — exact vs approx, sketch method, sample count, sketch seed — that
flows through the store (persisted as a manifest leaf), the engine router
(queryable per fingerprint), and the CLI. Consumers that care about
guarantees can see *what* they are querying; cache keys stay fingerprint-
based, so approximate and exact answers never alias.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional, Tuple

import jax

from repro.core.graph import CSRGraph
from repro.core.index import ScanIndex, build_index
from repro.core.lsh import approximate_similarities

#: methods and the similarity measure each one estimates
_METHOD_MEASURE = {
    "simhash": "cosine",
    "minhash": "jaccard",
    "kpartition": "jaccard",
}


@dataclasses.dataclass(frozen=True)
class ApproxParams:
    """Sketch configuration for one approximate build.

    ``seed`` pins the gaussian projections / permutations, so two builds
    with identical params produce bit-identical sketches, σ̂, and thus
    index fingerprints — which is what lets a restart re-derive the same
    approximate index it persisted.
    """

    method: str = "simhash"       # simhash | minhash | kpartition
    samples: int = 256
    seed: int = 0
    degree_heuristic: bool = True

    def __post_init__(self):
        if self.method not in _METHOD_MEASURE:
            raise ValueError(
                f"unknown LSH method {self.method!r}; "
                f"expected one of {sorted(_METHOD_MEASURE)}")
        if self.samples <= 0:
            raise ValueError(f"samples must be positive, got {self.samples}")

    @property
    def measure(self) -> str:
        """The similarity measure this sketch method estimates."""
        return _METHOD_MEASURE[self.method]

    @classmethod
    def parse(cls, spec: str) -> "ApproxParams":
        """Parse the CLI form ``method[:samples[:seed]]``.

        ``"simhash:256"`` → simhash with 256 samples, seed 0;
        ``"minhash:128:7"`` pins the sketch seed too.
        """
        parts = spec.split(":")
        if not 1 <= len(parts) <= 3 or not parts[0]:
            raise ValueError(
                f"bad approx spec {spec!r}; expected method[:samples[:seed]]")
        method = parts[0]
        try:
            samples = int(parts[1]) if len(parts) > 1 else 256
            seed = int(parts[2]) if len(parts) > 2 else 0
        except ValueError:
            raise ValueError(
                f"bad approx spec {spec!r}; samples/seed must be integers"
            ) from None
        return cls(method=method, samples=samples, seed=seed)

    def spec(self) -> str:
        return f"{self.method}:{self.samples}:{self.seed}"


@dataclasses.dataclass(frozen=True)
class IndexProvenance:
    """How an index's ``edge_sims`` were produced.

    The default-constructed instance (module constant
    :data:`EXACT_PROVENANCE`) names an exact build; approximate builds
    record the full sketch configuration so quality is attributable and
    the build is reproducible.
    """

    kind: str = "exact"                # "exact" | "approx"
    method: Optional[str] = None
    samples: int = 0
    seed: int = 0
    degree_heuristic: bool = True

    @property
    def is_approx(self) -> bool:
        return self.kind == "approx"

    def describe(self) -> str:
        if not self.is_approx:
            return "exact"
        dh = "+degree-heuristic" if self.degree_heuristic else ""
        return f"approx({self.method}, k={self.samples}, seed={self.seed}{dh})"

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, payload: str) -> "IndexProvenance":
        data = json.loads(payload)
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in data.items() if k in known})

    @classmethod
    def for_params(cls, params: ApproxParams) -> "IndexProvenance":
        return cls(kind="approx", method=params.method,
                   samples=params.samples, seed=params.seed,
                   degree_heuristic=params.degree_heuristic)


EXACT_PROVENANCE = IndexProvenance()


class ApproxIndexBuilder:
    """Build a queryable :class:`ScanIndex` from LSH-sketched similarities.

    ``measure`` must match what ``params.method`` estimates (simhash →
    cosine, minhash/kpartition → jaccard) — a mismatch is a config error,
    caught at construction, not a silently wrong index.
    """

    def __init__(self, measure: str = "cosine",
                 params: ApproxParams = ApproxParams(), *, policy=None):
        if params.measure != measure:
            raise ValueError(
                f"method {params.method!r} estimates {params.measure!r} "
                f"similarity, not {measure!r}")
        self.measure = measure
        self.params = params
        # execution policy for the sketch-comparison / exact-pass lanes
        # (None → the process default); lane choice never moves σ̂ bits
        self.policy = policy

    @property
    def provenance(self) -> IndexProvenance:
        return IndexProvenance.for_params(self.params)

    def similarities(self, g: CSRGraph) -> jax.Array:
        """The sketched per-half-edge σ̂ (exact on §6.3 heuristic edges)."""
        p = self.params
        return approximate_similarities(
            g, measure=self.measure, method=p.method, samples=p.samples,
            key=jax.random.PRNGKey(p.seed),
            degree_heuristic=p.degree_heuristic, policy=self.policy)

    def build(self, g: CSRGraph, *,
              tracer=None) -> Tuple[ScanIndex, IndexProvenance]:
        """→ (approximate index, its provenance tag).

        ``tracer`` (a :class:`repro.obs.Tracer`) wraps the construction in
        an ``index.approx_build`` span so approximate-build latency lands
        in the same histogram taxonomy as the rest of the serve stack.
        """
        p = self.params
        if tracer is not None:
            with tracer.span("index.approx_build", method=p.method,
                             samples=p.samples, seed=p.seed, n=g.n, m=g.m):
                index = build_index(g, self.measure,
                                    sims=self.similarities(g))
        else:
            index = build_index(g, self.measure, sims=self.similarities(g))
        return index, self.provenance


def build_approx_index(
    g: CSRGraph,
    *,
    measure: str = "cosine",
    method: str = "simhash",
    samples: int = 256,
    seed: int = 0,
    degree_heuristic: bool = True,
) -> Tuple[ScanIndex, IndexProvenance]:
    """One-shot convenience wrapper over :class:`ApproxIndexBuilder`."""
    params = ApproxParams(method=method, samples=samples, seed=seed,
                          degree_heuristic=degree_heuristic)
    return ApproxIndexBuilder(measure, params).build(g)
