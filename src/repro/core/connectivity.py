"""Parallel connectivity via min-label propagation + pointer jumping.

The paper uses Gazit's O(log n)-span connectivity (theory) and concurrent
union-find (implementation, §6.2). Neither CAS-loops nor work-stealing exist
on TPU, so we use the standard vector-parallel equivalent: every vertex
carries a label (initialized to its own id); each round scatter-mins
neighbor labels across the active edge set, then pointer-jumps
(``labels = labels[labels]``, twice) to compress chains. Each round is a
constant number of gathers/scatters → O(log n) rounds w.h.p. on real graphs,
matching the span target; a ``while_loop`` on the changed-flag guarantees
exact convergence regardless.

With ``axis_name`` the same loop runs with the edge set *sharded* over a
mesh axis (inside ``shard_map``): each shard scatter-mins its local edges
into a private proposal vector and an ``lax.pmin`` all-reduce merges the
proposals. min is associative, so the merged proposal equals the
single-device scatter over the full edge set and the per-round label
sequence — hence the fixed point — is bit-identical to the unsharded path.
The changed-flag is computed from replicated state, so every shard exits
the while_loop on the same round.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def connected_components(
    n: int,
    eu: jax.Array,         # int32[E] edge endpoints (half-edges fine)
    ev: jax.Array,         # int32[E]
    edge_mask: jax.Array,  # bool[E] active edges
    vertex_mask: jax.Array | None = None,  # bool[n] active vertices
    axis_name: str | None = None,  # set inside shard_map: edges are a shard
) -> jax.Array:
    """Labels int32[n]: min vertex id of the component (only meaningful where
    vertex_mask); inactive vertices keep label = own id."""
    if vertex_mask is None:
        vertex_mask = jnp.ones((n,), dtype=bool)

    init = jnp.arange(n, dtype=jnp.int32)
    big = jnp.int32(n)

    def body(state):
        labels, _ = state
        lv = jnp.where(edge_mask, labels[ev], big)
        # propagate min neighbor label into u (shard-local when sharded)
        prop = jnp.full((n,), big, dtype=jnp.int32).at[eu].min(lv, mode="drop")
        if axis_name is not None:
            prop = jax.lax.pmin(prop, axis_name)
        new = jnp.where(vertex_mask, jnp.minimum(labels, prop), labels)
        # pointer jumping (path compression) — twice per round
        new = new[new]
        new = new[new]
        changed = jnp.any(new != labels)
        return new, changed

    def cond(state):
        return state[1]

    labels, _ = jax.lax.while_loop(cond, body, (init, jnp.bool_(True)))
    return labels


def connected_components_allreduce(
    n: int,
    eu: jax.Array,         # int32[E/k] local edge shard
    ev: jax.Array,         # int32[E/k]
    edge_mask: jax.Array,  # bool[E/k] active edges in this shard
    vertex_mask: jax.Array,  # bool[n] active vertices (replicated)
    axis_name: str,
) -> jax.Array:
    """Sharded-edge spelling of :func:`connected_components` (see module
    docstring); must run inside ``shard_map`` over ``axis_name``."""
    return connected_components(n, eu, ev, edge_mask, vertex_mask,
                                axis_name=axis_name)
