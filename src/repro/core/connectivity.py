"""Parallel connectivity via min-label propagation + pointer jumping.

The paper uses Gazit's O(log n)-span connectivity (theory) and concurrent
union-find (implementation, §6.2). Neither CAS-loops nor work-stealing exist
on TPU, so we use the standard vector-parallel equivalent: every vertex
carries a label (initialized to its own id); each round scatter-mins
neighbor labels across the active edge set, then pointer-jumps
(``labels = labels[labels]``, twice) to compress chains. Each round is a
constant number of gathers/scatters → O(log n) rounds w.h.p. on real graphs,
matching the span target; a ``while_loop`` on the changed-flag guarantees
exact convergence regardless.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def connected_components(
    n: int,
    eu: jax.Array,         # int32[E] edge endpoints (half-edges fine)
    ev: jax.Array,         # int32[E]
    edge_mask: jax.Array,  # bool[E] active edges
    vertex_mask: jax.Array | None = None,  # bool[n] active vertices
) -> jax.Array:
    """Labels int32[n]: min vertex id of the component (only meaningful where
    vertex_mask); inactive vertices keep label = own id."""
    if vertex_mask is None:
        vertex_mask = jnp.ones((n,), dtype=bool)

    init = jnp.arange(n, dtype=jnp.int32)
    big = jnp.int32(n)

    def body(state):
        labels, _ = state
        lv = jnp.where(edge_mask, labels[ev], big)
        # propagate min neighbor label into u
        prop = jnp.full((n,), big, dtype=jnp.int32).at[eu].min(lv)
        new = jnp.where(vertex_mask, jnp.minimum(labels, prop), labels)
        # pointer jumping (path compression) — twice per round
        new = new[new]
        new = new[new]
        changed = jnp.any(new != labels)
        return new, changed

    def cond(state):
        return state[1]

    labels, _ = jax.lax.while_loop(cond, body, (init, jnp.bool_(True)))
    return labels
