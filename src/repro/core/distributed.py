"""Distributed SCAN index construction (beyond-paper, pod-scale posture).

The paper targets one shared-memory node. At pod scale the natural
decomposition keeps the similarity pass *edge-parallel*: half-edges are
sharded across the ``data`` axis of the mesh with ``shard_map``; the padded
neighbor matrix (or, for dense graphs, the packed LSH sketches — 32× smaller)
is replicated/all-gathered. The LSH sketches double as a *communication
compressor*: a k-bit sketch per vertex replaces the full neighbor row, which
is exactly the paper's "LSH wins on dense graphs" insight re-applied to the
network instead of the cache.

The global sorts for NO/CO lower to XLA's distributed sort under pjit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.graph import CSRGraph
from repro.core import lsh as lsh_mod


def sharded_edge_similarities(
    g: CSRGraph,
    nbr_mat: jax.Array,
    wgt_mat: jax.Array,
    norms: jax.Array,
    mesh: Mesh,
    axis: str = "data",
    measure: str = "cosine",
) -> jax.Array:
    """σ per half-edge with the edge axis sharded over ``axis``.

    Edge arrays must be padded to a multiple of the axis size by the caller
    (pad with edge (0,0) — results for padding are discarded).
    """
    cdeg = g.closed_degrees()

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(None, None), P(None, None), P(None), P(None)),
        out_specs=P(axis),
        check_rep=False,
    )
    def _shard(eu, ev, ew, nbr_m, wgt_m, nrm, cd):
        from repro.core.similarity import _edge_sims_chunk

        return _edge_sims_chunk(eu, ev, ew, nbr_m, wgt_m, nrm, cd, measure)

    return _shard(g.edge_u, g.nbrs, g.wgts, nbr_mat, wgt_mat, norms, cdeg)


def sharded_simhash_edge_similarities(
    g: CSRGraph,
    sketches: jax.Array,
    samples: int,
    mesh: Mesh,
    axis: str = "data",
) -> jax.Array:
    """LSH comparison pass, edges sharded, sketches replicated (k bits/vertex)."""

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(None, None)),
        out_specs=P(axis),
        check_rep=False,
    )
    def _shard(eu, ev, sk):
        return lsh_mod.simhash_edge_similarity(sk, eu, ev, samples)

    return _shard(g.edge_u, g.nbrs, sketches)
