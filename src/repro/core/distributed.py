"""Distributed SCAN index construction (beyond-paper, pod-scale posture).

The paper targets one shared-memory node. At pod scale the natural
decomposition keeps the similarity pass *edge-parallel*: half-edges are
sharded across the ``data`` axis of the mesh with ``shard_map``; the
degree-bucketed neighbor blocks (or, for dense graphs, the packed LSH
sketches — 32× smaller) are replicated/all-gathered. Bucketing shrinks the
replicated operand from the old O(n·Δ) dense padded matrix to O(m + n)
class blocks — on skewed graphs that is the difference between replicating
gigabytes and megabytes per device. The LSH sketches double as a further
*communication compressor*: a k-bit sketch per vertex replaces the full
neighbor row, which is exactly the paper's "LSH wins on dense graphs"
insight re-applied to the network instead of the cache.

The global sorts for NO/CO lower to XLA's distributed sort under pjit.
"""
from __future__ import annotations

import functools
import hashlib
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.connectivity import connected_components_allreduce
from repro.core.graph import CSRGraph
from repro.core import lsh as lsh_mod


@functools.partial(
    jax.jit, static_argnames=("sp", "st", "measure", "mesh", "axis"))
def _sharded_bucket_group(p0, pt, t0, tt, eu, ev, ew,
                          p_nbr, p_wgt, t_nbr, t_wgt, norms, cdeg,
                          *, sp, st, measure, mesh, axis):
    from repro.core.similarity import _bucket_sims_core

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis),) * 7 + (P(None, None),) * 4 + (P(None), P(None)),
        out_specs=P(axis),
        check_rep=False,
    )
    def _shard(p0, pt, t0, tt, eu, ev, ew, p_n, p_w, t_n, t_w, nrm, cd):
        return _bucket_sims_core(p0, pt, t0, tt, eu, ev, ew,
                                 p_n, p_w, t_n, t_w, nrm, cd,
                                 sp, st, measure)

    return _shard(p0, pt, t0, tt, eu, ev, ew,
                  p_nbr, p_wgt, t_nbr, t_wgt, norms, cdeg)


def sharded_edge_similarities(
    g: CSRGraph,
    plan=None,
    mesh: Mesh | None = None,
    axis: str = "data",
    measure: str = "cosine",
    policy=None,
) -> jax.Array:
    """σ per half-edge with the edge axis sharded over ``axis``.

    Degree-bucketed twin of :func:`repro.core.similarity.compute_similarities`:
    edges are routed host-side to (probe class, target class) groups, each
    group is padded to a multiple of the axis size and runs as one
    ``shard_map`` over its sharded edge chunk, with the two class blocks
    (O(m + n) total, not the old O(n·Δ) padded matrix) replicated.

    The placement inherits the execution policy (``hub_tile`` for a plan
    built here, lane attribution for the counters); the shard body is the
    jnp reference engine — the ``ref`` lane — so sharded σ stays
    bit-identical to the single-host path regardless of forced lanes.
    """
    from repro.backend.policy import LANE_REF, default_policy
    from repro.core import similarity as sim_mod

    pol = policy if policy is not None else default_policy()
    if plan is None:
        plan = sim_mod.plan_for(g, hub_tile=pol.profile.hub_tile)
    if mesh is None:
        mesh = query_mesh(axis=axis)
    k = mesh.shape[axis]
    eu = np.asarray(g.edge_u, np.int64)
    ev = np.asarray(g.nbrs, np.int64)
    ew = np.asarray(g.wgts, np.float32)
    if g.m2 == 0:
        return jnp.zeros((0,), jnp.float32)

    pu, pv, keys = plan.route(eu, ev)
    order = np.argsort(keys, kind="stable")
    bounds = np.flatnonzero(np.diff(keys[order])) + 1
    out = np.empty(g.m2, np.float32)
    for idx in np.split(order, bounds):
        pol.note("bucket_probe", LANE_REF)    # shard_map body = jnp engine
        cp = int(plan.vclass[pu[idx[0]]])
        ct = int(plan.vclass[pv[idx[0]]])
        sp = sim_mod._pow2ceil(int(plan.vtiles[pu[idx[0]]]))
        st = sim_mod._pow2ceil(int(plan.vtiles[pv[idx[0]]]))
        # same transient-working-set bound as the local engine, rounded up
        # to a k-multiple pow2-bucketed chunk so hub groups stream instead
        # of gathering one unbounded row matrix per device, and so repeated
        # graphs hit the same compiled shard_map shapes
        pe = sp * plan.widths[cp]
        te = st * plan.widths[ct]
        cap = max(sim_mod.CHUNK_ELEMS // max(pe + te, 1), 1)
        cap = 1 << (cap.bit_length() - 1)
        csize = -(-min(sim_mod._pow2_bucket(len(idx)), max(cap, 1)) // k) * k
        sent_p = plan.nbr_blocks[cp].shape[0] - 1
        sent_t = plan.nbr_blocks[ct].shape[0] - 1
        for s in range(0, len(idx), csize):
            sub = idx[s: s + csize]
            pad = csize - len(sub)
            res = _sharded_bucket_group(
                jnp.asarray(sim_mod._pad1(plan.vrow[pu[sub]], pad, sent_p)),
                jnp.asarray(sim_mod._pad1(plan.vtiles[pu[sub]], pad, 0)),
                jnp.asarray(sim_mod._pad1(plan.vrow[pv[sub]], pad, sent_t)),
                jnp.asarray(sim_mod._pad1(plan.vtiles[pv[sub]], pad, 0)),
                jnp.asarray(sim_mod._pad1(eu[sub].astype(np.int32), pad, 0)),
                jnp.asarray(sim_mod._pad1(ev[sub].astype(np.int32), pad, 0)),
                jnp.asarray(sim_mod._pad1(ew[sub], pad, 0.0)),
                plan.nbr_blocks[cp], plan.wgt_blocks[cp],
                plan.nbr_blocks[ct], plan.wgt_blocks[ct],
                plan.norms, plan.cdeg,
                sp=sp, st=st, measure=measure, mesh=mesh, axis=axis)
            out[sub] = np.asarray(res)[: len(sub)]
    return jnp.asarray(out)


def sharded_simhash_edge_similarities(
    g: CSRGraph,
    sketches: jax.Array,
    samples: int,
    mesh: Mesh,
    axis: str = "data",
) -> jax.Array:
    """LSH comparison pass, edges sharded, sketches replicated (k bits/vertex)."""

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(None, None)),
        out_specs=P(axis),
        check_rep=False,
    )
    def _shard(eu, ev, sk):
        return lsh_mod.simhash_edge_similarity(sk, eu, ev, samples)

    return _shard(g.edge_u, g.nbrs, sketches)


# ---------------------------------------------------------------------------
# Sharded clustering queries (giant-graph serving path)
# ---------------------------------------------------------------------------
# The single-device ``core.query`` holds every O(m) array — half-edges,
# similarities, the CO slot arrays — on one device. For giant graphs the
# edge axis is the memory that runs out (GPUSCAN++'s observation), so the
# sharded query path partitions *every* edge-sized array over the mesh
# ``data`` axis and keeps only O(n) label/working vectors replicated:
#
#   * core extraction      — each shard scans its CO slot chunk for the
#     θ ≥ ε prefix boundary; one pmin merges the boundary, one pmax merges
#     the scattered core mask.
#   * ε-similar filtering  — purely shard-local (each shard owns its edges).
#   * connectivity         — all-reduced label propagation
#     (:func:`connected_components_allreduce`): scatter-min locally,
#     pmin-merge, pointer-jump on the replicated labels.
#   * border attachment    — local scatter-max/min + pmax/pmin merges.
#
# Every merge is an associative min/max, so each round reproduces the
# single-device scatter exactly → results are bit-identical to
# ``core.query_batch`` (asserted in tests/test_distributed_query.py).


def force_host_devices(k: int) -> None:
    """Ask XLA for ``k`` host-platform devices (CLI/bench/demo helper).

    Appends ``--xla_force_host_platform_device_count=k`` to ``XLA_FLAGS``
    unless a count is already forced. Must run before jax's backend
    initializes (the flag is read exactly once, at first device use) —
    importing jax is fine, touching devices is not.
    """
    if k <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={k}").strip()


def query_mesh(n_shards: int | None = None, axis: str = "data") -> Mesh:
    """1-D device mesh for sharded queries (defaults to every device)."""
    devs = jax.devices()
    if n_shards is None:
        n_shards = len(devs)
    if n_shards > len(devs):
        raise ValueError(
            f"requested {n_shards} shards but only {len(devs)} devices are "
            "visible (set XLA_FLAGS=--xla_force_host_platform_device_count=K "
            "before importing jax to emulate K host devices)")
    return Mesh(np.asarray(devs[:n_shards]), (axis,))


@functools.partial(
    jax.jit, static_argnames=("n", "max_cdeg", "mesh", "axis"))
def _sharded_query_batch(
    eu, ev, esim, emask,            # edge-sized, padded to k·⌈E/k⌉
    co_vertex, co_theta, co_idx,    # CO-slot-sized, padded likewise
    co_offsets, mus, epss,          # replicated (small / parameter vectors)
    *, n: int, max_cdeg: int, mesh: Mesh, axis: str,
):
    big_idx = jnp.int32(2 ** 30)

    def one(mu, eps, eu, ev, esim, emask, co_v, co_t, co_i, co_off):
        mu = jnp.asarray(mu, jnp.int32)
        eps = jnp.asarray(eps, jnp.float32)

        # ---- cores: CO[μ] prefix with θ ≥ ε, slots sharded ----
        lo = co_off[jnp.clip(mu, 0, max_cdeg)]
        hi = co_off[jnp.clip(mu + 1, 0, max_cdeg + 1)]
        in_seg = (co_i >= lo) & (co_i < hi)
        below = in_seg & (co_t < eps)
        local_first = jnp.min(jnp.where(below, co_i, big_idx))
        first_below = jax.lax.pmin(local_first, axis)
        first_below = jnp.where(first_below == big_idx, hi, first_below)
        core_slots = in_seg & (co_i < first_below)
        local_mask = (
            jnp.zeros((n,), jnp.int32)
            .at[co_v]
            .max(core_slots.astype(jnp.int32), mode="drop")
        )
        is_core = jax.lax.pmax(local_mask, axis) > 0
        is_core = is_core & (mu >= 2) & (mu <= max_cdeg)

        # ---- ε-similar half-edges incident on cores (shard-local) ----
        sim_ok = (esim >= eps) & emask
        core_u = is_core[eu]
        core_v = is_core[ev]
        core_core = sim_ok & core_u & core_v

        labels0 = connected_components_allreduce(
            n, eu, ev, core_core, is_core, axis)
        labels = jnp.where(is_core, labels0, jnp.int32(-1))

        # ---- border attachment (scatter-max σ, tie to lower core id) ----
        border_edge = sim_ok & core_u & ~core_v
        neg = jnp.float32(-1.0)
        local_best = (
            jnp.full((n,), neg)
            .at[ev]
            .max(jnp.where(border_edge, esim, neg), mode="drop")
        )
        best_sim = jax.lax.pmax(local_best, axis)
        tie = border_edge & (esim >= best_sim[ev]) & (best_sim[ev] > neg)
        big = jnp.int32(n)
        local_core = (
            jnp.full((n,), big)
            .at[ev]
            .min(jnp.where(tie, eu, big), mode="drop")
        )
        best_core = jax.lax.pmin(local_core, axis)
        has_border = best_core < big
        border_label = labels0[jnp.clip(best_core, 0, n - 1)]
        labels = jnp.where(~is_core & has_border, border_label, labels)

        n_clusters = jnp.sum(is_core & (labels == jnp.arange(n)))
        return labels, is_core, n_clusters

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis),
                  P(axis), P(axis), P(axis),
                  P(None), P(None), P(None)),
        out_specs=(P(None), P(None), P(None)),
        check_rep=False,
    )
    def _shard(eu, ev, esim, emask, co_v, co_t, co_i, co_off, mus, epss):
        return jax.vmap(
            lambda m, e: one(m, e, eu, ev, esim, emask, co_v, co_t, co_i,
                             co_off)
        )(mus, epss)

    return _shard(eu, ev, esim, emask, co_vertex, co_theta, co_idx,
                  co_offsets, mus, epss)


def _pad_host(arr, total: int, fill, dtype) -> np.ndarray:
    """Pad a 1-D array to ``total`` entries with ``fill`` (host numpy, so
    per-shard chunks can be diffed against a predecessor plan)."""
    arr = np.asarray(arr, dtype=dtype)
    pad = total - arr.shape[0]
    if pad == 0:
        return arr
    return np.concatenate([arr, np.full((pad,), fill, dtype=dtype)])


class ShardedQueryPlan:
    """Padded, device-placed operands for repeated sharded queries over one
    (index, graph, mesh) triple.

    Padding and concatenating the O(m) edge/CO-slot arrays is per-*plan*
    work, not per-*query* work: the serve engine answers a flush every few
    milliseconds against a fixed index, so it builds the plan once at
    registration and every device call is just the jitted shard_map
    computation over already-sharded arrays. ``query_batch_sharded`` builds
    a throwaway plan for one-shot callers.

    Ragged edge counts are padded host-side to a multiple of the axis size;
    padding edges carry ``emask=False`` and padded CO slots sit outside
    every [lo, hi) segment, so they never contribute.

    :meth:`refresh` derives a successor plan after an incremental index
    update: per-shard chunks of each O(m) operand are compared host-side
    (sha256 content digests — 32 bytes per chunk retained, not the O(m)
    padded arrays themselves) and only *mutated* partitions are re-placed
    on device; unchanged shards — and the replicated CO offsets — adopt
    the old plan's buffers (an incremental edit batch typically touches a
    handful of partitions, not all k). ``refresh`` is loop-free pure
    compute, so the live-update path runs it in the engine's offload
    worker alongside ``apply_delta``.

    ``registry`` (a :class:`repro.obs.MetricsRegistry`) makes plan
    maintenance measurable per shard: each per-device chunk placement is
    timed into the ``sharded.place_chunk`` histogram (full-array initial
    placements into ``sharded.place_full``), reused vs re-placed chunks
    count into ``sharded.chunks_reused`` / ``sharded.chunks_placed``,
    and the whole build/refresh lands in ``sharded.plan_build``. The
    serve engine passes its registry through, so a hot-swap's refresh
    cost shows up next to the query latency it protects.
    """

    _SHARDED = ("emask", "eu", "ev", "esim", "co_v", "co_t", "co_i")

    def __init__(self, index, g: CSRGraph, mesh: Mesh, axis: str = "data",
                 *, registry=None,
                 _reuse_from: "ShardedQueryPlan | None" = None):
        t_build = time.monotonic()
        self.mesh = mesh
        self.axis = axis
        self.n = index.n
        self.max_cdeg = index.max_cdeg
        self._registry = (registry if registry is not None
                          else getattr(_reuse_from, "_registry", None))
        k = mesh.shape[axis]
        self._shard = NamedSharding(mesh, P(axis))
        repl = NamedSharding(mesh, P())

        ep = max(-(-max(g.m2, 1) // k) * k, k)   # edge slots per full array
        m_co = index.co_vertex.shape[0]
        cp = max(-(-max(m_co, 1) // k) * k, k)
        host = {   # transient: placed on device, digested, then dropped
            "emask": np.arange(ep) < g.m2,
            "eu": _pad_host(g.edge_u, ep, 0, np.int32),
            "ev": _pad_host(g.nbrs, ep, 0, np.int32),
            "esim": _pad_host(index.edge_sims, ep, 0.0, np.float32),
            "co_v": _pad_host(index.co_vertex, cp, 0, np.int32),
            "co_t": _pad_host(index.co_theta, cp, 0.0, np.float32),
            "co_i": _pad_host(np.arange(m_co, dtype=np.int32), cp, 2 ** 30,
                              np.int32),
        }
        self._chunk_digests: dict = {}
        stats = {"chunks": k * len(self._SHARDED), "reused": 0, "placed": 0,
                 "repl_reused": 0}
        for name in self._SHARDED:
            arr, reused = self._place(name, host[name], _reuse_from)
            setattr(self, name, arr)
            stats["reused"] += reused
            stats["placed"] += k - reused
        # the replicated CO segment offsets diff the same way the sharded
        # chunks do: unchanged content adopts the predecessor's buffer
        co_off_host = np.asarray(index.co_offsets)
        self._co_off_digest = (co_off_host.shape,
                               hashlib.sha256(co_off_host.tobytes()).digest())
        if (_reuse_from is not None and _reuse_from.mesh is self.mesh
                and getattr(_reuse_from, "_co_off_digest", None)
                == self._co_off_digest):
            self.co_offsets = _reuse_from.co_offsets
            stats["repl_reused"] = 1
        else:
            self.co_offsets = jax.device_put(index.co_offsets, repl)
        self.last_refresh = stats
        if self._registry is not None:
            self._registry.inc("sharded.chunks_reused", stats["reused"])
            self._registry.inc("sharded.chunks_placed", stats["placed"])
            self._registry.observe("sharded.plan_build",
                                   time.monotonic() - t_build)

    def _place(self, name: str, host: np.ndarray,
               prev: "ShardedQueryPlan | None"):
        """Device-place one sharded operand, adopting the predecessor's
        per-shard buffers wherever the chunk content digest is unchanged.
        Returns (global array, number of reused chunks)."""
        k = self.mesh.shape[self.axis]
        chunk = host.shape[0] // k
        digests = [
            hashlib.sha256(
                np.ascontiguousarray(host[i * chunk:(i + 1) * chunk])
                .tobytes()).digest()
            for i in range(k)]
        self._chunk_digests[name] = (host.shape, digests)
        if (prev is None or prev.mesh is not self.mesh
                or prev._chunk_digests[name][0] != host.shape):
            t0 = time.monotonic()
            arr = jax.device_put(jnp.asarray(host), self._shard)
            if self._registry is not None:
                self._registry.observe("sharded.place_full",
                                       time.monotonic() - t0)
            return arr, 0
        old_digests = prev._chunk_digests[name][1]
        old_arr = getattr(prev, name)
        by_start = {(s.index[0].start or 0): s.data
                    for s in old_arr.addressable_shards}
        devices = list(self.mesh.devices.flat)
        bufs, reused = [], 0
        for i in range(k):
            lo = i * chunk
            if old_digests[i] == digests[i]:
                bufs.append(by_start[lo])
                reused += 1
            else:
                t0 = time.monotonic()
                bufs.append(jax.device_put(
                    jnp.asarray(host[lo: lo + chunk]), devices[i]))
                if self._registry is not None:
                    # one sample per re-placed shard chunk: the per-shard
                    # cost of a hot-swap's operand refresh
                    self._registry.observe("sharded.place_chunk",
                                           time.monotonic() - t0)
        arr = jax.make_array_from_single_device_arrays(
            host.shape, self._shard, bufs)
        return arr, reused

    def refresh(self, index, g: CSRGraph) -> "ShardedQueryPlan":
        """Successor plan for an updated (index, graph): re-shards only
        the mutated partitions of the O(m) operands (see
        ``plan.last_refresh`` for the reuse/placed chunk counts). The old
        plan is left untouched, so an engine can serve in-flight traffic
        against it until the hot-swap completes."""
        return ShardedQueryPlan(index, g, self.mesh, self.axis,
                                _reuse_from=self)

    def __call__(self, mus, epss):
        from repro.core.query import ClusterResult

        mus = jnp.atleast_1d(jnp.asarray(mus, jnp.int32))
        epss = jnp.atleast_1d(jnp.asarray(epss, jnp.float32))
        labels, is_core, n_clusters = _sharded_query_batch(
            self.eu, self.ev, self.esim, self.emask,
            self.co_v, self.co_t, self.co_i,
            self.co_offsets, mus, epss,
            n=self.n, max_cdeg=self.max_cdeg, mesh=self.mesh,
            axis=self.axis)
        return ClusterResult(labels=labels, is_core=is_core,
                             n_clusters=n_clusters)


def query_batch_sharded(
    index,
    g: CSRGraph,
    mus,
    epss,
    *,
    mesh: Mesh | None = None,
    axis: str = "data",
):
    """Sharded twin of :func:`repro.core.query_batch`.

    Partitions the half-edge arrays (endpoints, similarities) and the CO
    slot arrays over ``mesh``'s ``axis``; returns the exact same
    ``ClusterResult`` (leading batch axis) as the single-device path.
    Repeated callers should build a :class:`ShardedQueryPlan` once instead.
    """
    if mesh is None:
        mesh = query_mesh(axis=axis)
    return ShardedQueryPlan(index, g, mesh, axis)(mus, epss)
