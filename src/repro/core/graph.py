"""Graph representation for the SCAN engine.

Graphs are stored in a jit-static padded CSR form:

  * ``offsets``  int32[n+1]  — row starts into the half-edge arrays.
  * ``nbrs``     int32[m2]   — neighbor vertex ids, each row sorted ascending.
  * ``wgts``     float32[m2] — edge weights (1.0 for unweighted graphs).
  * ``edge_u``   int32[m2]   — source vertex of each half-edge (CSR row id,
                               materialized so per-edge passes are gathers).

``m2 = 2m`` symmetric half-edges. Vertex ids are ``[0, n)`` (the paper uses
1-based ids; 0-based is the array-native choice). Graphs are simple:
no self-loops, no duplicate edges.

Everything downstream (similarity, index construction, queries, LSH) consumes
this structure with fixed shapes, which is what makes the whole SCAN engine
jit-able and shard_map-able.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Symmetric CSR graph. ``n``/``m2`` are static (python ints)."""

    offsets: jax.Array  # int32[n+1]
    nbrs: jax.Array     # int32[m2], row-sorted ascending
    wgts: jax.Array     # float32[m2]
    edge_u: jax.Array   # int32[m2]
    n: int = dataclasses.field(metadata=dict(static=True))
    m2: int = dataclasses.field(metadata=dict(static=True))

    @property
    def m(self) -> int:
        return self.m2 // 2

    def degrees(self) -> jax.Array:
        """Open-neighborhood degrees |N(v)|, int32[n]."""
        return jnp.diff(self.offsets)

    def closed_degrees(self) -> jax.Array:
        """Closed-neighborhood sizes |N̄(v)| = deg(v) + 1."""
        return self.degrees() + 1


def from_edge_list(
    n: int,
    edges: Sequence[Tuple[int, int]] | np.ndarray,
    weights: Optional[Sequence[float] | np.ndarray] = None,
) -> CSRGraph:
    """Build a CSRGraph from an undirected edge list (host-side).

    Deduplicates edges, drops self-loops, symmetrizes.
    """
    if n > 2 ** 31:
        # vertex ids must fit in 31 bits: the incremental-update path
        # (repro.core.update) merges edits on packed (u << 32 | v) int64
        # keys, which silently collide beyond that — refuse up front
        raise ValueError(
            f"n={n} exceeds 2**31: vertex ids must fit in 31 bits for the "
            "packed edit-merge keys used by incremental updates")
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    if weights is None:
        weights = np.ones(len(edges), dtype=np.float32)
    weights = np.asarray(weights, dtype=np.float32)
    if len(weights) != len(edges):
        raise ValueError("weights length must match edges length")
    # canonicalize, drop self loops, dedup (keep first weight)
    keep = edges[:, 0] != edges[:, 1]
    edges, weights = edges[keep], weights[keep]
    lo = np.minimum(edges[:, 0], edges[:, 1])
    hi = np.maximum(edges[:, 0], edges[:, 1])
    key = lo * n + hi
    _, first = np.unique(key, return_index=True)
    lo, hi, weights = lo[first], hi[first], weights[first]

    u = np.concatenate([lo, hi])
    v = np.concatenate([hi, lo])
    w = np.concatenate([weights, weights])
    order = np.lexsort((v, u))
    u, v, w = u[order], v[order], w[order]

    offsets = np.zeros(n + 1, dtype=np.int32)
    np.add.at(offsets, u + 1, 1)
    offsets = np.cumsum(offsets, dtype=np.int64).astype(np.int32)
    return CSRGraph(
        offsets=jnp.asarray(offsets),
        nbrs=jnp.asarray(v.astype(np.int32)),
        wgts=jnp.asarray(w),
        edge_u=jnp.asarray(u.astype(np.int32)),
        n=int(n),
        m2=int(len(u)),
    )


def to_dense(g: CSRGraph, closed: bool = False, weighted: bool = True) -> jax.Array:
    """Dense adjacency float32[n, n]. ``closed`` adds the identity (w=1)."""
    a = jnp.zeros((g.n, g.n), dtype=jnp.float32)
    vals = g.wgts if weighted else jnp.ones_like(g.wgts)
    a = a.at[g.edge_u, g.nbrs].set(vals)
    if closed:
        a = a + jnp.eye(g.n, dtype=jnp.float32)
    return a


def edge_endpoints(g: CSRGraph) -> Tuple[jax.Array, jax.Array]:
    """(u, v) int32[m2] arrays of half-edge endpoints."""
    return g.edge_u, g.nbrs


def undirected_edge_mask(g: CSRGraph) -> jax.Array:
    """bool[m2], true for the canonical (u < v) copy of each edge."""
    return g.edge_u < g.nbrs


def random_graph(
    n: int,
    avg_degree: float,
    *,
    seed: int = 0,
    weighted: bool = False,
    planted_clusters: int = 0,
    p_in_over_p_out: float = 8.0,
) -> CSRGraph:
    """Synthetic test graphs (host-side numpy).

    ``planted_clusters > 0`` draws a planted-partition graph (useful for
    quality metrics — SCAN should recover the blocks); otherwise G(n, p).
    """
    rng = np.random.default_rng(seed)
    target_m = int(n * avg_degree / 2)
    if planted_clusters > 1:
        labels = rng.integers(0, planted_clusters, size=n)
        # sample within/between edges with ratio p_in_over_p_out
        frac_in = p_in_over_p_out / (p_in_over_p_out + 1.0)
        m_in = int(target_m * frac_in)
        m_out = target_m - m_in
        edges = []
        # within-cluster edges
        for _ in range(4):  # oversample, dedup later
            u = rng.integers(0, n, size=2 * m_in)
            shift = rng.integers(1, max(2, n // planted_clusters), size=2 * m_in)
            order = np.argsort(labels, kind="stable")
            pos = np.searchsorted(labels[order], labels[u])
            cnt = np.bincount(labels, minlength=planted_clusters)
            v = order[(pos + shift % np.maximum(cnt[labels[u]], 1))]
            ok = labels[v] == labels[u]
            edges.append(np.stack([u[ok], v[ok]], axis=1))
        e_in = np.concatenate(edges)[: 2 * m_in]
        u = rng.integers(0, n, size=2 * m_out)
        v = rng.integers(0, n, size=2 * m_out)
        e_out = np.stack([u, v], axis=1)
        e = np.concatenate([e_in, e_out])
    else:
        u = rng.integers(0, n, size=3 * target_m)
        v = rng.integers(0, n, size=3 * target_m)
        e = np.stack([u, v], axis=1)
    e = e[e[:, 0] != e[:, 1]][: 2 * target_m]
    w = rng.uniform(0.1, 1.0, size=len(e)).astype(np.float32) if weighted else None
    return from_edge_list(n, e, w)


def power_law_graph(
    n: int,
    alpha: float = 2.1,
    *,
    avg_degree: float = 8.0,
    seed: int = 0,
    weighted: bool = False,
    hub_degree: int = 0,
) -> CSRGraph:
    """Chung–Lu-style power-law graph (host-side numpy).

    Vertex attachment weights follow w_i ∝ (i+1)^(-1/(α-1)) — the expected
    degree sequence of a power-law graph with exponent α — and edge
    endpoints are drawn ∝ w. ``hub_degree > 0`` additionally wires vertex 0
    to that many distinct random vertices, forcing one hub with
    deg ≫ median (the skew case the degree-bucketed similarity engine
    exists for; a dense-padded layout would pay O(n·hub_degree)).
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n + 1, dtype=np.float64)
    w = ranks ** (-1.0 / (alpha - 1.0))
    p = w / w.sum()
    target_m = int(n * avg_degree / 2)
    u = rng.choice(n, size=3 * target_m, p=p)
    v = rng.choice(n, size=3 * target_m, p=p)
    e = np.stack([u, v], axis=1)
    e = e[e[:, 0] != e[:, 1]][: 2 * target_m]
    if hub_degree > 0:
        others = rng.permutation(np.arange(1, n))[: min(hub_degree, n - 1)]
        hub_e = np.stack([np.zeros(len(others), np.int64), others], axis=1)
        e = np.concatenate([e, hub_e])
    wgt = (rng.uniform(0.1, 1.0, size=len(e)).astype(np.float32)
           if weighted else None)
    return from_edge_list(n, e, wgt)


def hub_ring_graph(
    n: int,
    hub_degree: int,
    *,
    seed: int = 0,
    weighted: bool = False,
) -> CSRGraph:
    """Star-with-ring: vertex 0 is a hub wired to ``hub_degree`` spokes,
    all other vertices form a ring (so every non-hub degree is 2–3 while
    the hub dominates — the adversarial case for any global-width padded
    layout: Δ = hub_degree, median degree ≈ 2).
    """
    rng = np.random.default_rng(seed)
    ring = np.stack([np.arange(1, n), np.concatenate(
        [np.arange(2, n), [1]])], axis=1)
    spokes = rng.permutation(np.arange(1, n))[: min(hub_degree, n - 1)]
    star = np.stack([np.zeros(len(spokes), np.int64), spokes], axis=1)
    e = np.concatenate([ring, star])
    w = (rng.uniform(0.1, 1.0, size=len(e)).astype(np.float32)
         if weighted else None)
    return from_edge_list(n, e, w)


def graph_from_dense(a: np.ndarray, weighted: bool = True) -> CSRGraph:
    """Build from a dense symmetric adjacency (testing convenience)."""
    a = np.asarray(a)
    n = a.shape[0]
    iu, iv = np.nonzero(np.triu(a, k=1))
    w = a[iu, iv].astype(np.float32) if weighted else None
    return from_edge_list(n, np.stack([iu, iv], axis=1), w)
