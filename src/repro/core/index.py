"""SCAN index construction (paper §4.1, Algorithms 1–2).

The index is the GS*-Index pair (neighbor order NO, core order CO), stored
as flat segmented arrays (all O(m)):

Neighbor order — the *closed* adjacency (each row = v plus its neighbors,
σ(v,v)=1) sorted within each row by descending σ. Built with **one global
sort** over all m2+n slots keyed by (row, -σ, ¬self, nbr) — exactly the
paper's "prepend v to every entry and sort everything once" integer-sort
trick (§4.1.2), mapped onto XLA's parallel sort.

Core order — for every (v, μ) with 2 ≤ μ ≤ |N̄(v)| the core threshold
θ(v, μ) is *already* the μ-th entry of NO[v], so CO is nothing more than a
re-sort of the NO slots by (μ, -θ, v): one more global sort, Σ(|N̄(v)|−1) =
2m entries, O(m) space — the same bound as GS*-Index.

Construction is host-orchestrated (graph building, padding, chunk loops)
around jit-compiled kernels; every array op is a bulk-parallel primitive
(sort / gather / scatter / segment ops) with O(log) span.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph
from repro.core import similarity as sim_mod
from repro.core import lsh as lsh_mod


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ScanIndex:
    """GS*-Index analogue. All arrays live on device; n/m2c/max_cdeg static."""

    # --- closed CSR (rows include the self slot) ---
    offsets_c: jax.Array    # int32[n+1]  row starts (offsets[v] + v)
    # --- neighbor order (σ-descending within each row) ---
    no_nbrs: jax.Array      # int32[m2c]
    no_sims: jax.Array      # float32[m2c]
    no_self: jax.Array      # bool[m2c]   marks the self slot
    # --- core order (μ-major, θ-descending segments) ---
    co_offsets: jax.Array   # int32[max_cdeg+2]  segment start per μ (CO[μ])
    co_vertex: jax.Array    # int32[m2]
    co_theta: jax.Array     # float32[m2]
    # --- misc ---
    cdeg: jax.Array         # int32[n] closed degrees
    edge_sims: jax.Array    # float32[m2] σ per original half-edge (graph order)
    n: int = dataclasses.field(metadata=dict(static=True))
    m2c: int = dataclasses.field(metadata=dict(static=True))
    max_cdeg: int = dataclasses.field(metadata=dict(static=True))

    def core_threshold(self, mu: jax.Array) -> jax.Array:
        """θ(v, μ) for all v, float32[n]; -inf where |N̄(v)| < μ."""
        slot = self.offsets_c[:-1] + (mu - 1)
        valid = self.cdeg >= mu
        theta = self.no_sims[jnp.clip(slot, 0, self.m2c - 1)]
        return jnp.where(valid, theta, -jnp.inf)


@jax.jit
def _build_orders(offsets, edge_u, nbrs, sims, n_arr):
    """Global sorts for NO and CO. n_arr = jnp.arange(n)."""
    n = n_arr.shape[0]
    # ---- closed slot arrays: n self slots + m2 edge slots ----
    rows = jnp.concatenate([n_arr, edge_u])
    nbrs_c = jnp.concatenate([n_arr, nbrs])
    sims_c = jnp.concatenate([jnp.ones((n,), jnp.float32), sims])
    not_self = jnp.concatenate(
        [jnp.zeros((n,), jnp.int32), jnp.ones((edge_u.shape[0],), jnp.int32)]
    )
    # one global sort: row asc, σ desc, self first, nbr asc
    perm = jnp.lexsort((nbrs_c, not_self, -sims_c, rows))
    no_nbrs = nbrs_c[perm]
    no_sims = sims_c[perm]
    no_self = not_self[perm] == 0
    rows_sorted = rows[perm]

    cdeg = jnp.diff(offsets) + 1
    offsets_c = offsets + jnp.arange(n + 1, dtype=offsets.dtype)

    # ---- core order: every slot with position μ ≥ 2 inside its row ----
    m2c = no_nbrs.shape[0]
    mu_of_slot = jnp.arange(m2c, dtype=jnp.int32) - offsets_c[rows_sorted] + 1
    is_co = mu_of_slot >= 2
    # key sort: μ asc, θ desc, v asc; inactive slots pushed to the end
    mu_key = jnp.where(is_co, mu_of_slot, jnp.int32(2**30))
    perm2 = jnp.lexsort((rows_sorted, -no_sims, mu_key))
    co_vertex = rows_sorted[perm2][: m2c - n]
    co_theta = no_sims[perm2][: m2c - n]
    co_mu = mu_key[perm2][: m2c - n]
    return (offsets_c, no_nbrs, no_sims, no_self, cdeg, co_vertex, co_theta, co_mu)


def build_index(
    g: CSRGraph,
    measure: str = "cosine",
    *,
    approx: Optional[str] = None,     # None | "simhash" | "minhash" | "kpartition"
    samples: int = 64,
    key: Optional[jax.Array] = None,
    degree_heuristic: bool = True,
    sims: Optional[jax.Array] = None,  # precomputed σ override (testing)
) -> ScanIndex:
    """Construct the SCAN index (exact or LSH-approximate similarities)."""
    if sims is None:
        if approx is None:
            sims = sim_mod.compute_similarities(g, measure)
        else:
            sims = lsh_mod.approximate_similarities(
                g,
                measure=measure,
                method=approx,
                samples=samples,
                key=key if key is not None else jax.random.PRNGKey(0),
                degree_heuristic=degree_heuristic,
            )
    sims = jnp.clip(sims.astype(jnp.float32), 0.0, 1.0)

    n_arr = jnp.arange(g.n, dtype=jnp.int32)
    (offsets_c, no_nbrs, no_sims, no_self, cdeg, co_vertex, co_theta, co_mu) = (
        _build_orders(g.offsets, g.edge_u, g.nbrs, sims, n_arr)
    )
    max_cdeg = int(np.asarray(cdeg).max()) if g.n else 1
    # segment starts per μ; CO[μ] = co_vertex[co_offsets[μ] : co_offsets[μ+1]]
    counts = np.bincount(np.asarray(co_mu), minlength=max_cdeg + 1)
    co_offsets = jnp.concatenate(
        [
            jnp.zeros((1,), jnp.int32),
            jnp.asarray(np.cumsum(counts), dtype=jnp.int32),
        ]
    )
    return ScanIndex(
        offsets_c=offsets_c,
        no_nbrs=no_nbrs,
        no_sims=no_sims,
        no_self=no_self,
        co_offsets=co_offsets,
        co_vertex=co_vertex,
        co_theta=co_theta,
        cdeg=cdeg,
        edge_sims=sims,
        n=g.n,
        m2c=g.m2 + g.n,
        max_cdeg=max_cdeg,
    )


def co_core_prefix(index: ScanIndex, mu, eps) -> Tuple[jax.Array, jax.Array]:
    """(lo, end): the CO[μ] slot range [lo, end) holding every core for
    (μ, ε), found in **O(log m)** per query.

    The CO slot arrays are globally sorted by the packed key (μ asc,
    −θ asc, v asc); ``co_offsets`` resolves the μ component exactly, so the
    prefix boundary is a searchsorted for −ε over the −θ component inside
    [lo, hi) — implemented as a branchless traced-bound binary search
    (``jnp.searchsorted`` cannot take traced slice bounds). This replaces
    the old masked arange-argmax, which scanned all m2 CO slots per query.
    """
    mu = jnp.asarray(mu, jnp.int32)
    eps = jnp.asarray(eps, jnp.float32)
    lo = index.co_offsets[jnp.clip(mu, 0, index.max_cdeg)].astype(jnp.int32)
    hi = index.co_offsets[jnp.clip(mu + 1, 0, index.max_cdeg + 1)].astype(
        jnp.int32)
    m_co = index.co_theta.shape[0]
    if m_co == 0:                       # edgeless graph: CO is empty
        return lo, lo

    def body(_, lohi):
        lo_, hi_ = lohi
        mid = (lo_ + hi_) // 2
        theta = index.co_theta[jnp.clip(mid, 0, max(m_co - 1, 0))]
        keep_hi = (mid < hi_) & (theta >= eps)     # mid in the θ ≥ ε prefix
        return (jnp.where(keep_hi, mid + 1, lo_), jnp.where(keep_hi, hi_, mid))

    steps = max(int(m_co).bit_length(), 1)
    lo_f, _ = jax.lax.fori_loop(0, steps, body, (lo, hi))
    return lo, lo_f


def get_cores(index: ScanIndex, mu: int, eps: float) -> jax.Array:
    """bool[n] core mask via the CO[μ] prefix (paper Algorithm 3).

    CO[μ] is θ-descending, so cores are the prefix with θ ≥ ε — the
    boundary comes from :func:`co_core_prefix`'s O(log m) packed-key
    search; scattering the prefix slots to a vertex mask is O(m2)
    elementwise work with no reductions (the old path burned three full
    masked reductions — any/argmax — per query, per vmap lane).
    """
    mu = jnp.asarray(mu, jnp.int32)
    eps = jnp.asarray(eps, jnp.float32)
    lo, first_below = co_core_prefix(index, mu, eps)
    idx = jnp.arange(index.co_vertex.shape[0], dtype=jnp.int32)
    core_slots = (idx >= lo) & (idx < first_below)
    mask = (
        jnp.zeros((index.n,), jnp.int32)
        .at[index.co_vertex]
        .max(core_slots.astype(jnp.int32), mode="drop")
    ) > 0
    valid_mu = (mu >= 2) & (mu <= index.max_cdeg)
    return mask & valid_mu
