"""Seed-set (local) clustering queries (ROADMAP "Local queries" item).

Per-user traffic asks "what is *my* community at (μ, ε)?" — answering it
with the global ``query()`` materializes all n labels to read one row.
Following the local-graph-clustering framing (Shun et al., PAPERS.md),
the GS*-Index already supports output-sensitive resolution: core
membership is one θ-slot probe per vertex (the ``co_core_prefix``
boundary expressed point-wise — v is a (μ, ε)-core iff the μ-th entry
of NO[v], i.e. ``core_threshold(v, μ)``, is ≥ ε), and the seed's
cluster is the connected component of its attachment core in the
ε-similar core–core graph, reachable by frontier expansion over NO row
prefixes. Work scales with the *output cluster*, not with n.

Shapes are serve-grade static: the expansion is a ``lax.while_loop``
over a pow2-capacity frontier (``frontier_cap``), each iteration
gathering a fixed ``window`` of every frontier row's ε-prefix (NO rows
are σ-descending, so the prefix is contiguous) and folding new cores in
with one sort-based set union — so thousands of concurrent seed
requests vmap into a single compiled artifact per
(frontier_cap, window, border_cap) triple.

Spill-to-full-query fallback: anything that outgrows the caps — a
cluster with more cores than ``frontier_cap``, an ε-prefix longer than
``window`` (only when the entry just past the window is still ε-similar;
a short row is not a spill), more candidate borders than ``border_cap``,
or a border row whose attachment is undecided inside its window — sets
the lane's ``spilled`` flag, and the host wrapper re-answers exactly
those lanes through the full ``query_batch``, padded to a fixed
``fallback_batch`` lane count so the fallback reuses one artifact too.
Either way every answer is **bit-identical** to extracting the seed's
row from the full ``query()`` output — the serve-layer seed cache and
the oracle tests depend on this.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph
from repro.core.index import ScanIndex
from repro.core.query import query_batch

DEFAULT_FRONTIER_CAP = 128   # member/frontier slots per lane (pow2)
DEFAULT_WINDOW = 32          # NO-row ε-prefix entries gathered per vertex
DEFAULT_BORDER_CAP = 512     # candidate-border slots per lane (pow2)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SeedBatchResult:
    """Answers for a batch of (seed, μ, ε) lanes."""

    labels: jax.Array       # int32[B]   seed's cluster label; -1 unclustered
    is_core: jax.Array      # bool[B]    seed is a (μ, ε)-core
    member_mask: jax.Array  # bool[B, n] membership of the seed's cluster
    n_members: jax.Array    # int32[B]   popcount of each member_mask row
    spilled: jax.Array      # bool[B]    lane exceeded a static cap


@dataclasses.dataclass(frozen=True)
class SeedResult:
    """One seed's answer, host-side — the unit the serve cache stores."""

    seed: int
    label: int
    is_core: bool
    member_mask: np.ndarray  # bool[n]

    @property
    def n_members(self) -> int:
        return int(self.member_mask.sum())

    @property
    def members(self) -> np.ndarray:
        return np.flatnonzero(self.member_mask)

    @staticmethod
    def from_batch_row(res: SeedBatchResult, i: int, seed: int
                       ) -> "SeedResult":
        # copy: a row view would pin the whole [B, n] batch array in the
        # cache for as long as the entry lives
        return SeedResult(seed=int(seed), label=int(res.labels[i]),
                          is_core=bool(res.is_core[i]),
                          member_mask=np.array(res.member_mask[i]))


def _is_core(index: ScanIndex, v, mu, eps):
    """(μ, ε)-core test for vertex ids ``v`` (any shape; out-of-range ids
    test non-core). One θ-slot probe per vertex: NO rows are σ-descending
    with the self slot pinned first, so the μ-th row entry *is* the core
    threshold θ(v, μ) — the same boundary ``co_core_prefix`` binary-
    searches globally, evaluated point-wise. Bit-identical to
    ``get_cores(index, mu, eps)[v]`` (μ > max_cdeg ⇒ cdeg < μ for all v,
    so the ``valid_mu`` clamp there is implied by the cdeg guard here)."""
    vc = jnp.clip(v, 0, index.n - 1)
    slot = index.offsets_c[vc].astype(jnp.int32) + (mu - 1)
    theta = index.no_sims[jnp.clip(slot, 0, index.m2c - 1)]
    ok = (v >= 0) & (v < index.n) & (mu >= 2) & (index.cdeg[vc] >= mu)
    return ok & (theta >= eps)


def _row_windows(index: ScanIndex, vs, mu, eps, window: int):
    """ε-prefix windows: positions 1..window of each NO row (position 0
    is always the self slot — σ(v,v)=1 sorts first). Returns
    (nbrs int32[K, W], keep bool[K, W], spill bool[K]); ``spill`` marks
    rows whose ε-prefix continues past the window — the only case the
    gather under-reports."""
    n = index.n
    vc = jnp.clip(vs, 0, n - 1)
    base = index.offsets_c[vc].astype(jnp.int32)
    width = index.cdeg[vc]
    valid = (vs >= 0) & (vs < n)
    pos = jnp.arange(1, window + 1, dtype=jnp.int32)
    slot = jnp.clip(base[:, None] + pos[None, :], 0, index.m2c - 1)
    nbrs = index.no_nbrs[slot]
    keep = (valid[:, None] & (pos[None, :] < width[:, None])
            & (index.no_sims[slot] >= eps))
    over = jnp.clip(base + jnp.int32(window + 1), 0, index.m2c - 1)
    spill = valid & (width > window + 1) & (index.no_sims[over] >= eps)
    return nbrs, keep, spill


def _compact(ids, mask, cap: int, sentinel):
    """Scatter the ``mask``-selected (ascending) ids into a fixed ``cap``
    slots, sentinel-padded; selections past ``cap`` drop (caller flags
    the overflow as a spill)."""
    pos = jnp.cumsum(mask) - 1
    idx = jnp.where(mask & (pos < cap), pos, cap)
    return (jnp.full((cap,), sentinel, jnp.int32)
            .at[idx].set(jnp.where(mask, ids, sentinel), mode="drop"))


def _seed_lane(index: ScanIndex, seed, mu, eps,
               fcap: int, window: int, bcap: int):
    """One (seed, μ, ε) lane; returns (label, is_core, member_mask,
    n_members, spilled). Designed as a fixed point once converged, so
    vmap's run-until-all-done semantics for ``while_loop`` are safe."""
    n = index.n
    sentinel = jnp.int32(n)
    seed_core = _is_core(index, seed, mu, eps)

    # Non-core seed: its cluster (if any) is the component of its
    # attachment core — the *first* core in the row's ε-prefix. The NO
    # tie order (σ desc, then neighbor id asc) makes "first" exactly the
    # full query's deterministic border rule: max σ, ties to the lower
    # core id.
    nb0, ok0, sp0 = _row_windows(index, seed[None], mu, eps, window)
    cand0 = ok0[0] & _is_core(index, nb0[0], mu, eps)
    has_attach = jnp.any(cand0)
    attach = jnp.where(has_attach, nb0[0, jnp.argmax(cand0)], sentinel)
    # no core inside the window but the ε-prefix continues: the true
    # attachment may lie beyond the window — undecidable without spill
    spill0 = (~seed_core) & (~has_attach) & sp0[0]

    start = jnp.where(seed_core, jnp.asarray(seed, jnp.int32), attach)
    members0 = jnp.full((fcap,), sentinel, jnp.int32).at[0].set(start)

    def cond(state):
        _, _, spill, n_new, it = state
        # each growing iteration adds ≥ 1 member, so fcap + 2 bounds the
        # loop even without the n_new test (belt and braces)
        return (n_new > 0) & (~spill) & (it < fcap + 2)

    def body(state):
        members, frontier, spill, _, it = state
        nb, keep, sp = _row_windows(index, frontier, mu, eps, window)
        keep = keep & _is_core(index, nb, mu, eps)
        spill = spill | jnp.any(sp)
        cand = jnp.where(keep, nb, sentinel).reshape(-1)
        # sort-based set union on packed (id, origin) keys: a member of
        # equal id sorts before a candidate, so a candidate surviving
        # first-occurrence filtering is genuinely new. int32 pack needs
        # 2·(n+1) ≤ 2³¹ — the wrapper enforces n < 2³⁰.
        key = jnp.sort(jnp.concatenate([members * 2, cand * 2 + 1]))
        ids = key >> 1
        fresh = (key & 1) == 1
        first = jnp.concatenate([jnp.ones((1,), bool), ids[1:] != ids[:-1]])
        first = first & (ids < n)
        new = first & fresh
        n_new = jnp.sum(new).astype(jnp.int32)
        spill = spill | (jnp.sum(first) > fcap)
        return (_compact(ids, first, fcap, sentinel),
                _compact(ids, new, fcap, sentinel),
                spill, n_new, it + 1)

    n_new0 = jnp.where(start < n, 1, 0).astype(jnp.int32)
    members, _, spill, _, _ = jax.lax.while_loop(
        cond, body, (members0, members0, spill0, n_new0, jnp.int32(0)))
    label = jnp.where(members[0] < n, members[0], jnp.int32(-1))

    # ---- border pass: non-core ε-neighbors of the member cores ----
    nb, keep, sp = _row_windows(index, members, mu, eps, window)
    spill = spill | jnp.any(sp)
    bc = jnp.where(keep & ~_is_core(index, nb, mu, eps), nb, sentinel)
    bs = jnp.sort(bc.reshape(-1))
    bfirst = jnp.concatenate([jnp.ones((1,), bool), bs[1:] != bs[:-1]])
    bfirst = bfirst & (bs < n)
    spill = spill | (jnp.sum(bfirst) > bcap)
    borders = _compact(bs, bfirst, bcap, sentinel)
    # each candidate attaches to the first core of its *own* ε-prefix;
    # it joins this cluster only if that core is one of the members
    bnb, bok, bsp = _row_windows(index, borders, mu, eps, window)
    bcore = bok & _is_core(index, bnb, mu, eps)
    bhas = jnp.any(bcore, axis=1)
    battach = jnp.where(
        bhas, bnb[jnp.arange(bcap), jnp.argmax(bcore, axis=1)], sentinel)
    # a border row with no core inside its window whose ε-prefix
    # continues is undecided about its attachment
    spill = spill | jnp.any((~bhas) & bsp)
    pos = jnp.searchsorted(members, battach)  # members sorted ascending
    inside = (members[jnp.clip(pos, 0, fcap - 1)] == battach) & (battach < n)
    accepted = jnp.where(bhas & inside, borders, sentinel)

    mask = jnp.zeros((n + 1,), bool)          # slot n absorbs sentinels
    mask = mask.at[members].set(True).at[accepted].set(True)[:n]
    return (label, seed_core, mask,
            jnp.sum(mask).astype(jnp.int32), spill)


@functools.partial(
    jax.jit, static_argnames=("frontier_cap", "window", "border_cap"))
def query_seeds_device(index: ScanIndex, g: CSRGraph, seeds, mus, epss, *,
                       frontier_cap: int = DEFAULT_FRONTIER_CAP,
                       window: int = DEFAULT_WINDOW,
                       border_cap: int = DEFAULT_BORDER_CAP
                       ) -> SeedBatchResult:
    """Device half of :func:`query_seeds`: one fixed-shape vmapped call,
    no host fallback — spilled lanes come back flagged, not resolved.
    ``g`` rides along for signature parity with the full query path (the
    kernel reads only the index arrays)."""
    del g
    seeds = jnp.atleast_1d(jnp.asarray(seeds, jnp.int32))
    mus = jnp.broadcast_to(jnp.asarray(mus, jnp.int32), seeds.shape)
    epss = jnp.broadcast_to(jnp.asarray(epss, jnp.float32), seeds.shape)
    label, core, mask, n_mem, spill = jax.vmap(
        lambda s, m, e: _seed_lane(index, s, m, e,
                                   frontier_cap, window, border_cap)
    )(seeds, mus, epss)
    return SeedBatchResult(labels=label, is_core=core, member_mask=mask,
                           n_members=n_mem, spilled=spill)


def _pow2(k: int) -> int:
    return 1 << max(k - 1, 0).bit_length()


def query_seeds(index: ScanIndex, g: CSRGraph, seeds, mu, eps, *,
                frontier_cap: int = DEFAULT_FRONTIER_CAP,
                window: int = DEFAULT_WINDOW,
                border_cap: int = DEFAULT_BORDER_CAP,
                fallback_batch: int | None = None) -> SeedBatchResult:
    """Per-seed cluster queries; host-side numpy results.

    ``mu`` / ``eps`` may be scalars (applied to every seed) or per-seed
    arrays. Lanes that exceed a static cap are transparently re-answered
    through the full ``query_batch``, padded to ``fallback_batch`` lanes
    (default: the spill count rounded up to a pow2) so repeated spills
    share one compiled artifact. Every row — expanded or fallen back —
    is bit-identical to extracting the seed's row from the full
    ``query()`` output: label, core flag, and the membership mask
    ``labels == labels[seed]`` (all-False when the seed is unclustered).
    """
    for name, cap in (("frontier_cap", frontier_cap), ("window", window),
                      ("border_cap", border_cap)):
        if cap < 1 or cap & (cap - 1):
            raise ValueError(f"{name} must be a power of two, got {cap}")
    if index.n >= 1 << 30:
        raise ValueError("seed kernel packs (id, origin) into int32 "
                         "keys: n must be < 2**30")
    seeds = np.atleast_1d(np.asarray(seeds, np.int32))
    if seeds.size == 0:
        return SeedBatchResult(
            labels=np.zeros(0, np.int32), is_core=np.zeros(0, bool),
            member_mask=np.zeros((0, index.n), bool),
            n_members=np.zeros(0, np.int32), spilled=np.zeros(0, bool))
    if int(seeds.min()) < 0 or int(seeds.max()) >= index.n:
        raise ValueError("seed vertex id out of range")
    b = seeds.shape[0]
    mus = np.broadcast_to(np.asarray(mu, np.int32), (b,))
    epss = np.broadcast_to(np.asarray(eps, np.float32), (b,))
    res = query_seeds_device(index, g, seeds, mus, epss,
                             frontier_cap=frontier_cap, window=window,
                             border_cap=border_cap)
    labels = np.asarray(res.labels).copy()
    is_core = np.asarray(res.is_core).copy()
    mask = np.asarray(res.member_mask).copy()
    n_mem = np.asarray(res.n_members).copy()
    spilled = np.asarray(res.spilled).copy()
    rows = np.flatnonzero(spilled)
    if len(rows):
        pad = int(fallback_batch) if fallback_batch else _pow2(len(rows))
        for lo in range(0, len(rows), pad):
            chunk = rows[lo:lo + pad]
            cm = np.full(pad, mus[chunk[0]], np.int32)
            ce = np.full(pad, epss[chunk[0]], np.float32)
            cm[:len(chunk)] = mus[chunk]
            ce[:len(chunk)] = epss[chunk]
            full = query_batch(index, g, cm, ce)
            flab = np.asarray(full.labels)
            fcore = np.asarray(full.is_core)
            for i, r in enumerate(chunk):
                s = int(seeds[r])
                lab = int(flab[i, s])
                labels[r] = lab
                is_core[r] = bool(fcore[i, s])
                row = (flab[i] == lab) if lab >= 0 \
                    else np.zeros(index.n, bool)
                mask[r] = row
                n_mem[r] = int(row.sum())
    return SeedBatchResult(labels=labels, is_core=is_core,
                           member_mask=mask, n_members=n_mem,
                           spilled=spilled)
