"""LSH-approximate similarities (paper §5, §6.3).

* SimHash (cosine, weighted or unweighted): sketch(v) = sign(N̄_w(v) · R),
  R ∈ ℝ^{n×k} i.i.d. N(0,1). The kn dot products are one (sparse) matmul —
  on TPU this is the Pallas ``simhash`` kernel's blocked MXU matmul; here the
  sparse gather/segment-sum form is used. Bits are packed into uint32 lanes;
  per-edge comparison is XOR + popcount (``lax.population_count``), the
  Pallas ``hamming`` kernel's job on TPU.
  Estimate: θ̂ = π·(#differing bits)/k, σ̂ = cos(θ̂)  — Theorem 5.2 applies.

* MinHash (Jaccard, unweighted): k independent universal hashes
  h_i(x) = (aᵢ·x + bᵢ) mod p; sketch(v)ᵢ = min_{x∈N̄(v)} hᵢ(x).
  Estimate: fraction of matching coordinates — Theorem 5.3 applies.

* k-partition MinHash / one-permutation hashing (fast path, §6.3): a single
  permutation π, k buckets, per-bucket min of π over N̄(v); empty buckets
  densified by circular borrowing (rotation). No tail bound (paper says the
  same), lower variance in practice.

Degree heuristic (§6.3): approximate only edges whose *both* endpoints have
closed degree above a threshold (k for cosine, 3k/2 for Jaccard); all other
edges get exact similarities, computed only on that compacted subset via
the degree-bucketed engine (:class:`repro.core.similarity.SimilarityPlan`).
The heuristic and the bucketed layout compose naturally: every exact edge
has a low-degree endpoint, so its probe routes to a small degree class —
the exact pass never touches a hub-width kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph
from repro.core import similarity as sim_mod


# --------------------------------------------------------------------------
# SimHash
# --------------------------------------------------------------------------
def simhash_sketches(g: CSRGraph, samples: int, key: jax.Array,
                     *, chunk: int = 512) -> jax.Array:
    """Packed sketches uint32[n, ceil(k/32)] of closed weighted neighborhoods.

    ``chunk`` bounds the (n, chunk) gaussian working set; it is a *memory*
    knob only. Each 32-sample word derives its projections from
    ``fold_in(key, word_index)``, so the sketch bits — and therefore σ̂ and
    every downstream index fingerprint — are invariant to the chunking.
    (The old per-chunk ``fold_in(key, w0)`` keyed the randomness on the
    chunk boundary itself: changing the chunk width silently changed every
    sketch.)
    """
    if chunk % 32 != 0 or chunk <= 0:
        raise ValueError(f"chunk must be a positive multiple of 32: {chunk}")
    k_pad = (samples + 31) // 32 * 32
    words = []
    for w0 in range(0, k_pad, chunk):  # chunk the sample axis to bound memory
        kw = min(chunk, k_pad - w0)
        words.append(_simhash_chunk(g.edge_u, g.nbrs, g.wgts, key,
                                    w0 // 32, g.n, kw, samples - w0))
    return jnp.concatenate(words, axis=1)


@functools.partial(jax.jit, static_argnames=("word0", "n", "kw", "valid"))
def _simhash_chunk(edge_u, nbrs, wgts, key, word0, n, kw, valid):
    # one fold_in per 32-sample word: bit w's projection column depends only
    # on (key, w // 32), never on which chunk it was generated in
    word_keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        key, word0 + jnp.arange(kw // 32))
    r = jax.vmap(lambda k: jax.random.normal(k, (n, 32), dtype=jnp.float32),
                 out_axes=1)(word_keys)                  # [n, kw/32, 32]
    r = r.reshape(n, kw)
    if valid < kw:  # zero out padding samples → identical bits on both sides
        r = r * (jnp.arange(kw) < valid)
    s = r + jax.ops.segment_sum(wgts[:, None] * r[nbrs], edge_u, num_segments=n)
    bits = (s >= 0.0) & (jnp.arange(kw) < max(valid, 0))
    bits = bits.reshape(n, kw // 32, 32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, None, :]
    return jnp.sum(bits.astype(jnp.uint32) * weights, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("samples",))
def simhash_edge_similarity(
    sketches: jax.Array, eu: jax.Array, ev: jax.Array, samples: int
) -> jax.Array:
    """cos(π·hamming/k) per edge from packed sketches."""
    x = jnp.bitwise_xor(sketches[eu], sketches[ev])
    diff = jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.float32)
    theta = jnp.pi * diff / samples
    return jnp.cos(theta)


# --------------------------------------------------------------------------
# standard MinHash — k independent uniformly random permutations (§2.1.2)
# --------------------------------------------------------------------------
def minhash_sketches(g: CSRGraph, samples: int, key: jax.Array,
                     *, chunk: int = 64) -> jax.Array:
    """Sketches int32[n, k]: sketch(v)ᵢ = min_{x∈N̄(v)} πᵢ(x).

    Permutation i is keyed by ``fold_in(key, i)`` — chunking (the memory
    knob) never changes the sketch, mirroring ``simhash_sketches``.
    """
    if chunk <= 0:
        raise ValueError(f"chunk must be positive: {chunk}")
    out = []
    for s0 in range(0, samples, chunk):  # chunk the sample axis
        kc = min(chunk, samples - s0)
        keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            key, s0 + jnp.arange(kc))
        out.append(_minhash_chunk(g.edge_u, g.nbrs, keys, g.n))
    return jnp.concatenate(out, axis=1)


@functools.partial(jax.jit, static_argnames=("n",))
def _minhash_chunk(edge_u, nbrs, keys, n):
    perms = jax.vmap(lambda k: jax.random.permutation(k, n))(keys)  # [kc, n]
    perms = perms.astype(jnp.int32).T                               # [n, kc]
    big = jnp.int32(np.iinfo(np.int32).max)
    mins = (
        jnp.full((n, perms.shape[1]), big, dtype=jnp.int32)
        .at[edge_u]
        .min(perms[nbrs])
    )
    return jnp.minimum(mins, perms)


@jax.jit
def minhash_edge_similarity(sketches, eu, ev):
    return jnp.mean(sketches[eu] == sketches[ev], axis=-1).astype(jnp.float32)


# --------------------------------------------------------------------------
# k-partition MinHash (one-permutation hashing + rotation densification)
# --------------------------------------------------------------------------
def kpartition_sketches(g: CSRGraph, samples: int, key: jax.Array) -> jax.Array:
    perm = jax.random.permutation(key, g.n).astype(jnp.int32)
    return _kpartition_build(g.edge_u, g.nbrs, perm, g.n, samples)


@functools.partial(jax.jit, static_argnames=("n", "k"))
def _kpartition_build(edge_u, nbrs, perm, n, k):
    big = jnp.int32(np.iinfo(np.int32).max)

    def bucket_val(x):
        px = perm[x]
        # (px * k) // n in int32 — requires n·k < 2^31 (documented constraint)
        return (px * jnp.int32(k)) // jnp.int32(n), px

    bk_n, val_n = bucket_val(nbrs)
    bk_s, val_s = bucket_val(jnp.arange(n, dtype=jnp.int32))
    flat = jnp.full((n * k,), big)
    flat = flat.at[edge_u * k + bk_n].min(val_n)
    flat = flat.at[jnp.arange(n, dtype=jnp.int32) * k + bk_s].min(val_s)
    sk = flat.reshape(n, k)

    # rotation densification: an empty bin borrows from a non-empty bin to
    # its right (circular), offset by borrow distance so bins densified from
    # different distances never spuriously match. Doubling ⇒ log2(k) rounds.
    val = sk
    dist = jnp.where(sk == big, big, 0)
    t = 0
    while (1 << t) < k:
        s = 1 << t
        cand_val = jnp.roll(val, -s, axis=1)
        cand_dist = jnp.roll(dist, -s, axis=1)
        take = (val == big) & (cand_val != big)
        val = jnp.where(take, cand_val, val)
        dist = jnp.where(take, cand_dist + s, dist)
        t += 1
    # encode (value, borrow distance) as one int32; requires (n+1)·k < 2^31
    return val + jnp.int32(n + 1) * dist


@jax.jit
def kpartition_edge_similarity(sketches, eu, ev):
    return jnp.mean(sketches[eu] == sketches[ev], axis=-1).astype(jnp.float32)


# --------------------------------------------------------------------------
# combined approximate-σ entry point with the §6.3 degree heuristic
# --------------------------------------------------------------------------
def approximate_similarities(
    g: CSRGraph,
    *,
    measure: str = "cosine",
    method: str = "simhash",
    samples: int = 64,
    key: Optional[jax.Array] = None,
    degree_heuristic: bool = True,
    policy=None,
) -> jax.Array:
    """σ̂ per half-edge. Sketch *construction* is always the chunk-invariant
    sparse jnp path (its bits define the approximate fingerprint); the
    sketch *comparison* resolves its lane through the execution policy —
    the ``hamming`` op's Pallas lanes consume the exact same sketches and
    reproduce the ``ref`` comparison bit-for-bit on host backends (the
    XOR/popcount sum is integer-exact; the cos epilogue is the same
    elementwise expression), so lane choice never moves a fingerprint."""
    from repro.backend.policy import LANE_REF, default_policy

    pol = policy if policy is not None else default_policy()
    if key is None:
        key = jax.random.PRNGKey(0)
    if method == "simhash":
        if measure != "cosine":
            raise ValueError("simhash approximates cosine similarity")
        sk = simhash_sketches(g, samples, key)
        lane = pol.lane("hamming")
        if lane == LANE_REF:
            pol.note("hamming", lane)
            approx = simhash_edge_similarity(sk, g.edge_u, g.nbrs, samples)
        else:
            from repro.kernels import ops
            approx = ops.simhash_edge_similarity_kernel(
                sk, g.edge_u, g.nbrs, samples, policy=pol, lane=lane)
        thr = samples
    elif method in ("minhash", "kpartition"):
        if measure != "jaccard":
            raise ValueError("minhash approximates jaccard similarity")
        if method == "minhash":
            sk = minhash_sketches(g, samples, key)
            approx = minhash_edge_similarity(sk, g.edge_u, g.nbrs)
        else:
            sk = kpartition_sketches(g, samples, key)
            approx = kpartition_edge_similarity(sk, g.edge_u, g.nbrs)
        thr = (3 * samples) // 2
    else:
        raise ValueError(f"unknown LSH method {method!r}")

    if not degree_heuristic:
        return jnp.clip(approx, 0.0, 1.0)

    # §6.3: exact σ for edges where either endpoint is low-degree; the exact
    # pass runs only on the compacted subset (real work saving, not a mask)
    # through the bucketed plan — each exact edge probes its low-degree side,
    # so the subset routes to the small degree-class kernels only.
    cdeg = np.asarray(g.closed_degrees())
    eu_h, ev_h = np.asarray(g.edge_u), np.asarray(g.nbrs)
    high = cdeg > thr
    use_exact = ~(high[eu_h] & high[ev_h])
    idx = np.nonzero(use_exact)[0]
    if len(idx) == 0:
        return jnp.clip(approx, 0.0, 1.0)   # pure-LSH path: no plan needed
    exact_subset = sim_mod.plan_for(g).edge_sims(
        eu_h[idx],
        ev_h[idx],
        np.asarray(g.wgts)[idx],
        measure=measure,
        policy=pol,
    )
    out = np.asarray(approx, dtype=np.float32).copy()
    out[idx] = np.asarray(exact_subset)
    return jnp.clip(jnp.asarray(out), 0.0, 1.0)
