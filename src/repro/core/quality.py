"""Clustering quality measures (paper §7.2): modularity and adjusted Rand index.

Host-side numpy — these are evaluation metrics, not training-path compute.
Unclustered vertices (label < 0) are treated as singleton clusters, matching
the paper's §7.3.4 convention.
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import CSRGraph


def _canonical_labels(labels: np.ndarray) -> np.ndarray:
    """Map labels to [0, C); each unclustered vertex becomes its own cluster."""
    labels = np.asarray(labels).copy()
    n = len(labels)
    un = labels < 0
    labels[un] = n + np.arange(np.sum(un))
    _, out = np.unique(labels, return_inverse=True)
    return out


def modularity(g: CSRGraph, labels: np.ndarray, weighted: bool = False) -> float:
    """Newman modularity Q = Σ_c (e_c/m - (d_c/2m)²) (weighted form optional)."""
    labels = _canonical_labels(labels)
    eu = np.asarray(g.edge_u)
    ev = np.asarray(g.nbrs)
    w = np.asarray(g.wgts) if weighted else np.ones(g.m2, dtype=np.float64)
    two_m = float(w.sum())  # both half-edge copies ⇒ = 2m (or Σ2w)
    if two_m == 0:
        return 0.0
    c = int(labels.max()) + 1
    within = np.zeros(c)
    np.add.at(within, labels[eu], np.where(labels[eu] == labels[ev], w, 0.0))
    deg = np.zeros(c)
    np.add.at(deg, labels[eu], w)
    return float(np.sum(within / two_m - (deg / two_m) ** 2))


def core_precision_recall(approx_cores: np.ndarray,
                          exact_cores: np.ndarray) -> tuple:
    """(precision, recall) of an approximate core set against the exact one.

    The §5 guarantees are *classification* guarantees — an edge far from ε
    classifies identically under σ̂ — so the natural quality readout for an
    approximate index is how faithfully it reproduces the exact core set at
    each (μ, ε). Empty sets follow the usual convention: precision is 1.0
    when nothing was predicted, recall is 1.0 when nothing was there to
    find.
    """
    approx = np.asarray(approx_cores, dtype=bool)
    exact = np.asarray(exact_cores, dtype=bool)
    assert approx.shape == exact.shape
    tp = float(np.sum(approx & exact))
    n_approx = float(approx.sum())
    n_exact = float(exact.sum())
    precision = tp / n_approx if n_approx else 1.0
    recall = tp / n_exact if n_exact else 1.0
    return precision, recall


def adjusted_rand_index(labels_a: np.ndarray, labels_b: np.ndarray) -> float:
    """ARI between two clusterings (paper §7.2 formula)."""
    a = _canonical_labels(labels_a)
    b = _canonical_labels(labels_b)
    n = len(a)
    assert len(b) == n
    ca, cb = a.max() + 1, b.max() + 1
    cont = np.zeros((ca, cb), dtype=np.int64)
    np.add.at(cont, (a, b), 1)

    def comb2(x):
        x = np.asarray(x, dtype=np.float64)
        return x * (x - 1) / 2.0

    sum_ij = comb2(cont).sum()
    sum_a = comb2(cont.sum(axis=1)).sum()
    sum_b = comb2(cont.sum(axis=0)).sum()
    total = comb2(np.array([n]))[0]
    if total == 0:
        return 1.0
    expected = sum_a * sum_b / total
    max_index = (sum_a + sum_b) / 2.0
    if max_index == expected:
        return 1.0
    return float((sum_ij - expected) / (max_index - expected))
