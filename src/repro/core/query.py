"""Cluster queries against the SCAN index (paper §4.2, Algorithms 3–5).

Given (μ, ε):
  1. cores         — prefix of CO[μ] with θ ≥ ε              (Algorithm 3)
  2. similar edges — per-row NO prefixes with σ ≥ ε on cores (Alg. 5 line 4)
  3. clusters      — connectivity over core–core ε-similar edges (line 6)
  4. borders       — non-core neighbors attach to an ε-similar core
                     (Algorithm 4; deterministic variant of §7.3.4:
                      most-similar core, ties to the lower core id)

The whole query is a single jit with (μ, ε) as traced scalars — one compiled
artifact answers every parameter setting, which is the point of the index.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.connectivity import connected_components
from repro.core.graph import CSRGraph
from repro.core.index import ScanIndex, get_cores


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ClusterResult:
    labels: jax.Array      # int32[n]; component id (min core vertex id) or -1
    is_core: jax.Array     # bool[n]
    n_clusters: jax.Array  # int32 scalar


@functools.partial(jax.jit, static_argnames=())
def query(index: ScanIndex, g: CSRGraph, mu, eps) -> ClusterResult:
    """SCAN clustering for parameters (μ, ε) from the index."""
    mu = jnp.asarray(mu, jnp.int32)
    eps = jnp.asarray(eps, jnp.float32)

    is_core = get_cores(index, mu, eps)

    # ε-similar half-edges incident on cores, in original graph order.
    eu, ev, esim = g.edge_u, g.nbrs, index.edge_sims
    sim_ok = esim >= eps
    core_u = is_core[eu]
    core_v = is_core[ev]
    core_core = sim_ok & core_u & core_v

    labels0 = connected_components(
        index.n, eu, ev, edge_mask=core_core, vertex_mask=is_core
    )
    labels = jnp.where(is_core, labels0, jnp.int32(-1))

    # ---- border assignment (Algorithm 4, deterministic scatter variant) ----
    # candidate half-edges: u core, v non-core, σ ≥ ε ⇒ v joins cluster[u]
    border_edge = sim_ok & core_u & ~core_v
    neg = jnp.float32(-1.0)
    # best similarity per border vertex
    best_sim = (
        jnp.full((index.n,), neg)
        .at[ev]
        .max(jnp.where(border_edge, esim, neg), mode="drop")
    )
    # among edges achieving best_sim: lowest core id wins (deterministic)
    tie = border_edge & (esim >= best_sim[ev]) & (best_sim[ev] > neg)
    big = jnp.int32(index.n)
    best_core = (
        jnp.full((index.n,), big)
        .at[ev]
        .min(jnp.where(tie, eu, big), mode="drop")
    )
    has_border = best_core < big
    border_label = labels0[jnp.clip(best_core, 0, index.n - 1)]
    labels = jnp.where(~is_core & has_border, border_label, labels)

    # count distinct clusters = number of cores that are their own label
    n_clusters = jnp.sum(is_core & (labels == jnp.arange(index.n)))
    return ClusterResult(labels=labels, is_core=is_core, n_clusters=n_clusters)


@functools.partial(jax.jit, static_argnames=())
def query_batch(index: ScanIndex, g: CSRGraph, mus, epss) -> ClusterResult:
    """Answer a whole batch of (μ, ε) settings in one compiled call.

    ``mus`` int32[B] / ``epss`` float32[B] → ClusterResult with a leading
    batch axis (labels int32[B, n], is_core bool[B, n], n_clusters int32[B]).

    Because ``query`` treats (μ, ε) as traced scalars over a fixed index,
    vmapping over them shares one compiled artifact across the batch — the
    index arrays are closed over (broadcast), only the parameters vary.
    The inner connectivity ``while_loop`` runs until every batch member has
    converged; min-label propagation is monotone so already-converged
    members are fixed points and extra rounds are no-ops.
    """
    mus = jnp.atleast_1d(jnp.asarray(mus, jnp.int32))
    epss = jnp.atleast_1d(jnp.asarray(epss, jnp.float32))
    return jax.vmap(lambda m, e: query(index, g, m, e))(mus, epss)


@jax.jit
def hubs_outliers(g: CSRGraph, labels: jax.Array):
    """Classify unclustered vertices (paper §4.3).

    hub     — neighbors in ≥ 2 distinct clusters
    outlier — unclustered, neighbors in ≤ 1 cluster
    Returns (is_hub bool[n], is_outlier bool[n]).
    """
    n = labels.shape[0]
    nbr_label = labels[g.nbrs]
    valid = nbr_label >= 0
    big = jnp.int32(n)
    lo = (
        jnp.full((n,), big).at[g.edge_u].min(jnp.where(valid, nbr_label, big))
    )
    hi = (
        jnp.full((n,), jnp.int32(-1))
        .at[g.edge_u]
        .max(jnp.where(valid, nbr_label, jnp.int32(-1)))
    )
    unclustered = labels < 0
    is_hub = unclustered & (hi > lo) & (hi >= 0)
    is_outlier = unclustered & ~is_hub
    return is_hub, is_outlier
