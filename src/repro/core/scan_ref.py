"""Sequential reference SCAN — the correctness oracle.

A direct, readable numpy/python transcription of the original SCAN
definitions (paper §3.1): per-edge similarity by explicit set intersection,
core determination, BFS structural-reachability clustering, deterministic
border attachment (most-similar core, ties to lower id — matching §7.3.4),
hub/outlier classification. O(m·Δ) time — test-scale only.

Every parallel-path test asserts exact agreement against this module.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, Tuple

import numpy as np

from repro.core.graph import CSRGraph


def _neigh(g, off, nbrs, v):
    return nbrs[off[v]: off[v + 1]]


def similarities_ref(g: CSRGraph, measure: str = "cosine") -> np.ndarray:
    """σ per half-edge (graph order), via explicit closed-set intersection."""
    off = np.asarray(g.offsets)
    nbrs = np.asarray(g.nbrs)
    wgts = np.asarray(g.wgts)
    eu = np.asarray(g.edge_u)
    n = g.n

    wmap: Dict[Tuple[int, int], float] = {}
    for i in range(g.m2):
        wmap[(int(eu[i]), int(nbrs[i]))] = float(wgts[i])

    def wfun(a, b):  # weight of N̄(a) at element b; w(a,a)=1
        return 1.0 if a == b else wmap[(a, b)]

    norms = np.zeros(n)
    for v in range(n):
        s = 1.0 + sum(wmap[(v, int(x))] ** 2 for x in _neigh(g, off, nbrs, v))
        norms[v] = np.sqrt(s)

    sims = np.zeros(g.m2, dtype=np.float64)
    for i in range(g.m2):
        u, v = int(eu[i]), int(nbrs[i])
        nu = set(map(int, _neigh(g, off, nbrs, u))) | {u}
        nv = set(map(int, _neigh(g, off, nbrs, v))) | {v}
        shared = nu & nv
        if measure == "cosine":
            dot = sum(wfun(u, x) * wfun(v, x) for x in shared)
            sims[i] = dot / (norms[u] * norms[v])
        elif measure == "jaccard":
            sims[i] = len(shared) / len(nu | nv)
        else:
            raise ValueError(measure)
    return sims.astype(np.float32)


def scan_ref(
    g: CSRGraph,
    mu: int,
    eps: float,
    measure: str = "cosine",
    sims: np.ndarray | None = None,
):
    """Full SCAN clustering. Returns dict with labels / is_core / is_hub /
    is_outlier (labels = min core id of the cluster, -1 unclustered)."""
    off = np.asarray(g.offsets)
    nbrs = np.asarray(g.nbrs)
    eu = np.asarray(g.edge_u)
    n = g.n
    if sims is None:
        sims = similarities_ref(g, measure)

    eps = np.float32(eps)  # match the parallel path's f32 threshold exactly
    smap: Dict[Tuple[int, int], float] = {}
    for i in range(g.m2):
        smap[(int(eu[i]), int(nbrs[i]))] = np.float32(sims[i])

    # ε-neighborhood sizes over closed neighborhoods (self always counts)
    is_core = np.zeros(n, dtype=bool)
    for v in range(n):
        cnt = 1  # σ(v,v) = 1 ≥ ε
        for x in _neigh(g, off, nbrs, v):
            if smap[(v, int(x))] >= eps:
                cnt += 1
        is_core[v] = cnt >= mu

    # BFS over cores through ε-similar core-core edges
    labels = np.full(n, -1, dtype=np.int64)
    comp = {}
    for s in range(n):
        if not is_core[s] or s in comp:
            continue
        group = [s]
        comp[s] = s
        q = deque([s])
        while q:
            u = q.popleft()
            for x in _neigh(g, off, nbrs, u):
                x = int(x)
                if is_core[x] and x not in comp and smap[(u, x)] >= eps:
                    comp[x] = s
                    group.append(x)
                    q.append(x)
        rep = min(group)
        for u in group:
            labels[u] = rep

    # border vertices: non-core, ε-similar to a core → most similar core,
    # ties to lower core id (deterministic §7.3.4 variant)
    for v in range(n):
        if is_core[v]:
            continue
        best = None
        for x in _neigh(g, off, nbrs, v):
            x = int(x)
            if is_core[x] and smap[(v, x)] >= eps:
                cand = (-smap[(v, x)], x)
                if best is None or cand < best:
                    best = cand
        if best is not None:
            labels[v] = labels[best[1]]

    # hubs / outliers among unclustered
    is_hub = np.zeros(n, dtype=bool)
    is_outlier = np.zeros(n, dtype=bool)
    for v in range(n):
        if labels[v] >= 0:
            continue
        neigh_clusters = {int(labels[int(x)]) for x in _neigh(g, off, nbrs, v)}
        neigh_clusters.discard(-1)
        if len(neigh_clusters) >= 2:
            is_hub[v] = True
        else:
            is_outlier[v] = True

    return dict(
        labels=labels,
        is_core=is_core,
        is_hub=is_hub,
        is_outlier=is_outlier,
        sims=sims,
    )
