"""Exact structural-similarity computation (paper §4.1.1, Algorithm 1).

σ(u,v) is computed for every half-edge by a **degree-bucketed similarity
engine**. Real-world graphs are power-law: one hub vertex used to inflate
the single dense ``[n, Δ]`` padded neighbor matrix to O(n·Δ) memory and
every edge probe to O(Δ) work. The bucketed layout kills that skew
pathology (the GPUSCAN++ work-partitioning insight, applied to the padded
operand layout):

* **Degree classes** — vertices are partitioned into power-of-two
  *open-degree* classes (widths 8, 16, 32, …, capped at ``HUB_TILE``):
  the class width is the padded width of the vertex's open neighbor row,
  the operand the probe kernels actually scan (closed-neighborhood terms
  are added analytically in the epilogue). Each class materializes one
  fixed-shape padded block ``[K_c, w_c]`` whose row width is the *class*
  width, not the global max. Total operand memory is
  Σ_v pow2(deg v) ≤ 2·m2 + n·``BUCKET_FLOOR`` = **O(m + n)**.

* **Hub-row splitting** — a vertex wider than ``HUB_TILE`` (the storage
  tile width) occupies ⌈deg/``HUB_TILE``⌉ consecutive *tile rows* of the
  top block instead of forcing one giant row: a degree-10⁶ hub streams
  through the engine in 2048-wide tiles. Tiles are contiguous slices of
  the sorted neighbor row, so a per-chunk gather + reshape reassembles a
  sorted full-width row transiently (bounded by the chunk budget), never
  as a persistent giant block.

* **Edge routing** — each edge probes its **min-degree side** into its
  max-degree side: the probe row (width = the smaller class) is binary
  searched inside the target row (sorted ascending). Edges are grouped by
  (probe class, target class, tile counts) and each group runs through one
  fixed-shape jit kernel, so total similarity work is
  O(Σ_e min-side-degree · log max-side-degree). Kernel shapes are pure
  powers of two — the jit cache is shared across graphs, construction,
  the LSH exact-edge pass, and every incremental ``apply_delta`` batch.

* **σ bit-stability** — σ(u,v) depends only on the two endpoint rows,
  their class widths/tile counts, the endpoint norms and closed degrees.
  All of those are local: an edit batch perturbs them exactly for edges
  with a touched endpoint, so the incremental-update path
  (:mod:`repro.core.update`) carries every other σ bit-for-bit with *no*
  global-width fallback (the old "padded width changed → full re-sim"
  escape hatch is gone; only the affected degree classes re-run).

Entry points:

* ``compute_similarities`` — σ for every half-edge (production path).
* ``edge_similarities_subset`` — σ for an arbitrary edge subset (the §6.3
  degree-heuristic exact pass under LSH, and the incremental-update
  frontier recompute). Group chunks are padded to power-of-two shapes so
  repeated calls reuse one compiled kernel per (class pair).
* ``SimilarityPlan`` — the bucketed operands for one graph (blocks, vertex
  routing tables, norms); build once via :func:`plan_for` and reuse.
  :meth:`SimilarityPlan.apply` derives the successor plan for an edited
  graph by patching only the affected degree-class blocks (see below), so
  the incremental-update path never rebuilds the O(m + n) operands.
* ``compute_similarities_dense`` — small-graph oracle: σ from the closed
  weighted adjacency product (W̄·W̄ᵀ) gathered at edges. The Pallas
  triangle kernel (repro.kernels.triangle_count) reproduces this product
  with blocked MXU tiles. For *unweighted* graphs every intermediate is a
  small integer, exact in float32 under any reduction order, so the
  bucketed engine is **bit-identical** to this oracle; weighted sums are
  order-sensitive at the ULP level (asserted in tests).
* ``compute_similarities_densepad`` — the legacy O(n·Δ) dense-padded path,
  kept as the benchmark baseline (``benchmarks/bench_index_construction``
  measures bucketed vs dense-padded on skewed graphs).

Per-group lane choice goes through :class:`repro.backend.ExecutionPolicy`
(``plan.edge_sims(..., policy=...)``): the ``ref`` lane is the jnp
searchsorted engine below, the Pallas lanes run the sorted-probe kernel
(:mod:`repro.kernels.bucket_probe`, the masked-gram pattern extended with
target-tile streaming) on the same gathered operands — auto-dispatch
sends groups at least ``profile.probe_min_width`` wide to the compiled
kernel on TPU, and ``REPRO_LANE`` pins a lane everywhere. All lanes are
bit-identical on unweighted σ (ULP on weighted), so lane choice never
moves a fingerprint.

Supported measures (paper §2.1/§4.1.1):
  * ``cosine``  — weighted cosine over closed neighborhoods (w(x,x)=1);
                  reduces to unweighted cosine when all weights are 1.
  * ``jaccard`` — Jaccard over closed neighborhoods (unweighted graphs).
"""
from __future__ import annotations

import dataclasses
import functools
import weakref
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.backend.padding import (
    np_log2 as _np_log2,
    np_pow2ceil as _np_pow2ceil,
    pad1 as _pad1,
    pow2_bucket as _pow2_bucket,
    pow2ceil as _pow2ceil,
)
from repro.backend.policy import (
    LANE_INTERPRET, LANE_REF, ExecutionPolicy, default_policy,
)
from repro.core.graph import CSRGraph, to_dense

MEASURES = ("cosine", "jaccard")

# smallest degree-class width: classes are 8, 16, 32, … (pow2)
BUCKET_FLOOR = 8
# storage tile width: rows wider than this split into HUB_TILE-wide tiles
HUB_TILE = 2048
# per-chunk element budget for the transient gathered row matrices
CHUNK_ELEMS = 1 << 22

# legacy dense-padded quantum (kept for the benchmark baseline path)
PAD_WIDTH_QUANTUM = 8


def _routing_tables(deg: np.ndarray, n: int, hub_tile: int):
    """Degree → bucketing derivation: (widths, vclass, vtiles).

    The single source of truth shared by :meth:`SimilarityPlan.build` and
    :meth:`SimilarityPlan.apply` — their bit-identity contract requires
    one implementation of the class rule, not two that must be kept in
    sync by hand."""
    if not n:
        return (), np.zeros(0, np.int32), np.zeros(0, np.int32)
    w_full = 1 << np.ceil(np.log2(np.maximum(deg, 1))).astype(np.int64)
    w_full = np.maximum(w_full, BUCKET_FLOOR)
    w_cap = np.minimum(w_full, hub_tile)
    vtiles = np.where(w_full > hub_tile,
                      -(-deg // hub_tile), 1).astype(np.int32)
    widths = tuple(int(w) for w in np.unique(w_cap[:n]))
    vclass = np.searchsorted(widths, w_cap[:n]).astype(np.int32)
    return widths, vclass, vtiles


def closed_norms(g: CSRGraph) -> jax.Array:
    """sqrt(Σ_{x∈N̄(v)} w(v,x)²) with w(v,v)=1, float32[n]."""
    sq = jax.ops.segment_sum(g.wgts**2, g.edge_u, num_segments=g.n)
    return jnp.sqrt(sq + 1.0)


# ---------------------------------------------------------------------------
# degree-bucketed plan
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class SimilarityPlan:
    """Bucketed similarity operands for one graph.

    Per degree class ``c``: a padded block ``nbr_blocks[c]`` int32[K_c, w_c]
    / ``wgt_blocks[c]`` float32[K_c, w_c] whose rows are `HUB_TILE`-capped
    tiles of sorted open-neighbor rows (pad id = n, sorts last; the final
    block row is an all-pad sentinel and K_c is rounded up to a power of
    two so block shapes — and therefore compiled kernels — are stable
    under small graph edits). Vertex routing tables (host numpy):
    ``vclass`` (class id), ``vrow`` (first tile row), ``vtiles`` (tile
    count; 1 unless the vertex is a hub).
    """

    n: int
    m2: int
    hub_tile: int
    widths: Tuple[int, ...]
    nbr_blocks: Tuple[jax.Array, ...]
    wgt_blocks: Tuple[jax.Array, ...]
    vclass: np.ndarray   # int32[n]
    vrow: np.ndarray     # int32[n]
    vtiles: np.ndarray   # int32[n]
    deg: np.ndarray      # int64[n] open degrees (host routing key)
    norms: jax.Array     # float32[n]
    cdeg: jax.Array      # int32[n]
    # observability: kernel groups the most recent edge_sims call routed to
    # (stat slot, not identity; written via object.__setattr__)
    last_groups: int = 0
    # observability: what the :meth:`apply` that produced this plan did
    # (None for plans built from scratch)
    last_apply: Optional[dict] = None

    # -- construction -------------------------------------------------------
    @staticmethod
    def build(g: CSRGraph,
              hub_tile: Optional[int] = None) -> "SimilarityPlan":
        if hub_tile is None:
            hub_tile = default_policy().profile.hub_tile
        deg = np.diff(np.asarray(g.offsets)).astype(np.int64)
        n = g.n
        widths, vclass, vtiles = _routing_tables(deg, n, hub_tile)

        offsets = np.asarray(g.offsets)
        eu = np.asarray(g.edge_u) if g.m2 else np.zeros(0, np.int64)
        nbrs = np.asarray(g.nbrs) if g.m2 else np.zeros(0, np.int32)
        wgts = np.asarray(g.wgts) if g.m2 else np.zeros(0, np.float32)
        pos = (np.arange(g.m2, dtype=np.int64) - offsets[eu]) if g.m2 \
            else np.zeros(0, np.int64)

        vrow = np.zeros(n, dtype=np.int32)
        nbr_blocks: List[jax.Array] = []
        wgt_blocks: List[jax.Array] = []
        for ci, w in enumerate(widths):
            members = np.flatnonzero(vclass == ci)
            tiles = vtiles[members].astype(np.int64)
            starts = np.concatenate([[0], np.cumsum(tiles)[:-1]])
            vrow[members] = starts
            k_rows = int(tiles.sum())
            # sentinel pad row at the end; round rows to pow2 for jit-cache
            # stability across incremental graph edits
            k_pad = _pow2ceil(k_rows + 1)
            nb = np.full((k_pad, w), n, dtype=np.int32)
            wb = np.zeros((k_pad, w), dtype=np.float32)
            if g.m2:
                sel = np.flatnonzero(vclass[eu] == ci)
                if len(sel):
                    p = pos[sel]
                    r = vrow[eu[sel]] + (p // w)
                    c = p % w
                    nb[r, c] = nbrs[sel]
                    wb[r, c] = wgts[sel]
            nbr_blocks.append(jnp.asarray(nb))
            wgt_blocks.append(jnp.asarray(wb))

        return SimilarityPlan(
            n=n, m2=g.m2, hub_tile=hub_tile, widths=widths,
            nbr_blocks=tuple(nbr_blocks), wgt_blocks=tuple(wgt_blocks),
            vclass=vclass, vrow=vrow, vtiles=vtiles, deg=deg,
            norms=closed_norms(g), cdeg=g.closed_degrees())

    # -- incremental maintenance -------------------------------------------
    def apply(self, g2: CSRGraph, touched) -> "SimilarityPlan":
        """Successor plan for an edited graph, patching only affected blocks.

        ``g2`` is the post-edit graph (same vertex set as this plan's
        graph); ``touched`` holds every vertex whose open neighbor row —
        content or weights — changed (a superset is correct, a subset is
        not). The result is **bit-identical** to
        ``SimilarityPlan.build(g2, self.hub_tile)`` (asserted by the
        edit-script oracle), but the per-batch block work is proportional
        to the *touched* rows/classes, never O(m):

        * a degree class with no touched member and an unchanged layout
          reuses its device blocks outright (``reused``);
        * touched rows of a layout-stable class re-pack in place — one
          scatter of the rewritten tile rows (``patched``);
        * a membership/tile-count change (a vertex migrating between its
          two pow2 classes, a hub splitting or merging tile rows under the
          ``HUB_TILE`` rule) re-derives the block by gathering kept rows
          from the old block and scattering the rewritten ones
          (``remapped``);
        * a class width with no predecessor block packs fresh — all its
          members are touched by construction (``built``).

        Vertex routing tables are recomputed host-side in O(n) (exactly as
        :meth:`build` does, so class ids / row starts match bit-for-bit),
        norms are patched only at ``touched`` via a frontier-restricted
        segment sum, and ``last_apply`` on the returned plan reports the
        work counters (``rows_written`` is the acceptance counter: block
        tile rows actually rewritten this batch).
        """
        if g2.n != self.n:
            raise ValueError(
                f"plan.apply: vertex count changed ({self.n} -> {g2.n}); "
                "incremental maintenance assumes a fixed vertex set")
        n = self.n
        hub_tile = self.hub_tile
        touched = np.asarray(touched, dtype=np.int64)
        tmask = np.zeros(n, dtype=bool)
        tmask[touched] = True

        off2 = np.asarray(g2.offsets)
        deg2 = np.diff(off2).astype(np.int64)
        # routing tables via the same derivation build() uses
        widths2, vclass2, vtiles2 = _routing_tables(deg2, n, hub_tile)

        nbrs2 = np.asarray(g2.nbrs) if g2.m2 else np.zeros(0, np.int32)
        wgts2 = np.asarray(g2.wgts) if g2.m2 else np.zeros(0, np.float32)
        old_ci_of_width = {w: i for i, w in enumerate(self.widths)}

        stats = {"classes": len(widths2), "reused": 0, "patched": 0,
                 "remapped": 0, "built": 0, "rows_written": 0}
        vrow2 = np.zeros(n, dtype=np.int32)
        nbr_blocks: List[jax.Array] = []
        wgt_blocks: List[jax.Array] = []
        for ci, w in enumerate(widths2):
            members = np.flatnonzero(vclass2 == ci)
            tiles = vtiles2[members].astype(np.int64)
            starts = np.concatenate([[0], np.cumsum(tiles)[:-1]])
            vrow2[members] = starts
            k_rows = int(tiles.sum())
            k_pad = _pow2ceil(k_rows + 1)
            oci = old_ci_of_width.get(w)
            rewrite = members[tmask[members]]

            if oci is None:
                # brand-new width: every member changed degree => touched
                nb = np.full((k_pad, w), n, dtype=np.int32)
                wb = np.zeros((k_pad, w), dtype=np.float32)
                rows, valn, valw = _member_tile_rows(
                    members, w, vrow2, vtiles2, off2, nbrs2, wgts2, n)
                nb[rows] = valn
                wb[rows] = valw
                stats["built"] += 1
                stats["rows_written"] += len(rows)
                nbr_blocks.append(jnp.asarray(nb))
                wgt_blocks.append(jnp.asarray(wb))
                continue

            old_nb = self.nbr_blocks[oci]
            old_wb = self.wgt_blocks[oci]
            members1 = np.flatnonzero(self.vclass == oci)
            stable = (old_nb.shape[0] == k_pad
                      and len(members1) == len(members)
                      and np.array_equal(members1, members)
                      and np.array_equal(self.vtiles[members],
                                         vtiles2[members]))
            if stable and len(rewrite) == 0:
                nbr_blocks.append(old_nb)
                wgt_blocks.append(old_wb)
                stats["reused"] += 1
                continue

            rows, valn, valw = _member_tile_rows(
                rewrite, w, vrow2, vtiles2, off2, nbrs2, wgts2, n)
            stats["rows_written"] += len(rows)
            # pad the scatter to a pow2 row count aimed at the sentinel row
            # (kept all-pad by writing pad content), so repeated batches hit
            # one compiled scatter per block shape
            r_pad = _pow2ceil(len(rows)) - len(rows)
            if r_pad:
                rows = np.concatenate(
                    [rows, np.full(r_pad, k_pad - 1, np.int32)])
                valn = np.concatenate(
                    [valn, np.full((r_pad, w), n, np.int32)])
                valw = np.concatenate(
                    [valw, np.zeros((r_pad, w), np.float32)])
            if stable:
                nb, wb = _patch_block(
                    old_nb, old_wb, jnp.asarray(rows),
                    jnp.asarray(valn), jnp.asarray(valw))
                stats["patched"] += 1
            else:
                # layout moved: gather kept members' rows from the old
                # block (they are bit-identical), then scatter the rest
                kept = members[~tmask[members]]
                src = np.full(k_pad, old_nb.shape[0] - 1, dtype=np.int32)
                if len(kept):
                    kt = vtiles2[kept].astype(np.int64)   # == old tiles
                    new_r = _expand_tile_rows(vrow2[kept], kt)
                    old_r = _expand_tile_rows(self.vrow[kept], kt)
                    src[new_r] = old_r
                nb, wb = _remap_block(
                    old_nb, old_wb, jnp.asarray(src), jnp.asarray(rows),
                    jnp.asarray(valn), jnp.asarray(valw))
                stats["remapped"] += 1
            nbr_blocks.append(nb)
            wgt_blocks.append(wb)

        # norms change exactly at touched vertices; the restricted segment
        # sum walks each touched row in CSR order — the same value sequence
        # the full closed_norms reduction uses, so patched entries are
        # bit-identical to a from-scratch build (oracle-asserted)
        if len(touched) and g2.m2:
            sel = tmask[np.asarray(g2.edge_u)]
            sq = jax.ops.segment_sum(
                jnp.asarray(wgts2[sel]) ** 2,
                jnp.asarray(np.asarray(g2.edge_u)[sel]),
                num_segments=n)
            t = jnp.asarray(touched)
            norms2 = self.norms.at[t].set(jnp.sqrt(sq + 1.0)[t])
        elif len(touched):
            norms2 = self.norms.at[jnp.asarray(touched)].set(1.0)
        else:
            norms2 = self.norms

        return SimilarityPlan(
            n=n, m2=g2.m2, hub_tile=hub_tile, widths=widths2,
            nbr_blocks=tuple(nbr_blocks), wgt_blocks=tuple(wgt_blocks),
            vclass=vclass2, vrow=vrow2, vtiles=vtiles2, deg=deg2,
            norms=norms2, cdeg=g2.closed_degrees(), last_apply=stats)

    # -- introspection ------------------------------------------------------
    def operand_bytes(self) -> int:
        """Persistent similarity-operand footprint (neighbor + weight
        blocks + norms + closed degrees) in bytes — O(m + n)."""
        total = sum(int(np.prod(b.shape)) * (4 + 4) for b in self.nbr_blocks)
        return total + 8 * self.n

    def route(self, eu: np.ndarray, ev: np.ndarray):
        """Host-side routing: probe side (min (deg, id)) per edge and the
        per-edge group key (probe class, probe tiles^, target class,
        target tiles^). Returns (pu, pv, keys) with keys int64[m]."""
        du, dv = self.deg[eu], self.deg[ev]
        swap = (dv < du) | ((dv == du) & (ev < eu))
        pu = np.where(swap, ev, eu)
        pv = np.where(swap, eu, ev)
        sp = _np_pow2ceil(self.vtiles[pu])
        st = _np_pow2ceil(self.vtiles[pv])
        keys = (((self.vclass[pu].astype(np.int64) * 64
                  + _np_log2(sp)) * 64
                 + self.vclass[pv]) * 64 + _np_log2(st))
        return pu, pv, keys

    def group_count(self, eu: np.ndarray, ev: np.ndarray) -> int:
        """Number of distinct (class-pair, tile-shape) kernel groups an
        edge subset routes to (observability for apply_delta)."""
        if len(eu) == 0:
            return 0
        _, _, keys = self.route(np.asarray(eu, np.int64),
                                np.asarray(ev, np.int64))
        return len(np.unique(keys))

    # -- the engine ---------------------------------------------------------
    def edge_sims(
        self,
        eu,
        ev,
        ew,
        measure: str = "cosine",
        chunk: int = 1 << 16,
        policy: Optional[ExecutionPolicy] = None,
    ) -> jax.Array:
        """σ (or triangle counts with measure='_count') for an edge subset.

        Each (class pair, tile shape) group resolves its lane through the
        execution policy: ``ref`` runs the jnp searchsorted kernel, the
        Pallas lanes run the sorted-probe kernel on identical gathered
        operands (bit-identical on unweighted σ, ULP on weighted). Lane
        decisions count under ``backend.lane.bucket_probe.<lane>``.
        """
        if measure not in MEASURES + ("_count",):
            raise ValueError(f"measure must be one of {MEASURES}")
        pol = policy if policy is not None else default_policy()
        eu = np.asarray(eu, dtype=np.int64)
        ev = np.asarray(ev, dtype=np.int64)
        ew = np.asarray(ew, dtype=np.float32)
        total = len(eu)
        out_dt = np.int32 if measure == "_count" else np.float32
        if total == 0:
            return jnp.zeros((0,), out_dt)

        pu, pv, keys = self.route(eu, ev)
        order = np.argsort(keys, kind="stable")
        bounds = np.flatnonzero(np.diff(keys[order])) + 1
        groups = np.split(order, bounds)
        object.__setattr__(self, "last_groups", len(groups))

        out = np.empty(total, out_dt)
        for idx in groups:
            cp = int(self.vclass[pu[idx[0]]])
            ct = int(self.vclass[pv[idx[0]]])
            sp = _pow2ceil(int(self.vtiles[pu[idx[0]]]))
            st = _pow2ceil(int(self.vtiles[pv[idx[0]]]))
            pe = sp * self.widths[cp]
            te = st * self.widths[ct]
            lane = pol.lane("bucket_probe", width=pe)
            pol.note("bucket_probe", lane)
            cap = max(CHUNK_ELEMS // max(pe + te, 1), 1)
            cap = 1 << (cap.bit_length() - 1)
            csize = min(_pow2_bucket(len(idx)), max(min(chunk, cap), 1))
            if lane == LANE_INTERPRET:
                # interpret-mode grids unroll at trace time: bound the
                # chunk so compile cost stays proportional to the profile
                csize = min(csize, _pow2ceil(
                    pol.profile.probe_interpret_chunk))
            sentinel_p = self.nbr_blocks[cp].shape[0] - 1
            for s in range(0, len(idx), csize):
                sub = idx[s: s + csize]
                pad = csize - len(sub)
                args = dict(
                    p0=_pad1(self.vrow[pu[sub]], pad, sentinel_p),
                    pt=_pad1(self.vtiles[pu[sub]], pad, 0),
                    t0=_pad1(self.vrow[pv[sub]], pad,
                             self.nbr_blocks[ct].shape[0] - 1),
                    tt=_pad1(self.vtiles[pv[sub]], pad, 0),
                    ceu=_pad1(eu[sub].astype(np.int32), pad, 0),
                    cev=_pad1(ev[sub].astype(np.int32), pad, 0),
                    cew=_pad1(ew[sub], pad, 0.0),
                )
                operands = (
                    jnp.asarray(args["p0"]), jnp.asarray(args["pt"]),
                    jnp.asarray(args["t0"]), jnp.asarray(args["tt"]),
                    jnp.asarray(args["ceu"]), jnp.asarray(args["cev"]),
                    jnp.asarray(args["cew"]),
                    self.nbr_blocks[cp], self.wgt_blocks[cp],
                    self.nbr_blocks[ct], self.wgt_blocks[ct],
                    self.norms, self.cdeg,
                )
                if lane == LANE_REF:
                    res = _bucket_sims_chunk(
                        *operands, sp=sp, st=st, measure=measure)
                else:
                    res = _bucket_sims_chunk_pallas(
                        *operands, sp=sp, st=st, measure=measure,
                        be=min(pol.profile.probe_be, csize),
                        bt=pol.profile.probe_bt,
                        interpret=pol.interpret(lane))
                out[sub] = np.asarray(res)[: len(sub)]
        return jnp.asarray(out)


def _expand_tile_rows(first: np.ndarray, tiles: np.ndarray) -> np.ndarray:
    """Concatenated [first_i, first_i + tiles_i) tile-row ranges, int32."""
    total = int(tiles.sum())
    if total == 0:
        return np.zeros(0, np.int32)
    ends = np.cumsum(tiles)
    within = np.arange(total, dtype=np.int64) - np.repeat(ends - tiles, tiles)
    return (np.repeat(np.asarray(first, np.int64), tiles)
            + within).astype(np.int32)


def _member_tile_rows(members, w, vrow, vtiles, offsets, nbrs, wgts, n):
    """Packed tile rows for a member set: (rows int32[R], nbr int32[R, w],
    wgt float32[R, w]) where R = Σ tiles — each member's sorted CSR row
    split into ``w``-wide tiles, padded with id ``n`` / weight 0."""
    members = np.asarray(members, np.int64)
    tiles = vtiles[members].astype(np.int64)
    rows = _expand_tile_rows(vrow[members], tiles)
    valn = np.full((len(rows), w), n, dtype=np.int32)
    valw = np.zeros((len(rows), w), dtype=np.float32)
    if len(members):
        degs = offsets[members + 1].astype(np.int64) - offsets[members]
        tot = int(degs.sum())
        if tot:
            ends = np.cumsum(degs)
            pos = np.arange(tot, dtype=np.int64) - np.repeat(
                ends - degs, degs)
            src = np.repeat(offsets[members].astype(np.int64), degs) + pos
            row_base = np.repeat(np.cumsum(tiles) - tiles, degs)
            r = row_base + pos // w
            c = pos % w
            valn[r, c] = nbrs[src]
            valw[r, c] = wgts[src]
    return rows, valn, valw


@jax.jit
def _patch_block(nb, wb, rows, valn, valw):
    """Scatter rewritten tile rows into a layout-stable block (functional
    update — the old block stays intact for the predecessor plan)."""
    return nb.at[rows].set(valn), wb.at[rows].set(valw)


@jax.jit
def _remap_block(nb, wb, src, rows, valn, valw):
    """Gather kept rows from the old block per ``src`` (sentinel index for
    vacated rows — all-pad, like a fresh block), then scatter rewrites."""
    return nb[src].at[rows].set(valn), wb[src].at[rows].set(valw)


def _gather_tiled_rows(block_n, block_w, first, cnt, s: int):
    """Reassemble [c, s·w] sorted rows from ``s`` consecutive tile rows per
    entry (hub-row splitting: tiles beyond ``cnt`` map to the all-pad
    sentinel row, which sorts last)."""
    k_sent = block_n.shape[0] - 1
    w = block_n.shape[1]
    t = jnp.arange(s, dtype=jnp.int32)[None, :]
    idx = jnp.where(t < cnt[:, None], first[:, None] + t, k_sent)
    c = first.shape[0]
    return (block_n[idx].reshape(c, s * w), block_w[idx].reshape(c, s * w))


def _sigma_epilogue(shared_dot, shared_cnt, eu, ev, ew, norms, cdeg,
                    measure: str):
    """Shared-dot/count → σ. One implementation for every engine lane
    (jnp searchsorted, Pallas probe, shard_map): the bit-identity contract
    requires the epilogue arithmetic to exist exactly once."""
    if measure == "_count":
        return shared_cnt.astype(jnp.int32)
    if measure == "cosine":
        # closed-neighborhood dot: open shared dot + x=u and x=v terms
        closed_dot = shared_dot + 2.0 * ew
        return closed_dot / (norms[eu] * norms[ev])
    elif measure == "jaccard":
        c = shared_cnt.astype(jnp.float32) + 2.0       # + {u, v}
        union = cdeg[eu].astype(jnp.float32) + cdeg[ev].astype(jnp.float32) - c
        return c / union
    raise ValueError(f"unknown measure {measure!r}")


def _bucket_sims_core(p0, pt, t0, tt, eu, ev, ew,
                      p_nbr, p_wgt, t_nbr, t_wgt, norms, cdeg,
                      sp: int, st: int, measure: str):
    """Sorted-probe body for one (probe class, target class) group chunk.

    Shared between the jitted single-host kernel and the shard_map path in
    :mod:`repro.core.distributed`. This is the ``ref`` lane of the
    ``bucket_probe`` op: the jnp searchsorted engine.
    """
    n = norms.shape[0]
    rows_p, w_p = _gather_tiled_rows(p_nbr, p_wgt, p0, pt, sp)
    rows_t, w_t = _gather_tiled_rows(t_nbr, t_wgt, t0, tt, st)

    pos = jax.vmap(jnp.searchsorted)(rows_t, rows_p)
    pos_c = jnp.minimum(pos, rows_t.shape[1] - 1)
    hit = jnp.take_along_axis(rows_t, pos_c, axis=1) == rows_p
    hit &= rows_p < n                                  # mask probe padding
    w_match = jnp.take_along_axis(w_t, pos_c, axis=1)
    shared_dot = jnp.sum(jnp.where(hit, w_p * w_match, 0.0), axis=1)
    shared_cnt = jnp.sum(hit, axis=1)
    return _sigma_epilogue(shared_dot, shared_cnt, eu, ev, ew, norms, cdeg,
                           measure)


@functools.partial(jax.jit, static_argnames=("sp", "st", "measure"))
def _bucket_sims_chunk(p0, pt, t0, tt, eu, ev, ew,
                       p_nbr, p_wgt, t_nbr, t_wgt, norms, cdeg,
                       *, sp: int, st: int, measure: str):
    """One fixed-shape per-(bucket_u, bucket_v) kernel invocation. Every
    shape in the signature is a power of two, so the jit cache is shared
    across graphs and across repeated ``apply_delta`` batches."""
    return _bucket_sims_core(p0, pt, t0, tt, eu, ev, ew,
                             p_nbr, p_wgt, t_nbr, t_wgt, norms, cdeg,
                             sp, st, measure)


@functools.partial(jax.jit, static_argnames=(
    "sp", "st", "measure", "be", "bt", "interpret"))
def _bucket_sims_chunk_pallas(p0, pt, t0, tt, eu, ev, ew,
                              p_nbr, p_wgt, t_nbr, t_wgt, norms, cdeg,
                              *, sp: int, st: int, measure: str,
                              be: int, bt: int, interpret: bool):
    """The Pallas lane of one group chunk: gather the same tiled rows the
    jnp engine would, run the sorted-probe kernel
    (:mod:`repro.kernels.bucket_probe`) instead of searchsorted, and apply
    the shared epilogue. Unweighted shared dots/counts are small integers
    (exact in f32 under any accumulation order), so this lane is
    bit-identical to :func:`_bucket_sims_core`; weighted dots agree to
    ULP."""
    from repro.kernels.bucket_probe import bucket_probe
    from repro.kernels.ops import probe_operands

    n = norms.shape[0]
    rows_p, w_p = _gather_tiled_rows(p_nbr, p_wgt, p0, pt, sp)
    rows_t, w_t = _gather_tiled_rows(t_nbr, t_wgt, t0, tt, st)
    ids_p, w_p, ids_t, w_t, bt = probe_operands(
        rows_p, w_p, rows_t, w_t, n, be, bt)
    dot, cnt = bucket_probe(ids_p, w_p, ids_t, w_t, be=be, bt=bt,
                            interpret=interpret)
    e0 = eu.shape[0]
    return _sigma_epilogue(dot[:e0], cnt[:e0], eu, ev, ew, norms, cdeg,
                           measure)


# ---------------------------------------------------------------------------
# plan cache (one plan per live graph object)
# ---------------------------------------------------------------------------
_PLAN_CACHE: Dict[Tuple[int, int], Tuple[object, SimilarityPlan]] = {}


def _evict_plan(key, ref) -> None:
    """Finalizer: drop a cache entry when its graph dies — but only if the
    slot still belongs to that graph (ids are reused, so a delayed
    finalizer must never pop a successor's entry)."""
    ent = _PLAN_CACHE.get(key)
    if ent is not None and ent[0] is ref:
        del _PLAN_CACHE[key]


def _cache_plan(g: CSRGraph, key, plan: SimilarityPlan) -> None:
    ref = weakref.ref(g)
    _PLAN_CACHE[key] = (ref, plan)
    # evict the moment the graph is collected: a dead graph's O(m + n)
    # device blocks must not squat in the cache until the next miss sweeps
    weakref.finalize(g, _evict_plan, key, ref)


def plan_for(g: CSRGraph,
             hub_tile: Optional[int] = None) -> SimilarityPlan:
    """The bucketed :class:`SimilarityPlan` for ``g``, cached per live graph
    object so construction, the LSH exact pass, triangle counting, and the
    incremental-update path share one set of device blocks. Entries are
    evicted by a ``weakref.finalize`` on the graph, so a plan never
    outlives its graph. ``hub_tile`` defaults to the active execution
    policy's autotune profile (legacy constant ``HUB_TILE`` when
    untuned)."""
    if hub_tile is None:
        hub_tile = default_policy().profile.hub_tile
    key = (id(g), hub_tile)
    ent = _PLAN_CACHE.get(key)
    if ent is not None and ent[0]() is g:
        return ent[1]
    plan = SimilarityPlan.build(g, hub_tile)
    _cache_plan(g, key, plan)
    return plan


def adopt_plan(g: CSRGraph, plan: SimilarityPlan) -> SimilarityPlan:
    """Seed the cache with an externally derived plan for ``g`` (the
    incremental-update path hands over :meth:`SimilarityPlan.apply`'s
    successor so the post-edit graph never triggers an O(m) rebuild)."""
    _cache_plan(g, (id(g), plan.hub_tile), plan)
    return plan


def cached_plan(g: CSRGraph,
                hub_tile: Optional[int] = None) -> Optional[SimilarityPlan]:
    """The cached plan for ``g`` if one exists (None otherwise; never
    builds). Lets tests distinguish a maintained plan from a fresh one."""
    if hub_tile is None:
        hub_tile = default_policy().profile.hub_tile
    ent = _PLAN_CACHE.get((id(g), hub_tile))
    if ent is not None and ent[0]() is g:
        return ent[1]
    return None


def plan_cache_size() -> int:
    """Live entry count of the per-graph plan cache (leak detection)."""
    return len(_PLAN_CACHE)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
def edge_similarities_subset(
    g: CSRGraph,
    eu: jax.Array,
    ev: jax.Array,
    ew: jax.Array,
    measure: str = "cosine",
    chunk: int = 1 << 16,
) -> jax.Array:
    """Exact σ for an arbitrary subset of edges (endpoint arrays).

    Used for the full-graph pass, the §6.3 degree-heuristic compacted
    exact pass under LSH, and the incremental-update frontier recompute.
    Edges route to per-degree-class kernels with power-of-two chunk
    shapes, so repeated calls (e.g. update batches at the same pow2 size)
    reuse one compiled function per class pair.
    """
    if measure not in MEASURES:
        raise ValueError(f"measure must be one of {MEASURES}")
    return plan_for(g).edge_sims(eu, ev, ew, measure, chunk)


def compute_similarities(
    g: CSRGraph, measure: str = "cosine", chunk: int = 1 << 16
) -> jax.Array:
    """Exact σ for every half-edge, float32[m2]. Host-orchestrated routing
    over the degree-bucketed engine."""
    return edge_similarities_subset(g, g.edge_u, g.nbrs, g.wgts, measure, chunk)


def triangle_counts(g: CSRGraph) -> jax.Array:
    """|N(u) ∩ N(v)| per half-edge (the paper's triangle-counting
    primitive), via the bucketed sorted-probe engine."""
    return plan_for(g).edge_sims(g.edge_u, g.nbrs, g.wgts, "_count")


# ---------------------------------------------------------------------------
# small-graph dense oracle
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("measure",))
def _dense_sims(adj_c, eu, ev, cdeg, measure):
    prod = adj_c @ adj_c.T
    dots = prod[eu, ev]
    if measure == "cosine":
        norms = jnp.sqrt(jnp.diag(prod))
        return dots / (norms[eu] * norms[ev])
    union = cdeg[eu].astype(jnp.float32) + cdeg[ev].astype(jnp.float32) - dots
    return dots / union


def compute_similarities_dense(g: CSRGraph, measure: str = "cosine") -> jax.Array:
    """Small-graph oracle via the closed adjacency product."""
    weighted = measure == "cosine"
    adj_c = to_dense(g, closed=True, weighted=weighted)
    return _dense_sims(adj_c, g.edge_u, g.nbrs, g.closed_degrees(), measure)


# ---------------------------------------------------------------------------
# legacy dense-padded path — benchmark baseline only
# ---------------------------------------------------------------------------
def padded_width(g: CSRGraph) -> int:
    """[legacy baseline] Global padded row width M: max open degree rounded
    up to ``PAD_WIDTH_QUANTUM``. One hub inflates M (and the O(n·M) padded
    matrices below) for every vertex — the skew pathology the bucketed
    engine exists to remove."""
    deg = np.asarray(g.degrees())
    m = int(deg.max()) if len(deg) else 1
    m = max(m, 1)
    return -(-m // PAD_WIDTH_QUANTUM) * PAD_WIDTH_QUANTUM


def padded_neighbors(g: CSRGraph) -> Tuple[jax.Array, jax.Array, int]:
    """[legacy baseline] Dense padded (nbr_mat[n, M], wgt_mat[n, M], M).
    Pad id = n (sorts last). O(n·M) memory — superseded by
    :class:`SimilarityPlan`; retained for the construction benchmark's
    dense-vs-bucketed comparison."""
    m = padded_width(g)
    offsets = np.asarray(g.offsets)
    nbr_mat = np.full((g.n, m), g.n, dtype=np.int32)
    wgt_mat = np.zeros((g.n, m), dtype=np.float32)
    if g.m2:
        eu = np.asarray(g.edge_u)
        pos = np.arange(g.m2, dtype=np.int64) - offsets[eu]
        nbr_mat[eu, pos] = np.asarray(g.nbrs)
        wgt_mat[eu, pos] = np.asarray(g.wgts)
    return jnp.asarray(nbr_mat), jnp.asarray(wgt_mat), m


def densepad_operand_bytes(g: CSRGraph) -> int:
    """[legacy baseline] Peak similarity-operand bytes of the dense-padded
    layout: the two O(n·M) matrices plus norms/closed degrees."""
    return g.n * padded_width(g) * (4 + 4) + 8 * g.n


@functools.partial(jax.jit, static_argnames=("measure",))
def _edge_sims_chunk(
    eu: jax.Array,        # int32[c] chunk of half-edge sources
    ev: jax.Array,        # int32[c] chunk of half-edge targets
    ew: jax.Array,        # float32[c] chunk of half-edge weights
    nbr_mat: jax.Array,   # int32[n, M]
    wgt_mat: jax.Array,   # float32[n, M]
    norms: jax.Array,     # float32[n]
    cdeg: jax.Array,      # int32[n] closed degrees
    measure: str,
) -> jax.Array:
    """[legacy baseline] σ for one chunk of half-edges via vectorized
    binary search over the global-width padded rows."""
    rows_u = nbr_mat[eu]                      # [c, M] probe row
    w_u = wgt_mat[eu]                         # [c, M]
    rows_v = nbr_mat[ev]                      # [c, M] target row (sorted)
    w_v = wgt_mat[ev]                         # [c, M]

    pos = jax.vmap(jnp.searchsorted)(rows_v, rows_u)       # [c, M]
    pos_c = jnp.minimum(pos, rows_v.shape[1] - 1)
    hit = jnp.take_along_axis(rows_v, pos_c, axis=1) == rows_u
    hit &= rows_u < nbr_mat.shape[0]                        # mask row padding
    w_match = jnp.take_along_axis(w_v, pos_c, axis=1)
    shared_dot = jnp.sum(jnp.where(hit, w_u * w_match, 0.0), axis=1)
    shared_cnt = jnp.sum(hit, axis=1)

    if measure == "cosine":
        closed_dot = shared_dot + 2.0 * ew
        return closed_dot / (norms[eu] * norms[ev])
    elif measure == "jaccard":
        c = shared_cnt.astype(jnp.float32) + 2.0            # + {u, v}
        union = cdeg[eu].astype(jnp.float32) + cdeg[ev].astype(jnp.float32) - c
        return c / union
    raise ValueError(f"unknown measure {measure!r}")


def compute_similarities_densepad(
    g: CSRGraph, measure: str = "cosine", chunk: int = 1 << 16
) -> jax.Array:
    """[legacy baseline] σ for every half-edge via the O(n·Δ) dense-padded
    layout. Benchmark comparison path only — every production consumer
    runs on the bucketed engine."""
    if measure not in MEASURES:
        raise ValueError(f"measure must be one of {MEASURES}")
    nbr_mat, wgt_mat, m = padded_neighbors(g)
    norms = closed_norms(g)
    cdeg = g.closed_degrees()
    total = g.m2
    if total == 0:
        return jnp.zeros((0,), jnp.float32)
    # bound the transient [c, M] working set like the bucketed engine does
    cap = max(CHUNK_ELEMS // max(2 * m, 1), 1)
    cap = 1 << (cap.bit_length() - 1)
    chunk = min(max(min(chunk, cap), 1), _pow2_bucket(total))
    out = []
    for s in range(0, total, chunk):
        e = min(s + chunk, total)
        pad = chunk - (e - s)
        cu = jnp.pad(g.edge_u[s:e], (0, pad))
        cv = jnp.pad(g.nbrs[s:e], (0, pad))
        cw = jnp.pad(g.wgts[s:e], (0, pad))
        sims = _edge_sims_chunk(cu, cv, cw, nbr_mat, wgt_mat, norms, cdeg,
                                measure)
        out.append(sims[: e - s])
    return jnp.concatenate(out) if len(out) > 1 else out[0]
