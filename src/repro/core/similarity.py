"""Exact structural-similarity computation (paper §4.1.1, Algorithm 1).

σ(u,v) is computed for every half-edge. Two execution paths:

* ``compute_similarities`` — the production path: vectorized sorted-CSR
  intersection. For each half-edge (u→v) we binary-search u's (padded)
  neighbor row inside v's row. This is the TPU-native analogue of the
  paper's merge-based triangle counting (§6.1): sorted-array probes instead
  of hash probes, fully data-parallel, chunked so the working set is bounded.

* ``compute_similarities_dense`` — small-graph oracle: σ from the closed
  weighted adjacency product (W̄·W̄ᵀ) gathered at edges. The Pallas triangle
  kernel (repro.kernels.triangle_count) reproduces this product with blocked
  MXU tiles; its ``ref.py`` delegates here.

Supported measures (paper §2.1/§4.1.1):
  * ``cosine``  — weighted cosine over closed neighborhoods (w(x,x)=1);
                  reduces to unweighted cosine when all weights are 1.
  * ``jaccard`` — Jaccard over closed neighborhoods (unweighted graphs).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph, to_dense

MEASURES = ("cosine", "jaccard")


PAD_WIDTH_QUANTUM = 8


def padded_width(g: CSRGraph) -> int:
    """Static padded row width M for :func:`padded_neighbors`.

    M is the max open degree rounded up to a multiple of
    ``PAD_WIDTH_QUANTUM``. The rounding keeps M (and therefore every
    compiled similarity kernel *and* every σ bit pattern, which depends on
    the reduction width) stable under small degree changes — the property
    the incremental-update path (:mod:`repro.core.update`) relies on to
    carry σ values over unchanged edges bit-identically.
    """
    deg = np.asarray(g.degrees())
    m = int(deg.max()) if len(deg) else 1
    m = max(m, 1)
    return -(-m // PAD_WIDTH_QUANTUM) * PAD_WIDTH_QUANTUM


def padded_neighbors(g: CSRGraph) -> Tuple[jax.Array, jax.Array, int]:
    """Dense padded (nbr_mat[n, M], wgt_mat[n, M], M). Pad id = n (sorts last).

    Host-side helper (concrete offsets required to derive the static M);
    fully vectorized — one scatter per matrix, no per-vertex loop.
    """
    m = padded_width(g)
    offsets = np.asarray(g.offsets)
    nbr_mat = np.full((g.n, m), g.n, dtype=np.int32)
    wgt_mat = np.zeros((g.n, m), dtype=np.float32)
    if g.m2:
        eu = np.asarray(g.edge_u)
        pos = np.arange(g.m2, dtype=np.int64) - offsets[eu]
        nbr_mat[eu, pos] = np.asarray(g.nbrs)
        wgt_mat[eu, pos] = np.asarray(g.wgts)
    return jnp.asarray(nbr_mat), jnp.asarray(wgt_mat), m


def closed_norms(g: CSRGraph) -> jax.Array:
    """sqrt(Σ_{x∈N̄(v)} w(v,x)²) with w(v,v)=1, float32[n]."""
    sq = jax.ops.segment_sum(g.wgts**2, g.edge_u, num_segments=g.n)
    return jnp.sqrt(sq + 1.0)


@functools.partial(jax.jit, static_argnames=("measure",))
def _edge_sims_chunk(
    eu: jax.Array,        # int32[c] chunk of half-edge sources
    ev: jax.Array,        # int32[c] chunk of half-edge targets
    ew: jax.Array,        # float32[c] chunk of half-edge weights
    nbr_mat: jax.Array,   # int32[n, M]
    wgt_mat: jax.Array,   # float32[n, M]
    norms: jax.Array,     # float32[n]
    cdeg: jax.Array,      # int32[n] closed degrees
    measure: str,
) -> jax.Array:
    """σ for one chunk of half-edges via vectorized binary search."""
    rows_u = nbr_mat[eu]                      # [c, M] probe row
    w_u = wgt_mat[eu]                         # [c, M]
    rows_v = nbr_mat[ev]                      # [c, M] target row (sorted)
    w_v = wgt_mat[ev]                         # [c, M]

    # position of each of u's neighbors inside v's sorted row
    pos = jax.vmap(jnp.searchsorted)(rows_v, rows_u)       # [c, M]
    pos_c = jnp.minimum(pos, rows_v.shape[1] - 1)
    hit = jnp.take_along_axis(rows_v, pos_c, axis=1) == rows_u
    hit &= rows_u < nbr_mat.shape[0]                        # mask row padding
    w_match = jnp.take_along_axis(w_v, pos_c, axis=1)
    shared_dot = jnp.sum(jnp.where(hit, w_u * w_match, 0.0), axis=1)
    shared_cnt = jnp.sum(hit, axis=1)

    if measure == "cosine":
        # closed-neighborhood dot: open shared dot + x=u and x=v terms
        closed_dot = shared_dot + 2.0 * ew
        return closed_dot / (norms[eu] * norms[ev])
    elif measure == "jaccard":
        c = shared_cnt.astype(jnp.float32) + 2.0            # + {u, v}
        union = cdeg[eu].astype(jnp.float32) + cdeg[ev].astype(jnp.float32) - c
        return c / union
    raise ValueError(f"unknown measure {measure!r}")


def _pow2_bucket(total: int, floor: int = 64) -> int:
    """Smallest power-of-two ≥ ``total`` (≥ ``floor``) — the fixed chunk
    shapes that let repeated subset passes share compiled kernels."""
    b = floor
    while b < total:
        b <<= 1
    return b


def edge_similarities_subset(
    g: CSRGraph,
    eu: jax.Array,
    ev: jax.Array,
    ew: jax.Array,
    measure: str = "cosine",
    chunk: int = 1 << 16,
) -> jax.Array:
    """Exact σ for an arbitrary subset of edges (endpoint arrays).

    Used for the full-graph pass, the §6.3 degree-heuristic compacted
    exact pass under LSH, and the incremental-update frontier recompute.
    Chunks are padded to power-of-two buckets so calls with similar subset
    sizes (e.g. repeated update batches) reuse one compiled kernel.
    """
    if measure not in MEASURES:
        raise ValueError(f"measure must be one of {MEASURES}")
    nbr_mat, wgt_mat, _ = padded_neighbors(g)
    norms = closed_norms(g)
    cdeg = g.closed_degrees()
    total = int(eu.shape[0])
    if total == 0:
        return jnp.zeros((0,), jnp.float32)
    chunk = min(chunk, _pow2_bucket(total))
    out = []
    for s in range(0, total, chunk):
        e = min(s + chunk, total)
        pad = chunk - (e - s)
        cu = jnp.pad(eu[s:e], (0, pad))
        cv = jnp.pad(ev[s:e], (0, pad))
        cw = jnp.pad(ew[s:e], (0, pad))
        sims = _edge_sims_chunk(cu, cv, cw, nbr_mat, wgt_mat, norms, cdeg, measure)
        out.append(sims[: e - s])
    return jnp.concatenate(out) if len(out) > 1 else out[0]


def compute_similarities(
    g: CSRGraph, measure: str = "cosine", chunk: int = 1 << 16
) -> jax.Array:
    """Exact σ for every half-edge, float32[m2]. Host-orchestrated chunking."""
    return edge_similarities_subset(g, g.edge_u, g.nbrs, g.wgts, measure, chunk)


@functools.partial(jax.jit, static_argnames=("measure",))
def _dense_sims(adj_c, eu, ev, cdeg, measure):
    prod = adj_c @ adj_c.T
    dots = prod[eu, ev]
    if measure == "cosine":
        norms = jnp.sqrt(jnp.diag(prod))
        return dots / (norms[eu] * norms[ev])
    union = cdeg[eu].astype(jnp.float32) + cdeg[ev].astype(jnp.float32) - dots
    return dots / union


def compute_similarities_dense(g: CSRGraph, measure: str = "cosine") -> jax.Array:
    """Small-graph oracle via the closed adjacency product."""
    weighted = measure == "cosine"
    adj_c = to_dense(g, closed=True, weighted=weighted)
    return _dense_sims(adj_c, g.edge_u, g.nbrs, g.closed_degrees(), measure)


def triangle_counts(g: CSRGraph) -> jax.Array:
    """|N(u) ∩ N(v)| per half-edge (the paper's triangle-counting primitive)."""
    nbr_mat, wgt_mat, _ = padded_neighbors(g)
    ones = jnp.ones_like(wgt_mat)
    norms = closed_norms(g)
    cdeg = g.closed_degrees()
    # jaccard path returns (t+2)/union; invert to t for exactness instead:
    rows_u = nbr_mat[g.edge_u]
    rows_v = nbr_mat[g.nbrs]
    pos = jax.vmap(jnp.searchsorted)(rows_v, rows_u)
    pos_c = jnp.minimum(pos, rows_v.shape[1] - 1)
    hit = jnp.take_along_axis(rows_v, pos_c, axis=1) == rows_u
    hit &= rows_u < g.n
    del ones, norms, cdeg
    return jnp.sum(hit, axis=1).astype(jnp.int32)
