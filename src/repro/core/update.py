"""Incremental GS*-Index maintenance (beyond-paper: dynamic graphs).

The paper's premise is that the index is built once and amortized over many
(μ, ε) queries — but serving workloads mutate the graph under the queries.
``apply_delta`` maintains an existing :class:`ScanIndex` under a batch of
edge inserts/deletes. The expensive parts of construction — the bucketed
similarity pass, its O(m + n) operand build, and the O(m log m) device
sorts — all shrink to the *touched* structure: σ recomputes only on the
frontier (edges incident to touched endpoints), and the degree-bucketed
``SimilarityPlan`` is itself **maintained incrementally**
(:meth:`repro.core.similarity.SimilarityPlan.apply`: touched rows re-pack
in place, class migrations move a vertex between exactly two blocks, hub
rows split/merge under the ``HUB_TILE`` rule, untouched blocks are reused
outright). What remains per batch is O(m) host data movement (CSR
reassembly, shifted NO copies, the CO merge) — measured crossover curves
live in ``benchmarks/bench_update.py`` and ``BENCH_update.json``:

  * **similarity** — σ(u, v) depends only on N̄(u) and N̄(v), so an edit
    batch changes σ exactly for edges with a touched endpoint. The live
    plan's successor is derived block-patch-wise (``plan.apply``, work
    proportional to touched rows/classes, seeded into the plan cache for
    the post-edit graph) and the frontier routes through it exactly as in
    construction: (probe class, target class) kernels, power-of-two padded
    chunks → repeated update calls reuse one compiled function per class
    pair, and **only the affected degree classes re-run**; every other σ
    is carried over bit-for-bit.
  * **neighbor order (NO)** — rows whose content changed (touched vertices
    and their current neighbors) are re-sorted locally; every other row is
    copied with a position shift (its sorted content is unchanged, only
    its CSR offset moved).
  * **core order (CO)** — entries of unaffected rows keep their relative
    order in the (μ asc, θ desc, v asc) global sort, so CO repair is a
    *merge* of the kept entries with the re-sorted affected entries —
    O(m) movement, no global sort.

**Bit-identity with rebuild** is the maintained invariant (asserted by the
edit-script oracle in ``tests/test_incremental_index.py``): after any
update sequence the index equals ``build_index(from_edge_list(n, edges))``
array-for-array. Two properties make that possible:

  1. every sort key used during construction is *unique* (a NO slot is
     keyed by (row, -σ, ¬self, nbr); a CO slot by (μ, -θ, v)), so host
     ``np.lexsort`` and device ``jnp.lexsort`` agree exactly;
  2. σ bit patterns depend only on *local* quantities — the two endpoint
     rows, their power-of-two degree-class widths/tile counts, and the
     endpoint norms — all of which change exactly for touched endpoints.
     The degree-bucketed engine therefore needs **no global fallback**:
     the old dense-padded layout's "padded width changed → full σ
     recompute" escape hatch is gone, because a hub edit perturbs only its
     own degree class, never every vertex's kernel width.

Deletes are applied before inserts, so a delete+insert of the same edge in
one batch re-inserts it (with the new weight). Deleting an absent edge and
re-inserting an identical one are no-ops and do not grow the frontier.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph, from_edge_list
from repro.core.index import ScanIndex
from repro.core import similarity as sim_mod


MAX_VERTEX_ID = 2 ** 31 - 1   # the packed (u, v) merge key is one int64


def _pack(u: np.ndarray, v: np.ndarray) -> np.ndarray:
    """Order-preserving (u, v) → int64 key. Ids must fit in 31 bits —
    enforced at :meth:`EdgeDelta.make` / ``from_edge_list`` (a wider id
    would silently collide keys and corrupt the CO merge)."""
    return (u.astype(np.int64) << 32) | v.astype(np.int64)


def _check_id_width(*arrays) -> None:
    """Reject vertex ids the packed int64 edit keys cannot represent."""
    for a in arrays:
        if len(a) and int(np.max(a)) > MAX_VERTEX_ID:
            raise ValueError(
                f"vertex id {int(np.max(a))} exceeds {MAX_VERTEX_ID} "
                "(2**31 - 1): ids must fit in 31 bits for the packed "
                "edit-merge keys")


@dataclasses.dataclass(frozen=True)
class EdgeDelta:
    """One canonical batch of undirected edge edits.

    Arrays hold canonical (u < v) endpoint pairs: ``del_*`` first, then
    ``ins_*`` with per-edge weights. Build via :meth:`make` (dedups,
    canonicalizes, drops self-loops; duplicate inserts keep the last
    weight).
    """

    ins_u: np.ndarray   # int64[K]
    ins_v: np.ndarray   # int64[K]
    ins_w: np.ndarray   # float32[K]
    del_u: np.ndarray   # int64[L]
    del_v: np.ndarray   # int64[L]

    @staticmethod
    def make(
        inserts: Optional[Sequence[Tuple[int, int]] | np.ndarray] = None,
        deletes: Optional[Sequence[Tuple[int, int]] | np.ndarray] = None,
        weights: Optional[Sequence[float] | np.ndarray] = None,
    ) -> "EdgeDelta":
        ins = np.asarray(inserts if inserts is not None else [],
                         dtype=np.int64).reshape(-1, 2)
        dels = np.asarray(deletes if deletes is not None else [],
                          dtype=np.int64).reshape(-1, 2)
        _check_id_width(ins.reshape(-1), dels.reshape(-1))
        if weights is None:
            w = np.ones(len(ins), dtype=np.float32)
        else:
            w = np.asarray(weights, dtype=np.float32)
            if len(w) != len(ins):
                raise ValueError("weights length must match inserts length")
        keep = ins[:, 0] != ins[:, 1]
        ins, w = ins[keep], w[keep]
        ilo = np.minimum(ins[:, 0], ins[:, 1])
        ihi = np.maximum(ins[:, 0], ins[:, 1])
        # duplicate inserts: LAST weight wins (unique-first on the reversal)
        _, first = np.unique(_pack(ilo, ihi)[::-1], return_index=True)
        sel = len(ilo) - 1 - first
        ilo, ihi, w = ilo[sel], ihi[sel], w[sel]

        dels = dels[dels[:, 0] != dels[:, 1]]
        dlo = np.minimum(dels[:, 0], dels[:, 1])
        dhi = np.maximum(dels[:, 0], dels[:, 1])
        _, first = np.unique(_pack(dlo, dhi), return_index=True)
        dlo, dhi = dlo[first], dhi[first]
        return EdgeDelta(ins_u=ilo, ins_v=ihi, ins_w=w.astype(np.float32),
                         del_u=dlo, del_v=dhi)

    def __len__(self) -> int:
        return len(self.ins_u) + len(self.del_u)


def random_delta(g: CSRGraph, k: int, rng) -> EdgeDelta:
    """K synthetic edits against ``g``: ~K/2 deletes of existing edges,
    ~K/2 random inserts (shared by the bench and the CLI edit stream)."""
    eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
    canon = np.flatnonzero(eu < ev)
    n_del = min(k // 2, len(canon))
    pick = (rng.choice(canon, size=n_del, replace=False)
            if n_del else np.zeros(0, np.int64))
    dels = np.stack([eu[pick], ev[pick]], axis=1)
    ins = rng.integers(0, g.n, size=(k - n_del, 2))
    w = rng.uniform(0.1, 1.0, size=len(ins)).astype(np.float32)
    return EdgeDelta.make(inserts=ins, weights=w, deletes=dels)


@dataclasses.dataclass(frozen=True)
class UpdateInfo:
    """What one ``apply_delta`` actually did (observability + bench)."""

    n_inserted: int        # effective inserts (new edge or weight change)
    n_deleted: int         # effective deletes (edge existed)
    n_touched: int         # endpoints whose neighborhood changed
    n_frontier: int        # half-edges whose σ was recomputed
    n_affected_rows: int   # NO rows re-sorted (touched ∪ their neighbors)
    n_sim_groups: int      # degree-class kernel groups the frontier ran
    n_plan_rows: int = 0   # block tile rows SimilarityPlan.apply rewrote
    n_plan_classes: int = 0  # class blocks not reused (patched/remapped/built)
    # Vertices whose *local* query result could differ from the
    # predecessor index: the affected rows (touched ∪ frontier-edge
    # endpoints — every vertex whose core bit, row order, or incident σ
    # could have changed) closed under two adjacency hops of the new
    # graph. Two hops because a border can re-attach into a cluster it
    # never touched: an edit flips a core bit at z, z's neighbor b falls
    # through to its next-best core c, and b joins c's cluster — c is
    # two hops from z. Any seed outside this set, whose members avoid
    # it, provably keeps a bit-identical answer (the serve layer's
    # seed-cache invalidation rule).
    frontier_vertices: Optional[np.ndarray] = None  # int ids, sorted

    def stale_mask(self, n: int) -> np.ndarray:
        """bool[n] over :attr:`frontier_vertices` (empty → all-False)."""
        mask = np.zeros(n, dtype=bool)
        if self.frontier_vertices is not None:
            mask[self.frontier_vertices] = True
        return mask


def _edit_edge_set(g: CSRGraph, delta: EdgeDelta):
    """Apply the batch to the canonical edge set (host side).

    Returns (new_lo, new_hi, new_w, touched_vertex_ids, n_ins, n_del) —
    ``touched`` holds only endpoints of *effective* edits.
    """
    eu = np.asarray(g.edge_u)
    ev = np.asarray(g.nbrs)
    w = np.asarray(g.wgts)
    mask = eu < ev
    lo, hi, wc = eu[mask], ev[mask], w[mask]
    keys = _pack(lo, hi)                      # ascending (CSR lex order)

    # -- deletes first --
    dkeys = _pack(delta.del_u.astype(np.int64), delta.del_v.astype(np.int64))
    pos = np.searchsorted(keys, dkeys)
    dhit = (pos < len(keys)) & (keys[np.minimum(pos, max(len(keys) - 1, 0))]
                                == dkeys) if len(keys) else np.zeros(
                                    len(dkeys), bool)
    keep = np.ones(len(keys), dtype=bool)
    keep[pos[dhit]] = False

    # -- inserts --
    ikeys = _pack(delta.ins_u.astype(np.int64), delta.ins_v.astype(np.int64))
    ipos = np.searchsorted(keys, ikeys)
    ipresent = (ipos < len(keys)) & (
        keys[np.minimum(ipos, max(len(keys) - 1, 0))] == ikeys
    ) if len(keys) else np.zeros(len(ikeys), bool)
    ipresent &= keep[np.minimum(ipos, max(len(keys) - 1, 0))] if len(keys) \
        else False
    same_w = np.zeros(len(ikeys), dtype=bool)
    if len(keys):
        same_w[ipresent] = (
            wc[ipos[ipresent]].view(np.uint32)
            == delta.ins_w[ipresent].view(np.uint32))
    effective_ins = ~(ipresent & same_w)      # new edge OR weight change
    # rows being overwritten by an insert drop out of the kept set
    keep[ipos[ipresent]] = False

    new_lo = np.concatenate([lo[keep], delta.ins_u])
    new_hi = np.concatenate([hi[keep], delta.ins_v])
    new_w = np.concatenate([wc[keep], delta.ins_w]).astype(np.float32)
    order = np.argsort(_pack(new_lo, new_hi), kind="stable")
    new_lo, new_hi, new_w = new_lo[order], new_hi[order], new_w[order]

    touched = np.unique(np.concatenate([
        delta.del_u[dhit], delta.del_v[dhit],
        delta.ins_u[effective_ins], delta.ins_v[effective_ins]]))
    return (new_lo, new_hi, new_w, touched,
            int(effective_ins.sum()), int(dhit.sum()))


def _repair_no(index: ScanIndex, g2: CSRGraph, sims2: np.ndarray,
               aff_mask: np.ndarray):
    """New NO arrays: shifted copy for unaffected rows, local sort for
    affected rows. Returns (offsets_c_new, no_nbrs, no_sims, no_self,
    row_of_new_slot)."""
    n = g2.n
    off2 = np.asarray(g2.offsets)
    eu2 = np.asarray(g2.edge_u)
    ev2 = np.asarray(g2.nbrs)
    cdeg_old = np.asarray(index.cdeg)
    cdeg_new = np.diff(off2) + 1
    offc_old = np.asarray(index.offsets_c)
    offc_new = (off2 + np.arange(n + 1, dtype=np.int32)).astype(np.int32)
    m2c_new = g2.m2 + n

    row_old = np.repeat(np.arange(n), cdeg_old)
    row_new = np.repeat(np.arange(n), cdeg_new)

    no_nbrs = np.empty(m2c_new, np.int32)
    no_sims = np.empty(m2c_new, np.float32)
    no_self = np.empty(m2c_new, bool)

    unaff = ~aff_mask[row_old]
    if unaff.any():
        shift = offc_new[:n].astype(np.int64) - offc_old[:n]
        src = np.flatnonzero(unaff)
        dst = src + shift[row_old[src]]
        no_nbrs[dst] = np.asarray(index.no_nbrs)[src]
        no_sims[dst] = np.asarray(index.no_sims)[src]
        no_self[dst] = np.asarray(index.no_self)[src]

    aff_rows = np.flatnonzero(aff_mask)
    if len(aff_rows):
        aff_edge = aff_mask[eu2]
        rows_a = np.concatenate([aff_rows, eu2[aff_edge]])
        nbrs_a = np.concatenate([aff_rows, ev2[aff_edge]])
        sims_a = np.concatenate([
            np.ones(len(aff_rows), np.float32), sims2[aff_edge]])
        notself_a = np.concatenate([
            np.zeros(len(aff_rows), np.int32),
            np.ones(int(aff_edge.sum()), np.int32)])
        # same (unique) key order as _build_orders' global NO sort
        perm = np.lexsort((nbrs_a, notself_a, -sims_a, rows_a))
        dst = np.flatnonzero(aff_mask[row_new])
        no_nbrs[dst] = nbrs_a[perm].astype(np.int32)
        no_sims[dst] = sims_a[perm]
        no_self[dst] = notself_a[perm] == 0
    return offc_new, no_nbrs, no_sims, no_self, row_new


def _merge_co(kept_v, kept_t, kept_mu, new_v, new_t, new_mu, n, max_cdeg):
    """Merge two (μ asc, θ desc, v asc)-sorted CO entry runs.

    Keys are packed into uint64 when they fit (μ | sortable(-θ) | v) so the
    merge is two searchsorteds; otherwise falls back to one stable lexsort
    over the concatenation (still exact — keys are unique)."""
    total = len(kept_v) + len(new_v)
    co_v = np.empty(total, np.int32)
    co_t = np.empty(total, np.float32)
    vbits = max(int(n - 1).bit_length(), 1) if n > 1 else 1
    mubits = max(int(max_cdeg).bit_length(), 1)
    if mubits + 32 + vbits <= 64:
        def key(mu, t, v):
            tdesc = np.uint64(0xFFFFFFFF) - t.astype(np.float32).view(
                np.uint32).astype(np.uint64)
            return ((mu.astype(np.uint64) << np.uint64(32 + vbits))
                    | (tdesc << np.uint64(vbits)) | v.astype(np.uint64))
        kk = key(kept_mu, kept_t, kept_v)
        nk = key(new_mu, new_t, new_v)
        pos_k = np.arange(len(kk)) + np.searchsorted(nk, kk)
        pos_n = np.arange(len(nk)) + np.searchsorted(kk, nk)
        co_v[pos_k], co_t[pos_k] = kept_v, kept_t
        co_v[pos_n], co_t[pos_n] = new_v, new_t
    else:  # pragma: no cover - graphs beyond the packable id range
        mu = np.concatenate([kept_mu, new_mu])
        t = np.concatenate([kept_t, new_t]).astype(np.float32)
        v = np.concatenate([kept_v, new_v])
        perm = np.lexsort((v, -t, mu))
        co_v, co_t = v[perm].astype(np.int32), t[perm]
    return co_v, co_t


def apply_delta(
    index: ScanIndex,
    g: CSRGraph,
    delta: EdgeDelta,
    measure: str = "cosine",
) -> Tuple[ScanIndex, CSRGraph, UpdateInfo]:
    """Maintain (index, graph) under one edit batch.

    Returns ``(new_index, new_graph, info)``; the inputs are untouched
    (both are frozen dataclasses), so callers can hot-swap atomically.
    The result is bit-identical to ``build_index(new_graph, measure)``.
    """
    n = g.n
    if len(delta.ins_u) and (int(delta.ins_v.max()) >= n
                             or int(delta.ins_u.min()) < 0):
        raise ValueError("insert endpoint out of range")
    if len(delta.del_u) and (int(delta.del_v.max()) >= n
                             or int(delta.del_u.min()) < 0):
        raise ValueError("delete endpoint out of range")

    new_lo, new_hi, new_w, touched, n_ins, n_del = _edit_edge_set(g, delta)
    g2 = from_edge_list(n, np.stack([new_lo, new_hi], axis=1)
                        if len(new_lo) else np.zeros((0, 2), np.int64),
                        new_w)
    eu2 = np.asarray(g2.edge_u)
    ev2 = np.asarray(g2.nbrs)

    touched_mask = np.zeros(n, dtype=bool)
    touched_mask[touched] = True
    frontier = (touched_mask[eu2] | touched_mask[ev2]) if g2.m2 else \
        np.zeros(0, dtype=bool)

    # ---- bucketed plan: patch the live blocks, never rebuild O(m) ----
    # The predecessor plan (cached per live graph; built once if this is
    # the first delta against a cold graph) is maintained block-patch-wise
    # and seeded into the cache for g2 — construction work per batch is
    # proportional to touched rows/classes.
    plan2 = sim_mod.adopt_plan(
        g2, sim_mod.plan_for(g).apply(g2, touched))
    pstats = plan2.last_apply

    # ---- σ: carry unchanged edges, recompute the frontier ----
    # Per-edge kernel widths are local degree classes, so an edit can never
    # invalidate a carried σ bit pattern: only the frontier's own degree
    # classes re-run, whatever the batch does to the degree distribution.
    sims2 = np.empty(g2.m2, np.float32)
    n_sim_groups = 0
    if (~frontier).any():
        hk_old = _pack(np.asarray(g.edge_u), np.asarray(g.nbrs))
        hk_new = _pack(eu2[~frontier], ev2[~frontier])
        sims2[~frontier] = np.asarray(index.edge_sims)[
            np.searchsorted(hk_old, hk_new)]
    n_frontier = int(frontier.sum())
    if n_frontier:
        fr = plan2.edge_sims(
            eu2[frontier], ev2[frontier],
            np.asarray(g2.wgts)[frontier], measure)
        sims2[frontier] = np.clip(np.asarray(fr), 0.0, 1.0)
        n_sim_groups = plan2.last_groups

    # ---- NO repair ----
    aff_mask = touched_mask.copy()
    if g2.m2:
        aff_mask[eu2[frontier]] = True
    offc_new, no_nbrs, no_sims, no_self, row_new = _repair_no(
        index, g2, sims2, aff_mask)

    # ---- CO repair (merge) ----
    m2c_new = g2.m2 + n
    mu_slot = (np.arange(m2c_new, dtype=np.int64)
               - offc_new[row_new].astype(np.int64) + 1)
    co_old_v = np.asarray(index.co_vertex)
    co_old_t = np.asarray(index.co_theta)
    co_off_old = np.asarray(index.co_offsets)
    co_old_mu = (np.searchsorted(
        co_off_old, np.arange(len(co_old_v)), side="right") - 1) \
        if len(co_old_v) else np.zeros(0, np.int64)
    kept = ~aff_mask[co_old_v] if len(co_old_v) else np.zeros(0, bool)

    aff_co = aff_mask[row_new] & (mu_slot >= 2)
    av = row_new[aff_co]
    at = no_sims[aff_co]
    amu = mu_slot[aff_co]
    perm = np.lexsort((av, -at, amu))
    av, at, amu = av[perm], at[perm], amu[perm]

    cdeg_new = (np.diff(np.asarray(g2.offsets)) + 1).astype(np.int32)
    max_cdeg = int(cdeg_new.max()) if n else 1
    co_v, co_t = _merge_co(co_old_v[kept], co_old_t[kept], co_old_mu[kept],
                           av, at, amu, n, max_cdeg)

    counts = np.bincount(
        np.concatenate([co_old_mu[kept], amu]).astype(np.int64),
        minlength=max_cdeg + 1)
    co_offsets = np.concatenate(
        [np.zeros(1, np.int64), np.cumsum(counts)]).astype(np.int32)

    new_index = ScanIndex(
        offsets_c=jnp.asarray(offc_new),
        no_nbrs=jnp.asarray(no_nbrs),
        no_sims=jnp.asarray(no_sims),
        no_self=jnp.asarray(no_self),
        co_offsets=jnp.asarray(co_offsets),
        co_vertex=jnp.asarray(co_v),
        co_theta=jnp.asarray(co_t),
        cdeg=jnp.asarray(cdeg_new),
        edge_sims=jnp.asarray(sims2),
        n=n,
        m2c=m2c_new,
        max_cdeg=max_cdeg,
    )
    # seed-cache invalidation set: affected rows closed under two
    # adjacency hops of the new graph (see UpdateInfo.frontier_vertices
    # for why two) — O(m) boolean gathers, host-side
    stale = aff_mask.copy()
    for _ in range(2):
        ext = np.zeros(n, dtype=bool)
        if g2.m2:
            ext[ev2[stale[eu2]]] = True
        stale |= ext

    info = UpdateInfo(
        n_inserted=n_ins, n_deleted=n_del, n_touched=len(touched),
        n_frontier=n_frontier, n_affected_rows=int(aff_mask.sum()),
        n_sim_groups=n_sim_groups,
        n_plan_rows=pstats["rows_written"],
        n_plan_classes=(pstats["patched"] + pstats["remapped"]
                       + pstats["built"]),
        frontier_vertices=np.flatnonzero(stale))
    return new_index, g2, info
