"""Deterministic, stateless, shard-aware synthetic LM data pipeline.

Every batch is a pure function of (seed, step) — restart/elasticity come for
free: after restoring a checkpoint at step k the pipeline resumes at k with
no state to recover, and re-sharding to a different mesh re-slices the same
global batch. A Zipf-ish unigram mix with short-range induction patterns
gives models something learnable (loss visibly decreases in examples).

The SCAN bridge: ``doc_similarity_graph`` builds a document-similarity graph
over batches (shingle Jaccard) that examples feed to the SCAN engine for
dedup/curation — the paper's technique as a first-class data-pipeline stage.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.core.graph import CSRGraph, from_edge_list


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    accum: int = 1         # leading microbatch axis
    frontend: str = "none"
    d_model: int = 0       # for stub embedding inputs
    n_frames: int = 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        b, s = self.global_batch, self.seq_len
        # zipf unigrams folded into vocab + induction-head copy patterns
        base = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64) % self.vocab
        period = 1 + (step % 7)
        copy_from = np.maximum(np.arange(s + 1) - period, 0)
        mix = rng.random((b, s + 1)) < 0.5
        tokens = np.where(mix, base, base[:, copy_from])
        tokens = tokens.astype(np.int32)
        out = {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:].astype(np.int32),
        }
        if self.frontend == "vision_stub":
            out = {
                "embeddings": rng.standard_normal(
                    (b, s, self.d_model)).astype(np.float32),
                "labels": out["labels"],
            }
        elif self.frontend == "audio_stub":
            out["frames"] = rng.standard_normal(
                (b, self.n_frames, self.d_model)).astype(np.float32)
        if self.accum > 1:
            assert b % self.accum == 0
            out = {k: v.reshape(self.accum, b // self.accum, *v.shape[1:])
                   for k, v in out.items()}
        return out

    def shard_slice(self, step: int, shard: int, n_shards: int):
        """The rows of the global batch owned by a data shard (host-level
        ingestion path for multi-process launches)."""
        full = self.batch(step)
        b = self.global_batch // n_shards
        return {k: v[..., shard * b:(shard + 1) * b, :] if v.ndim >= 2 else v
                for k, v in full.items()}


def doc_similarity_graph(
    docs: np.ndarray, shingle: int = 3, min_shared: int = 1
) -> CSRGraph:
    """Document-similarity graph for SCAN-based dedup/curation.

    Vertices = documents (token rows); edges connect documents sharing at
    least ``min_shared`` shingles (k-gram hashes). SCAN clustering over this
    graph groups near-duplicates; cores of large clusters are dedup
    candidates, hubs are boundary/template docs.
    """
    n, s = docs.shape
    hashes = []
    for i in range(n):
        grams = {
            hash(tuple(docs[i, j: j + shingle].tolist())) & 0x7FFFFFFF
            for j in range(0, s - shingle + 1, shingle)
        }
        hashes.append(grams)
    edges = []
    for i in range(n):
        for j in range(i + 1, n):
            if len(hashes[i] & hashes[j]) >= min_shared:
                edges.append((i, j))
    if not edges:
        edges = [(0, min(1, n - 1))] if n > 1 else []
    return from_edge_list(n, np.asarray(edges, dtype=np.int64))
