"""Distribution layer: sharding rules, expert parallelism, fault tolerance.

Submodules (imported lazily by callers to keep device state untouched):
  * ``sharding``        — :class:`Sharder`, the mesh→PartitionSpec rule engine.
  * ``ep``              — explicit shard_map expert-parallel MoE FFN.
  * ``fault_tolerance`` — :class:`Supervisor`, the restart/resume train loop.
"""
