"""Explicit expert parallelism (shard_map) for the MoE FFN.

The pjit baseline in ``models/moe.py`` scatters data-sharded tokens into an
expert-sharded ``[E, capacity, d]`` buffer and lets XLA pick the
collectives. This module is the explicit variant: a ``shard_map`` over the
(data, model) mesh where every device

  1. all-gathers the token shard over the data axes (routing is replicated
     math — identical top-k and capacity positions on every device, so no
     f32 cotangent crosses the shard boundary);
  2. builds the dispatch buffer *only for its local experts* (the ``model``
     axis owns ``E / tp`` experts each) and runs the three expert einsums;
  3. psum-combines the weighted expert outputs over the ``model`` axis
     (each (token, slot) lives on exactly one expert shard; dropped slots
     contribute zero everywhere) and slices its own token rows back out.

Capacity, ordering and renormalized router weights are computed from the
*global* token count, so outputs match the pjit baseline to float tolerance.

The mesh is process-global state (``set_ep_mesh``) because the config that
selects ``moe_impl="ep"`` is a frozen dataclass threaded through jit — the
mesh handle cannot ride along as a traced value.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

_EP_MESH = None
_EP_DP_AXES: Tuple[str, ...] = ()
_EP_AXIS: str = "model"


def set_ep_mesh(mesh, dp_axes: Optional[Tuple[str, ...]] = None,
                ep_axis: str = "model") -> None:
    """Install (or clear, with ``mesh=None``) the EP mesh."""
    global _EP_MESH, _EP_DP_AXES, _EP_AXIS
    _EP_MESH = mesh
    _EP_DP_AXES = tuple(dp_axes) if dp_axes else ()
    _EP_AXIS = ep_axis


def ep_enabled() -> bool:
    return _EP_MESH is not None


def ep_ffn(xf, router, w_gate, w_up, w_down, cfg):
    """Expert-parallel routed FFN. ``xf``: [T, d] (data-sharded), expert
    weights [E, ...] (sharded over the EP axis). Returns [T, d]."""
    mesh, dp_axes, ep_axis = _EP_MESH, _EP_DP_AXES, _EP_AXIS
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = max(int(cfg.capacity_factor * t * k / e), 1)
    dp_entry = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None)

    def shard_fn(x_l, router_l, wg, wu, wd):
        # ---- replicate tokens within the expert group ----
        x_g = x_l
        for a in reversed(dp_axes):          # inner-most axis first
            x_g = jax.lax.all_gather(x_g, a, axis=0, tiled=True)

        # ---- routing (replicated math, same as the pjit baseline) ----
        logits = (x_g @ router_l.astype(x_g.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_i = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        flat_e = top_i.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        counts = jnp.bincount(flat_e, length=e)
        starts = jnp.cumsum(counts) - counts
        pos = (jnp.arange(t * k, dtype=jnp.int32)
               - starts[sorted_e].astype(jnp.int32))
        keep = pos < cap

        # ---- local experts only ----
        e_l = wg.shape[0]
        e0 = jax.lax.axis_index(ep_axis) * e_l
        local = keep & (sorted_e >= e0) & (sorted_e < e0 + e_l)
        dest = jnp.where(local, (sorted_e - e0) * cap + pos, e_l * cap)
        src_token = order // k
        buf = jnp.zeros((e_l * cap, d), x_g.dtype).at[dest].set(
            x_g[src_token], mode="drop")
        h = buf.reshape(e_l, cap, d)
        act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, wg))
        act = act * jnp.einsum("ecd,edf->ecf", h, wu)
        out_buf = jnp.einsum("ecf,efd->ecd", act, wd).reshape(e_l * cap, d)

        dest_of_slot = jnp.zeros((t * k,), jnp.int32).at[order].set(
            jnp.where(local, dest, e_l * cap).astype(jnp.int32))
        padded = jnp.concatenate(
            [out_buf, jnp.zeros((1, d), x_g.dtype)], axis=0)
        expert_out = padded[dest_of_slot].reshape(t, k, d)
        combined = jnp.sum(
            expert_out * top_p[..., None].astype(x_g.dtype), axis=1)
        combined = jax.lax.psum(combined, ep_axis)

        # ---- back to this device's token rows ----
        idx = 0
        for a in dp_axes:                    # outer-major linear index
            idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
        t_l = x_l.shape[0]
        return jax.lax.dynamic_slice_in_dim(combined, idx * t_l, t_l, axis=0)

    return shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(P(dp_entry, None), P(None, None),
                  P(ep_axis, None, None), P(ep_axis, None, None),
                  P(ep_axis, None, None)),
        out_specs=P(dp_entry, None),
        check_rep=False,
    )(xf, router, w_gate, w_up, w_down)
