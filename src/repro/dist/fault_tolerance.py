"""Fault-tolerant training supervisor.

The supervisor wraps the inner ``step_fn`` loop with the three behaviours a
long-running multi-host job needs:

* **restart-on-failure** — a step that raises is retried (up to
  ``max_retries_per_step``) after restoring the last committed checkpoint,
  so a flaky node loses at most ``ckpt_every`` steps of work;
* **resume** — a fresh supervisor pointed at a populated ``ckpt_dir``
  continues from the latest committed step instead of step 0 (elastic
  restart path);
* **straggler detection** — per-step wall times are compared against a
  running mean; ``straggler_factor``× slowdowns sustained for
  ``straggler_patience`` consecutive steps flag a persistent straggler
  (the caller decides whether to re-mesh).

Every decision is recorded in ``self.events`` as ``(step, kind, detail)``
tuples — the audit log the tests (and an operator) read.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from repro.ckpt import checkpoint


@dataclasses.dataclass(frozen=True)
class SupervisorConfig:
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 100
    keep: int = 3
    max_retries_per_step: int = 3
    straggler_factor: float = 2.0
    straggler_patience: int = 3


class Supervisor:
    """Runs ``state = step_fn(params, opt_state, batch)`` with checkpointed
    restart/resume. ``state`` is ``{"params", "opt_state", "step"}``."""

    def __init__(self, cfg: SupervisorConfig):
        self.cfg = cfg
        self.events: list[tuple] = []
        self._step_times: list[float] = []
        self._straggler_streak = 0
        self._stop_requested = False

    # ------------------------------------------------------------------
    # event log
    # ------------------------------------------------------------------
    def _event(self, step: int, kind: str, detail: str = "") -> None:
        self.events.append((step, kind, detail))

    # ------------------------------------------------------------------
    # straggler detection
    # ------------------------------------------------------------------
    def observe_step_time(self, step: int, seconds: float) -> bool:
        """Record one step's wall time; True if it looks like a straggler."""
        prior = self._step_times
        is_straggler = bool(
            prior
            and seconds > self.cfg.straggler_factor * (sum(prior) / len(prior))
        )
        if is_straggler:
            self._straggler_streak += 1
            self._event(step, "straggler", f"{seconds:.3f}s")
        else:
            self._straggler_streak = 0
            self._step_times.append(seconds)
        return is_straggler

    def straggler_persistent(self) -> bool:
        return self._straggler_streak >= self.cfg.straggler_patience

    # ------------------------------------------------------------------
    # signals
    # ------------------------------------------------------------------
    def install_signal_handlers(self) -> None:
        """SIGTERM/SIGINT request a graceful checkpoint-and-exit. No-op when
        not on the main thread (e.g. under a test runner)."""

        def _handler(signum, frame):  # noqa: ARG001
            self._stop_requested = True

        try:
            signal.signal(signal.SIGTERM, _handler)
            signal.signal(signal.SIGINT, _handler)
        except ValueError:  # not the main thread
            pass

    # ------------------------------------------------------------------
    # checkpoint plumbing
    # ------------------------------------------------------------------
    def _save(self, state: Dict[str, Any], step: int) -> None:
        if not self.cfg.ckpt_dir:
            return
        tree = {
            "params": state["params"],
            "opt_state": state["opt_state"],
            "step": jnp.int32(step),
        }
        checkpoint.save(self.cfg.ckpt_dir, step, tree, keep=self.cfg.keep)
        self._event(step, "checkpoint", "")

    def _restore(self, like: Dict[str, Any], step: int, shardings) -> Dict[str, Any]:
        like_tree = {
            "params": like["params"],
            "opt_state": like["opt_state"],
            "step": jnp.int32(0),
        }
        sh_tree = None
        if shardings is not None:
            sh_tree = {
                "params": shardings.get("params"),
                "opt_state": shardings.get("opt_state"),
                "step": None,
            }
        tree = checkpoint.restore(self.cfg.ckpt_dir, step, like_tree,
                                  shardings=sh_tree)
        return {"params": tree["params"], "opt_state": tree["opt_state"],
                "step": int(tree["step"])}

    # ------------------------------------------------------------------
    # the loop
    # ------------------------------------------------------------------
    def run(
        self,
        state: Dict[str, Any],
        step_fn: Callable,
        get_batch: Callable[[int], Any],
        total_steps: int,
        *,
        shardings: Optional[Dict[str, Any]] = None,
        hooks: Optional[Dict[str, Callable]] = None,
    ) -> Dict[str, Any]:
        hooks = hooks or {}
        initial = state

        latest = (checkpoint.latest_step(self.cfg.ckpt_dir)
                  if self.cfg.ckpt_dir else None)
        if latest is not None and latest > int(state["step"]):
            state = self._restore(initial, latest, shardings)
            self._event(latest, "resume", f"from step {latest}")

        step = int(state["step"])
        retries = 0
        while step < total_steps:
            if self._stop_requested:
                self._save(state, step)
                self._event(step, "preempted", "signal")
                break
            batch = get_batch(step)
            t0 = time.time()
            try:
                params, opt_state, metrics = step_fn(
                    state["params"], state["opt_state"], batch)
            except Exception as e:  # noqa: BLE001 — injected node failures
                retries += 1
                self._event(step, "failure", repr(e))
                if retries > self.cfg.max_retries_per_step:
                    raise
                latest = (checkpoint.latest_step(self.cfg.ckpt_dir)
                          if self.cfg.ckpt_dir else None)
                if latest is not None:
                    state = self._restore(initial, latest, shardings)
                    step = int(state["step"])
                    self._event(step, "restart", f"rolled back to {step}")
                else:
                    self._event(step, "restart", "retrying in place")
                continue
            retries = 0
            step += 1
            state = {"params": params, "opt_state": opt_state, "step": step}
            self.observe_step_time(step, time.time() - t0)
            if "on_step" in hooks:
                hooks["on_step"](step, metrics)
            if self.cfg.ckpt_dir and step % self.cfg.ckpt_every == 0:
                self._save(state, step)
        if (self.cfg.ckpt_dir and not self._stop_requested
                and checkpoint.latest_step(self.cfg.ckpt_dir) != step):
            self._save(state, step)
        return state
