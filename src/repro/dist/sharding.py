"""Mesh → PartitionSpec rule engine.

One class owns every sharding decision so launchers, the dry-run driver and
tests agree on the layout:

* **params** — tensor-parallel over the ``model`` axis. The rule is a
  fallback chain, not a name table: shard the largest dim divisible by the
  TP degree, else the next, else replicate. The chain guarantees the
  invariant the tests pin down — a sharded dim always divides its mesh-axis
  size, and nothing ≥ 64M elements stays fully replicated on a 16-way mesh.
* **optimizer state** — params layout plus ZeRO-1: the fp32 m/v/master
  trees additionally shard their largest replicated dim over the data
  axes, shrinking per-device optimizer bytes by the full mesh size.
* **batches** — data-parallel over the non-``model`` axes (axis 0, or
  axis 1 under a leading gradient-accumulation axis).
* **decode/prefill caches** — batch over data axes; the largest remaining
  TP-divisible dim (heads, or sequence for long caches) over ``model``.

All meshes here use ``AxisType.Auto``, so a spec is a layout request —
XLA inserts the collectives that keep the math identical to the
unsharded program.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_REPLICATE_BELOW = 1 << 16   # leaves smaller than this stay replicated


def _is_spec(x) -> bool:
    return isinstance(x, P)


class Sharder:
    def __init__(self, mesh, cfg):
        self.mesh = mesh
        self.cfg = cfg
        shape = dict(mesh.shape)
        self.tp = int(shape.get("model", 1))
        self.dp_axes = tuple(a for a in shape if a != "model")
        self.dp = 1
        for a in self.dp_axes:
            self.dp *= int(shape[a])

    # ------------------------------------------------------------------
    # spec → sharding plumbing
    # ------------------------------------------------------------------
    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def tree_named(self, specs):
        return jax.tree.map(self.named, specs, is_leaf=_is_spec)

    def _dp_entry(self):
        if not self.dp_axes:
            return None
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    # ------------------------------------------------------------------
    # parameters
    # ------------------------------------------------------------------
    def _param_spec(self, shape) -> P:
        shape = tuple(int(s) for s in shape)
        size = int(np.prod(shape)) if shape else 1
        if self.tp <= 1 or not shape or size < _REPLICATE_BELOW:
            return P(*([None] * len(shape)))
        entries = [None] * len(shape)
        # largest divisible dim wins; later dims break ties (the contraction
        # output dim, which keeps matmul outputs sharded like megatron)
        for d in sorted(range(len(shape)),
                        key=lambda d: (shape[d], d), reverse=True):
            if shape[d] >= self.tp and shape[d] % self.tp == 0:
                entries[d] = "model"
                break
        return P(*entries)

    def param_specs(self, params):
        return jax.tree.map(lambda leaf: self._param_spec(leaf.shape), params)

    # ------------------------------------------------------------------
    # optimizer state (ZeRO-1 over the data axes)
    # ------------------------------------------------------------------
    def _zero_spec(self, spec: P, shape) -> P:
        shape = tuple(int(s) for s in shape)
        entries = list(spec) + [None] * (len(shape) - len(spec))
        dp_entry = self._dp_entry()
        if self.dp > 1 and dp_entry is not None:
            for d in sorted(range(len(shape)),
                            key=lambda d: (shape[d], d), reverse=True):
                if (entries[d] is None and shape[d] >= self.dp
                        and shape[d] % self.dp == 0
                        and np.prod(shape) >= _REPLICATE_BELOW):
                    entries[d] = dp_entry
                    break
        return P(*entries)

    def opt_specs(self, pspecs, params):
        def zero_tree():
            return jax.tree.map(
                lambda sp, leaf: self._zero_spec(sp, leaf.shape),
                pspecs, params, is_leaf=_is_spec)

        return {"m": zero_tree(), "v": zero_tree(), "master": zero_tree(),
                "count": P()}

    # ------------------------------------------------------------------
    # batches and caches
    # ------------------------------------------------------------------
    def batch_specs(self, batch, leading_accum: bool = False):
        bdim = 1 if leading_accum else 0
        dp_entry = self._dp_entry()

        def spec(leaf):
            shape = tuple(int(s) for s in leaf.shape)
            if (self.dp <= 1 or dp_entry is None or len(shape) <= bdim
                    or shape[bdim] % self.dp != 0):
                return P(*([None] * len(shape)))
            entries = [None] * len(shape)
            entries[bdim] = dp_entry
            return P(*entries)

        return jax.tree.map(spec, batch)

    def cache_specs(self, cache, kind: str | None = None):  # noqa: ARG002
        dp_entry = self._dp_entry()

        def spec(leaf):
            shape = tuple(int(s) for s in leaf.shape)
            entries = [None] * len(shape)
            if (shape and self.dp > 1 and dp_entry is not None
                    and shape[0] % self.dp == 0):
                entries[0] = dp_entry
            if self.tp > 1:
                for d in sorted(range(1, len(shape)),
                                key=lambda d: (shape[d], d), reverse=True):
                    if shape[d] >= self.tp and shape[d] % self.tp == 0:
                        entries[d] = "model"
                        break
            return P(*entries)

        return jax.tree.map(spec, cache)
