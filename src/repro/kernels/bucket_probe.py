"""Pallas TPU kernel: degree-bucketed shared-neighbor probe.

The bucketed similarity engine (repro.core.similarity) routes each edge to
a fixed-shape (probe class, target class) kernel: the min-degree side's
sorted row is matched against the max-degree side's sorted row. On TPU the
heaviest classes run this kernel instead of the jnp searchsorted path.

The pattern extends ``triangle_count.py``'s masked-gram accumulation: the
matmul's k-axis becomes the **target-row tile axis**. Each grid step holds
one (be × P) probe block resident in VMEM and streams one (be × bt) tile
of the target rows past it — this is the hub-row splitting rule in kernel
form: a degree-10⁶ hub row is never materialized as one VMEM block, it
flows through in bt-wide tiles while the per-edge accumulators
(shared weighted dot, shared count) stay resident:

    dot[e]  = Σ_i Σ_j [p_ids[e,i] == t_ids[e,j]] · p_w[e,i] · t_w[e,j]
    cnt[e]  = Σ_i Σ_j [p_ids[e,i] == t_ids[e,j]]

The equality test replaces the masked-gram's multiply: instead of masking
a dense W̄·W̄ᵀ product, the id-match matrix *is* the mask and the weighted
contribution is rank-1 per hit (graphs are simple, so each probe id hits
at most once per target row). Padding must be pre-sanitized by the caller:
probe pad ids < 0 and target pad ids < 0 with **different** values (e.g.
-1 / -2) so padding never matches padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ids_ref, p_w_ref, t_ids_ref, t_w_ref, dot_ref, cnt_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        dot_ref[...] = jnp.zeros_like(dot_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    eq = p_ids_ref[...][:, :, None] == t_ids_ref[...][:, None, :]
    w = p_w_ref[...][:, :, None] * t_w_ref[...][:, None, :]
    dot_ref[...] += jnp.sum(jnp.where(eq, w, 0.0), axis=(1, 2))
    cnt_ref[...] += jnp.sum(eq, axis=(1, 2)).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("be", "bt", "interpret")
)
def bucket_probe(
    p_ids: jax.Array,   # int32[e, P]   probe rows (pad id -1)
    p_w: jax.Array,     # float32[e, P]
    t_ids: jax.Array,   # int32[e, T]   target rows (pad id -2)
    t_w: jax.Array,     # float32[e, T]
    *,
    be: int = 256,
    bt: int = 256,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(shared weighted dot float32[e], shared count int32[e]).

    ``e`` must be a multiple of ``be`` and ``T`` of ``bt``; the caller pads
    (repro.kernels.ops.bucket_probe_stats does)."""
    e, p = p_ids.shape
    t = t_ids.shape[1]
    assert p_w.shape == (e, p) and t_ids.shape == (e, t) \
        and t_w.shape == (e, t)
    assert e % be == 0, "pad edge count to a block multiple"
    assert t % bt == 0, "pad target width to a tile multiple"
    grid = (e // be, t // bt)
    return pl.pallas_call(
        _kernel,
        out_shape=(
            jax.ShapeDtypeStruct((e,), jnp.float32),
            jax.ShapeDtypeStruct((e,), jnp.int32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((be, p), lambda i, j: (i, 0)),    # probe resident
            pl.BlockSpec((be, p), lambda i, j: (i, 0)),
            pl.BlockSpec((be, bt), lambda i, j: (i, j)),   # target streams
            pl.BlockSpec((be, bt), lambda i, j: (i, j)),
        ],
        out_specs=(
            pl.BlockSpec((be,), lambda i, j: (i,)),
            pl.BlockSpec((be,), lambda i, j: (i,)),
        ),
        interpret=interpret,
    )(p_ids, p_w, t_ids, t_w)
