"""Pallas TPU kernel: fused blocked attention (flash-style, fwd).

The LM serving/prefill hot path. Online-softmax over KV blocks so the
(S_q × S_kv) score matrix never leaves VMEM: for each (batch·head, q-block)
grid cell the kernel streams KV blocks, maintaining running max m, running
denominator l, and the rescaled accumulator in VMEM scratch.

Supports causal masking (block-level early-out via the grid plus in-block
triangular mask) and an optional sliding window (for Hymba's SWA layers).
Q/K/V tiles are MXU-aligned; head_dim is expected to be a multiple of 128
after padding (the ops.py wrapper pads and slices).

Training uses the pure-JAX chunked path in models/layers.py (differentiable,
rematerialized); this kernel is the serving-path artifact validated against
ref.py in interpret mode and intended for real-TPU deployment.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, nkv: int, bq: int, bkv: int, causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # [bq, d]
    k = k_ref[0]                       # [bkv, d]
    v = v_ref[0]                       # [bkv, d]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # [bq, bkv]

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
    k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = jnp.ones((bq, bkv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # [bq, 1]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)                               # [bq, bkv]
    alpha = jnp.exp(m_prev - m_cur)                      # [bq, 1]
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_cur

    @pl.when(ki == nkv - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "bq", "bkv", "interpret"),
)
def flash_attention(
    q: jax.Array,   # [bh, sq, d]   (batch·heads flattened)
    k: jax.Array,   # [bh, skv, d]
    v: jax.Array,   # [bh, skv, d]
    *,
    causal: bool = True,
    window: int = 0,          # 0 = disabled; else sliding window size
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = True,
) -> jax.Array:
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    assert sq % bq == 0 and skv % bkv == 0
    scale = 1.0 / (d ** 0.5)
    q = (q * scale).astype(q.dtype)
    nkv = skv // bkv
    grid = (bh, sq // bq, nkv)
    return pl.pallas_call(
        functools.partial(
            _kernel, nkv=nkv, bq=bq, bkv=bkv, causal=causal, window=window
        ),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
