"""Pallas TPU kernel: per-edge sketch comparison (XOR + popcount → cos θ̂).

Consumes packed uint32 SimHash sketches gathered to edge endpoints and emits
the approximate cosine similarity per edge:

    diff = Σ_w popcount(sk_u[w] XOR sk_v[w]);  σ̂ = cos(π · diff / k)

Popcount is implemented as branch-free SWAR arithmetic (shift/mask/multiply)
— plain VPU integer ops that lower on every backend, no dependence on a
native population-count instruction. One grid dimension over edge blocks;
each block is a VMEM-resident (be × words) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _popcount_u32(x: jax.Array) -> jax.Array:
    """SWAR popcount, uint32 → uint32."""
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> 24


def _kernel(su_ref, sv_ref, o_ref, *, samples: int):
    x = jnp.bitwise_xor(su_ref[...], sv_ref[...])
    diff = jnp.sum(_popcount_u32(x), axis=-1).astype(jnp.float32)
    theta = jnp.pi * diff / samples
    o_ref[...] = jnp.cos(theta)


@functools.partial(jax.jit, static_argnames=("samples", "be", "interpret"))
def hamming_cosine(
    sk_u: jax.Array,   # uint32[e, words] sketches gathered at edge sources
    sk_v: jax.Array,   # uint32[e, words] sketches gathered at edge targets
    *,
    samples: int,
    be: int = 1024,
    interpret: bool = True,
) -> jax.Array:
    """σ̂ per edge, float32[e]. e must be a multiple of be."""
    e, words = sk_u.shape
    assert sk_v.shape == (e, words)
    assert e % be == 0, "pad edge count to a block multiple"
    return pl.pallas_call(
        functools.partial(_kernel, samples=samples),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.float32),
        grid=(e // be,),
        in_specs=[
            pl.BlockSpec((be, words), lambda i: (i, 0)),
            pl.BlockSpec((be, words), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((be,), lambda i: (i,)),
        interpret=interpret,
    )(sk_u, sk_v)
