"""jit'd public wrappers around the Pallas kernels.

Each wrapper pads inputs to block multiples, dispatches to the kernel
(interpret=True on CPU — the TPU target compiles the same kernel body), and
slices the result back. These are the entry points the SCAN engine and the
serving path call; `ref.py` holds the pure-jnp oracles used by tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import CSRGraph, to_dense
from repro.kernels.triangle_count import masked_gram
from repro.kernels.bucket_probe import bucket_probe
from repro.kernels.simhash import simhash_pack
from repro.kernels.hamming import hamming_cosine
from repro.kernels.flash_attention import flash_attention

_ON_TPU = jax.default_backend() == "tpu"
_INTERPRET = not _ON_TPU


def _pad_to(x: jax.Array, mult: int, axes) -> jax.Array:
    pads = [(0, 0)] * x.ndim
    for ax in axes:
        rem = (-x.shape[ax]) % mult
        pads[ax] = (0, rem)
    return jnp.pad(x, pads)


def edge_similarities_gram(
    g: CSRGraph, measure: str = "cosine", block: int = 128
) -> jax.Array:
    """Exact σ per half-edge via the Pallas masked-gram kernel.

    Dense-adjacency path: the TPU-native analogue of Algorithm 1 for graphs
    whose adjacency fits in memory (padded n ≤ a few 10⁴ per shard; larger
    graphs use the CSR searchsorted path in core.similarity).
    """
    weighted = measure == "cosine"
    w = to_dense(g, closed=True, weighted=weighted)
    mask = (to_dense(g, closed=True, weighted=False) > 0).astype(jnp.float32)
    n0 = w.shape[0]
    w = _pad_to(w, block, (0, 1))
    mask = _pad_to(mask, block, (0, 1))
    prod = masked_gram(w, mask, bm=block, bn=block, bk=block,
                       interpret=_INTERPRET)[:n0, :n0]
    dots = prod[g.edge_u, g.nbrs]
    if measure == "cosine":
        norms = jnp.sqrt(prod[jnp.arange(n0), jnp.arange(n0)])
        return dots / (norms[g.edge_u] * norms[g.nbrs])
    cdeg = g.closed_degrees().astype(jnp.float32)
    union = cdeg[g.edge_u] + cdeg[g.nbrs] - dots
    return dots / union


def bucket_probe_stats(
    rows_p: jax.Array,   # int32[e, P] sorted probe rows (pad id = n)
    w_p: jax.Array,      # float32[e, P]
    rows_t: jax.Array,   # int32[e, T] sorted target rows (pad id = n)
    w_t: jax.Array,      # float32[e, T]
    n: int,              # vertex count (ids ≥ n are padding)
    *,
    be: int = 256,
    bt: int = 256,
) -> tuple[jax.Array, jax.Array]:
    """(shared weighted dot, shared count) per edge via the Pallas
    degree-bucketed probe kernel (repro.kernels.bucket_probe).

    Sanitizes padding ids (probe → -1, target → -2 so pads never match),
    pads the edge axis to ``be`` and the target width to ``bt`` (the
    hub-row tile the kernel streams), and slices the results back. The
    TPU dispatch path for the heaviest degree classes; the jnp
    searchsorted engine in core.similarity is the CPU/reference path.
    """
    e0, p = rows_p.shape
    t = rows_t.shape[1]
    bt = min(bt, max(t, 1))
    pad_w = (-t) % bt
    # widen with the sentinel id n BEFORE sanitizing, so width padding
    # becomes -2 like every other target pad (0 would alias vertex id 0)
    rows_t = jnp.pad(rows_t, ((0, 0), (0, pad_w)), constant_values=n)
    w_t = jnp.pad(w_t, ((0, 0), (0, pad_w)))
    ids_p = jnp.where(rows_p < n, rows_p, -1).astype(jnp.int32)
    ids_t = jnp.where(rows_t < n, rows_t, -2).astype(jnp.int32)
    ids_p = _pad_to(ids_p, be, (0,))
    w_p = _pad_to(w_p, be, (0,))
    ids_t = _pad_to(ids_t, be, (0,))
    w_t = _pad_to(w_t, be, (0,))
    dot, cnt = bucket_probe(ids_p, w_p, ids_t, w_t, be=be, bt=bt,
                            interpret=_INTERPRET)
    return dot[:e0], cnt[:e0]


def simhash_sketches_kernel(
    g: CSRGraph, samples: int, key: jax.Array, block: int = 128
) -> jax.Array:
    """Packed SimHash sketches uint32[n, ceil(k/32)] via the Pallas kernel."""
    w = to_dense(g, closed=True, weighted=True)
    n0 = w.shape[0]
    k_pad = max((samples + 127) // 128 * 128, 128)
    r = jax.random.normal(key, (n0, k_pad), dtype=jnp.float32)
    # zero padding samples so both endpoints agree on padded bits
    r = r * (jnp.arange(k_pad) < samples)
    w = _pad_to(w, block, (0, 1))
    r = _pad_to(r, block, (0,))
    sk = simhash_pack(w, r, bm=block, bs=128, bk=block, interpret=_INTERPRET)
    return sk[:n0, : (samples + 31) // 32]


def simhash_edge_similarity_kernel(
    sketches: jax.Array, eu: jax.Array, ev: jax.Array, samples: int,
    block: int = 1024
) -> jax.Array:
    """σ̂ per edge from packed sketches via the Pallas hamming kernel."""
    e0 = eu.shape[0]
    su = _pad_to(sketches[eu], block, (0,))
    sv = _pad_to(sketches[ev], block, (0,))
    out = hamming_cosine(su, sv, samples=samples, be=block,
                         interpret=_INTERPRET)
    return out[:e0]


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    window: int = 0, bq: int = 128, bkv: int = 128
) -> jax.Array:
    """Flash attention over [bh, s, d] tensors (pads s and d to blocks)."""
    bh, sq, d0 = q.shape
    skv = k.shape[1]
    d_pad = max((d0 + 127) // 128 * 128, 128)
    qp = _pad_to(q, d_pad, (2,))
    kp = _pad_to(k, d_pad, (2,))
    vp = _pad_to(v, d_pad, (2,))
    sq_p = (sq + bq - 1) // bq * bq
    skv_p = (skv + bkv - 1) // bkv * bkv
    # pad kv with zeros & mask via window/causal handled by padding at end:
    # padded kv positions get score NEG_INF only under causal mask when
    # k_pos > q_pos; for non-causal we must not attend padding — extend the
    # causal guard by masking padded keys through an additive bias is not
    # supported here, so we require exact multiples for non-causal use.
    if not causal:
        assert sq % bq == 0 and skv % bkv == 0, "pad seq for non-causal"
    qp = _pad_to(qp, sq_p, (1,))[:, :sq_p]
    kp = _pad_to(kp, skv_p, (1,))[:, :skv_p]
    vp = _pad_to(vp, skv_p, (1,))[:, :skv_p]
    # scale uses true d0, not padded width (padding contributes zero dot)
    out = flash_attention(
        qp * (d_pad ** 0.5) / (d0 ** 0.5), kp, vp,
        causal=causal, window=window, bq=bq, bkv=bkv, interpret=_INTERPRET,
    )
    return out[:, :sq, :d0]
