"""Public wrappers around the Pallas kernels, dispatched per call.

Each wrapper pads inputs to block multiples, resolves its lane through the
:class:`repro.backend.ExecutionPolicy` (``ref`` pure-jnp oracle /
``pallas-interpret`` / ``pallas-compiled``), dispatches, and slices the
result back. Nothing here captures the backend at import time: platform
detection and the ``REPRO_LANE`` override are read on every call, so
``JAX_PLATFORMS`` set after import is honored and importing this module
never initializes the jax backend.

Block shapes default to the policy's :class:`AutotuneProfile`; explicit
``block=``/``be=``/``bt=`` arguments still win. All lanes of one op are
bit-identical on integer-valued inputs (unweighted graphs) and agree to
ULP on weighted ones — the lane-matrix oracle test in
``tests/test_backend.py`` is the gate.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.backend.padding import pad_to
from repro.backend.policy import (
    LANE_REF, ExecutionPolicy, default_policy,
)
from repro.core.graph import CSRGraph, to_dense
from repro.kernels import ref as kref
from repro.kernels.triangle_count import masked_gram
from repro.kernels.bucket_probe import bucket_probe
from repro.kernels.simhash import simhash_pack
from repro.kernels.hamming import hamming_cosine
from repro.kernels.flash_attention import flash_attention


def _resolve(policy: Optional[ExecutionPolicy], op: str,
             lane: Optional[str]) -> tuple[ExecutionPolicy, str]:
    pol = policy if policy is not None else default_policy()
    if lane is None:
        lane = pol.kernel_lane(op)
    pol.note(op, lane)
    return pol, lane


def edge_similarities_gram(
    g: CSRGraph, measure: str = "cosine", block: Optional[int] = None,
    *, policy: Optional[ExecutionPolicy] = None, lane: Optional[str] = None,
) -> jax.Array:
    """Exact σ per half-edge via the masked-gram product (triangle_count op).

    Dense-adjacency path: the TPU-native analogue of Algorithm 1 for graphs
    whose adjacency fits in memory (padded n ≤ a few 10⁴ per shard; larger
    graphs use the CSR searchsorted path in core.similarity).
    """
    pol, lane = _resolve(policy, "triangle_count", lane)
    block = block or pol.profile.gram_block
    weighted = measure == "cosine"
    w = to_dense(g, closed=True, weighted=weighted)
    mask = (to_dense(g, closed=True, weighted=False) > 0).astype(jnp.float32)
    n0 = w.shape[0]
    w = pad_to(w, block, (0, 1))
    mask = pad_to(mask, block, (0, 1))
    if lane == LANE_REF:
        prod = kref.masked_gram_ref(w, mask)[:n0, :n0]
    else:
        prod = masked_gram(w, mask, bm=block, bn=block, bk=block,
                           interpret=pol.interpret(lane))[:n0, :n0]
    dots = prod[g.edge_u, g.nbrs]
    if measure == "cosine":
        norms = jnp.sqrt(prod[jnp.arange(n0), jnp.arange(n0)])
        return dots / (norms[g.edge_u] * norms[g.nbrs])
    cdeg = g.closed_degrees().astype(jnp.float32)
    union = cdeg[g.edge_u] + cdeg[g.nbrs] - dots
    return dots / union


def probe_operands(rows_p, w_p, rows_t, w_t, n: int, be: int, bt: int):
    """Sanitize + pad bucket-probe operands (trace-safe; shared with the
    similarity engine's Pallas lane).

    Sanitizes padding ids (probe → -1, target → -2 so pads never match),
    pads the edge axis to ``be`` and the target width to ``bt`` (the
    hub-row tile the kernel streams). Widens with the sentinel id ``n``
    BEFORE sanitizing, so width padding becomes -2 like every other target
    pad (0 would alias vertex id 0). Returns (ids_p, w_p, ids_t, w_t, bt).
    """
    t = rows_t.shape[1]
    bt = min(bt, max(t, 1))
    pad_w = (-t) % bt
    rows_t = jnp.pad(rows_t, ((0, 0), (0, pad_w)), constant_values=n)
    w_t = jnp.pad(w_t, ((0, 0), (0, pad_w)))
    ids_p = jnp.where(rows_p < n, rows_p, -1).astype(jnp.int32)
    ids_t = jnp.where(rows_t < n, rows_t, -2).astype(jnp.int32)
    ids_p = pad_to(ids_p, be, (0,))
    w_p = pad_to(w_p, be, (0,))
    ids_t = pad_to(ids_t, be, (0,))
    w_t = pad_to(w_t, be, (0,))
    return ids_p, w_p, ids_t, w_t, bt


def bucket_probe_stats(
    rows_p: jax.Array,   # int32[e, P] sorted probe rows (pad id = n)
    w_p: jax.Array,      # float32[e, P]
    rows_t: jax.Array,   # int32[e, T] sorted target rows (pad id = n)
    w_t: jax.Array,      # float32[e, T]
    n: int,              # vertex count (ids ≥ n are padding)
    *,
    be: Optional[int] = None,
    bt: Optional[int] = None,
    policy: Optional[ExecutionPolicy] = None,
    lane: Optional[str] = None,
) -> tuple[jax.Array, jax.Array]:
    """(shared weighted dot, shared count) per edge via the degree-bucketed
    probe op (repro.kernels.bucket_probe; ref lane = the all-pairs
    equality oracle). The accelerator dispatch path for the heaviest
    degree classes; the jnp searchsorted engine in core.similarity is the
    host reference path."""
    pol, lane = _resolve(policy, "bucket_probe", lane)
    be = be or pol.profile.probe_be
    bt = bt or pol.profile.probe_bt
    e0 = rows_p.shape[0]
    ids_p, w_p, ids_t, w_t, bt = probe_operands(
        rows_p, w_p, rows_t, w_t, n, be, bt)
    if lane == LANE_REF:
        dot, cnt = kref.bucket_probe_ref(ids_p, w_p, ids_t, w_t)
    else:
        dot, cnt = bucket_probe(ids_p, w_p, ids_t, w_t, be=be, bt=bt,
                                interpret=pol.interpret(lane))
    return dot[:e0], cnt[:e0]


def simhash_sketches_kernel(
    g: CSRGraph, samples: int, key: jax.Array, block: Optional[int] = None,
    *, policy: Optional[ExecutionPolicy] = None, lane: Optional[str] = None,
) -> jax.Array:
    """Packed SimHash sketches uint32[n, ceil(k/32)] via the simhash op."""
    pol, lane = _resolve(policy, "simhash", lane)
    block = block or pol.profile.simhash_block
    w = to_dense(g, closed=True, weighted=True)
    n0 = w.shape[0]
    k_pad = max((samples + 127) // 128 * 128, 128)
    r = jax.random.normal(key, (n0, k_pad), dtype=jnp.float32)
    # zero padding samples so both endpoints agree on padded bits
    r = r * (jnp.arange(k_pad) < samples)
    w = pad_to(w, block, (0, 1))
    r = pad_to(r, block, (0,))
    if lane == LANE_REF:
        sk = kref.simhash_pack_ref(w, r)
    else:
        sk = simhash_pack(w, r, bm=block, bs=128, bk=block,
                          interpret=pol.interpret(lane))
    return sk[:n0, : (samples + 31) // 32]


# jitted so the ref lane's cos lowers through the same compiler path as
# the Pallas lanes — eager dispatch picks a different cos approximation
# on CPU (1-ULP drift), which would break the lane bit-identity contract
_hamming_ref_jit = jax.jit(kref.hamming_cosine_ref, static_argnums=2)


def simhash_edge_similarity_kernel(
    sketches: jax.Array, eu: jax.Array, ev: jax.Array, samples: int,
    block: Optional[int] = None,
    *, policy: Optional[ExecutionPolicy] = None, lane: Optional[str] = None,
) -> jax.Array:
    """σ̂ per edge from packed sketches via the hamming op."""
    pol, lane = _resolve(policy, "hamming", lane)
    block = block or pol.profile.hamming_block
    e0 = eu.shape[0]
    if lane == LANE_REF:
        return _hamming_ref_jit(sketches[eu], sketches[ev], samples)
    su = pad_to(sketches[eu], block, (0,))
    sv = pad_to(sketches[ev], block, (0,))
    out = hamming_cosine(su, sv, samples=samples, be=block,
                         interpret=pol.interpret(lane))
    return out[:e0]


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    window: int = 0, bq: int = 128, bkv: int = 128,
    policy: Optional[ExecutionPolicy] = None, lane: Optional[str] = None,
) -> jax.Array:
    """Flash attention over [bh, s, d] tensors (pads s and d to blocks)."""
    pol, lane = _resolve(policy, "attention", lane)
    if lane == LANE_REF:
        # the oracle handles arbitrary shapes; no padding (or rescale) needed
        return kref.flash_attention_ref(q, k, v, causal=causal, window=window)
    bh, sq, d0 = q.shape
    skv = k.shape[1]
    d_pad = max((d0 + 127) // 128 * 128, 128)
    qp = pad_to(q, d_pad, (2,))
    kp = pad_to(k, d_pad, (2,))
    vp = pad_to(v, d_pad, (2,))
    sq_p = (sq + bq - 1) // bq * bq
    skv_p = (skv + bkv - 1) // bkv * bkv
    # pad kv with zeros & mask via window/causal handled by padding at end:
    # padded kv positions get score NEG_INF only under causal mask when
    # k_pos > q_pos; for non-causal we must not attend padding — extend the
    # causal guard by masking padded keys through an additive bias is not
    # supported here, so we require exact multiples for non-causal use.
    if not causal:
        assert sq % bq == 0 and skv % bkv == 0, "pad seq for non-causal"
    qp = pad_to(qp, sq_p, (1,))[:, :sq_p]
    kp = pad_to(kp, skv_p, (1,))[:, :skv_p]
    vp = pad_to(vp, skv_p, (1,))[:, :skv_p]
    # scale uses true d0, not padded width (padding contributes zero dot)
    out = flash_attention(
        qp * (d_pad ** 0.5) / (d0 ** 0.5), kp, vp,
        causal=causal, window=window, bq=bq, bkv=bkv,
        interpret=pol.interpret(lane),
    )
    return out[:, :sq, :d0]
