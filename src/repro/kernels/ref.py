"""Pure-jnp oracles for every Pallas kernel. Tests assert allclose."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_gram_ref(w: jax.Array, mask: jax.Array) -> jax.Array:
    return (w @ w.T) * mask


def bucket_probe_ref(p_ids, p_w, t_ids, t_w):
    """(shared dot, shared count) per edge row-pair; pad ids must differ
    between probe (-1) and target (-2) so padding never matches."""
    eq = p_ids[:, :, None] == t_ids[:, None, :]
    w = p_w[:, :, None] * t_w[:, None, :]
    dot = jnp.sum(jnp.where(eq, w, 0.0), axis=(1, 2))
    cnt = jnp.sum(eq, axis=(1, 2)).astype(jnp.int32)
    return dot, cnt


def simhash_pack_ref(w: jax.Array, r: jax.Array) -> jax.Array:
    s = w @ r
    bits = (s >= 0.0).astype(jnp.uint32)
    n, k = bits.shape
    lanes = bits.reshape(n, k // 32, 32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(lanes * weights[None, None, :], axis=-1, dtype=jnp.uint32)


def hamming_cosine_ref(sk_u: jax.Array, sk_v: jax.Array, samples: int) -> jax.Array:
    x = jnp.bitwise_xor(sk_u, sk_v)
    diff = jnp.sum(jax.lax.population_count(x), axis=-1).astype(jnp.float32)
    return jnp.cos(jnp.pi * diff / samples)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
    window: int = 0
) -> jax.Array:
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / (d ** 0.5)
    sq, skv = s.shape[-2], s.shape[-1]
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v).astype(q.dtype)
