"""Pallas TPU kernel: SimHash sketch construction (paper §5).

sketch(v) = sign(W̄c[v, :] · R) for R ∈ ℝ^{n×k} i.i.d. N(0,1): the kn dot
products are one matmul — the MXU path. The kernel fuses the sign and packs
32 sample bits per uint32 word *before* the HBM write-back, cutting sketch
bandwidth 32× (sketches are re-read once per edge by the hamming kernel, so
the packing pays on both sides).

Grid (n/bm, k/bs, n/bk): the contraction over the vertex axis (bk) is the
innermost loop accumulating into a VMEM scratch tile; the final k-step
applies sign → bit-pack → uint32 store. ``bs`` must be a multiple of 32;
all blocks default to 128 (MXU/VPU lane-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(w_ref, r_ref, o_ref, acc_ref, *, nk: int, bs: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        w_ref[...], r_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _pack():
        bits = (acc_ref[...] >= 0.0).astype(jnp.uint32)      # [bm, bs]
        bm = bits.shape[0]
        lanes = bits.reshape(bm, bs // 32, 32)
        weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
        o_ref[...] = jnp.sum(
            lanes * weights[None, None, :], axis=-1, dtype=jnp.uint32
        )


@functools.partial(jax.jit, static_argnames=("bm", "bs", "bk", "interpret"))
def simhash_pack(
    w: jax.Array,   # float32[n, n] closed weighted adjacency (padded)
    r: jax.Array,   # float32[n, k] gaussian projections (k multiple of 32)
    *,
    bm: int = 128,
    bs: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Packed sketches uint32[n, k/32]."""
    n, k = w.shape[0], r.shape[1]
    assert w.shape == (n, n) and r.shape[0] == n
    assert n % bm == 0 and n % bk == 0 and k % bs == 0 and bs % 32 == 0
    nk = n // bk
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, bs=bs),
        out_shape=jax.ShapeDtypeStruct((n, k // 32), jnp.uint32),
        grid=(n // bm, k // bs, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bs), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bs // 32), lambda i, j, kk: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bs), jnp.float32)],
        interpret=interpret,
    )(w, r)
