"""Pallas TPU kernel: blocked masked adjacency product (triangle counting).

The paper's similarity pass is triangle counting: for every edge (u,v) it
needs the closed-neighborhood dot  P[u,v] = Σ_x W̄[u,x]·W̄[v,x]  (§4.1.1).
On a CPU that is hash/merge intersection; on TPU the same quantity is a
*blocked matrix product on the MXU*:

    P = (W̄ · W̄ᵀ) ⊙ M ,   M = A + I

masked so only edge positions (and the diagonal, which carries the squared
norms) are ever written back to HBM — non-edge entries of the product are
dead work downstream and masking them in VMEM saves the write bandwidth.

Grid is (n/bm, n/bn, n/bk) with the k-axis innermost; each (i,j) output tile
stays resident in VMEM across the k loop (classic accumulate-in-place
pattern), giving arithmetic intensity ≈ bk/2 FLOP/byte per tile pass.
Block shapes default to 128 — MXU-native (128×128 systolic array).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, wt_ref, m_ref, o_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        w_ref[...], wt_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _mask():
        o_ref[...] = o_ref[...] * m_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def masked_gram(
    w: jax.Array,      # float32[n, n]  closed weighted adjacency W̄ (padded)
    mask: jax.Array,   # float32[n, n]  A + I (1.0 where the product is kept)
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """(W̄ · W̄ᵀ) ⊙ mask, float32[n, n]. n must be divisible by the blocks."""
    n = w.shape[0]
    assert w.shape == (n, n) and mask.shape == (n, n)
    assert n % bm == 0 and n % bn == 0 and n % bk == 0, "pad to block multiple"
    nk = n // bk
    grid = (n // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),   # W̄ row tile
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),   # W̄ᵀ col tile
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),   # mask tile
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        interpret=interpret,
    )(w, w.T, mask)
