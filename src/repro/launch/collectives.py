"""Parse collective ops (+ their data volumes) out of optimized HLO text.

``compiled.cost_analysis()`` does not expose collective bytes, so the
roofline's collective term comes from here: every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op's result shape is read
off the (post-SPMD, per-device) HLO and converted into per-device link bytes
with a ring model:

    all-reduce       2·b·(g−1)/g      (reduce-scatter + all-gather halves)
    all-gather       b·(g−1)/g        (b = full gathered result bytes)
    reduce-scatter   b·(g−1)          (b = scattered result bytes; input g·b)
    all-to-all       b·(g−1)/g
    collective-permute  b

g = participating group size, parsed from replica_groups (explicit
{{...},...} or iota [n,g]<=[...] form).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
          "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_EXPL_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype)
    if size is None:
        return 0
    total = size
    if dims:
        for d in dims.split(","):
            total *= int(d)
    return total


def _group_size(line: str) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _EXPL_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _volume(kind: str, result_bytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    if kind == "collective-permute":
        return float(result_bytes)
    return 0.0


def parse_collectives(hlo_text: str) -> Dict:
    """→ {"ops": [...], "per_kind": {...}, "total_link_bytes": float,
          "count": int}. Bytes are PER DEVICE (HLO is post-partitioning)."""
    ops: List[Dict] = []
    for line in hlo_text.splitlines():
        # match "<result-shapes> <kind>(" or "<kind>-start("
        found = None
        for kind in _KINDS:
            if f" {kind}(" in line or f" {kind}-start(" in line:
                found = kind
                break
        if not found or "-done" in line.split("=")[0]:
            continue
        head = line.split(f" {found}", 1)[0]
        shapes = _SHAPE_RE.findall(head)
        if not shapes:
            continue
        rb = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        g = _group_size(line)
        ops.append({
            "kind": found, "result_bytes": rb, "group": g,
            "link_bytes": _volume(found, rb, g),
        })
    per_kind = defaultdict(lambda: {"count": 0, "result_bytes": 0.0,
                                    "link_bytes": 0.0})
    for op in ops:
        e = per_kind[op["kind"]]
        e["count"] += 1
        e["result_bytes"] += op["result_bytes"]
        e["link_bytes"] += op["link_bytes"]
    top = sorted(ops, key=lambda o: -o["link_bytes"])[:8]
    return {
        "ops": ops,
        "per_kind": dict(per_kind),
        "total_link_bytes": float(sum(o["link_bytes"] for o in ops)),
        "count": len(ops),
        "top_ops": top,
    }
