"""Multi-pod dry-run driver.

For every (architecture × input shape × mesh) cell:
  lower the step function (train_step / prefill / decode_step) with
  ShapeDtypeStruct inputs under the production mesh, .compile() it, and
  record memory_analysis / cost_analysis / HLO collective stats as a JSON
  artifact for EXPERIMENTS.md §Dry-run and benchmarks/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--cells N,M]
Artifacts: experiments/dryrun/<mesh>/<arch>__<shape>.json
"""
import os
os.environ["XLA_FLAGS"] = (
    os.environ.get("REPRO_XLA_EXTRA", "") +
    " --xla_force_host_platform_device_count=512"
)
# ^ MUST precede every other import (jax locks device count on first init).

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, all_arch_ids
from repro.configs.shapes import SHAPES, cell_runs
from repro.dist.sharding import Sharder
from repro.launch.collectives import parse_collectives
from repro.launch.mesh import make_production_mesh
from repro.models import model as mdl
from repro.models import layers as Lyr
from repro.optim import adamw
from repro.train.train_step import make_train_step

# --- TPU v5e hardware constants (roofline) ---
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (1-link conservative model)

# per-arch microbatch accumulation for train_4k (global batch 256);
# clamped so each microbatch still covers the DP axis.
ACCUM = {
    "whisper-small": 2, "pixtral-12b": 8, "granite-20b": 8, "yi-34b": 16,
    "granite-34b": 16, "granite-8b": 4, "mamba2-780m": 2,
    "deepseek-v2-lite-16b": 4, "moonshot-v1-16b-a3b": 4, "hymba-1.5b": 2,
}


def build_cell(arch: str, shape_name: str, mesh, *, overrides=None,
               sp: bool = False, accum_override=None):
    """→ (lowered, meta) for one cell, or raises."""
    from jax.sharding import PartitionSpec as P

    cfg = get_config(arch)
    over = dict(overrides or {})
    # serving cells need the chunked attention path for 32k; training too
    over.setdefault("attn_impl", "chunked")
    over.setdefault("q_chunk", 4096)
    cfg = cfg.scaled(**over)
    shape = SHAPES[shape_name]
    sharder = Sharder(mesh, cfg)
    dp = sharder.dp
    dp_axes = sharder.dp_axes if len(sharder.dp_axes) > 1 else sharder.dp_axes[0]

    if sp:
        Lyr.set_sp_spec(P(dp_axes, "model", None))
    else:
        Lyr.set_sp_spec(None)
    Lyr.set_softmax_dtype(jnp.bfloat16 if cfg.softmax_dtype == "bf16"
                          else jnp.float32)
    from repro.dist import ep as ep_mod
    if cfg.moe_impl == "ep":
        ep_mod.set_ep_mesh(mesh, sharder.dp_axes, "model")
    else:
        ep_mod.set_ep_mesh(None)

    param_shapes = jax.eval_shape(
        lambda k: mdl.init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = sharder.param_specs(param_shapes)
    pshard = sharder.tree_named(pspecs)

    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "n_params": int(sum(np.prod(l.shape) for l in jax.tree.leaves(param_shapes))),
            "n_active_params": mdl.count_active_params(cfg)}

    if shape.kind == "train":
        # ACCUM holds the deployment values (memory-fit); the dry-run uses
        # accum=1 — roofline terms are accum-invariant (same global math)
        # and compile time scales with the unrolled microstep count.
        accum = accum_override or 1
        accum = max(1, min(accum, shape.global_batch // dp))
        micro = shape.global_batch // accum
        meta["accum"] = accum
        specs = mdl.input_specs(cfg, shape)["batch"]
        batch_shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((accum, micro) + tuple(s.shape[1:]),
                                           s.dtype), specs)
        bspecs = sharder.batch_specs(batch_shapes, leading_accum=True)
        bshard = sharder.tree_named(bspecs)
        opt_shapes = jax.eval_shape(adamw.init, param_shapes)
        ospecs = sharder.opt_specs(pspecs, param_shapes)
        oshard = sharder.tree_named(ospecs)
        hp = adamw.AdamWConfig()
        step = make_train_step(cfg, hp, accum=accum)
        jitted = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                         out_shardings=(pshard, oshard, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(param_shapes, opt_shapes, batch_shapes)

    elif shape.kind == "prefill":
        specs = mdl.input_specs(cfg, shape)
        batch_shapes = specs["batch"]
        bspecs = sharder.batch_specs(batch_shapes)
        bshard = sharder.tree_named(bspecs)
        cache_shapes = jax.eval_shape(lambda: mdl.init_cache(
            cfg, shape.global_batch, shape.seq_len))
        cspecs = sharder.cache_specs(cache_shapes, kind="prefill")
        cshard = sharder.tree_named(cspecs)

        def prefill_fn(params, batch):
            return mdl.prefill(cfg, params, batch, shape.seq_len)

        jitted = jax.jit(prefill_fn, in_shardings=(pshard, bshard),
                         out_shardings=(None, cshard))
        lowered = jitted.lower(param_shapes, batch_shapes)

    elif shape.kind == "decode":
        specs = mdl.input_specs(cfg, shape)
        cache_shapes = specs["cache"]
        cspecs = sharder.cache_specs(cache_shapes)
        cshard = sharder.tree_named(cspecs)
        tshard = sharder.named(sharder.batch_specs(
            {"t": specs["token"]})["t"])
        pos = mdl.decode_pos(cfg, shape)

        def decode_fn(params, cache, token, pos_):
            return mdl.decode_step(cfg, params, cache, token, pos_)

        jitted = jax.jit(decode_fn,
                         in_shardings=(pshard, cshard, tshard, None),
                         out_shardings=(None, cshard),
                         donate_argnums=(1,))
        lowered = jitted.lower(param_shapes, cache_shapes, specs["token"],
                               jax.ShapeDtypeStruct((), jnp.int32))
        meta["decode_pos"] = pos
    else:
        raise ValueError(shape.kind)

    Lyr.set_sp_spec(None)
    return lowered, meta


def analytic_memory(cfg, shape, meta, n_dev: int, dp: int, tp: int) -> dict:
    """Analytic per-device HBM estimates (the tight counterpart to the
    HLO 'bytes accessed' upper bound — CPU-backend buffer accounting ignores
    remat/serialization, so both bounds are reported; see EXPERIMENTS.md).

    * weights_GiB: persistent param bytes per device (TP-sharded big tensors)
    * opt_GiB:     fp32 m/v/master, ZeRO-sharded over the full mesh
    * act_peak_GiB: live activations with per-layer remat + chunked attention
    * traffic_GiB: minimum HBM traffic per step (params + residual r/w)
    """
    n = meta["n_params"]
    bytes_params = 2 * n / min(tp, 16)      # bf16, TP-sharded (approx)
    kind = shape.kind
    accum = meta.get("accum", 1)
    b_dev = max(shape.global_batch // max(accum, 1) // dp, 1)
    s = shape.seq_len
    d = cfg.d_model
    layers = cfg.n_layers + cfg.n_enc_layers
    if kind == "train":
        opt = 12 * n / n_dev                # ZeRO-1 over full mesh
        resid = layers * b_dev * s * d * 2  # saved layer inputs (remat)
        chunk_peak = 4 * b_dev * max(cfg.n_heads // tp, 1) * cfg.q_chunk * s
        logits = 4 * b_dev * s * cfg.vocab_padded / tp
        act = resid + chunk_peak + logits
        traffic = 3 * bytes_params + 2 * opt + 4 * resid
    elif kind == "prefill":
        opt = 0
        act = 2 * b_dev * s * d * 4 + 4 * b_dev * max(
            cfg.n_heads // tp, 1) * cfg.q_chunk * s
        traffic = bytes_params + 2 * act
    else:  # decode
        opt = 0
        act = b_dev * d * 4 * layers
        cache = meta.get("cache_bytes_dev", 0.0)
        traffic = bytes_params + cache
    return {
        "weights_GiB": bytes_params / 2**30,
        "opt_GiB": opt / 2**30,
        "act_peak_GiB": act / 2**30,
        "min_traffic_GiB": traffic / 2**30,
        "min_memory_s": traffic / HBM_BW,
    }


def model_flops(meta, shape) -> float:
    """Analytic MODEL_FLOPS for the cell (global, per step)."""
    n = meta["n_active_params"]
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch      # decode: one token per row


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             *, sp=False, overrides=None, accum_override=None,
             tag: str = "") -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    runs, reason = cell_runs(cfg.family, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "skip", "reason": reason}
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    path = os.path.join(out_dir, mesh_name,
                        f"{arch}__{shape_name}{tag}.json")
    if not runs:
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] SKIP {arch} {shape_name}: {reason}", flush=True)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    try:
        with mesh:
            lowered, meta = build_cell(arch, shape_name, mesh, sp=sp,
                                       overrides=overrides,
                                       accum_override=accum_override)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):   # jax 0.4.x wraps in a list
                ca = ca[0] if ca else {}
            ma = compiled.memory_analysis()
            hlo = compiled.as_text()
            coll = parse_collectives(hlo)
    except Exception as e:  # noqa: BLE001
        rec.update(status="error", error=repr(e),
                   trace=traceback.format_exc()[-2000:])
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[dryrun] ERROR {arch} {shape_name} ({mesh_name}): {e!r}",
              flush=True)
        return rec

    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    link_bytes = coll["total_link_bytes"]
    mf = model_flops(meta, shape)
    sharder_tmp = Sharder(mesh, get_config(arch))
    analytic = analytic_memory(get_config(arch), shape, meta, n_dev,
                               sharder_tmp.dp, sharder_tmp.tp)
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": link_bytes / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    rec.update(
        status="ok",
        meta=meta,
        devices=n_dev,
        lower_s=round(t1 - t0, 2),
        compile_s=round(t2 - t1, 2),
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_link_bytes_per_device=link_bytes,
        collectives={k: {kk: vv for kk, vv in v.items()}
                     for k, v in coll["per_kind"].items()},
        collective_count=coll["count"],
        top_collectives=coll.get("top_ops", []),
        memory=dict(
            argument_GiB=ma.argument_size_in_bytes / 2**30,
            output_GiB=ma.output_size_in_bytes / 2**30,
            temp_GiB=ma.temp_size_in_bytes / 2**30,
            alias_GiB=ma.alias_size_in_bytes / 2**30,
        ),
        analytic=analytic,
        roofline=dict(
            terms_s=terms,
            dominant=dominant,
            model_flops_global=mf,
            model_flops_per_device=mf / n_dev,
            hlo_flops_per_device=flops_dev,
            useful_ratio=(mf / n_dev) / flops_dev if flops_dev else None,
            roofline_fraction=(mf / n_dev / PEAK_FLOPS) / max(
                terms.values()) if max(terms.values()) > 0 else None,
        ),
    )
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    dom_ms = terms[dominant] * 1e3
    print(f"[dryrun] OK {arch} {shape_name} ({mesh_name}) "
          f"compile={rec['compile_s']}s dominant={dominant}"
          f"({dom_ms:.2f}ms) frac={rec['roofline']['roofline_fraction']:.3f} "
          f"temp={rec['memory']['temp_GiB']:.2f}GiB "
          f"arg={rec['memory']['argument_GiB']:.2f}GiB", flush=True)
    return rec


def all_cells():
    cells = []
    for arch in all_arch_ids():
        for shape_name in SHAPES:
            cells.append((arch, shape_name))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--cells", help="comma-separated indices into all_cells()")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--sp", action="store_true", help="sequence-parallel acts")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (repeatable)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    overrides = {}
    for kv in args.override:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    if args.all or args.cells:
        cells = all_cells()
        if args.cells:
            idx = [int(i) for i in args.cells.split(",")]
            cells = [cells[i] for i in idx]
        for arch, shape_name in cells:
            mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
            path = os.path.join(args.out, mesh_name,
                                f"{arch}__{shape_name}{args.tag}.json")
            if args.skip_existing and os.path.exists(path):
                print(f"[dryrun] EXISTS {arch} {shape_name}", flush=True)
                continue
            run_cell(arch, shape_name, args.multi_pod, args.out, sp=args.sp,
                     overrides=overrides, accum_override=args.accum,
                     tag=args.tag)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        run_cell(args.arch, args.shape, args.multi_pod, args.out, sp=args.sp,
                 overrides=overrides, accum_override=args.accum, tag=args.tag)


if __name__ == "__main__":
    main()
