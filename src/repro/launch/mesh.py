"""Production mesh builders.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any device
query; smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def _mesh(shape, axes):
    # jax ≥ 0.5 takes axis_types=(AxisType.Auto, ...); 0.4.x has no axis
    # types (every axis is implicitly auto). Support both.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the 'pod' axis carries
    cross-pod data parallelism over DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests, elastic re-mesh)."""
    return _mesh(shape, axes)
