"""SCAN query service launcher.

Build (or load) a persistent SCAN index, then either print a (μ, ε)
parameter-sweep table or run the micro-batching engine under synthetic
concurrent traffic:

    # build an index, persist it, sweep a parameter grid
    PYTHONPATH=src python -m repro.launch.scan_serve sweep \
        --n 8192 --avg-degree 16 --save /tmp/scan_idx \
        --mus 2,4,8 --epss 0.2:0.8:7

    # reload the persisted index and serve concurrent clients
    PYTHONPATH=src python -m repro.launch.scan_serve serve \
        --load /tmp/scan_idx --clients 32 --requests 64 --max-batch 32
"""
from __future__ import annotations

import argparse
import asyncio
import time

import numpy as np

from repro.core import build_index, random_graph
from repro.serve import (EngineConfig, IndexStore, MicroBatchEngine,
                         grid_sweep, index_fingerprint, sweep_stats)


def parse_values(spec: str, kind):
    """``"2,4,8"`` → list, or ``"0.1:0.9:5"`` → linspace."""
    if ":" in spec:
        lo, hi, num = spec.split(":")
        return [kind(v) for v in np.linspace(float(lo), float(hi), int(num))]
    return [kind(v) for v in spec.split(",")]


def get_index(args):
    if args.load:
        store = IndexStore(args.load)
        index, g, fp = store.load()
        print(f"loaded index v{store.latest_version()} from {args.load} "
              f"(n={g.n}, m={g.m}, fingerprint={fp[:12]})")
        return index, g, fp
    g = random_graph(args.n, args.avg_degree, seed=args.seed,
                     weighted=args.weighted,
                     planted_clusters=args.clusters)
    t0 = time.time()
    index = build_index(g, args.measure)
    fp = index_fingerprint(index, g)
    print(f"built index in {time.time() - t0:.2f}s "
          f"(n={g.n}, m={g.m}, fingerprint={fp[:12]})")
    if args.save:
        path = IndexStore(args.save).save(index, g)
        print(f"persisted to {path}")
    return index, g, fp


def cmd_sweep(args):
    index, g, _ = get_index(args)
    mus = parse_values(args.mus, int)
    epss = parse_values(args.epss, float)
    t0 = time.time()
    rows = sweep_stats(index, g, mus, epss)
    dt = time.time() - t0
    print(f"\n{len(rows)} (μ, ε) settings in one vmapped call "
          f"({dt:.2f}s incl. compile)")
    print(f"{'mu':>4} {'eps':>6} {'clusters':>9} {'cores':>7} "
          f"{'coverage':>9} {'modularity':>11}")
    for r in rows:
        print(f"{r['mu']:>4} {r['eps']:>6.2f} {r['n_clusters']:>9} "
              f"{r['n_cores']:>7} {r['coverage']:>9.3f} "
              f"{r['modularity']:>11.4f}")
    best = max(rows, key=lambda r: r["modularity"])
    print(f"best modularity: mu={best['mu']} eps={best['eps']:.2f} "
          f"Q={best['modularity']:.4f}")


def cmd_serve(args):
    index, g, fp = get_index(args)
    cfg = EngineConfig(max_batch=args.max_batch, flush_ms=args.flush_ms)
    engine = MicroBatchEngine(index, g, fingerprint=fp, config=cfg)
    rng = np.random.default_rng(0)
    pool = [(int(m), float(e))
            for m in (2, 3, 4, 5, 8)
            for e in np.round(np.linspace(0.1, 0.9, 17), 3)]

    async def client(cid: int):
        for _ in range(args.requests):
            mu, eps = pool[rng.integers(len(pool))]
            res = await engine.query(mu, eps)
            del res
            await asyncio.sleep(0)

    async def main():
        async with engine:
            # warm the single compiled batch shape before timing
            await engine.query(*pool[0])
            t0 = time.time()
            await asyncio.gather(*[client(i) for i in range(args.clients)])
            return time.time() - t0

    dt = asyncio.run(main())
    total = args.clients * args.requests
    st = engine.batch_stats()
    print(f"\n{total} queries from {args.clients} clients in {dt:.2f}s "
          f"→ {total / dt:.1f} q/s")
    print(f"device calls={st['device_queries']} avg_batch={st['avg_batch']:.1f} "
          f"cache_hits={st['cache_hits']} deduped={st['deduped']} "
          f"hit_rate={st['cache_hit_rate']:.2f}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name, fn in (("sweep", cmd_sweep), ("serve", cmd_serve)):
        p = sub.add_parser(name)
        p.set_defaults(fn=fn)
        p.add_argument("--load", help="load a persisted index directory")
        p.add_argument("--save", help="persist the built index here")
        p.add_argument("--n", type=int, default=8192)
        p.add_argument("--avg-degree", type=float, default=16.0)
        p.add_argument("--seed", type=int, default=0)
        p.add_argument("--clusters", type=int, default=0)
        p.add_argument("--weighted", action="store_true")
        p.add_argument("--measure", default="cosine")
        if name == "sweep":
            p.add_argument("--mus", default="2,4,8")
            p.add_argument("--epss", default="0.1:0.9:9")
        else:
            p.add_argument("--clients", type=int, default=16)
            p.add_argument("--requests", type=int, default=32)
            p.add_argument("--max-batch", type=int, default=32)
            p.add_argument("--flush-ms", type=float, default=2.0)
    args = ap.parse_args()
    args.fn(args)


if __name__ == "__main__":
    main()
