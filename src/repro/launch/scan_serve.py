"""SCAN query service launcher.

Build (or load) persistent SCAN indexes, then either print a (μ, ε)
parameter-sweep table — optionally sharded over a device mesh — or run the
micro-batching engine under synthetic concurrent traffic, optionally
routing several indexes through one engine:

    # build an index, persist it, sweep a parameter grid
    PYTHONPATH=src python -m repro.launch.scan_serve sweep \
        --n 8192 --avg-degree 16 --save /tmp/scan_idx \
        --mus 2,4,8 --epss 0.2:0.8:7

    # the same sweep with the edge arrays sharded over 8 host devices
    PYTHONPATH=src python -m repro.launch.scan_serve sweep --shards 8

    # reload the persisted index and serve concurrent clients
    PYTHONPATH=src python -m repro.launch.scan_serve serve \
        --load /tmp/scan_idx --clients 32 --requests 64 --max-batch 32

    # one engine, three indexes, mixed-fingerprint traffic
    PYTHONPATH=src python -m repro.launch.scan_serve serve --indexes 3

    # seed-set (local) traffic: every client asks for single vertices'
    # clusters via query_seed(v, μ, ε) instead of full clusterings
    PYTHONPATH=src python -m repro.launch.scan_serve serve \
        --traffic seed --n 8192 --clients 32

    # 50/50 seed + global traffic against the live-update service: the
    # seed cache survives each delta through frontier migration
    PYTHONPATH=src python -m repro.launch.scan_serve update \
        --traffic mixed --updates 8

    # resident live-update process: a synthetic edit stream mutates the
    # graph while concurrent clients keep querying it
    PYTHONPATH=src python -m repro.launch.scan_serve update \
        --n 4096 --updates 16 --update-batch 8 --clients 8

    # approximate-first ingest: serve an LSH-sketched index immediately,
    # refine to the exact index in the background, hot-swap when it lands
    PYTHONPATH=src python -m repro.launch.scan_serve serve \
        --approx simhash:256 --n 8192 --clients 16

    # sweep a (μ, ε) grid against a sketched index (paper §5/§6.3)
    PYTHONPATH=src python -m repro.launch.scan_serve sweep \
        --approx simhash:128 --n 8192

    # replicated read fleet under chaos: one writer + 3 replicas tail
    # the DeltaLog while a seeded fault schedule crashes/stalls/corrupts;
    # exits nonzero if any answer diverges from the writer's bits or a
    # timeout escapes the admission/retry machinery unshed
    PYTHONPATH=src python -m repro.launch.scan_serve fleet \
        --replicas 3 --updates 8 --chaos crash:0.02,stall:0.05,corrupt:0.1

``--shards K`` forces K host-platform devices itself when jax would
otherwise see fewer (same effect as
``XLA_FLAGS=--xla_force_host_platform_device_count=K``).

Telemetry (``serve`` and ``update``): the engine's metrics registry
(counters, gauges, and the latency histograms behind every span — see
ROADMAP.md § Observability) is always live; ``--stats-every S`` prints a
compact one-line dump of it every S seconds while traffic runs, and
``--metrics-json PATH`` writes the full registry snapshot (JSON, incl.
per-bucket histogram counts) when the run finishes. Both runs also print
p50/p90/p99 queue-wait and end-to-end latency measured from the real
request histograms.
"""
from __future__ import annotations

import argparse
import asyncio
import contextlib
import os
import time

import numpy as np


def _fmt_latency(st: dict) -> str:
    """One line of queue-wait / e2e quantiles (``engine.latency_stats``)."""
    return (f"latency: e2e p50={st['e2e_p50'] * 1e3:.2f}ms "
            f"p90={st['e2e_p90'] * 1e3:.2f}ms "
            f"p99={st['e2e_p99'] * 1e3:.2f}ms (n={st['e2e_n']}); "
            f"queue-wait p50={st['wait_p50'] * 1e3:.2f}ms "
            f"p99={st['wait_p99'] * 1e3:.2f}ms (n={st['wait_n']})")


_SEED_SHARE = {"global": 0.0, "seed": 1.0, "mixed": 0.5}


def _fmt_seed_report(bst: dict, lst: dict) -> str:
    """Two lines of seed-path counters + latency (``--traffic seed|mixed``)."""
    return (f"seed path: requests={bst['seed_requests']} "
            f"device_calls={bst['seed_device_queries']} "
            f"buckets={bst['seed_batches']} "
            f"cache_hits={bst['seed_cache_hits']} "
            f"deduped={bst['seed_deduped']} spills={bst['seed_spills']} "
            f"warmed={bst['seed_warmed']}\n"
            f"seed latency: e2e p50={lst['seed_e2e_p50'] * 1e3:.2f}ms "
            f"p99={lst['seed_e2e_p99'] * 1e3:.2f}ms "
            f"(n={lst['seed_e2e_n']}); "
            f"queue-wait p50={lst['seed_wait_p50'] * 1e3:.2f}ms "
            f"(n={lst['seed_wait_n']})")


@contextlib.asynccontextmanager
async def _periodic_stats(registry, every: float):
    """Run the obs dump loop alongside traffic when ``--stats-every`` > 0."""
    from repro.obs import dump_loop

    task = None
    if every and every > 0:
        task = asyncio.get_running_loop().create_task(
            dump_loop(registry, every))
    try:
        yield
    finally:
        if task is not None:
            task.cancel()
            with contextlib.suppress(asyncio.CancelledError):
                await task


def _write_metrics(registry, path) -> None:
    if not path:
        return
    from repro.obs import write_json

    write_json(registry.snapshot(), path)
    print(f"wrote metrics snapshot to {path}")


def parse_values(spec: str, kind):
    """``"2,4,8"`` → list, or ``"0.1:0.9:5"`` → linspace."""
    if ":" in spec:
        lo, hi, num = spec.split(":")
        return [kind(v) for v in np.linspace(float(lo), float(hi), int(num))]
    return [kind(v) for v in spec.split(",")]


def get_index(args, *, seed=None):
    from repro.core import build_index, random_graph
    from repro.serve import IndexStore, index_fingerprint

    if args.load:
        store = IndexStore(args.load)
        index, g, fp = store.load()
        print(f"loaded index v{store.latest_version()} from {args.load} "
              f"(n={g.n}, m={g.m}, fingerprint={fp[:12]}, "
              f"{store.provenance().describe()})")
        return index, g, fp
    seed = args.seed if seed is None else seed
    g = random_graph(args.n, args.avg_degree, seed=seed,
                     weighted=args.weighted,
                     planted_clusters=args.clusters)
    t0 = time.time()
    provenance = None
    if getattr(args, "approx", None):
        from repro.core import ApproxIndexBuilder, ApproxParams
        builder = ApproxIndexBuilder(args.measure,
                                     ApproxParams.parse(args.approx))
        index, provenance = builder.build(g)
    else:
        index = build_index(g, args.measure)
    fp = index_fingerprint(index, g)
    kind = provenance.describe() if provenance is not None else "exact"
    print(f"built {kind} index in {time.time() - t0:.2f}s "
          f"(n={g.n}, m={g.m}, seed={seed}, fingerprint={fp[:12]})")
    if args.save:
        path = IndexStore(args.save).save(index, g, measure=args.measure,
                                          provenance=provenance)
        print(f"persisted to {path}")
    return index, g, fp


def cmd_sweep(args):
    from repro.serve import sweep_stats

    index, g, _ = get_index(args)
    mus = parse_values(args.mus, int)
    epss = parse_values(args.epss, float)
    mesh = None
    if args.shards > 1:
        import jax
        from repro.core import query_mesh
        mesh = query_mesh(args.shards)
        print(f"sharded sweep: edge arrays over {args.shards} of "
              f"{jax.device_count()} devices (axis 'data')")
    t0 = time.time()
    rows = sweep_stats(index, g, mus, epss, mesh=mesh)
    dt = time.time() - t0
    shard_note = f", {args.shards} shards" if mesh is not None else ""
    print(f"\n{len(rows)} (μ, ε) settings in one vmapped call "
          f"({dt:.2f}s incl. compile{shard_note})")
    print(f"{'mu':>4} {'eps':>6} {'clusters':>9} {'cores':>7} "
          f"{'coverage':>9} {'modularity':>11}")
    for r in rows:
        print(f"{r['mu']:>4} {r['eps']:>6.2f} {r['n_clusters']:>9} "
              f"{r['n_cores']:>7} {r['coverage']:>9.3f} "
              f"{r['modularity']:>11.4f}")
    best = max(rows, key=lambda r: r["modularity"])
    print(f"best modularity: mu={best['mu']} eps={best['eps']:.2f} "
          f"Q={best['modularity']:.4f}")


def cmd_serve(args):
    from repro.serve import EngineConfig, MicroBatchEngine

    if args.load and args.indexes > 1:
        raise SystemExit(
            "--indexes K>1 builds K distinct graphs and cannot be combined "
            "with --load (a persisted directory holds one index)")
    cfg = EngineConfig(max_batch=args.max_batch, flush_ms=args.flush_ms,
                       warm_ahead=not args.no_warm,
                       shards=args.shards if args.shards > 1 else None,
                       lane=args.lane)
    if args.approx:
        if args.load:
            raise SystemExit(
                "--approx builds a fresh LSH-sketched index and cannot be "
                "combined with --load (the loaded artifact is already "
                "built; its provenance travels with it)")
        return _serve_approx(args, cfg)
    engine = MicroBatchEngine(config=cfg)
    catalog = None
    if args.indexes > 1 and args.save:
        # K indexes need K named stores, not K versions of one store (only
        # the last version would survive a --load); route through a catalog
        from repro.serve import IndexCatalog
        catalog = IndexCatalog(args.save)
        args.save = None
    fps, sizes = [], []
    for k in range(max(args.indexes, 1)):
        index, g, fp = get_index(args, seed=args.seed + k)
        if catalog is not None:
            path = catalog.save(f"idx{k}", index, g, measure=args.measure)
            print(f"persisted to {path}")
        fps.append(engine.register(index, g, fingerprint=fp))
        sizes.append(g.n)
    rng = np.random.default_rng(0)
    pool = [(int(m), float(e))
            for m in (2, 3, 4, 5, 8)
            for e in np.round(np.linspace(0.1, 0.9, 17), 3)]
    seed_share = _SEED_SHARE[args.traffic]

    async def client(cid: int):
        for _ in range(args.requests):
            mu, eps = pool[rng.integers(len(pool))]
            k = rng.integers(len(fps))
            if rng.random() < seed_share:
                res = await engine.query_seed(int(rng.integers(sizes[k])),
                                              mu, eps, fingerprint=fps[k])
            else:
                res = await engine.query(mu, eps, fingerprint=fps[k])
            del res
            await asyncio.sleep(0)

    async def main():
        async with engine:
            # warm every index's compiled batch shape(s) before timing
            for fp in fps:
                if seed_share < 1.0:
                    await engine.query(*pool[0], fingerprint=fp)
                if seed_share > 0.0:
                    await engine.query_seed(0, *pool[0], fingerprint=fp)
            async with _periodic_stats(engine.registry, args.stats_every):
                t0 = time.time()
                await asyncio.gather(
                    *[client(i) for i in range(args.clients)])
                return time.time() - t0

    dt = asyncio.run(main())
    total = args.clients * args.requests
    st = engine.batch_stats()
    mode = f"{len(fps)} indexes" + (f", {args.shards} shards"
                                    if cfg.shards else "")
    print(f"\n{total} {args.traffic} requests from {args.clients} clients "
          f"({mode}) in {dt:.2f}s → {total / dt:.1f} req/s")
    print(f"device calls={st['device_queries']} buckets={st['batches']} "
          f"avg_batch={st['avg_batch']:.1f} cache_hits={st['cache_hits']} "
          f"deduped={st['deduped']} warmed={st['warmed']} "
          f"hit_rate={st['cache_hit_rate']:.2f} "
          f"partitions={st['cache_partitions']} "
          f"jit_recompiles={st['jit_recompiles']}")
    lst = engine.latency_stats()
    if seed_share > 0.0:
        print(_fmt_seed_report(st, lst))
    print(_fmt_latency(lst))
    _write_metrics(engine.registry, args.metrics_json)


def _serve_approx(args, cfg):
    """Approximate-first serve: LSH-sketched indexes answer traffic from
    second zero while exact refinement runs in the background and
    hot-swaps in behind the drain barrier (``--approx simhash:256``)."""
    import tempfile

    from repro.core import ApproxParams, random_graph
    from repro.serve import LiveIndexService

    params = ApproxParams.parse(args.approx)
    if params.measure != args.measure:
        raise SystemExit(
            f"--approx {params.method} estimates {params.measure} "
            f"similarity; pass --measure {params.measure}")
    root = args.save or tempfile.mkdtemp(prefix="scan_approx_")
    svc = LiveIndexService(root, config=cfg, measure=args.measure)
    names, sizes = [], []
    for k in range(max(args.indexes, 1)):
        g = random_graph(args.n, args.avg_degree, seed=args.seed + k,
                         weighted=args.weighted,
                         planted_clusters=args.clusters)
        name = f"idx{k}"
        t0 = time.time()
        fp = svc.register_approximate(name, g, params=params)
        print(f"approx index {name!r} built+serving in "
              f"{time.time() - t0:.2f}s (n={g.n}, m={g.m}, "
              f"fingerprint={fp[:12]}, "
              f"{svc.provenance(name).describe()}) → {root}")
        names.append(name)
        sizes.append(g.n)
    rng = np.random.default_rng(0)
    pool = [(int(m), float(e))
            for m in (2, 3, 4, 5, 8)
            for e in np.round(np.linspace(0.1, 0.9, 17), 3)]
    seed_share = _SEED_SHARE[args.traffic]
    refine_s = {}

    async def client(cid: int):
        for _ in range(args.requests):
            mu, eps = pool[rng.integers(len(pool))]
            k = rng.integers(len(names))
            if rng.random() < seed_share:
                await svc.query_seed(names[k],
                                     int(rng.integers(sizes[k])), mu, eps)
            else:
                await svc.query(names[k], mu, eps)
            await asyncio.sleep(0)

    async def refiner(name: str):
        t0 = time.time()
        await svc.refine(name)
        refine_s[name] = time.time() - t0

    async def main():
        async with svc:
            for name in names:
                await svc.query(name, *pool[0])   # warm the batch shape
                if seed_share > 0.0:
                    await svc.query_seed(name, 0, *pool[0])
            async with _periodic_stats(svc.engine.registry,
                                       args.stats_every):
                t0 = time.time()
                # refinement races the full traffic wave — queries are
                # served from σ̂ until each exact swap lands
                await asyncio.gather(
                    *[refiner(name) for name in names],
                    *[client(i) for i in range(args.clients)])
                return time.time() - t0

    dt = asyncio.run(main())
    total = args.clients * args.requests
    st = svc.stats()
    print(f"\n{total} queries from {args.clients} clients "
          f"({len(names)} approximate-first indexes) in {dt:.2f}s "
          f"→ {total / dt:.1f} q/s")
    for name in names:
        status = svc.status(name)
        print(f"  {name}: refined to exact in {refine_s[name]:.2f}s → "
              f"fingerprint={status['fingerprint'][:12]} "
              f"({status['provenance']}, seq={status['seq']})")
    print(f"device calls={st['device_queries']} cache_hits={st['cache_hits']} "
          f"warmed={st['warmed']} hit_rate={st['cache_hit_rate']:.2f} "
          f"approx_indexes_remaining={st['approx_indexes']}")
    if seed_share > 0.0:
        print(_fmt_seed_report(svc.engine.batch_stats(),
                               svc.engine.latency_stats()))
    reg = svc.engine.registry
    for span in ("index.approx_build", "live.refine", "live.refine_build"):
        hist = reg.histogram(span)
        if hist.count:
            print(f"{span}: p50={hist.quantile(0.5):.2f}s "
                  f"(n={hist.count})")
    print(_fmt_latency(svc.engine.latency_stats()))
    _write_metrics(reg, args.metrics_json)


def cmd_update(args):
    """Resident live-update demo: apply an edit stream while serving."""
    import tempfile

    from repro.core import random_graph
    from repro.core.update import random_delta
    from repro.serve import EngineConfig, IndexStore, LiveIndexService

    if args.save:
        raise SystemExit(
            "the update service persists snapshots + delta chains under "
            "its own catalog root; use --root DIR instead of --save")
    cfg = EngineConfig(max_batch=args.max_batch, flush_ms=args.flush_ms,
                       warm_ahead=not args.no_warm,
                       shards=args.shards if args.shards > 1 else None,
                       lane=args.lane)
    root = args.root or tempfile.mkdtemp(prefix="scan_live_")
    svc = LiveIndexService(root, config=cfg, measure=args.measure,
                           compact_every=args.compact_every)
    t0 = time.time()
    if args.load:
        store = IndexStore(args.load)
        stored = store.measure()
        if stored is not None and stored != args.measure:
            raise SystemExit(
                f"{args.load} was built with --measure {stored}; "
                f"updating it with --measure {args.measure} would mix "
                "similarity measures (pass the matching --measure)")
        index, g, _ = store.load()
        fp = svc.create("live", g, index=index)
        verb = f"adopted from {args.load}"
    else:
        g = random_graph(args.n, args.avg_degree, seed=args.seed,
                         weighted=args.weighted,
                         planted_clusters=args.clusters)
        fp = svc.create("live", g)
        verb = "built"
    print(f"live index {verb} in {time.time() - t0:.2f}s "
          f"(n={g.n}, m={g.m}, fingerprint={fp[:12]}) → {root}")

    rng = np.random.default_rng(args.seed + 1)
    pool = [(int(m), float(e))
            for m in (2, 3, 4, 5)
            for e in np.round(np.linspace(0.1, 0.9, 9), 3)]
    seed_share = _SEED_SHARE[args.traffic]
    apply_times, frontier_sizes = [], []

    async def editor():
        for _ in range(args.updates):
            delta = random_delta(svc.graph("live"), args.update_batch, rng)
            t0 = time.time()
            info = await svc.apply("live", delta)
            apply_times.append(time.time() - t0)
            frontier_sizes.append(info.n_frontier)
            await asyncio.sleep(0)

    async def client(cid: int):
        for _ in range(args.requests):
            mu, eps = pool[rng.integers(len(pool))]
            if rng.random() < seed_share:
                # seed entries ride through each delta via frontier
                # migration; n is stable under random_delta edit streams
                await svc.query_seed("live",
                                     int(rng.integers(g.n)), mu, eps)
            else:
                await svc.query("live", mu, eps)
            await asyncio.sleep(0)

    async def main_():
        async with svc:
            await svc.query("live", *pool[0])     # compile warmup
            if seed_share > 0.0:
                await svc.query_seed("live", 0, *pool[0])
            async with _periodic_stats(svc.engine.registry,
                                       args.stats_every):
                t0 = time.time()
                await asyncio.gather(
                    editor(), *[client(i) for i in range(args.clients)])
                return time.time() - t0

    dt = asyncio.run(main_())
    total = args.clients * args.requests
    st = svc.stats()
    status = svc.status("live")
    print(f"\n{total} queries under {args.updates} live update batches "
          f"(size {args.update_batch}) in {dt:.2f}s → {total / dt:.1f} q/s")
    print(f"updates: mean apply={np.mean(apply_times) * 1e3:.1f}ms "
          f"mean frontier={np.mean(frontier_sizes):.0f} half-edges; "
          f"final seq={status['seq']} "
          f"snapshot_seq={status['snapshot_seq']} "
          f"fingerprint={status['fingerprint'][:12]}")
    print(f"engine: device calls={st['device_queries']} "
          f"cache_hits={st['cache_hits']} warmed={st['warmed']} "
          f"hit_rate={st['cache_hit_rate']:.2f} "
          f"partitions={st['cache_partitions']} "
          f"jit_recompiles={st['jit_recompiles']}")
    lst = svc.engine.latency_stats()
    if seed_share > 0.0:
        reg = svc.engine.registry
        print(_fmt_seed_report(svc.engine.batch_stats(), lst))
        print(f"seed cache vs deltas: migrated="
              f"{reg.counter('live.seed_entries_migrated').value} "
              f"dropped={reg.counter('live.seed_entries_dropped').value}")
    print(_fmt_latency(lst))
    apply_hist = svc.engine.registry.histogram("live.apply_delta")
    if apply_hist.count:
        print(f"apply pipeline: apply_delta p50="
              f"{apply_hist.quantile(0.5) * 1e3:.1f}ms "
              f"p99={apply_hist.quantile(0.99) * 1e3:.1f}ms "
              f"(n={apply_hist.count}); offload jobs="
              f"{svc.engine.registry.counter('engine.offload_jobs').value}")
    _write_metrics(svc.engine.registry, args.metrics_json)


def cmd_fleet(args):
    """Replicated-fleet verification run: writer + N tailing replicas +
    router under synthetic traffic and (optionally) a seeded chaos
    schedule, with a bit-identity oracle over every routed answer."""
    import tempfile

    from repro.core import random_graph
    from repro.core.update import random_delta
    from repro.serve import (ChaosPolicy, EngineConfig, Fleet,
                             FleetExhausted, Overloaded, RouterConfig)
    from repro.obs import write_json

    if args.load or args.save:
        raise SystemExit(
            "the fleet serves its own catalog root (snapshots + delta "
            "chains for writer and replicas alike); use --root DIR")
    chaos = None
    if args.chaos:
        chaos = ChaosPolicy.parse(args.chaos, seed=args.chaos_seed)
        print(f"armed {chaos.describe()}")
    cfg = EngineConfig(max_batch=args.max_batch, flush_ms=args.flush_ms,
                       warm_ahead=not args.no_warm, lane=args.lane)
    root = args.root or tempfile.mkdtemp(prefix="scan_fleet_")
    fleet = Fleet(root, n_replicas=args.replicas, writer_config=cfg,
                  router_config=RouterConfig(timeout_s=args.timeout_s,
                                             hedge_after_s=args.hedge_after),
                  measure=args.measure, compact_every=args.compact_every,
                  chaos=chaos)
    g = random_graph(args.n, args.avg_degree, seed=args.seed,
                     weighted=args.weighted,
                     planted_clusters=args.clusters)
    rng = np.random.default_rng(args.seed + 1)
    pool = [(int(m), float(e))
            for m in (2, 3, 4, 5)
            for e in np.round(np.linspace(0.1, 0.9, 9), 3)]
    seed_share = _SEED_SHARE[args.traffic]
    # oracle: the writer records each seq's content fingerprint the
    # moment the delta commits; any answer must match the fingerprint
    # recorded at *its* seq (stale is legal, divergent bits are not)
    oracle_fp = {}
    tally = {"ok": 0, "stale": 0, "shed": 0, "unavailable": 0,
             "divergent": 0, "unshed_timeouts": 0}

    async def editor():
        for _ in range(args.updates):
            delta = random_delta(fleet.writer.graph("g"),
                                 args.update_batch, rng)
            await fleet.apply("g", delta)
            oracle_fp[fleet.target_seq("g")] = fleet.writer.fingerprint("g")
            await asyncio.sleep(0)

    async def client(cid: int):
        for _ in range(args.requests):
            mu, eps = pool[rng.integers(len(pool))]
            if rng.random() < seed_share:
                coro = fleet.query_seed("g", int(rng.integers(g.n)), mu, eps)
            else:
                coro = fleet.query("g", mu, eps)
            try:
                # guard-s is the *unshed* timeout detector: the router's
                # own timeout/retry budget is far below it, so tripping
                # the guard means a request escaped every typed exit
                ans = await asyncio.wait_for(coro, args.guard_s)
            except Overloaded:
                tally["shed"] += 1
            except FleetExhausted:
                tally["unavailable"] += 1
            except asyncio.TimeoutError:
                tally["unshed_timeouts"] += 1
            else:
                want = oracle_fp.get(ans.seq)
                if want is None or ans.fingerprint != want:
                    tally["divergent"] += 1
                    print(f"DIVERGENT answer: replica={ans.replica} "
                          f"seq={ans.seq} fp={ans.fingerprint[:12]} "
                          f"oracle={'missing' if want is None else want[:12]}")
                else:
                    tally["ok"] += 1
                    if ans.seq < max(oracle_fp):
                        tally["stale"] += 1
            await asyncio.sleep(0)

    async def main_():
        async with fleet:
            fleet.create("g", g)
            oracle_fp[0] = fleet.writer.fingerprint("g")
            # wait for every replica to discover + restore the snapshot,
            # then warm the compiled batch shapes through the router
            await fleet.converged("g", timeout_s=30.0)
            if seed_share < 1.0:
                await fleet.query("g", *pool[0])
            if seed_share > 0.0:
                await fleet.query_seed("g", 0, *pool[0])
            async with _periodic_stats(fleet.registry, args.stats_every):
                t0 = time.time()
                await asyncio.gather(
                    editor(), *[client(i) for i in range(args.clients)])
                dt = time.time() - t0
            settled = await fleet.converged("g", timeout_s=5.0)
            rows = [(rep.replica_id, rep.healthy, rep.crashed,
                     rep.seq("g") if "g" in rep._tracked else None)
                    for rep in fleet.replicas]
            return dt, settled, rows

    dt, settled, rows = asyncio.run(main_())
    total = sum(tally[k] for k in
                ("ok", "shed", "unavailable", "divergent", "unshed_timeouts"))
    snap = fleet.metrics_snapshot()
    c = snap.get("counters", {})
    print(f"\n{total} {args.traffic} requests from {args.clients} clients "
          f"over {args.replicas} replicas ({args.updates} deltas applied) "
          f"in {dt:.2f}s → {total / dt:.1f} req/s")
    print(f"answers: ok={tally['ok']} (stale-but-consistent="
          f"{tally['stale']}) shed={tally['shed']} "
          f"unavailable={tally['unavailable']} "
          f"divergent={tally['divergent']} "
          f"unshed_timeouts={tally['unshed_timeouts']}")
    print(f"router: requests={c.get('fleet.requests', 0)} "
          f"failovers={c.get('fleet.failovers', 0)} "
          f"retries={c.get('fleet.retries', 0)} "
          f"hedges={c.get('fleet.hedges', 0)} "
          f"hedge_wins={c.get('fleet.hedge_wins', 0)} "
          f"overload_spills={c.get('fleet.overload_spills', 0)} "
          f"exhausted={c.get('fleet.exhausted', 0)}")
    print(f"replication: replays={c.get('fleet.replays', 0)} "
          f"swaps={c.get('fleet.swaps', 0)} "
          f"resyncs={c.get('fleet.resyncs', 0)} "
          f"corrupt_entries={c.get('fleet.corrupt_entries', 0)} "
          f"fingerprint_mismatches="
          f"{c.get('fleet.fingerprint_mismatches', 0)} "
          f"injected_corruptions={c.get('fleet.injected_corruptions', 0)} "
          f"crashes={c.get('fleet.crashes', 0)} "
          f"stalls={c.get('fleet.stalls', 0)}")
    target = fleet.target_seq("g")
    for rid, healthy, crashed, pos in rows:
        print(f"  {rid}: healthy={healthy} crashed={crashed} "
              f"seq={pos if pos is not None else '-'}/{target}")
    note = "converged" if settled else \
        "NOT converged (last-good service continues; staleness gauge " \
        f"= {snap.get('gauges', {}).get('fleet.staleness_seq', 0):g})"
    print(f"fleet {note}; writer at seq {target}")
    if args.metrics_json:
        write_json(snap, args.metrics_json)
        print(f"wrote merged fleet metrics snapshot to {args.metrics_json}")
    if tally["divergent"] or tally["unshed_timeouts"]:
        raise SystemExit(
            f"FLEET CHECK FAILED: divergent={tally['divergent']} "
            f"unshed_timeouts={tally['unshed_timeouts']}")
    print("fleet check passed: every answer carried the writer's exact "
          "bits for its sequence number")


_FLEET_EPILOG = """\
worked example — a chaos soak that must exit 0:

    PYTHONPATH=src python -m repro.launch.scan_serve fleet \\
        --n 2048 --avg-degree 8 --replicas 3 --clients 8 --requests 16 \\
        --updates 8 --chaos crash:0.02,stall:0.05,corrupt:0.1 \\
        --chaos-seed 7 --metrics-json /tmp/fleet_metrics.json

Every routed answer carries (fingerprint, seq, replica); the run fails
(exit 1) if any answer's fingerprint differs from the one the writer
recorded at that seq — bit divergence — or if a request times out
without a typed Overloaded/FleetExhausted exit. Stale answers (an older
seq than the writer's tip) are legal and reported separately; the
`fleet.staleness_seq` gauge in --metrics-json is the fleet-wide
watermark. Chaos spec keys: crash, stall, slow, corrupt, delay
(values are probabilities; the schedule is fully determined by
--chaos-seed, so a failing seed is a regression test)."""


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    for name, fn in (("sweep", cmd_sweep), ("serve", cmd_serve),
                     ("update", cmd_update), ("fleet", cmd_fleet)):
        p = sub.add_parser(
            name,
            epilog=_FLEET_EPILOG if name == "fleet" else None,
            formatter_class=argparse.RawDescriptionHelpFormatter)
        p.set_defaults(fn=fn)
        p.add_argument("--load", help="load a persisted index directory")
        p.add_argument("--save", help="persist the built index here")
        p.add_argument("--n", type=int, default=8192)
        p.add_argument("--avg-degree", type=float, default=16.0)
        p.add_argument("--seed", type=int, default=0)
        p.add_argument("--clusters", type=int, default=0)
        p.add_argument("--weighted", action="store_true")
        p.add_argument("--measure", default="cosine")
        p.add_argument("--shards", type=int, default=0,
                       help="shard the query path over K devices")
        p.add_argument("--lane",
                       choices=("ref", "pallas-interpret", "pallas-compiled"),
                       help="force every kernel onto one execution lane: "
                       "'ref' is the pure-jnp oracle, 'pallas-interpret' "
                       "emulates the Pallas kernel bodies on host, "
                       "'pallas-compiled' dispatches them to the "
                       "accelerator. All lanes are bit-identical on "
                       "unweighted graphs (ULP-close on weighted), so "
                       "this is a debugging/benchmarking knob, not a "
                       "quality one. Default: auto per call (the "
                       "REPRO_LANE env var overrides either way)")
        if name in ("sweep", "serve"):
            p.add_argument("--approx", metavar="METHOD[:K[:SEED]]",
                           help="build LSH-sketched (approximate-first) "
                           "indexes, e.g. simhash:256 or minhash:128:7; "
                           "under `serve` the exact index is refined in "
                           "the background and hot-swapped in while "
                           "traffic runs")
        if name == "sweep":
            p.add_argument("--mus", default="2,4,8")
            p.add_argument("--epss", default="0.1:0.9:9")
        else:
            p.add_argument("--clients", type=int, default=16)
            p.add_argument("--requests", type=int, default=32)
            p.add_argument("--traffic", choices=("global", "seed", "mixed"),
                           default="global",
                           help="client workload shape: 'global' clusters "
                           "the whole graph per request (query(μ, ε)); "
                           "'seed' asks for single random vertices' "
                           "clusters (query_seed(v, μ, ε) — served by the "
                           "fixed-shape local frontier kernel + the "
                           "seed-keyed cache); 'mixed' draws 50/50 per "
                           "request. Under `update`, seed cache entries "
                           "survive deltas via frontier migration")
            p.add_argument("--max-batch", type=int, default=32)
            p.add_argument("--flush-ms", type=float, default=2.0)
            p.add_argument("--no-warm", action="store_true",
                           help="disable sweep-ahead cache warming")
            p.add_argument("--metrics-json", metavar="PATH",
                           help="write the engine's full metrics-registry "
                           "snapshot (counters, gauges, latency histogram "
                           "buckets) as JSON when the run finishes")
            p.add_argument("--stats-every", type=float, default=0.0,
                           metavar="SECONDS",
                           help="periodically print a one-line metrics "
                           "dump while traffic runs (0 = off)")
        if name == "serve":
            p.add_argument("--indexes", type=int, default=1,
                           help="serve K indexes through one engine")
        if name in ("update", "fleet"):
            p.add_argument("--root", help="service catalog root "
                           "(snapshots + delta chains; default: tempdir)")
            p.add_argument("--updates", type=int, default=16,
                           help="number of edit batches to apply")
            p.add_argument("--update-batch", type=int, default=8,
                           help="edits per batch (half ins, half del)")
            p.add_argument("--compact-every", type=int, default=8,
                           help="snapshot + prune after this many deltas")
        if name == "fleet":
            p.add_argument("--replicas", type=int, default=3,
                           help="read replicas tailing the writer's chain")
            p.add_argument("--chaos", metavar="SPEC",
                           help="seeded fault schedule, e.g. "
                           "crash:0.02,stall:0.05,corrupt:0.1 (keys: "
                           "crash, stall, slow, corrupt, delay; values "
                           "are probabilities)")
            p.add_argument("--chaos-seed", type=int, default=0,
                           help="rng seed for the chaos schedule (a "
                           "failing seed replays exactly)")
            p.add_argument("--timeout-s", type=float, default=5.0,
                           help="router per-attempt timeout")
            p.add_argument("--hedge-after", type=float, default=0.25,
                           help="race a sibling replica if the primary "
                           "has not answered within this many seconds")
            p.add_argument("--guard-s", type=float, default=30.0,
                           help="wall-clock guard per request; tripping "
                           "it counts as an *unshed* timeout and fails "
                           "the run")
    args = ap.parse_args()
    if args.lane:
        # export rather than thread: the per-call REPRO_LANE read reaches
        # every dispatch site, including index *construction* paths that
        # run before any EngineConfig exists
        os.environ["REPRO_LANE"] = args.lane
    if getattr(args, "shards", 0) > 1:
        # must happen before jax's backend initializes — which is why all
        # heavier repro imports are deferred into the command functions
        from repro.core.distributed import force_host_devices
        force_host_devices(args.shards)
    args.fn(args)


if __name__ == "__main__":
    main()
