"""Production serving launcher: prefill + streaming decode over a mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch hymba-1.5b \
        --scale tiny --batch 4 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.dist.sharding import Sharder
from repro.launch.mesh import make_mesh
from repro.launch.train import SCALES
from repro.models import model as mdl
from repro.train.serve_step import greedy_generate


def reduced(arch, scale):
    cfg = get_config(arch)
    over = dict(SCALES[scale])
    if cfg.family == "moe":
        over.update(n_experts=8, top_k=2, d_ff=64,
                    d_ff_dense=over.get("d_ff", 256), capacity_factor=4.0)
        if cfg.use_mla:
            over.update(kv_lora_rank=64, qk_nope_dim=32, qk_rope_dim=16,
                        v_head_dim=32)
    if cfg.family in ("ssm", "hybrid"):
        over.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    if cfg.family == "hybrid":
        over.update(global_layers=(0,), window=32, meta_tokens=8)
    return cfg.scaled(**over) if over else cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--scale", default="tiny", choices=SCALES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mesh", default="1x1")
    args = ap.parse_args()

    cfg = reduced(args.arch, args.scale)
    dshape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dshape, ("data", "model"))
    with mesh:
        params = mdl.init_params(cfg, jax.random.PRNGKey(0))
        sharder = Sharder(mesh, cfg)
        params = jax.device_put(
            params, sharder.tree_named(sharder.param_specs(params)))
        prompts = jax.random.randint(
            jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)
        t0 = time.time()
        out = greedy_generate(cfg, params, {"tokens": prompts},
                              steps=args.gen,
                              max_len=args.prompt_len + args.gen + 1)
        jax.block_until_ready(out)
        dt = time.time() - t0
        print(f"{args.batch}×{args.gen} tokens in {dt:.2f}s "
              f"({args.batch * args.gen / dt:.1f} tok/s)")
        print("first row:", np.asarray(out[0]).tolist())


if __name__ == "__main__":
    main()
