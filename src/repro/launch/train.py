"""Production training launcher.

Wires together: mesh → sharding rules → sharded init → fault-tolerant
supervisor loop → atomic checkpoints. On this container it runs real
(small) configs on the single CPU device; on a pod the same entry point
runs the full mesh (the mesh/axis logic is identical — only device count
changes).

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --scale tiny --steps 100 [--mesh 1x1]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.data.pipeline import SyntheticLM
from repro.dist.fault_tolerance import Supervisor, SupervisorConfig
from repro.dist.sharding import Sharder
from repro.launch.mesh import make_mesh
from repro.models import model as mdl
from repro.optim import adamw
from repro.train.train_step import make_train_step

SCALES = {
    "tiny": dict(n_layers=2, d_model=128, d_ff=256, vocab=1024,
                 n_heads=4, n_kv_heads=2, head_dim=32, dtype="float32",
                 q_chunk=64),
    "small": dict(n_layers=6, d_model=512, d_ff=2048, vocab=8192,
                  n_heads=8, n_kv_heads=4, head_dim=64, dtype="float32",
                  q_chunk=128),
    "full": dict(),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--scale", default="tiny", choices=SCALES)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    args = ap.parse_args()

    cfg = get_config(args.arch).scaled(**SCALES[args.scale]) \
        if SCALES[args.scale] else get_config(args.arch)
    dshape = tuple(int(x) for x in args.mesh.split("x"))
    mesh = make_mesh(dshape, ("data", "model"))
    sharder = Sharder(mesh, cfg)

    with mesh:
        params = mdl.init_params(cfg, jax.random.PRNGKey(0))
        pspecs = sharder.param_specs(params)
        pshard = sharder.tree_named(pspecs)
        params = jax.device_put(params, pshard)
        opt = adamw.init(params)
        ospecs = sharder.opt_specs(pspecs, params)
        oshard = sharder.tree_named(ospecs)
        opt = jax.device_put(opt, oshard)

        hp = adamw.AdamWConfig(lr=1e-3, warmup_steps=10,
                               total_steps=args.steps)
        step_fn = jax.jit(make_train_step(cfg, hp, accum=args.accum),
                          in_shardings=(pshard, oshard, None),
                          out_shardings=(pshard, oshard, None))

        data = SyntheticLM(vocab=cfg.vocab, seq_len=args.seq,
                           global_batch=args.batch, accum=args.accum,
                           frontend=cfg.frontend, d_model=cfg.d_model,
                           n_frames=cfg.n_frames)

        def get_batch(step):
            b = data.batch(step)
            if args.accum == 1:   # pipeline emits no accum axis at accum=1
                b = {k: v[None] for k, v in b.items()}
            return jax.tree.map(jnp.asarray, b)

        sup = Supervisor(SupervisorConfig(ckpt_dir=args.ckpt_dir,
                                          ckpt_every=max(args.steps // 4, 10)))
        sup.install_signal_handlers()
        losses = []

        def on_step(step, metrics):
            losses.append(float(metrics["ce"]))
            if step % 10 == 0:
                print(f"step {step:4d} ce={losses[-1]:.4f}", flush=True)

        t0 = time.time()
        state = sup.run({"params": params, "opt_state": opt, "step": 0},
                        step_fn, get_batch, total_steps=args.steps,
                        shardings={"params": pshard, "opt_state": oshard},
                        hooks={"on_step": on_step})
        dt = time.time() - t0
        toks = args.batch * args.seq * int(state["step"])
        print(f"done {int(state['step'])} steps, {toks/dt:.0f} tok/s, "
              f"loss {np.mean(losses[:5]):.3f} → {np.mean(losses[-5:]):.3f}")


if __name__ == "__main__":
    main()
