"""Hymba — hybrid-head decoder: every layer runs attention heads and SSM
(mamba) heads *in parallel* on the same normalized input, combines the two
paths after per-path normalization, then a gated MLP.

Layout per assignment: 32L, d=1600, 25 attn heads (GQA kv=5, head_dim 64),
SSM heads 25×64 (state 16), 128 learned meta tokens prepended to every
sequence (attention sinks), global attention in layers {0, 15, 31}, sliding
window 1024 elsewhere.

Decode caches: global layers keep a full KV cache; SWA layers keep a
**ring buffer** of (meta + window) slots with a position-tracking array —
O(window) memory regardless of sequence length, which is what makes the
long_500k cell legal for this arch. The SSM path carries O(1) state.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S


def _is_global(cfg, i: int) -> bool:
    return i in cfg.global_layers


def hybrid_layer_init(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    return {
        "attn": L.gqa_init(ks[0], cfg),
        "ssm": S.mamba_block_init(ks[1], cfg),
        "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.compute_dtype,
                          cfg.act),
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
        "norm_attn": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
        "norm_ssm": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
    }


def hybrid_init(cfg, key) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_layers + 2)
    return {
        "emb": L.dense_init(ks[0], cfg.vocab_padded, cfg.d_model,
                            cfg.compute_dtype),
        "meta": L.dense_init(ks[1], cfg.meta_tokens, cfg.d_model,
                             cfg.compute_dtype) if cfg.meta_tokens else None,
        "ln_f": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
        "layers": [hybrid_layer_init(ks[i + 2], cfg)
                   for i in range(cfg.n_layers)],
    }


def _layer_apply(p, x, cfg, i):
    xin = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    window = 0 if _is_global(cfg, i) else cfg.window
    att = L.gqa_apply(p["attn"], xin, cfg, causal=True, window=window,
                      sink=cfg.meta_tokens)
    ssm = S.mamba_block_apply(p["ssm"], xin, cfg)
    mixed = 0.5 * (L.rmsnorm(att, p["norm_attn"], cfg.norm_eps)
                   + L.rmsnorm(ssm, p["norm_ssm"], cfg.norm_eps))
    h = x + mixed
    return h + L.mlp_apply(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps),
                           cfg.act)


def _with_meta(params, tokens, cfg):
    x = params["emb"][tokens]
    if cfg.meta_tokens:
        b = x.shape[0]
        meta = jnp.broadcast_to(params["meta"][None],
                                (b, cfg.meta_tokens, cfg.d_model))
        x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
    return x


def hybrid_forward(params, tokens, cfg, return_hidden=False):
    x = _with_meta(params, tokens, cfg)
    for i, p in enumerate(params["layers"]):
        f = L.remat(_layer_apply, cfg, static_argnums=(2, 3))
        x = L.sp(f(p, x, cfg, i))
    x = x[:, cfg.meta_tokens:]                  # drop meta outputs
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    if return_hidden:
        return x, params["emb"].T
    return x @ params["emb"].T


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
def hybrid_init_cache(cfg, batch: int, max_len: int, dtype):
    """max_len counts generated/prompt tokens EXCLUDING meta tokens."""
    meta = cfg.meta_tokens
    caches = []
    for i in range(cfg.n_layers):
        size = meta + (max_len if _is_global(cfg, i) else cfg.window)
        caches.append({
            "k": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((batch, size, cfg.n_kv_heads, cfg.hd), dtype),
            "pos": jnp.full((size,), -1, jnp.int32),
            "ssm": S.ssm_cache_leaf(cfg, batch, dtype),
        })
    return caches


def _ring_slots(cfg, i, positions):
    """Cache slots for absolute positions (meta tokens at slots [0, meta))."""
    meta = cfg.meta_tokens
    if _is_global(cfg, i):
        return positions
    return jnp.where(positions < meta, positions,
                     meta + (positions - meta) % cfg.window)


def hybrid_prefill(params, tokens, cfg, max_len: int):
    b, s = tokens.shape
    x = _with_meta(params, tokens, cfg)
    total = x.shape[1]
    positions = jnp.arange(total)
    cache = hybrid_init_cache(cfg, b, max_len, cfg.compute_dtype)
    for i, p in enumerate(params["layers"]):
        xin = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        window = 0 if _is_global(cfg, i) else cfg.window
        q, k, v = L.gqa_project(p["attn"], xin, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        att = L.attention(q, k, v, causal=True, window=window,
                          sink=cfg.meta_tokens, impl=cfg.attn_impl,
                          q_chunk=cfg.q_chunk, remat_chunks=False)
        # write to cache: all positions for global layers; meta + the last
        # `window` positions (distinct ring slots) for SWA layers
        if _is_global(cfg, i):
            wpos = positions
        else:
            keep = min(cfg.window, total - cfg.meta_tokens)
            wpos = jnp.concatenate(
                [jnp.arange(cfg.meta_tokens), total - keep + jnp.arange(keep)])
        slots = _ring_slots(cfg, i, wpos)
        cache[i]["k"] = cache[i]["k"].at[:, slots].set(k[:, wpos])
        cache[i]["v"] = cache[i]["v"].at[:, slots].set(v[:, wpos])
        cache[i]["pos"] = cache[i]["pos"].at[slots].set(wpos.astype(jnp.int32))

        ssm_out, ssm_cache = S.mamba_block_prefill(p["ssm"], xin, cfg)
        cache[i]["ssm"] = ssm_cache
        mixed = 0.5 * (
            L.rmsnorm(att.reshape(b, total, -1) @ p["attn"]["wo"],
                      p["norm_attn"], cfg.norm_eps)
            + L.rmsnorm(ssm_out, p["norm_ssm"], cfg.norm_eps))
        x = x + mixed
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps),
                            cfg.act)
    x = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    return (x @ params["emb"].T)[:, 0], cache


def hybrid_decode_step(params, cache, token, pos, cfg):
    """pos = absolute position INCLUDING meta offset (i.e. meta + #tokens)."""
    b = token.shape[0]
    x = params["emb"][token][:, None]
    positions = jnp.full((1,), pos, jnp.int32)
    new_cache = []
    for i, p in enumerate(params["layers"]):
        xin = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        window = 0 if _is_global(cfg, i) else cfg.window
        q, k, v = L.gqa_project(p["attn"], xin, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        slot = _ring_slots(cfg, i, positions)[0]
        ck = jax.lax.dynamic_update_slice_in_dim(cache[i]["k"], k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache[i]["v"], v, slot, axis=1)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache[i]["pos"], positions, slot, axis=0)
        valid = cpos >= 0
        if window > 0:
            valid &= (cpos > pos - window) | (cpos < cfg.meta_tokens)
        att = L.decode_attention(q, ck, cv, valid)
        ssm_out, ssm_cache = S.mamba_block_decode(p["ssm"], xin,
                                                  cache[i]["ssm"], cfg)
        mixed = 0.5 * (
            L.rmsnorm(att.reshape(b, 1, -1) @ p["attn"]["wo"],
                      p["norm_attn"], cfg.norm_eps)
            + L.rmsnorm(ssm_out, p["norm_ssm"], cfg.norm_eps))
        x = x + mixed
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps),
                            cfg.act)
        new_cache.append({"k": ck, "v": cv, "pos": cpos, "ssm": ssm_cache})
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return (x @ params["emb"].T)[:, 0], new_cache
