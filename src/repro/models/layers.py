"""Shared model layers: norms, RoPE, MLPs, attention (dense / chunked /
decode), KV caches. Pure-functional: params are plain dict pytrees.

Attention memory policy: ``dense`` materializes (S_q × S_kv) scores — fine
for short sequences and smoke tests; ``chunked`` python-loops over q-blocks
(unrolled ⇒ exact dry-run FLOP accounting) with per-chunk ``jax.checkpoint``
so training at 32k keeps O(S·q_chunk) live scores. ``auto`` picks by size.

Sharding notes (see dist/sharding.py): attention computes with KV repeated
to the full head count so the q-head axis is the tensor-parallel axis when
divisible; the repeat of a replicated KV tensor to a head-sharded layout is
local slicing, not communication.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

# --------------------------------------------------------------------------
# sequence-parallel activation constraint (set by the launcher; models call
# sp() on the residual stream at layer boundaries — no-op unless enabled)
# --------------------------------------------------------------------------
_SP_SPEC = None


def set_sp_spec(spec) -> None:
    """spec: PartitionSpec for [B, S, D] activations (e.g. P(dp,'model',None))
    or None to disable. Resolved under the ambient mesh at trace time."""
    global _SP_SPEC
    _SP_SPEC = spec


def sp(x: jax.Array) -> jax.Array:
    if _SP_SPEC is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _SP_SPEC)


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------
def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rmsnorm_init(d: int, dtype):
    return jnp.ones((d,), dtype)


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: [S] or [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [d/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, d/2]
    cos = jnp.cos(ang)[..., :, None, :]                # [..., S, 1, d/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# MLP (gated-SiLU or GELU)
# --------------------------------------------------------------------------
def mlp_init(key, d: int, d_ff: int, dtype, act: str) -> Params:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], d, d_ff, dtype),
         "w_down": dense_init(ks[1], d_ff, d, dtype)}
    if act == "silu":  # gated
        p["w_gate"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def mlp_apply(p: Params, x: jax.Array, act: str) -> jax.Array:
    up = x @ p["w_up"]
    if act == "silu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        h = jax.nn.gelu(up)
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# attention core
# --------------------------------------------------------------------------
NEG_INF = -1e30


def _mask_bias(q_pos, k_pos, causal, window, sink):
    """Additive bias [Sq, Sk] from position vectors."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        in_win = k_pos[None, :] > q_pos[:, None] - window
        if sink > 0:
            in_win |= k_pos[None, :] < sink
        ok &= in_win
    return jnp.where(ok, 0.0, NEG_INF)


_SOFTMAX_DTYPE = jnp.float32


def set_softmax_dtype(dtype) -> None:
    """f32 (default) or bf16 score buffers. The bf16 path subtracts the row
    max (computed in f32) before exp and accumulates the denominator in f32
    — the PaLM-style reduced-precision softmax. Set by the launcher for the
    §Perf memory-term experiments."""
    global _SOFTMAX_DTYPE
    _SOFTMAX_DTYPE = dtype


def _sdpa_dense(q, k, v, bias):
    """q:[B,Sq,H,D] k/v:[B,Sk,H,D] bias:[Sq,Sk] → [B,Sq,H,D]."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    if _SOFTMAX_DTYPE == jnp.float32:
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
        s = s + bias[None, None]
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v)
    # reduced-precision score buffers: [B,H,Sq,Sk] stays bf16
    s = (jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
         + bias[None, None].astype(q.dtype))
    m = jnp.max(s.astype(jnp.float32), axis=-1, keepdims=True)
    p = jnp.exp(s - m.astype(s.dtype))                     # bf16 buffer
    denom = jnp.sum(p, axis=-1, keepdims=True, dtype=jnp.float32)
    p = (p / denom.astype(p.dtype))
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def attention(
    q: jax.Array,           # [B, Sq, H, D]
    k: jax.Array,           # [B, Sk, KV, D]
    v: jax.Array,           # [B, Sk, KV, D]
    *,
    causal: bool = True,
    window: int = 0,
    sink: int = 0,
    impl: str = "auto",
    q_chunk: int = 2048,
    remat_chunks: bool = True,
    q_offset: int = 0,      # q positions start here (prefill continuation)
) -> jax.Array:
    """Multi-head attention with GQA repeat, masks, and chunking."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    if kv != h:  # GQA: repeat kv to full head count (local slice under TP)
        rep = h // kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    sk = k.shape[1]
    if impl == "auto":
        impl = "dense" if sq * sk <= 4096 * 4096 or sq < q_chunk else "chunked"

    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(sk)
    if impl == "dense" or sq <= q_chunk:
        bias = _mask_bias(q_pos, k_pos, causal, window, sink)
        return _sdpa_dense(q, k, v, bias)

    # chunked: unrolled python loop over q blocks (remainder chunk allowed);
    # each block rematerialized
    def block(qc, q_pos_c, k, v):
        bias = _mask_bias(q_pos_c, k_pos, causal, window, sink)
        return _sdpa_dense(qc, k, v, bias)

    if remat_chunks:
        block = jax.checkpoint(block)
    outs = []
    for lo in range(0, sq, q_chunk):
        hi = min(lo + q_chunk, sq)
        qc = jax.lax.slice_in_dim(q, lo, hi, axis=1)
        outs.append(block(qc, q_pos[lo:hi], k, v))
    return jnp.concatenate(outs, axis=1)


def decode_attention(
    q: jax.Array,            # [B, 1, H, D]
    k_cache: jax.Array,      # [B, S, KV, D]
    v_cache: jax.Array,      # [B, S, KV, D]
    valid: jax.Array,        # bool[B, S] or [S] — which cache slots count
) -> jax.Array:
    b, _, h, d = q.shape
    kv = k_cache.shape[2]
    if kv != h:
        rep = h // kv
        k_cache = jnp.repeat(k_cache, rep, axis=2)
        v_cache = jnp.repeat(v_cache, rep, axis=2)
    scale = 1.0 / (d ** 0.5)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k_cache).astype(jnp.float32) * scale
    if valid.ndim == 1:
        bias = jnp.where(valid, 0.0, NEG_INF)[None, None, None, :]
    else:
        bias = jnp.where(valid, 0.0, NEG_INF)[:, None, None, :]
    p = jax.nn.softmax(s + bias, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v_cache)


# --------------------------------------------------------------------------
# standard GQA attention block (init/apply/decode)
# --------------------------------------------------------------------------
def gqa_init(key, cfg) -> Params:
    ks = jax.random.split(key, 4)
    d, hd = cfg.d_model, cfg.hd
    return {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, cfg.compute_dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, cfg.compute_dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, cfg.compute_dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, cfg.compute_dtype),
    }


def gqa_project(p, x, cfg):
    b, s, _ = x.shape
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    return q, k, v


def gqa_apply(
    p: Params, x: jax.Array, cfg, *, causal=True, window=0, sink=0,
    positions=None, rope=True, kv_source: Optional[jax.Array] = None,
) -> jax.Array:
    """Full-sequence attention (train / prefill). kv_source → cross-attn."""
    b, s, _ = x.shape
    src = kv_source if kv_source is not None else x
    hd = cfg.hd
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (src @ p["wk"]).reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    v = (src @ p["wv"]).reshape(b, src.shape[1], cfg.n_kv_heads, hd)
    if rope:
        pos = positions if positions is not None else jnp.arange(s)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos if kv_source is None else jnp.arange(src.shape[1]),
                       cfg.rope_theta)
    out = attention(
        q, k, v, causal=causal, window=window, sink=sink,
        impl=cfg.attn_impl, q_chunk=cfg.q_chunk,
    )
    return out.reshape(b, s, cfg.n_heads * hd) @ p["wo"]


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------
def remat(f, cfg, static_argnums=()):
    """jax.checkpoint with the configured policy."""
    if not cfg.remat:
        return f
    policy = None
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint(f, static_argnums=static_argnums, policy=policy)


def cross_entropy_chunked(
    hidden: jax.Array,     # [B, S, D] final hidden states
    head: jax.Array,       # [D, Vp]
    labels: jax.Array,     # [B, S]
    vocab: int,
    chunk: int,
) -> jax.Array:
    """CE without materializing [B,S,Vp] f32 logits: per-seq-chunk logits +
    logsumexp, rematerialized in backward. HBM traffic drops from O(B·S·V)
    to O(B·chunk·V) live."""
    b, s, d = hidden.shape
    vp = head.shape[1]
    assert s % chunk == 0, (s, chunk)
    pad_bias = jnp.where(jnp.arange(vp) < vocab, 0.0, NEG_INF)

    def piece(h_c, l_c):
        logits = (h_c @ head).astype(jnp.float32) + pad_bias
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l_c[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    piece = jax.checkpoint(piece)
    total = jnp.float32(0.0)
    for i in range(s // chunk):
        total = total + piece(
            jax.lax.slice_in_dim(hidden, i * chunk, (i + 1) * chunk, axis=1),
            jax.lax.slice_in_dim(labels, i * chunk, (i + 1) * chunk, axis=1))
    return total / (b * s)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  vocab: int) -> jax.Array:
    """Mean CE over tokens; logits [B,S,Vp] (padded vocab masked out)."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > vocab:
        pad_bias = jnp.where(jnp.arange(vp) < vocab, 0.0, NEG_INF)
        logits = logits + pad_bias
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)
