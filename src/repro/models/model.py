"""Unified model API over the five families + dry-run input specs.

batch dicts:
  * LM families:  {"tokens" [B,S] i32, "labels" [B,S] i32}
  * [vlm] stub:   {"embeddings" [B,S,d] (precomputed patch+text), "labels"}
  * [audio] stub: {"frames" [B,F,d] (precomputed log-mel embeddings),
                   "tokens", "labels"}
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import transformer as T
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import hybrid as H


# --------------------------------------------------------------------------
# dispatch
# --------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key: jax.Array):
    return {
        "dense": T.dense_init,
        "encdec": T.encdec_init,
        "moe": M.moe_init,
        "ssm": S.ssm_init,
        "hybrid": H.hybrid_init,
    }[cfg.family](cfg, key)


def forward(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, jax.Array]:
    """→ (logits [B,S,Vp], aux_loss scalar)."""
    zero = jnp.float32(0.0)
    if cfg.family == "dense":
        logits = T.dense_forward(params, batch.get("tokens"), cfg,
                                 embeddings=batch.get("embeddings"))
        return logits, zero
    if cfg.family == "encdec":
        return T.encdec_forward(params, batch, cfg), zero
    if cfg.family == "moe":
        return M.moe_forward(params, batch["tokens"], cfg)
    if cfg.family == "ssm":
        return S.ssm_forward(params, batch["tokens"], cfg), zero
    if cfg.family == "hybrid":
        return H.hybrid_forward(params, batch["tokens"], cfg), zero
    raise ValueError(cfg.family)


def forward_hidden(cfg: ModelConfig, params, batch):
    """→ ((hidden [B,S,D], head [D,Vp]), aux) — for chunked cross-entropy."""
    zero = jnp.float32(0.0)
    if cfg.family == "dense":
        out = T.dense_forward(params, batch.get("tokens"), cfg,
                              embeddings=batch.get("embeddings"),
                              return_hidden=True)
        return out, zero
    if cfg.family == "encdec":
        return T.encdec_forward(params, batch, cfg, return_hidden=True), zero
    if cfg.family == "moe":
        return M.moe_forward(params, batch["tokens"], cfg, return_hidden=True)
    if cfg.family == "ssm":
        return S.ssm_forward(params, batch["tokens"], cfg,
                             return_hidden=True), zero
    if cfg.family == "hybrid":
        return H.hybrid_forward(params, batch["tokens"], cfg,
                                return_hidden=True), zero
    raise ValueError(cfg.family)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dt = cfg.compute_dtype
    return {
        "dense": T.dense_init_cache,
        "encdec": T.encdec_init_cache,
        "moe": M.moe_init_cache,
        "ssm": S.ssm_init_cache,
        "hybrid": H.hybrid_init_cache,
    }[cfg.family](cfg, batch, max_len, dt)


def prefill(cfg: ModelConfig, params, batch, max_len: int):
    if cfg.family == "dense":
        return T.dense_prefill(params, batch.get("tokens"), cfg, max_len,
                               embeddings=batch.get("embeddings"))
    if cfg.family == "encdec":
        return T.encdec_prefill(params, batch, cfg, max_len)
    if cfg.family == "moe":
        return M.moe_prefill(params, batch["tokens"], cfg, max_len)
    if cfg.family == "ssm":
        return S.ssm_prefill(params, batch["tokens"], cfg, max_len)
    if cfg.family == "hybrid":
        return H.hybrid_prefill(params, batch["tokens"], cfg, max_len)
    raise ValueError(cfg.family)


def decode_step(cfg: ModelConfig, params, cache, token, pos):
    return {
        "dense": T.dense_decode_step,
        "encdec": T.encdec_decode_step,
        "moe": M.moe_decode_step,
        "ssm": S.ssm_decode_step,
        "hybrid": H.hybrid_decode_step,
    }[cfg.family](params, cache, token, pos, cfg)


# --------------------------------------------------------------------------
# analytic parameter counts (for MODEL_FLOPS — no allocation)
# --------------------------------------------------------------------------
def count_params(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(shapes)
                   if hasattr(l, "shape")))


def count_active_params(cfg: ModelConfig) -> int:
    """Per-token active params (= total for non-MoE)."""
    total = count_params(cfg)
    if cfg.family != "moe":
        return total
    n_moe_layers = cfg.n_layers - cfg.first_dense_layers
    per_expert = 3 * cfg.d_model * cfg.d_ff
    routed_total = n_moe_layers * cfg.n_experts * per_expert
    routed_active = n_moe_layers * cfg.top_k * per_expert
    return total - routed_total + routed_active


# --------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct — no device allocation)
# --------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Stand-ins for every model input of the given shape cell.

    For ``decode`` cells the cache spec is derived via jax.eval_shape over
    init_cache (KV of length seq_len), matching the assignment: one new
    token against a seq_len cache.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    cdt = cfg.compute_dtype

    def tok(shape_):
        return jax.ShapeDtypeStruct(shape_, i32)

    if shape.kind == "train":
        batch = {"tokens": tok((b, s)), "labels": tok((b, s))}
        if cfg.frontend == "vision_stub":
            batch = {"embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), cdt),
                     "labels": tok((b, s))}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model), cdt)
        return {"batch": batch}

    if shape.kind == "prefill":
        batch = {"tokens": tok((b, s))}
        if cfg.frontend == "vision_stub":
            batch = {"embeddings": jax.ShapeDtypeStruct((b, s, cfg.d_model), cdt)}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct((b, cfg.n_frames, cfg.d_model), cdt)
        return {"batch": batch, "max_len": s}

    if shape.kind == "decode":
        cache_shapes = jax.eval_shape(lambda: init_cache(cfg, b, s))
        return {
            "cache": cache_shapes,
            "token": tok((b,)),
            "pos": jax.ShapeDtypeStruct((), i32),
        }
    raise ValueError(shape.kind)


def decode_pos(cfg: ModelConfig, shape: ShapeSpec) -> int:
    """The decode position for a (arch, decode-shape) cell."""
    base = shape.seq_len - 1
    if cfg.family == "hybrid":
        return cfg.meta_tokens + base
    return base
