"""Mixture-of-Experts decoders: DeepSeek-V2-Lite (MLA attention) and
Moonlight/moonshot (GQA attention). Shared + routed experts, top-k routing.

Dispatch is **sort-based with a static capacity** (GShard/Switch "dropping"
semantics, capacity_factor configurable): tokens are argsorted by expert id,
scattered into an [E, capacity, d] buffer, processed as one batched einsum
per weight (exact active-FLOP accounting — no one-hot dispatch matmuls), and
combined back weighted by the (renormalized) router probabilities. Overflow
tokens fall through on the residual path.

Expert tensors are sharded on the expert axis ('model'); the scatter from
data-sharded tokens to expert-sharded buffers is the EP boundary — the pjit
baseline lets XLA insert the collectives; dist/ep.py provides the explicit
shard_map all_to_all variant used in the perf hillclimb.

MLA (multi-head latent attention) supports two decode cache modes:
  * ``full``   — materialized per-head K/V cache (baseline),
  * ``latent`` — cache only (c_kv, k_rope); score/value projections absorbed
                 into the query/output (the DeepSeek-V2 inference trick) —
                 cache bytes drop from H·(192+128) to (512+64) per token.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


# ==========================================================================
# routed-expert FFN
# ==========================================================================
def moe_ffn_init(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = cfg.compute_dtype

    def experts(k, din, dout):
        scale = (1.0 / din) ** 0.5
        return (jax.random.normal(k, (e, din, dout), jnp.float32) * scale).astype(dt)

    p = {
        "router": L.dense_init(ks[0], d, e, jnp.float32),
        "w_gate": experts(ks[1], d, ff),
        "w_up": experts(ks[2], d, ff),
        "w_down": experts(ks[3], ff, d),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, ff * cfg.n_shared_experts, dt, "silu")
    return p


def moe_ffn_apply(p, x, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] → (out [B, S, d], aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    e = cfg.n_experts
    xf = x.reshape(t, d)

    # bf16 matmul (f32 softmax): keeps the aux-loss backward cotangents
    # bf16 — the dominant TP collectives halve (EXPERIMENTS §Perf B4)
    logits = (xf @ p["router"].astype(xf.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # [T, E]
    top_p, top_i = jax.lax.top_k(probs, k)                       # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E · Σ_e f_e · P_e
    f_e = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0) / (t * k)
    p_e = probs.mean(axis=0)
    aux = e * jnp.sum(f_e * p_e)

    from repro.dist import ep as ep_mod
    if cfg.moe_impl == "ep" and ep_mod.ep_enabled():
        # routing re-done inside the shard (replicated math, f32 cast local)
        # so no f32 cotangent crosses the shard_map boundary; aux keeps the
        # outside (global-batch) statistics above.
        combined = ep_mod.ep_ffn(xf, p["router"], p["w_gate"], p["w_up"],
                                 p["w_down"], cfg)
        if "shared" in p:
            combined = combined + L.mlp_apply(p["shared"], xf, "silu")
        return combined.reshape(b, s, d), aux

    cap = max(int(cfg.capacity_factor * t * k / e), 1)

    flat_e = top_i.reshape(-1)                                   # [T·k]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = pos < cap
    dest = jnp.where(keep, sorted_e * cap + pos, e * cap)        # drop slot

    src_token = order // k
    buf = jnp.zeros((e * cap, d), x.dtype).at[dest].set(
        xf[src_token], mode="drop"
    )
    h = buf.reshape(e, cap, d)
    act = jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, p["w_gate"]))
    act = act * jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", act, p["w_down"]).reshape(e * cap, d)

    # map each (token, slot) back to its buffer row
    dest_of_slot = jnp.zeros((t * k,), jnp.int32).at[order].set(
        jnp.where(keep, dest, e * cap).astype(jnp.int32)
    )
    padded = jnp.concatenate([out_buf, jnp.zeros((1, d), x.dtype)], axis=0)
    expert_out = padded[dest_of_slot].reshape(t, k, d)
    combined = jnp.sum(expert_out * top_p[..., None].astype(x.dtype), axis=1)

    if "shared" in p:
        combined = combined + L.mlp_apply(p["shared"], xf, "silu")
    return combined.reshape(b, s, d), aux


# ==========================================================================
# MLA attention (DeepSeek-V2)
# ==========================================================================
def mla_init(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    d, h = cfg.d_model, cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    dt = cfg.compute_dtype
    return {
        "wq": L.dense_init(ks[0], d, h * qk, dt),
        "w_dkv": L.dense_init(ks[1], d, cfg.kv_lora_rank + cfg.qk_rope_dim, dt),
        "kv_ln": L.rmsnorm_init(cfg.kv_lora_rank, dt),
        "w_ukv": L.dense_init(
            ks[2], cfg.kv_lora_rank, h * (cfg.qk_nope_dim + cfg.v_head_dim), dt
        ),
        "wo": L.dense_init(ks[3], h * cfg.v_head_dim, d, dt),
    }


def _mla_qkv(p, x, cfg, positions):
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = (x @ p["wq"]).reshape(b, s, h, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = L.apply_rope(qr, positions, cfg.rope_theta)

    dkv = x @ p["w_dkv"]                                  # [B,S,lora+dr]
    ckv = L.rmsnorm(dkv[..., : cfg.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    kr = dkv[..., cfg.kv_lora_rank:][:, :, None, :]       # [B,S,1,dr]
    kr = L.apply_rope(kr, positions, cfg.rope_theta)

    kv = (ckv @ p["w_ukv"]).reshape(b, s, h, dn + dv)
    kn, v = kv[..., :dn], kv[..., dn:]
    q_full = jnp.concatenate([qn, qr], axis=-1)
    k_full = jnp.concatenate([kn, jnp.broadcast_to(kr, (b, s, h, dr))], axis=-1)
    return q_full, k_full, v, ckv, kr


def mla_apply(p, x, cfg, positions=None):
    b, s, _ = x.shape
    pos = positions if positions is not None else jnp.arange(s)
    q, k, v, _, _ = _mla_qkv(p, x, cfg, pos)
    out = L.attention(q, k, v, causal=True, impl=cfg.attn_impl,
                      q_chunk=cfg.q_chunk)
    return out.reshape(b, s, -1) @ p["wo"]


def mla_decode_full(p, x, cache, pos, cfg):
    """Baseline decode: per-head K/V cache (like GQA but H heads)."""
    b = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v, _, _ = _mla_qkv(p, x, cfg, positions)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1)
    valid = jnp.arange(ck.shape[1]) <= pos
    att = L.decode_attention(q, ck, cv, valid)
    return att.reshape(b, 1, -1) @ p["wo"], {"k": ck, "v": cv}


def mla_decode_latent(p, x, cache, pos, cfg):
    """Absorbed decode: cache only (c_kv, k_rope); W_uk folded into q,
    W_uv applied after the attention-weighted latent sum."""
    b = x.shape[0]
    h, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    lora = cfg.kv_lora_rank
    positions = jnp.full((1,), pos, jnp.int32)

    q = (x @ p["wq"]).reshape(b, 1, h, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = L.apply_rope(qr, positions, cfg.rope_theta)

    dkv = x @ p["w_dkv"]
    ckv_new = L.rmsnorm(dkv[..., :lora], p["kv_ln"], cfg.norm_eps)
    kr_new = L.apply_rope(dkv[..., lora:][:, :, None, :], positions,
                          cfg.rope_theta)[:, :, 0, :]

    c_ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new, pos, axis=1)
    c_kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_new, pos, axis=1)

    w_ukv = p["w_ukv"].reshape(lora, h, dn + dv)
    w_uk = w_ukv[..., :dn]                               # [lora, H, dn]
    w_uv = w_ukv[..., dn:]                               # [lora, H, dv]

    q_lat = jnp.einsum("bqhn,lhn->bqhl", qn, w_uk)       # absorb W_uk
    s_lat = jnp.einsum("bqhl,bkl->bhqk", q_lat, c_ckv)
    s_rope = jnp.einsum("bqhr,bkr->bhqk", qr, c_kr)
    scale = 1.0 / ((dn + dr) ** 0.5)
    s = (s_lat + s_rope).astype(jnp.float32) * scale
    valid = jnp.arange(c_ckv.shape[1]) <= pos
    s = s + jnp.where(valid, 0.0, L.NEG_INF)[None, None, None, :]
    pr = jax.nn.softmax(s, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqk,bkl->bqhl", pr, c_ckv)        # latent context
    att = jnp.einsum("bqhl,lhv->bqhv", ctx, w_uv)        # absorb W_uv
    out = att.reshape(b, 1, h * dv) @ p["wo"]
    return out, {"ckv": c_ckv, "kr": c_kr}


# ==========================================================================
# MoE decoder model
# ==========================================================================
def _is_dense_layer(cfg, i: int) -> bool:
    return i < cfg.first_dense_layers


def moe_layer_init(key, cfg, i: int) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    attn = mla_init(ks[0], cfg) if cfg.use_mla else L.gqa_init(ks[0], cfg)
    if _is_dense_layer(cfg, i):
        ffn = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff_dense, cfg.compute_dtype,
                         "silu")
    else:
        ffn = moe_ffn_init(ks[1], cfg)
    return {
        "attn": attn,
        "ffn": ffn,
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
    }


def moe_init(cfg, key) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_layers + 2)
    return {
        "emb": L.dense_init(ks[0], cfg.vocab_padded, cfg.d_model,
                            cfg.compute_dtype),
        "head": L.dense_init(ks[1], cfg.d_model, cfg.vocab_padded,
                             cfg.compute_dtype),
        "ln_f": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
        "layers": [moe_layer_init(ks[i + 2], cfg, i) for i in range(cfg.n_layers)],
    }


def moe_forward(params, tokens, cfg, return_hidden=False):
    """Returns (logits, aux_loss) or ((hidden, head), aux_loss)."""
    x = params["emb"][tokens]
    aux_total = jnp.float32(0.0)
    for i, p in enumerate(params["layers"]):
        def layer(p, x, i=i):
            xin = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
            if cfg.use_mla:
                att = mla_apply(p["attn"], xin, cfg)
            else:
                att = L.gqa_apply(p["attn"], xin, cfg, causal=True)
            h = x + att
            hin = L.rmsnorm(h, p["ln2"], cfg.norm_eps)
            if _is_dense_layer(cfg, i):
                return h + L.mlp_apply(p["ffn"], hin, "silu"), jnp.float32(0.0)
            out, aux = moe_ffn_apply(p["ffn"], hin, cfg)
            return h + out, aux
        f = L.remat(layer, cfg)
        x, aux = f(p, x)
        x = L.sp(x)
        aux_total = aux_total + aux
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    if return_hidden:
        return (x, params["head"]), aux_total
    return x @ params["head"], aux_total


def moe_init_cache(cfg, batch: int, max_len: int, dtype):
    if cfg.use_mla and cfg.mla_cache_mode == "latent":
        per = lambda: {
            "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, max_len, cfg.qk_rope_dim), dtype),
        }
    elif cfg.use_mla:
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        per = lambda: {
            "k": jnp.zeros((batch, max_len, cfg.n_heads, qk), dtype),
            "v": jnp.zeros((batch, max_len, cfg.n_heads, cfg.v_head_dim), dtype),
        }
    else:
        per = lambda: {
            "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        }
    return [per() for _ in range(cfg.n_layers)]


def moe_prefill(params, tokens, cfg, max_len: int):
    b, s = tokens.shape
    x = params["emb"][tokens]
    cache = moe_init_cache(cfg, b, max_len, cfg.compute_dtype)
    positions = jnp.arange(s)
    for i, p in enumerate(params["layers"]):
        xin = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        if cfg.use_mla:
            q, k, v, ckv, kr = _mla_qkv(p["attn"], xin, cfg, positions)
            if cfg.mla_cache_mode == "latent":
                cache[i]["ckv"] = jax.lax.dynamic_update_slice_in_dim(
                    cache[i]["ckv"], ckv, 0, axis=1)
                cache[i]["kr"] = jax.lax.dynamic_update_slice_in_dim(
                    cache[i]["kr"], kr[:, :, 0, :], 0, axis=1)
            else:
                cache[i]["k"] = jax.lax.dynamic_update_slice_in_dim(
                    cache[i]["k"], k, 0, axis=1)
                cache[i]["v"] = jax.lax.dynamic_update_slice_in_dim(
                    cache[i]["v"], v, 0, axis=1)
            att = L.attention(q, k, v, causal=True, impl=cfg.attn_impl,
                              q_chunk=cfg.q_chunk, remat_chunks=False)
            x = x + att.reshape(b, s, -1) @ p["attn"]["wo"]
        else:
            q, k, v = L.gqa_project(p["attn"], xin, cfg)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            cache[i]["k"] = jax.lax.dynamic_update_slice_in_dim(
                cache[i]["k"], k, 0, axis=1)
            cache[i]["v"] = jax.lax.dynamic_update_slice_in_dim(
                cache[i]["v"], v, 0, axis=1)
            att = L.attention(q, k, v, causal=True, impl=cfg.attn_impl,
                              q_chunk=cfg.q_chunk, remat_chunks=False)
            x = x + att.reshape(b, s, -1) @ p["attn"]["wo"]
        hin = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if _is_dense_layer(cfg, i):
            x = x + L.mlp_apply(p["ffn"], hin, "silu")
        else:
            out, _ = moe_ffn_apply(p["ffn"], hin, cfg)
            x = x + out
    x = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    return (x @ params["head"])[:, 0], cache


def moe_decode_step(params, cache, token, pos, cfg):
    b = token.shape[0]
    x = params["emb"][token][:, None]
    positions = jnp.full((1,), pos, jnp.int32)
    new_cache = []
    for i, p in enumerate(params["layers"]):
        xin = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        if cfg.use_mla:
            if cfg.mla_cache_mode == "latent":
                att, nc = mla_decode_latent(p["attn"], xin, cache[i], pos, cfg)
            else:
                att, nc = mla_decode_full(p["attn"], xin, cache[i], pos, cfg)
            x = x + att
        else:
            q, k, v = L.gqa_project(p["attn"], xin, cfg)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            ck = jax.lax.dynamic_update_slice_in_dim(cache[i]["k"], k, pos, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache[i]["v"], v, pos, axis=1)
            nc = {"k": ck, "v": cv}
            valid = jnp.arange(ck.shape[1]) <= pos
            att = L.decode_attention(q, ck, cv, valid)
            x = x + att.reshape(b, 1, -1) @ p["attn"]["wo"]
        new_cache.append(nc)
        hin = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if _is_dense_layer(cfg, i):
            x = x + L.mlp_apply(p["ffn"], hin, "silu")
        else:
            out, _ = moe_ffn_apply(p["ffn"], hin, cfg)
            x = x + out
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return (x @ params["head"])[:, 0], new_cache
