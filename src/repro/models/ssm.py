"""Mamba2 — SSD (state-space duality) blocks, chunked dual form.

Per head h (state N=128, head dim P=64):
    h_t = exp(Δ_t·A_h)·h_{t-1} + Δ_t·(x_t ⊗ B_t)
    y_t = C_t·h_t + D_h·x_t
The chunked dual form (chunk Q) splits this into an intra-chunk
"masked-attention" term (batched matmuls — MXU-friendly, computed for all
chunks at once *outside* any scan) and an inter-chunk recurrence over tiny
per-chunk states carried by ``lax.associative_scan`` (log-depth, negligible
FLOPs) — so the dry-run FLOP accounting stays exact (DESIGN.md §6).

Decode is the O(1) recurrence on a cached state [H, P, N] + conv tail.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


# --------------------------------------------------------------------------
# chunked SSD core
# --------------------------------------------------------------------------
def ssd_chunked(
    x: jax.Array,    # [B, T, H, P]
    b_mat: jax.Array,  # [B, T, N]   (G=1 shared across heads)
    c_mat: jax.Array,  # [B, T, N]
    dt: jax.Array,   # [B, T, H]   (post-softplus)
    a_log: jax.Array,  # [H]       A = -exp(a_log)
    chunk: int,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y [B,T,H,P], final_state [B,H,P,N])."""
    bsz, t0, h, p = x.shape
    n = b_mat.shape[-1]
    # pad to a chunk multiple: padded steps carry dt=0 ⇒ decay=1, update=0,
    # so the final state is unaffected and padded outputs are sliced away.
    pad = (-t0) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    t = t0 + pad
    cn, q = t // chunk, chunk

    a = -jnp.exp(a_log.astype(jnp.float32))                   # [H]
    dta = dt.astype(jnp.float32) * a                          # [B,T,H]
    xr = x.reshape(bsz, cn, q, h, p)
    br = b_mat.reshape(bsz, cn, q, n)
    cr = c_mat.reshape(bsz, cn, q, n)
    dtr = dt.reshape(bsz, cn, q, h).astype(jnp.float32)
    dtar = dta.reshape(bsz, cn, q, h)

    cum = jnp.cumsum(dtar, axis=2)                            # [B,Cn,Q,H]
    total = cum[:, :, -1:, :]                                 # [B,Cn,1,H]

    # ---- intra-chunk (quadratic in Q, batched matmuls) ----
    cb = jnp.einsum("bcin,bcjn->bcij", cr, br)                # [B,Cn,Q,Q]
    lam = jnp.exp(
        jnp.clip(cum[:, :, :, None, :] - cum[:, :, None, :, :], -60.0, 0.0)
    )                                                          # [B,Cn,Qi,Qj,H]
    causal = jnp.tril(jnp.ones((q, q), bool))
    w = cb[:, :, :, :, None] * lam * dtr[:, :, None, :, :]    # [B,Cn,Qi,Qj,H]
    w = jnp.where(causal[None, None, :, :, None], w, 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(x.dtype), xr)

    # ---- per-chunk states ----
    decay_to_end = jnp.exp(jnp.clip(total - cum, -60.0, 0.0))  # [B,Cn,Q,H]
    s = jnp.einsum(
        "bcjh,bcjhp,bcjn->bchpn",
        (decay_to_end * dtr).astype(x.dtype), xr, br,
    )                                                          # [B,Cn,H,P,N]

    # ---- inter-chunk recurrence: H_c = d_c·H_{c-1} + S_c ----
    d_c = jnp.exp(jnp.clip(total[:, :, 0, :], -60.0, 0.0))     # [B,Cn,H]

    def combine(e1, e2):
        dc1, s1 = e1
        dc2, s2 = e2
        return dc1 * dc2, s1 * dc2[..., None, None].astype(s1.dtype) + s2

    _, h_all = jax.lax.associative_scan(combine, (d_c, s), axis=1)
    h_prev = jnp.concatenate(
        [jnp.zeros_like(h_all[:, :1]), h_all[:, :-1]], axis=1
    )                                                          # [B,Cn,H,P,N]

    y_inter = jnp.einsum(
        "bcin,bcih,bchpn->bcihp",
        cr, jnp.exp(jnp.clip(cum, -60.0, 0.0)).astype(x.dtype), h_prev,
    )
    y = (y_intra + y_inter).reshape(bsz, t, h, p)[:, :t0]
    return y, h_all[:, -1]                                     # final state


def ssd_ref(x, b_mat, c_mat, dt, a_log):
    """Naive sequential recurrence oracle (tests)."""
    bsz, t, h, p = x.shape
    n = b_mat.shape[-1]
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(state, inp):
        xt, bt, ct, dtt = inp                      # [B,H,P],[B,N],[B,N],[B,H]
        decay = jnp.exp(dtt * a)                   # [B,H]
        upd = dtt[..., None, None] * xt[..., None] * bt[:, None, None, :]
        state = state * decay[..., None, None] + upd
        yt = jnp.einsum("bhpn,bn->bhp", state, ct)
        return state, yt

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (
        jnp.moveaxis(x.astype(jnp.float32), 1, 0),
        jnp.moveaxis(b_mat.astype(jnp.float32), 1, 0),
        jnp.moveaxis(c_mat.astype(jnp.float32), 1, 0),
        jnp.moveaxis(dt.astype(jnp.float32), 1, 0),
    )
    final, ys = jax.lax.scan(step, init, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final


# --------------------------------------------------------------------------
# mamba2 block
# --------------------------------------------------------------------------
def _conv_dim(cfg):
    return cfg.d_inner + 2 * cfg.ssm_state


def mamba_block_init(key, cfg) -> Dict[str, Any]:
    """Input projections are stored per segment (z / x / B / C / dt) so each
    can carry its own TP sharding without resharding at split points; the
    depthwise conv is likewise split (per-channel ⇒ segment-separable)."""
    ks = jax.random.split(key, 7)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    dt_ = cfg.compute_dtype

    def conv_w(k, c):
        return (jax.random.normal(k, (cfg.d_conv, c), jnp.float32) * 0.2
                ).astype(dt_)

    return {
        "in_z": L.dense_init(ks[0], d, di, dt_),
        "in_x": L.dense_init(ks[1], d, di, dt_),
        "in_b": L.dense_init(ks[2], d, n, dt_),
        "in_c": L.dense_init(ks[3], d, n, dt_),
        "in_dt": L.dense_init(ks[4], d, h, dt_),
        "conv_x_w": conv_w(ks[5], di),
        "conv_x_b": jnp.zeros((di,), dt_),
        "conv_b_w": conv_w(ks[6], n),
        "conv_b_b": jnp.zeros((n,), dt_),
        "conv_c_w": conv_w(jax.random.fold_in(ks[6], 1), n),
        "conv_c_b": jnp.zeros((n,), dt_),
        "a_log": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "out_norm": L.rmsnorm_init(di, dt_),
        "out_proj": L.dense_init(ks[4], di, d, dt_),
    }


def _causal_conv(xc, w, b):
    """Depthwise causal conv width K via shifted adds. xc: [B,T,C]."""
    k = w.shape[0]
    out = xc * w[-1]
    for i in range(1, k):
        shifted = jnp.pad(xc, ((0, 0), (i, 0), (0, 0)))[:, : xc.shape[1]]
        out = out + shifted * w[k - 1 - i]
    return jax.nn.silu(out + b)


def _project(p, x, cfg):
    z = x @ p["in_z"]
    xr = x @ p["in_x"]
    br = x @ p["in_b"]
    cr = x @ p["in_c"]
    dt_raw = x @ p["in_dt"]
    return z, xr, br, cr, dt_raw


def _mamba_core(p, x, cfg):
    """Shared forward: returns (y [B,T,d], final_state, conv tails)."""
    bsz, t, _ = x.shape
    di, h, pd = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    z, xr, br, cr, dt_raw = _project(p, x, cfg)
    xc = _causal_conv(xr, p["conv_x_w"], p["conv_x_b"])
    bc = _causal_conv(br, p["conv_b_w"], p["conv_b_b"])
    cc = _causal_conv(cr, p["conv_c_w"], p["conv_c_b"])
    xin = xc.reshape(bsz, t, h, pd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    y, state = ssd_chunked(xin, bc, cc, dt, p["a_log"], cfg.ssm_chunk)
    y = y + xin * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, t, di) * jax.nn.silu(z)
    y = L.rmsnorm(y, p["out_norm"], cfg.norm_eps)
    kc = cfg.d_conv - 1
    tails = {"x": xr[:, t - kc:], "b": br[:, t - kc:], "c": cr[:, t - kc:]}
    return y @ p["out_proj"], state, tails


def mamba_block_apply(p, x, cfg):
    """Full-sequence forward. x: [B,T,d] → [B,T,d]."""
    out, _, _ = _mamba_core(p, x, cfg)
    return out


def mamba_block_prefill(p, x, cfg):
    """Like apply, but also returns the decode cache."""
    out, state, tails = _mamba_core(p, x, cfg)
    return out, {"state": state, "conv": tails}


def _conv_step(tail, new, w, b):
    window = jnp.concatenate([tail, new], axis=1)               # [B,K,C]
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    return jax.nn.silu(out), window[:, 1:]


def mamba_block_decode(p, x, cache, cfg):
    """One-token step. x: [B,1,d]; cache {state [B,H,P,N], conv{x,b,c}}."""
    bsz = x.shape[0]
    di, h, pd = cfg.d_inner, cfg.ssm_heads, cfg.ssm_head_dim
    z, xr, br, cr, dt_raw = _project(p, x, cfg)
    xc, tail_x = _conv_step(cache["conv"]["x"], xr, p["conv_x_w"], p["conv_x_b"])
    bc, tail_b = _conv_step(cache["conv"]["b"], br, p["conv_b_w"], p["conv_b_b"])
    cc, tail_c = _conv_step(cache["conv"]["c"], cr, p["conv_c_w"], p["conv_c_b"])
    xin = xc.reshape(bsz, h, pd)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a)                                     # [B,H]
    upd = dt[..., None, None] * xin.astype(jnp.float32)[..., None] \
        * bc.astype(jnp.float32)[:, None, None, :]
    state = cache["state"].astype(jnp.float32) * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, cc.astype(jnp.float32))
    y = y.astype(x.dtype) + xin * p["d_skip"][None, :, None].astype(x.dtype)
    y = y.reshape(bsz, 1, di) * jax.nn.silu(z)
    y = L.rmsnorm(y, p["out_norm"], cfg.norm_eps)
    new_cache = {"state": state.astype(cache["state"].dtype),
                 "conv": {"x": tail_x, "b": tail_b, "c": tail_c}}
    return y @ p["out_proj"], new_cache


# --------------------------------------------------------------------------
# full mamba2 model
# --------------------------------------------------------------------------
def ssm_init(cfg, key) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_layers + 1)
    return {
        "emb": L.dense_init(ks[0], cfg.vocab_padded, cfg.d_model,
                            cfg.compute_dtype),
        "ln_f": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
        "layers": [
            {"mixer": mamba_block_init(ks[i + 1], cfg),
             "ln": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype)}
            for i in range(cfg.n_layers)
        ],
    }


def ssm_forward(params, tokens, cfg, return_hidden=False):
    x = params["emb"][tokens]
    for p in params["layers"]:
        def layer(p, x):
            return x + mamba_block_apply(p["mixer"],
                                         L.rmsnorm(x, p["ln"], cfg.norm_eps), cfg)
        x = L.sp(L.remat(layer, cfg)(p, x))
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    if return_hidden:
        return x, params["emb"].T
    return x @ params["emb"].T


def ssm_cache_leaf(cfg, batch: int, dtype):
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    kc = cfg.d_conv - 1
    return {
        "state": jnp.zeros((batch, h, pd, n), jnp.float32),
        "conv": {"x": jnp.zeros((batch, kc, cfg.d_inner), dtype),
                 "b": jnp.zeros((batch, kc, n), dtype),
                 "c": jnp.zeros((batch, kc, n), dtype)},
    }


def ssm_init_cache(cfg, batch: int, max_len: int, dtype):
    return [ssm_cache_leaf(cfg, batch, dtype) for _ in range(cfg.n_layers)]


def ssm_prefill(params, tokens, cfg, max_len: int):
    b = tokens.shape[0]
    x = params["emb"][tokens]
    cache = []
    for p in params["layers"]:
        out, c = mamba_block_prefill(p["mixer"],
                                     L.rmsnorm(x, p["ln"], cfg.norm_eps), cfg)
        x = x + out
        cache.append(c)
    x = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    return (x @ params["emb"].T)[:, 0], cache


def ssm_decode_step(params, cache, token, pos, cfg):
    del pos  # recurrence is position-free
    x = params["emb"][token][:, None]
    new_cache = []
    for p, c in zip(params["layers"], cache):
        out, nc = mamba_block_decode(p["mixer"],
                                     L.rmsnorm(x, p["ln"], cfg.norm_eps), c, cfg)
        x = x + out
        new_cache.append(nc)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return (x @ params["emb"].T)[:, 0], new_cache
