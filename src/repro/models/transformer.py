"""Dense decoder-only transformers (llama family: granite/yi/pixtral
backbone) and the whisper-small encoder–decoder.

Layers run in an unrolled python loop (exact dry-run FLOP accounting — see
DESIGN.md §6); per-layer ``jax.checkpoint`` implements the remat policy for
training. Forward functions return logits over the *padded* vocab; the loss
masks padding columns.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


# ==========================================================================
# dense decoder
# ==========================================================================
def dense_layer_init(key, cfg) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    return {
        "attn": L.gqa_init(ks[0], cfg),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.compute_dtype, cfg.act),
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
    }


def dense_layer_apply(p, x, cfg, *, window=0, sink=0, positions=None):
    h = x + L.gqa_apply(
        p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
        causal=True, window=window, sink=sink, positions=positions,
    )
    return h + L.mlp_apply(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps), cfg.act)


def dense_init(cfg, key) -> Dict[str, Any]:
    ks = jax.random.split(key, cfg.n_layers + 2)
    params = {
        "emb": L.dense_init(ks[0], cfg.vocab_padded, cfg.d_model,
                            cfg.compute_dtype),
        "ln_f": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
        "layers": [dense_layer_init(ks[i + 2], cfg) for i in range(cfg.n_layers)],
    }
    if not cfg.tie_embeddings:
        params["head"] = L.dense_init(ks[1], cfg.d_model, cfg.vocab_padded,
                                      cfg.compute_dtype)
    return params


def _lm_head(params, x, cfg):
    if "head" in params:
        return x @ params["head"]
    return x @ params["emb"].T


def _embed(params, tokens, cfg, embeddings=None):
    """Token embedding, or pre-computed frontend embeddings for [vlm]."""
    if embeddings is not None:
        return embeddings.astype(cfg.compute_dtype)
    return params["emb"][tokens]


def dense_forward(params, tokens, cfg, *, embeddings=None,
                  return_hidden=False):
    x = _embed(params, tokens, cfg, embeddings)
    for i, p in enumerate(params["layers"]):
        f = L.remat(dense_layer_apply, cfg, static_argnums=(2,))
        x = L.sp(f(p, x, cfg))
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    if return_hidden:
        return x, (params["head"] if "head" in params else params["emb"].T)
    return _lm_head(params, x, cfg)


# ---- serving ----
def dense_init_cache(cfg, batch: int, max_len: int, dtype):
    hd = cfg.hd
    return [
        {
            "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
        }
        for _ in range(cfg.n_layers)
    ]


def dense_prefill(params, tokens, cfg, max_len: int, *, embeddings=None):
    """Run the prompt; return (last-token logits, filled cache)."""
    b, s = tokens.shape[:2] if tokens is not None else embeddings.shape[:2]
    x = _embed(params, tokens, cfg, embeddings)
    cache = dense_init_cache(cfg, b, max_len, cfg.compute_dtype)
    positions = jnp.arange(s)
    for i, p in enumerate(params["layers"]):
        xin = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.gqa_project(p["attn"], xin, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        cache[i]["k"] = jax.lax.dynamic_update_slice_in_dim(cache[i]["k"], k, 0, axis=1)
        cache[i]["v"] = jax.lax.dynamic_update_slice_in_dim(cache[i]["v"], v, 0, axis=1)
        att = L.attention(q, k, v, causal=True, impl=cfg.attn_impl,
                          q_chunk=cfg.q_chunk, remat_chunks=False)
        x = x + att.reshape(b, s, -1) @ p["attn"]["wo"]
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act)
    x = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    return _lm_head(params, x, cfg)[:, 0], cache


def dense_decode_step(params, cache, token, pos, cfg):
    """One decode step. token [B], pos scalar (tokens so far). Returns
    (logits [B, Vp], new cache)."""
    b = token.shape[0]
    x = params["emb"][token][:, None]          # [B, 1, d]
    positions = jnp.full((1,), pos, jnp.int32)
    s_max = cache[0]["k"].shape[1]
    valid = jnp.arange(s_max) <= pos
    new_cache = []
    for i, p in enumerate(params["layers"]):
        xin = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.gqa_project(p["attn"], xin, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice_in_dim(cache[i]["k"], k, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache[i]["v"], v, pos, axis=1)
        new_cache.append({"k": ck, "v": cv})
        att = L.decode_attention(q, ck, cv, valid)
        x = x + att.reshape(b, 1, -1) @ p["attn"]["wo"]
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return _lm_head(params, x, cfg)[:, 0], new_cache


# ==========================================================================
# whisper-small encoder–decoder
# ==========================================================================
def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encdec_init(cfg, key) -> Dict[str, Any]:
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_enc_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)

    def enc_layer(k):
        kk = jax.random.split(k, 2)
        return {
            "attn": L.gqa_init(kk[0], cfg),
            "mlp": L.mlp_init(kk[1], cfg.d_model, cfg.d_ff, cfg.compute_dtype, cfg.act),
            "ln1": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
        }

    def dec_layer(k):
        kk = jax.random.split(k, 3)
        return {
            "self": L.gqa_init(kk[0], cfg),
            "cross": L.gqa_init(kk[1], cfg),
            "mlp": L.mlp_init(kk[2], cfg.d_model, cfg.d_ff, cfg.compute_dtype, cfg.act),
            "ln1": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
            "ln2": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
            "ln3": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
        }

    return {
        "emb": L.dense_init(ks[2], cfg.vocab_padded, cfg.d_model, cfg.compute_dtype),
        "enc_layers": [enc_layer(k) for k in enc_keys],
        "dec_layers": [dec_layer(k) for k in dec_keys],
        "ln_enc": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
        "ln_f": L.rmsnorm_init(cfg.d_model, cfg.compute_dtype),
    }


def encode(params, frames, cfg):
    """frames: [B, F, d] precomputed stub embeddings (conv frontend stub)."""
    f = frames.shape[1]
    x = frames.astype(cfg.compute_dtype)
    x = x + _sinusoid(jnp.arange(f), cfg.d_model).astype(cfg.compute_dtype)
    for p in params["enc_layers"]:
        def enc_apply(p, x):
            h = x + L.gqa_apply(p["attn"], L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                                cfg, causal=False, rope=False)
            return h + L.mlp_apply(p["mlp"], L.rmsnorm(h, p["ln2"], cfg.norm_eps),
                                   cfg.act)
        x = L.remat(enc_apply, cfg)(p, x)
    return L.rmsnorm(x, params["ln_enc"], cfg.norm_eps)


def encdec_forward(params, batch, cfg, return_hidden=False):
    """batch = {frames [B,F,d], tokens [B,S]} → decoder logits."""
    enc = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = params["emb"][tokens]
    x = x + _sinusoid(jnp.arange(s), cfg.d_model).astype(cfg.compute_dtype)

    def dec_apply(p, x, enc):
        h = x + L.gqa_apply(p["self"], L.rmsnorm(x, p["ln1"], cfg.norm_eps),
                            cfg, causal=True, rope=False)
        h = h + L.gqa_apply(p["cross"], L.rmsnorm(h, p["ln2"], cfg.norm_eps),
                            cfg, causal=False, rope=False, kv_source=enc)
        return h + L.mlp_apply(p["mlp"], L.rmsnorm(h, p["ln3"], cfg.norm_eps),
                               cfg.act)

    for p in params["dec_layers"]:
        f = L.remat(dec_apply, cfg)
        x = f(p, x, enc)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    if return_hidden:
        return x, params["emb"].T
    return x @ params["emb"].T


def encdec_init_cache(cfg, batch: int, max_len: int, dtype):
    hd = cfg.hd
    return {
        "self": [
            {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype),
             "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dtype)}
            for _ in range(cfg.n_layers)
        ],
        # cross-attention K/V over the encoder output (filled by prefill;
        # zero-initialized so the cache pytree is shape-complete for the
        # decode dry-run and for checkpointing)
        "cross_kv": [
            {"k": jnp.zeros((batch, cfg.n_frames, cfg.n_kv_heads, hd), dtype),
             "v": jnp.zeros((batch, cfg.n_frames, cfg.n_kv_heads, hd), dtype)}
            for _ in range(cfg.n_layers)
        ],
    }


def encdec_prefill(params, batch, cfg, max_len: int):
    """Encode frames, run prompt tokens, build self+cross caches."""
    enc = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    b, s = tokens.shape
    cache = encdec_init_cache(cfg, b, max_len, cfg.compute_dtype)
    # precompute cross K/V once per layer (fixed for the whole decode)
    cross = []
    for p in params["dec_layers"]:
        k = (enc @ p["cross"]["wk"]).reshape(b, enc.shape[1], cfg.n_kv_heads, cfg.hd)
        v = (enc @ p["cross"]["wv"]).reshape(b, enc.shape[1], cfg.n_kv_heads, cfg.hd)
        cross.append({"k": k, "v": v})
    cache["cross_kv"] = cross

    x = params["emb"][tokens]
    x = x + _sinusoid(jnp.arange(s), cfg.d_model).astype(cfg.compute_dtype)
    for i, p in enumerate(params["dec_layers"]):
        xin = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.gqa_project(p["self"], xin, cfg)
        cache["self"][i]["k"] = jax.lax.dynamic_update_slice_in_dim(
            cache["self"][i]["k"], k, 0, axis=1)
        cache["self"][i]["v"] = jax.lax.dynamic_update_slice_in_dim(
            cache["self"][i]["v"], v, 0, axis=1)
        att = L.attention(q, k, v, causal=True, impl=cfg.attn_impl,
                          q_chunk=cfg.q_chunk, remat_chunks=False)
        x = x + att.reshape(b, s, -1) @ p["self"]["wo"]
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        qc = (h @ p["cross"]["wq"]).reshape(b, s, cfg.n_heads, cfg.hd)
        catt = L.attention(qc, cross[i]["k"], cross[i]["v"], causal=False,
                           impl="dense", remat_chunks=False)
        x = x + catt.reshape(b, s, -1) @ p["cross"]["wo"]
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["ln3"], cfg.norm_eps), cfg.act)
    x = L.rmsnorm(x[:, -1:], params["ln_f"], cfg.norm_eps)
    return (x @ params["emb"].T)[:, 0], cache


def encdec_decode_step(params, cache, token, pos, cfg):
    b = token.shape[0]
    x = params["emb"][token][:, None]
    x = x + _sinusoid(jnp.full((1,), pos, jnp.int32), cfg.d_model).astype(
        cfg.compute_dtype)
    s_max = cache["self"][0]["k"].shape[1]
    valid = jnp.arange(s_max) <= pos
    new_self = []
    for i, p in enumerate(params["dec_layers"]):
        xin = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
        q, k, v = L.gqa_project(p["self"], xin, cfg)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["self"][i]["k"], k, pos, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["self"][i]["v"], v, pos, axis=1)
        new_self.append({"k": ck, "v": cv})
        att = L.decode_attention(q, ck, cv, valid)
        x = x + att.reshape(b, 1, -1) @ p["self"]["wo"]
        h = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
        qc = (h @ p["cross"]["wq"]).reshape(b, 1, cfg.n_heads, cfg.hd)
        cr = cache["cross_kv"][i]
        catt = L.decode_attention(qc, cr["k"], cr["v"],
                                  jnp.ones((cr["k"].shape[1],), bool))
        x = x + catt.reshape(b, 1, -1) @ p["cross"]["wo"]
        x = x + L.mlp_apply(p["mlp"], L.rmsnorm(x, p["ln3"], cfg.norm_eps), cfg.act)
    x = L.rmsnorm(x, params["ln_f"], cfg.norm_eps)
    new_cache = {"self": new_self, "cross_kv": cache["cross_kv"]}
    return (x @ params["emb"].T)[:, 0], new_cache
