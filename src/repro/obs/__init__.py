"""Observability: metrics registry, latency histograms, trace spans.

Dependency-free (stdlib-only) telemetry for the serving stack. The paper
sells *query latency under many parameter settings*; this package is how
the repo measures that claim instead of asserting it:

  * :mod:`repro.obs.metrics` — :class:`MetricsRegistry` holding
    thread-safe counters, gauges, and fixed log-spaced-bucket latency
    :class:`Histogram`\\ s (mergeable across replicas, diffable across
    snapshots, JSON round-trippable);
  * :mod:`repro.obs.trace`   — :class:`Tracer` whose ``span()`` context
    manager emits structured events (monotonic timestamps, parent/child
    nesting via contextvars) *and* feeds the same-named registry
    histogram, so the span taxonomy is the latency taxonomy;
  * :mod:`repro.obs.export`  — JSON snapshot writer, Prometheus text
    renderer, and the periodic one-line stats dump used by
    ``scan_serve serve``/``update``.

The serve wiring (span names + attributes per layer) is documented in
ROADMAP.md § Observability.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                               hist_delta, hist_quantile)
from repro.obs.trace import Span, Tracer
from repro.obs.export import dump_loop, render_line, to_prometheus, write_json

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "hist_delta", "hist_quantile",
    "Span", "Tracer",
    "dump_loop", "render_line", "to_prometheus", "write_json",
]
