"""Export surfaces for a :class:`~repro.obs.metrics.MetricsRegistry`:
JSON files, Prometheus text exposition, and a periodic one-line dump for
long-running ``scan_serve serve``/``update`` processes.
"""
from __future__ import annotations

import asyncio
import json
import re
from typing import Iterable, Optional

__all__ = ["to_prometheus", "write_json", "render_line", "dump_loop"]

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    """Metric name → Prometheus-legal name (dots and dashes become _)."""
    out = _NAME_RE.sub("_", name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def to_prometheus(snapshot: dict, prefix: str = "repro_") -> str:
    """Render a registry snapshot in the Prometheus text exposition
    format. Histograms follow the standard convention: cumulative
    ``_bucket{le="..."}`` series (underflow folds into the first finite
    edge, overflow into ``+Inf``), plus ``_sum`` and ``_count``.
    """
    lines = []
    for name, value in snapshot.get("counters", {}).items():
        p = prefix + _prom_name(name)
        lines.append(f"# TYPE {p} counter")
        lines.append(f"{p} {value}")
    for name, value in snapshot.get("gauges", {}).items():
        p = prefix + _prom_name(name)
        lines.append(f"# TYPE {p} gauge")
        lines.append(f"{p} {value}")
    for name, h in snapshot.get("histograms", {}).items():
        p = prefix + _prom_name(name)
        lines.append(f"# TYPE {p} histogram")
        acc = 0
        for edge, count in zip(h["edges"], h["counts"]):
            acc += count
            lines.append(f'{p}_bucket{{le="{edge:g}"}} {acc}')
        acc += h["counts"][len(h["edges"])]
        lines.append(f'{p}_bucket{{le="+Inf"}} {acc}')
        lines.append(f"{p}_sum {h['sum']}")
        lines.append(f"{p}_count {h['count']}")
    return "\n".join(lines) + "\n"


def write_json(snapshot: dict, path: str) -> None:
    """Write a registry snapshot as indented JSON (CLI ``--metrics-json``,
    CI artifact)."""
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=2)
        f.write("\n")


def render_line(snapshot: dict,
                quantiles: Iterable[float] = (0.5, 0.99)) -> str:
    """One compact status line per dump tick: every counter and gauge,
    plus count/quantiles of every histogram (units: milliseconds)."""
    from repro.obs.metrics import hist_quantile

    parts = []
    for name, v in snapshot.get("counters", {}).items():
        parts.append(f"{name}={v}")
    for name, v in snapshot.get("gauges", {}).items():
        parts.append(f"{name}={v:g}")
    for name, h in snapshot.get("histograms", {}).items():
        if not h["count"]:
            continue
        qs = "/".join(
            f"{hist_quantile(h, q) * 1e3:.2f}" for q in quantiles)
        tag = "/".join(f"p{int(q * 100)}" for q in quantiles)
        parts.append(f"{name}[n={h['count']},{tag}={qs}ms]")
    return "stats: " + " ".join(parts)


async def dump_loop(registry, interval_s: float,
                    emit=print, max_dumps: Optional[int] = None) -> None:
    """Periodically print a compact registry status line (the
    ``scan_serve stats``-style dump that runs alongside ``serve`` /
    ``update`` traffic). Cancel the task to stop it; ``max_dumps``
    bounds it for tests."""
    n = 0
    while max_dumps is None or n < max_dumps:
        await asyncio.sleep(interval_s)
        emit(render_line(registry.snapshot()))
        n += 1
