"""Metrics primitives: counters, gauges, and log-bucketed histograms.

Dependency-free (stdlib only) so every layer — core kernels, the serve
stack, benchmarks, the CLI — can record into one
:class:`MetricsRegistry` without import cycles or optional extras.

Design constraints, in order:

* **hot-path cheap** — ``Histogram.record`` is a bisect over ~60 floats
  plus a few adds under a per-instance lock; ``Counter.inc`` is one add
  under a lock. Both are microseconds against device calls that take
  milliseconds, so the serve engine can record every request.
* **thread-safe** — the serve stack mutates metrics from the event loop
  *and* the engine's single-worker offload executor (``apply_delta``
  runs off-loop since PR 5). Every mutation takes the owning
  primitive's lock; plain ``dict[key] += 1`` (the old ``engine.stats``)
  is a lost-update bug under that split and is gone.
* **mergeable** — histograms with identical bucket edges add
  bucket-wise, so per-replica registries can aggregate into fleet-wide
  latency distributions (the ROADMAP's replica-fleet direction) and a
  benchmark can diff two snapshots to isolate one traffic wave
  (:func:`hist_delta`).
* **snapshot = JSON** — :meth:`MetricsRegistry.snapshot` returns plain
  dicts/lists/numbers; ``json.dumps`` round-trips it losslessly
  (:meth:`Histogram.from_snapshot`).

Buckets are **fixed log-spaced** bounds: ``buckets_per_decade`` buckets
per power of ten between ``lo`` and ``hi``, plus an underflow bucket
(values ≤ ``lo``, including 0) and an overflow bucket (values ≥ ``hi``).
Quantile estimates return the upper edge of the bucket holding the
``ceil(q·count)``-th smallest observation — the same rank the
``inverted_cdf`` order statistic uses — so the estimate is within one
(multiplicative) bucket width of the true order statistic by
construction.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Dict, Iterable, List, Optional

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "hist_delta", "hist_quantile",
]


class Counter:
    """Monotone event count (thread-safe)."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, amount: int = 1) -> None:
        with self._lock:
            self._value += amount

    @property
    def value(self) -> int:
        return self._value


class Gauge:
    """Point-in-time level (queue depth, live index count); thread-safe.

    ``mode`` declares how this gauge folds across a fleet of registries
    (:meth:`MetricsRegistry.merge_snapshot`): ``"sum"`` for additive
    levels (queue depths add across replicas), ``"max"`` for watermarks
    (the fleet's replication staleness is its *worst* replica's lag —
    summing three replicas each 2 deltas behind into "6 behind" is a
    lie). The mode travels in snapshots so the aggregating side needs no
    out-of-band schema.
    """

    MODES = ("sum", "max")

    __slots__ = ("_lock", "_value", "mode")

    def __init__(self, mode: str = "sum") -> None:
        if mode not in self.MODES:
            raise ValueError(f"gauge mode must be one of {self.MODES}, "
                             f"got {mode!r}")
        self._lock = threading.Lock()
        self._value = 0.0
        self.mode = mode

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def add(self, amount: float) -> None:
        with self._lock:
            self._value += amount

    def merge_value(self, value: float) -> None:
        """Fold one peer registry's reading in, per this gauge's mode."""
        with self._lock:
            if self.mode == "max":
                self._value = max(self._value, float(value))
            else:
                self._value += float(value)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed log-spaced-bucket latency histogram (thread-safe, mergeable).

    ``edges`` holds the **upper** edge of every finite bucket:
    ``edges[0] == lo`` closes the underflow bucket; the overflow bucket
    (values ≥ ``hi``) is the trailing ``counts`` slot with no finite
    edge. Two histograms merge iff their edges match exactly.
    """

    __slots__ = ("_lock", "edges", "counts", "count", "sum", "min", "max")

    def __init__(self, lo: float = 1e-6, hi: float = 100.0,
                 buckets_per_decade: int = 8, *,
                 _edges: Optional[List[float]] = None) -> None:
        if _edges is not None:
            self.edges = list(_edges)
        else:
            if not (0.0 < lo < hi):
                raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
            n_inner = int(math.ceil(
                (math.log10(hi) - math.log10(lo)) * buckets_per_decade))
            # exact endpoint replaces the last computed edge so hi itself
            # lands in the overflow bucket regardless of float rounding
            self.edges = [lo] + [
                lo * 10.0 ** (i / buckets_per_decade)
                for i in range(1, n_inner)] + [hi]
        if any(a >= b for a, b in zip(self.edges, self.edges[1:])):
            raise ValueError("bucket edges must be strictly increasing")
        self._lock = threading.Lock()
        self.counts = [0] * (len(self.edges) + 1)   # + overflow
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    # ------------------------------------------------------------------
    def bucket_index(self, value: float) -> int:
        """Index of the bucket ``value`` falls in (0 = underflow,
        ``len(edges)`` = overflow). Bucket *i* < overflow covers
        ``(edges[i-1], edges[i]]`` (underflow: ``(-inf, edges[0]]``)."""
        return bisect.bisect_left(self.edges, value)

    def record(self, value: float) -> None:
        value = float(value)
        i = self.bucket_index(value)
        with self._lock:
            self.counts[i] += 1
            self.count += 1
            self.sum += value
            if self.min is None or value < self.min:
                self.min = value
            if self.max is None or value > self.max:
                self.max = value

    def merge(self, other: "Histogram") -> None:
        """Add ``other``'s observations into this histogram in place."""
        if self.edges != other.edges:
            raise ValueError("cannot merge histograms with different edges")
        with other._lock:
            counts = list(other.counts)
            count, total = other.count, other.sum
            omin, omax = other.min, other.max
        with self._lock:
            for i, c in enumerate(counts):
                self.counts[i] += c
            self.count += count
            self.sum += total
            if omin is not None and (self.min is None or omin < self.min):
                self.min = omin
            if omax is not None and (self.max is None or omax > self.max):
                self.max = omax

    # ------------------------------------------------------------------
    def quantile(self, q: float) -> float:
        """Upper edge of the bucket holding the ``ceil(q·count)``-th
        smallest observation (the ``inverted_cdf`` order-statistic rank).
        Underflow reports ``edges[0]``, overflow the observed max; an
        empty histogram reports 0.0."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile must be in [0, 1], got {q}")
        with self._lock:
            if self.count == 0:
                return 0.0
            rank = max(1, math.ceil(q * self.count))
            acc = 0
            for i, c in enumerate(self.counts):
                acc += c
                if acc >= rank:
                    if i >= len(self.edges):          # overflow bucket
                        return float(self.max)
                    return self.edges[i]
            return float(self.max)                     # unreachable

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            return {
                "edges": list(self.edges),
                "counts": list(self.counts),
                "count": self.count,
                "sum": self.sum,
                "min": self.min,
                "max": self.max,
            }

    @classmethod
    def from_snapshot(cls, snap: dict) -> "Histogram":
        h = cls(_edges=snap["edges"])
        h.counts = list(snap["counts"])
        h.count = int(snap["count"])
        h.sum = float(snap["sum"])
        h.min = snap.get("min")
        h.max = snap.get("max")
        return h


def hist_quantile(snap: dict, q: float) -> float:
    """:meth:`Histogram.quantile` over a snapshot dict (no live object)."""
    return Histogram.from_snapshot(snap).quantile(q)


def hist_delta(now: dict, before: dict) -> dict:
    """Snapshot of the observations recorded *between* two snapshots of
    one histogram (``before`` taken earlier). Lets a benchmark isolate
    one traffic wave's latency distribution out of a cumulative
    histogram. ``min``/``max`` cannot be un-merged and report the
    interval-inclusive ``now`` values."""
    if now["edges"] != before["edges"]:
        raise ValueError("snapshots come from different histograms")
    return {
        "edges": list(now["edges"]),
        "counts": [a - b for a, b in zip(now["counts"], before["counts"])],
        "count": now["count"] - before["count"],
        "sum": now["sum"] - before["sum"],
        "min": now["min"],
        "max": now["max"],
    }


class MetricsRegistry:
    """Named counters, gauges, and histograms behind one lock-per-kind
    namespace. Get-or-create accessors make call sites one-liners::

        reg.inc("engine.requests")
        reg.observe("engine.e2e", dt)
        reg.gauge("engine.queue_depth").set(q.qsize())

    ``snapshot()`` is pure data (JSON-ready); ``merge_snapshot()`` folds
    another registry's snapshot in (counters/histograms add; gauges fold
    per their declared mode — sum for levels, max for watermarks).
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._hists: Dict[str, Histogram] = {}

    # ------------------------------------------------------------------
    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def inc(self, name: str, amount: int = 1) -> None:
        self.counter(name).inc(amount)

    def gauge(self, name: str, mode: Optional[str] = None) -> Gauge:
        """Get-or-create a gauge. ``mode`` (``"sum"``/``"max"``) fixes
        the fleet-merge semantics at creation; re-access with a
        *different* explicit mode is a taxonomy bug and raises."""
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(mode or "sum")
            elif mode is not None and g.mode != mode:
                raise ValueError(
                    f"gauge {name!r} already registered with mode "
                    f"{g.mode!r}, not {mode!r}")
            return g

    def histogram(self, name: str, **kwargs) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram(**kwargs)
            return h

    def observe(self, name: str, value: float) -> None:
        self.histogram(name).record(value)

    # ------------------------------------------------------------------
    def snapshot(self) -> dict:
        """All metrics as plain JSON-serializable data."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._hists)
        out = {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {k: h.snapshot() for k, h in sorted(hists.items())},
        }
        modes = {k: g.mode for k, g in sorted(gauges.items())
                 if g.mode != "sum"}
        if modes:   # only non-default modes travel (old snapshots: all sum)
            out["gauge_modes"] = modes
        return out

    def merge_snapshot(self, snap: dict) -> None:
        """Fold another registry's snapshot into this one (counters and
        histograms add; gauges fold per their merge mode — ``sum`` for
        additive levels like queue depth, ``max`` for watermarks like
        replication staleness). The incoming snapshot's ``gauge_modes``
        wins when this registry has not seen the gauge yet; snapshots
        predating modes merge as all-sum (the old behavior)."""
        for name, v in snap.get("counters", {}).items():
            self.inc(name, int(v))
        modes = snap.get("gauge_modes", {})
        for name, v in snap.get("gauges", {}).items():
            self.gauge(name, modes.get(name)).merge_value(float(v))
        for name, hsnap in snap.get("histograms", {}).items():
            with self._lock:
                h = self._hists.get(name)
                if h is None:
                    h = self._hists[name] = Histogram(
                        _edges=hsnap["edges"])
            h.merge(Histogram.from_snapshot(hsnap))

    def names(self) -> List[str]:
        with self._lock:
            return sorted({*self._counters, *self._gauges, *self._hists})
