"""Structured trace spans: what happened, when, inside what.

A :class:`Tracer` hands out ``span()`` context managers. Each finished
span becomes one structured event — name, monotonic start time, duration,
attributes, and parent/child linkage — appended to a bounded ring buffer,
and (when the tracer owns a registry) its duration is recorded into the
histogram of the same name, so *every span taxonomy is automatically a
latency histogram taxonomy*: ``live.apply_delta`` the span and
``live.apply_delta`` the histogram are the same measurements.

Nesting is tracked with a :mod:`contextvars` variable, so concurrent
asyncio tasks each see their own span stack (a span opened in task A is
never the parent of a span opened in task B). Plain
``loop.run_in_executor`` does **not** carry context into worker threads —
callers that offload work and want the worker's spans parented under the
caller's span must ship the context explicitly
(``contextvars.copy_context().run(fn)``), which is exactly what
``MicroBatchEngine.run_offloaded`` does for the apply pipeline.
"""
from __future__ import annotations

import contextlib
import contextvars
import itertools
import threading
import time
from collections import deque
from typing import Iterator, List, Optional

__all__ = ["Span", "Tracer"]

# the innermost open span of the current task/thread (contextvar: each
# asyncio task and each thread sees its own chain)
_CURRENT: contextvars.ContextVar[Optional["Span"]] = contextvars.ContextVar(
    "repro_obs_current_span", default=None)


class Span:
    """One timed, attributed region. ``set(key=value, ...)`` attaches
    attributes any time before the ``with`` block exits."""

    __slots__ = ("name", "span_id", "parent_id", "t_start", "attrs")

    def __init__(self, name: str, span_id: int, parent_id: Optional[int],
                 t_start: float, attrs: dict) -> None:
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.t_start = t_start
        self.attrs = attrs

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self


class Tracer:
    """Span factory + bounded event buffer, optionally metric-backed.

    ``max_events`` bounds memory: the buffer is a ring, old events fall
    off. The histograms in the registry keep the *aggregate* view
    forever; the ring keeps the recent *structured* view for debugging.
    """

    def __init__(self, registry=None, max_events: int = 2048) -> None:
        self.registry = registry
        self._events: deque = deque(maxlen=max_events)
        self._ids = itertools.count(1)
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    @contextlib.contextmanager
    def span(self, name: str, **attrs) -> Iterator[Span]:
        parent = _CURRENT.get()
        sp = Span(name=name, span_id=next(self._ids),
                  parent_id=parent.span_id if parent is not None else None,
                  t_start=time.monotonic(), attrs=dict(attrs))
        token = _CURRENT.set(sp)
        try:
            yield sp
        finally:
            _CURRENT.reset(token)
            self._finish(sp, time.monotonic() - sp.t_start)

    def event(self, name: str, duration_s: float, *,
              t_start: Optional[float] = None, **attrs) -> None:
        """Record a span-shaped event for a duration measured elsewhere
        (e.g. queue wait, derived from an enqueue timestamp after the
        fact — there is no ``with`` block to wrap)."""
        parent = _CURRENT.get()
        sp = Span(name=name, span_id=next(self._ids),
                  parent_id=parent.span_id if parent is not None else None,
                  t_start=(time.monotonic() - duration_s
                           if t_start is None else t_start),
                  attrs=dict(attrs))
        self._finish(sp, duration_s)

    def _finish(self, sp: Span, duration_s: float) -> None:
        self._events.append({
            "name": sp.name,
            "span_id": sp.span_id,
            "parent_id": sp.parent_id,
            "t_start": sp.t_start,
            "duration_s": duration_s,
            "attrs": dict(sp.attrs),
        })
        if self.registry is not None:
            self.registry.observe(sp.name, duration_s)

    # ------------------------------------------------------------------
    def events(self, name: Optional[str] = None) -> List[dict]:
        """Snapshot of buffered events, oldest first (filtered by name)."""
        evs = list(self._events)
        if name is not None:
            evs = [e for e in evs if e["name"] == name]
        return evs

    def clear(self) -> None:
        self._events.clear()
