"""AdamW in pure JAX with fp32 master weights — ZeRO-1 shardable.

State layout: {"m", "v", "master"} mirror the param tree in fp32 plus a
scalar step count. Model params stay bf16 (compute dtype); each update
recomputes them from the master copy. Sharding the three fp32 trees over
*all* mesh axes (dist/sharding.py::opt_state_specs) gives ZeRO-1: per-device
optimizer bytes shrink by the full mesh size while gradients/params keep
their TP layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(hp: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(hp.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - hp.warmup_steps)
                    / jnp.maximum(hp.total_steps - hp.warmup_steps, 1), 0.0, 1.0)
    cos = hp.min_lr_frac + (1 - hp.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return hp.lr * warm * cos


def init(params) -> Dict[str, Any]:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, params),
        "v": jax.tree.map(f32, params),
        "master": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def update(grads, state, hp: AdamWConfig):
    """→ (new_params (compute dtype), new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, hp.grad_clip / (gnorm + 1e-9)) if hp.grad_clip else 1.0
    lr = schedule(hp, count)
    b1c = 1 - hp.b1 ** count.astype(jnp.float32)
    b2c = 1 - hp.b2 ** count.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = hp.b1 * m + (1 - hp.b1) * g
        v = hp.b2 * v + (1 - hp.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        step_ = mh / (jnp.sqrt(vh) + hp.eps) + hp.weight_decay * master
        return m, v, master - lr * step_

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_ma = jax.tree.leaves(state["master"])
    new_m, new_v, new_ma = [], [], []
    for g, m, v, ma in zip(flat_g, flat_m, flat_v, flat_ma):
        m2, v2, ma2 = upd(g, m, v, ma)
        new_m.append(m2)
        new_v.append(v2)
        new_ma.append(ma2)
    new_state = {
        "m": jax.tree.unflatten(tdef, new_m),
        "v": jax.tree.unflatten(tdef, new_v),
        "master": jax.tree.unflatten(tdef, new_ma),
        "count": count,
    }
    # compute-dtype params derived from masters (keeps original dtypes)
    dtypes = [l.dtype for l in flat_g]
    new_params = jax.tree.unflatten(
        tdef, [ma.astype(dt) for ma, dt in zip(new_ma, dtypes)]
    )
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
