"""Gradient compression for the cross-pod (DCN) hop.

Two schemes with error feedback (the residual of one step is added back
before the next compression, so compression error doesn't accumulate):

* int8 block quantization — per-block (1024) absmax scaling, 4× wire
  reduction vs fp32 / 2× vs bf16;
* top-k sparsification — keep the k largest-|g| entries per tensor.

The supervisor's cross-pod reducer (dist/fault_tolerance.py) applies
compress → sum over pods → decompress; inside a pod gradients stay exact
(ICI is cheap, DCN is not). Pure functions — unit-tested for round-trip
error bounds and error-feedback convergence.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 1024


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """→ (int8 values, per-block fp32 scales)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape)


def compress_int8_ef(g: jax.Array, residual: jax.Array):
    """Error-feedback int8: returns (q, scale, new_residual)."""
    corrected = g.astype(jnp.float32) + residual
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale, g.shape)
    return q, scale, corrected - deq


def topk_sparsify(g: jax.Array, k: int):
    """→ (values[k], indices[k]) of the largest-magnitude entries."""
    flat = g.astype(jnp.float32).reshape(-1)
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    return flat[idx], idx


def topk_densify(vals: jax.Array, idx: jax.Array, shape) -> jax.Array:
    size = 1
    for s in shape:
        size *= s
    return jnp.zeros((size,), jnp.float32).at[idx].set(vals).reshape(shape)


def compress_topk_ef(g: jax.Array, residual: jax.Array, k: int):
    corrected = g.astype(jnp.float32) + residual
    vals, idx = topk_sparsify(corrected, k)
    deq = topk_densify(vals, idx, g.shape)
    return (vals, idx), corrected - deq
