"""SCAN query service — the index as a servable artifact.

The paper's GS*-Index exists because SCAN users explore many (μ, ε)
settings against one graph: construction cost is amortized over queries.
This package is the serving layer that completes that story:

  * :mod:`repro.serve.store`  — persist / restore ``ScanIndex`` +
    ``CSRGraph`` through the atomic checkpoint manifest, with a content
    fingerprint for cache invalidation;
  * :mod:`repro.serve.sweep`  — vmapped batch-query engine: a whole grid
    of (μ, ε) settings in one compiled device call, plus per-setting
    quality stats for "explore settings" workloads;
  * :mod:`repro.serve.cache`  — LRU result cache keyed on
    (index fingerprint, μ, quantized ε), with per-index partitions for the
    multi-index router and the sweep-ahead warming neighborhood;
  * :mod:`repro.serve.engine` — async micro-batching request loop that
    coalesces concurrent single queries into per-index vmapped device
    calls: requests carry an index fingerprint, buckets flush per index,
    failures isolate per bucket, and padding slots pre-warm the (μ, ε)
    neighborhood of observed traffic. ``EngineConfig(shards=k)`` runs the
    device calls sharded over a k-way mesh for giant graphs. Seed-set
    (local) queries — ``engine.query_seed(seed, μ, ε)`` — ride the same
    loop as their own request kind with their own fixed batch shape and
    a dedicated ``SeedResultCache`` keyed on (fingerprint, seed, μ,
    quantized ε), whose entries survive live deltas when the seed's
    cluster provably didn't change (frontier migration);
  * :mod:`repro.serve.live`  — resident update+query process:
    ``LiveIndexService`` applies ``EdgeDelta`` batches to its indexes
    incrementally (``repro.core.update``), hot-swaps them atomically into
    the router, persists the edit stream as a delta chain with periodic
    compaction, and re-warms observed traffic after every swap;
  * :mod:`repro.serve.errors` — typed rejections (``EngineStopped``,
    ``Overloaded`` with ``retry_after``, ``ReplicaUnavailable``,
    ``FleetExhausted``), all ``RuntimeError`` subclasses for back-compat;
  * :mod:`repro.serve.admission` — per-client token buckets,
    queue/offload-depth load shedding, deadline-aware rejection
    (``EngineConfig(admission=AdmissionConfig(...))``);
  * :mod:`repro.serve.fleet` — replicated read fleet: ``ReadReplica``
    engines tail the writer's ``DeltaLog`` (verify → replay → fingerprint
    check → hot-swap; never serve divergent bits), fronted by a
    ``FleetRouter`` (consistent hashing by index name, health checks,
    jittered retry, hedged failover) — the ``Fleet`` harness wires
    writer + replicas + router over one catalog;
  * :mod:`repro.serve.chaos` — seeded fault injection (``ChaosPolicy``:
    replica crash, stall, slow replay, torn/corrupt chain entry, delayed
    delivery) for the fleet's test suite, CI soak, and
    ``scan_serve fleet`` CLI mode.

Telemetry: every engine owns a :class:`repro.obs.MetricsRegistry` and a
:class:`repro.obs.Tracer` (``engine.registry`` / ``engine.tracer``);
``LiveIndexService`` traces its whole apply pipeline through them. See
ROADMAP.md § Observability for the span taxonomy and
``scan_serve ... --metrics-json`` / ``--stats-every`` for the export
surfaces.

CLI: ``PYTHONPATH=src python -m repro.launch.scan_serve --help``.
"""
from repro.serve.store import (DeltaLog, IndexCatalog, IndexStore,
                               index_fingerprint)
from repro.serve.sweep import SweepResult, sweep, grid_sweep, sweep_stats
from repro.serve.cache import (PartitionedResultCache, ResultCache,
                               SeedResultCache, neighborhood, quantize_eps)
from repro.serve.errors import (ServeError, EngineStopped, Overloaded,
                                ReplicaUnavailable, FleetExhausted)
from repro.serve.admission import (AdmissionConfig, AdmissionController,
                                   TokenBucket)
from repro.serve.engine import MicroBatchEngine, EngineConfig
from repro.serve.live import LiveIndexService
from repro.serve.chaos import ChaosPolicy, corrupt_entry
from repro.serve.fleet import (Fleet, FleetAnswer, FleetRouter, ReadReplica,
                               RouterConfig)
