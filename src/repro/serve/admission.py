"""Admission control for the micro-batching engine.

An engine without admission control degrades the worst possible way
under overload: every request is accepted, the queue grows without
bound, every client's latency climbs together, and the first visible
symptom is timeouts *everywhere at once*. This module makes overload an
explicit, typed, per-request decision made **at enqueue time** — before
a future is parked behind a queue the collector may take seconds to
drain:

* **per-client token buckets** — each ``client`` id refills at
  ``client_rate`` tokens/s up to ``client_burst``; a client that burns
  its burst gets :class:`~repro.serve.errors.Overloaded` with the exact
  ``retry_after`` until its next token, while well-behaved clients on
  the same engine are untouched (fairness under a skewed client mix —
  the fleet's hot-key reality).
* **queue-depth shedding** — beyond ``max_queue_depth`` waiting
  requests, new work is shed with ``retry_after`` = the estimated time
  to drain the backlog. Bounded queue ⇒ bounded worst-case latency for
  everything already admitted.
* **offload-depth shedding** — ``engine.offload_depth`` >
  ``max_offload_depth`` means maintenance (applies/refines) is queueing
  behind the single offload worker; shedding query admissions while the
  backlog clears keeps an update storm from starving the collector.
* **deadline-aware rejection** — a request carrying ``deadline_s``
  smaller than the estimated queue wait is rejected *immediately*:
  executing it would burn a device-batch slot producing an answer the
  client has already abandoned.

Decisions are recorded per cause in the engine's registry
(``admission.admitted`` / ``admission.shed_client_rate`` /
``admission.shed_queue_depth`` / ``admission.shed_offload_depth`` /
``admission.shed_deadline``), so "how much load did we refuse and why"
is a snapshot read, not archaeology.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, Optional

from repro.serve.errors import Overloaded

__all__ = ["AdmissionConfig", "AdmissionController", "TokenBucket"]


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill, ``burst`` cap.

    ``take()`` returns 0.0 on admit (one token consumed) or the seconds
    until the next token frees up (nothing consumed) — exactly the
    ``retry_after`` a client should be told. Thread-safe; time is
    injectable for deterministic tests.
    """

    __slots__ = ("rate", "burst", "_tokens", "_t", "_lock", "_clock")

    def __init__(self, rate: float, burst: int, *, clock=time.monotonic):
        if rate <= 0:
            raise ValueError(f"rate must be > 0, got {rate}")
        if burst < 1:
            raise ValueError(f"burst must be >= 1, got {burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)
        self._t = clock()
        self._lock = threading.Lock()

    def take(self) -> float:
        """→ 0.0 and consume a token, or seconds until one is available."""
        now = self._clock()
        with self._lock:
            self._tokens = min(self.burst,
                               self._tokens + (now - self._t) * self.rate)
            self._t = now
            if self._tokens >= 1.0:
                self._tokens -= 1.0
                return 0.0
            return (1.0 - self._tokens) / self.rate


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Limits for one engine's :class:`AdmissionController`.

    ``client_rate`` = 0 disables per-client buckets (anonymous traffic
    and trusted internal callers); ``max_queue_depth`` /
    ``max_offload_depth`` = 0 disable those sheds.
    """

    max_queue_depth: int = 256     # waiting requests before shedding
    max_offload_depth: int = 4     # queued maintenance jobs before shedding
    client_rate: float = 0.0       # tokens/s per client id (0 = unlimited)
    client_burst: int = 32         # bucket capacity per client id
    max_clients: int = 4096        # LRU cap on tracked client buckets


class AdmissionController:
    """Per-request admit/shed decisions for one engine.

    The engine calls :meth:`check` from ``_admit`` with its live queue
    and offload depths plus its per-flush service estimate; a shed
    raises :class:`Overloaded` (typed, with ``retry_after``) without
    enqueueing anything.
    """

    def __init__(self, cfg: AdmissionConfig, registry, *,
                 clock=time.monotonic):
        self.cfg = cfg
        self.registry = registry
        self._clock = clock
        self._buckets: Dict[str, TokenBucket] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _bucket(self, client: str) -> TokenBucket:
        with self._lock:
            b = self._buckets.get(client)
            if b is None:
                if len(self._buckets) >= self.cfg.max_clients:
                    # drop the oldest tracked client (dict preserves
                    # insertion order); a returning client restarts with
                    # a full burst, which only ever errs permissive
                    self._buckets.pop(next(iter(self._buckets)))
                b = self._buckets[client] = TokenBucket(
                    self.cfg.client_rate, self.cfg.client_burst,
                    clock=self._clock)
            return b

    def _shed(self, reason: str, retry_after: float) -> None:
        self.registry.inc(f"admission.shed_{reason}")
        raise Overloaded(retry_after=retry_after, reason=reason)

    # ------------------------------------------------------------------
    def check(self, *, client: Optional[str], deadline_s: Optional[float],
              queue_depth: int, offload_depth: float,
              est_wait_s: float) -> None:
        """Admit (return) or shed (raise :class:`Overloaded`) one request.

        ``est_wait_s`` is the engine's estimate of time-to-service at the
        current queue depth (collector flush cadence × backlog flushes);
        it doubles as the shed ``retry_after`` and as the deadline test.
        """
        cfg = self.cfg
        if cfg.client_rate > 0 and client is not None:
            wait = self._bucket(client).take()
            if wait > 0.0:
                self._shed("client_rate", wait)
        if cfg.max_queue_depth > 0 and queue_depth >= cfg.max_queue_depth:
            self._shed("queue_depth", max(est_wait_s, 1e-3))
        if cfg.max_offload_depth > 0 and offload_depth > cfg.max_offload_depth:
            self._shed("offload_depth", max(est_wait_s, 1e-3))
        if deadline_s is not None and est_wait_s > deadline_s:
            # rejecting now is strictly better than timing out later:
            # the client learns immediately and the batch slot goes to a
            # request that can still make its deadline
            self._shed("deadline", est_wait_s)
        self.registry.inc("admission.admitted")
