"""LRU result cache for SCAN queries.

Key design: ``(index fingerprint, μ, quantized ε)``.

* The **fingerprint** (see ``serve/store.py``) names the graph + similarity
  content, so a rebuilt-but-identical index keeps its cache hits while any
  real change invalidates everything at once — no TTLs, no manual flushes.
* **ε is quantized** to a fixed grid (default step 1e-4) before keying.
  σ values are float32 with ~7 significant digits; clients exploring
  "ε = 0.6" vs "ε = 0.60000002" mean the same query, and SCAN results are
  a step function of ε (they only change when ε crosses one of the O(m)
  distinct σ values), so a 1e-4 grid aliases only hairline-different
  queries. The quantized value is also what gets *executed* on a miss,
  keeping cached and computed answers consistent.

The cache stores host-side results (numpy), so hits never touch the device.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Hashable, Optional, Tuple

DEFAULT_EPS_QUANTUM = 1e-4


def quantize_eps(eps: float, quantum: float = DEFAULT_EPS_QUANTUM) -> float:
    """Snap ε onto the cache grid (also the value actually executed)."""
    return round(round(float(eps) / quantum) * quantum, 10)


class ResultCache:
    """Plain LRU over (fingerprint, μ, quantized ε) → result."""

    def __init__(self, capacity: int = 1024,
                 eps_quantum: float = DEFAULT_EPS_QUANTUM):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.eps_quantum = eps_quantum
        self._data: OrderedDict[Hashable, object] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def key(self, fingerprint: str, mu: int, eps: float
            ) -> Tuple[str, int, float]:
        return (fingerprint, int(mu), quantize_eps(eps, self.eps_quantum))

    def get(self, fingerprint: str, mu: int, eps: float) -> Optional[object]:
        k = self.key(fingerprint, mu, eps)
        if k in self._data:
            self._data.move_to_end(k)
            self.hits += 1
            return self._data[k]
        self.misses += 1
        return None

    def peek(self, fingerprint: str, mu: int, eps: float) -> Optional[object]:
        """Like ``get`` but without touching the hit/miss counters (for
        internal re-checks that shouldn't distort the stats)."""
        k = self.key(fingerprint, mu, eps)
        if k in self._data:
            self._data.move_to_end(k)
            return self._data[k]
        return None

    def put(self, fingerprint: str, mu: int, eps: float, value) -> None:
        k = self.key(fingerprint, mu, eps)
        if k in self._data:
            self._data.move_to_end(k)
        self._data[k] = value
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1

    def invalidate(self, fingerprint: Optional[str] = None) -> int:
        """Drop entries for one fingerprint (or everything); → count."""
        if fingerprint is None:
            n = len(self._data)
            self._data.clear()
            return n
        stale = [k for k in self._data if k[0] == fingerprint]
        for k in stale:
            del self._data[k]
        return len(stale)

    def __len__(self) -> int:
        return len(self._data)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"size": len(self._data), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0}
