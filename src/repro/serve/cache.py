"""LRU result cache for SCAN queries.

Key design: ``(index fingerprint, μ, quantized ε)``.

* The **fingerprint** (see ``serve/store.py``) names the graph + similarity
  content, so a rebuilt-but-identical index keeps its cache hits while any
  real change invalidates everything at once — no TTLs, no manual flushes.
* **ε is quantized** to a fixed grid (default step 1e-4) before keying.
  σ values are float32 with ~7 significant digits; clients exploring
  "ε = 0.6" vs "ε = 0.60000002" mean the same query, and SCAN results are
  a step function of ε (they only change when ε crosses one of the O(m)
  distinct σ values), so a 1e-4 grid aliases only hairline-different
  queries. The quantized value is also what gets *executed* on a miss,
  keeping cached and computed answers consistent.

The cache stores host-side results (numpy), so hits never touch the device.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Hashable, Optional, Tuple

DEFAULT_EPS_QUANTUM = 1e-4


def quantize_eps(eps: float, quantum: float = DEFAULT_EPS_QUANTUM) -> float:
    """Snap ε onto the cache grid (also the value actually executed)."""
    return round(round(float(eps) / quantum) * quantum, 10)


class ResultCache:
    """Plain LRU over (fingerprint, μ, quantized ε) → result."""

    def __init__(self, capacity: int = 1024,
                 eps_quantum: float = DEFAULT_EPS_QUANTUM):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.eps_quantum = eps_quantum
        self._data: OrderedDict[Hashable, object] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def key(self, fingerprint: str, mu: int, eps: float
            ) -> Tuple[str, int, float]:
        return (fingerprint, int(mu), quantize_eps(eps, self.eps_quantum))

    def get(self, fingerprint: str, mu: int, eps: float) -> Optional[object]:
        k = self.key(fingerprint, mu, eps)
        if k in self._data:
            self._data.move_to_end(k)
            self.hits += 1
            return self._data[k]
        self.misses += 1
        return None

    def peek(self, fingerprint: str, mu: int, eps: float) -> Optional[object]:
        """Like ``get`` but without touching the hit/miss counters (for
        internal re-checks that shouldn't distort the stats)."""
        k = self.key(fingerprint, mu, eps)
        if k in self._data:
            self._data.move_to_end(k)
            return self._data[k]
        return None

    def put(self, fingerprint: str, mu: int, eps: float, value) -> None:
        k = self.key(fingerprint, mu, eps)
        if k in self._data:
            self._data.move_to_end(k)
        self._data[k] = value
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1

    def invalidate(self, fingerprint: Optional[str] = None) -> int:
        """Drop entries for one fingerprint (or everything); → count."""
        if fingerprint is None:
            n = len(self._data)
            self._data.clear()
            return n
        stale = [k for k in self._data if k[0] == fingerprint]
        for k in stale:
            del self._data[k]
        return len(stale)

    def __len__(self) -> int:
        return len(self._data)

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"size": len(self._data), "capacity": self.capacity,
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0}


class PartitionedResultCache:
    """Per-index LRU partitions behind the ``ResultCache`` interface.

    One flat LRU shared by several indexes lets a hot index evict a cold
    index's entries (capacity interference); the multi-index router instead
    gives every fingerprint its own ``ResultCache`` of ``capacity`` entries,
    created on first touch and dropped whole on
    ``invalidate(fingerprint)`` — which is also what index unregistration
    calls, so partitions never outlive their index.
    """

    def __init__(self, capacity: int = 1024,
                 eps_quantum: float = DEFAULT_EPS_QUANTUM):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.eps_quantum = eps_quantum
        self._parts: dict[str, ResultCache] = {}
        self._phantom_misses = 0   # get() misses on not-yet-created parts

    def partition(self, fingerprint: str) -> ResultCache:
        part = self._parts.get(fingerprint)
        if part is None:
            part = self._parts[fingerprint] = ResultCache(
                self.capacity, self.eps_quantum)
        return part

    def get(self, fingerprint: str, mu: int, eps: float) -> Optional[object]:
        # reads never create partitions — probing unknown fingerprints must
        # not leak empty LRUs into _parts (only put() materializes one)
        part = self._parts.get(fingerprint)
        if part is None:
            self._phantom_misses += 1   # still a miss for hit_rate purposes
            return None
        return part.get(fingerprint, mu, eps)

    def peek(self, fingerprint: str, mu: int, eps: float) -> Optional[object]:
        part = self._parts.get(fingerprint)
        return part.peek(fingerprint, mu, eps) if part is not None else None

    def put(self, fingerprint: str, mu: int, eps: float, value) -> None:
        self.partition(fingerprint).put(fingerprint, mu, eps, value)

    def invalidate(self, fingerprint: Optional[str] = None) -> int:
        if fingerprint is None:
            n = sum(len(p) for p in self._parts.values())
            self._parts.clear()
            return n
        part = self._parts.pop(fingerprint, None)
        return len(part) if part is not None else 0

    def __len__(self) -> int:
        return sum(len(p) for p in self._parts.values())

    def stats(self) -> dict:
        parts = self._parts.values()
        hits = sum(p.hits for p in parts)
        misses = sum(p.misses for p in parts) + self._phantom_misses
        total = hits + misses
        return {"size": len(self), "capacity": self.capacity,
                "partitions": len(self._parts),
                "hits": hits, "misses": misses,
                "evictions": sum(p.evictions for p in parts),
                "hit_rate": hits / total if total else 0.0}


class SeedResultCache:
    """Per-fingerprint LRU partitions over (seed, μ, quantized ε) →
    :class:`~repro.core.local.SeedResult`.

    Same partitioning philosophy as :class:`PartitionedResultCache` —
    one hot index cannot evict a sibling's entries, and unregistration
    drops a partition wholesale — but with one extra verb the global
    cache cannot have: :meth:`migrate`. A delta changes the serving
    fingerprint, which for *global* results invalidates everything; a
    *seed* result is local, so entries whose seed and members all avoid
    the delta's stale set (``UpdateInfo.frontier_vertices``) are provably
    bit-identical under the new index and carry over to its fingerprint
    instead of being recomputed.
    """

    def __init__(self, capacity: int = 4096,
                 eps_quantum: float = DEFAULT_EPS_QUANTUM):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.eps_quantum = eps_quantum
        self._parts: dict[str, OrderedDict] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.migrated = 0
        self.dropped = 0

    def key(self, seed: int, mu: int, eps: float) -> Tuple[int, int, float]:
        return (int(seed), int(mu), quantize_eps(eps, self.eps_quantum))

    def get(self, fingerprint: str, seed: int, mu: int, eps: float
            ) -> Optional[object]:
        part = self._parts.get(fingerprint)
        if part is None:
            self.misses += 1
            return None
        k = self.key(seed, mu, eps)
        if k in part:
            part.move_to_end(k)
            self.hits += 1
            return part[k]
        self.misses += 1
        return None

    def peek(self, fingerprint: str, seed: int, mu: int, eps: float
             ) -> Optional[object]:
        """``get`` without the hit/miss accounting (internal re-checks)."""
        part = self._parts.get(fingerprint)
        if part is None:
            return None
        k = self.key(seed, mu, eps)
        if k in part:
            part.move_to_end(k)
            return part[k]
        return None

    def put(self, fingerprint: str, seed: int, mu: int, eps: float,
            value) -> None:
        part = self._parts.get(fingerprint)
        if part is None:
            part = self._parts[fingerprint] = OrderedDict()
        k = self.key(seed, mu, eps)
        if k in part:
            part.move_to_end(k)
        part[k] = value
        while len(part) > self.capacity:
            part.popitem(last=False)
            self.evictions += 1

    def migrate(self, old_fp: str, new_fp: str,
                stale_mask) -> Tuple[int, int]:
        """Carry the old fingerprint's still-valid entries to the new one.

        An entry survives iff neither its seed nor any of its members
        lies in ``stale_mask`` (bool[n], from
        ``UpdateInfo.frontier_vertices``) — outside that set the new
        index answers bit-identically, so the cached result *is* the new
        result. Returns (kept, dropped); the old partition is consumed
        either way (in-flight traffic may lazily recreate it; the
        caller's unregister sweeps that up).
        """
        part = self._parts.pop(old_fp, None)
        if not part:
            return (0, 0)
        kept: OrderedDict = OrderedDict()
        dropped = 0
        for k, res in part.items():
            seed = k[0]
            if stale_mask[seed] or bool(
                    (res.member_mask & stale_mask).any()):
                dropped += 1
                continue
            kept[k] = res
        if kept:
            dest = self._parts.setdefault(new_fp, OrderedDict())
            for k, res in kept.items():
                if k in dest:
                    dest.move_to_end(k)
                dest[k] = res
            while len(dest) > self.capacity:
                dest.popitem(last=False)
                self.evictions += 1
        self.migrated += len(kept)
        self.dropped += dropped
        return (len(kept), dropped)

    def invalidate(self, fingerprint: Optional[str] = None) -> int:
        if fingerprint is None:
            n = sum(len(p) for p in self._parts.values())
            self._parts.clear()
            return n
        part = self._parts.pop(fingerprint, None)
        return len(part) if part is not None else 0

    def __len__(self) -> int:
        return sum(len(p) for p in self._parts.values())

    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"size": len(self), "capacity": self.capacity,
                "partitions": len(self._parts),
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "migrated": self.migrated, "dropped": self.dropped,
                "hit_rate": self.hits / total if total else 0.0}


def neighborhood(mu: int, eps: float, *,
                 eps_step: float = 0.05,
                 quantum: float = DEFAULT_EPS_QUANTUM) -> list:
    """Sweep-ahead candidates around one observed (μ, ε) setting.

    Users exploring SCAN parameters walk the grid locally — the next request
    after (μ, ε) is overwhelmingly (μ±1, ε) or (μ, ε±step). These are the
    settings the engine pre-warms into otherwise-wasted padding slots of the
    fixed-shape device batch.

    Every candidate is **clamped to the valid query domain** (μ ≥ 2,
    ε ∈ [0, 1]) and deduplicated *after* the clamp — candidates that fall
    outside the domain (or collapse onto the observed setting, or onto
    each other, once clamped) would burn padding slots computing queries
    no client can ever hit. A non-finite observed ε yields no candidates
    at all (NaN survives min/max clamping)."""
    mu = int(mu)
    eps = float(eps)
    if not math.isfinite(eps):
        return []
    # clamp before any quantization: quantize_eps on a huge finite ε
    # overflows round() (ε/quantum → inf), and an out-of-domain observed
    # value should anchor the neighborhood at the domain edge anyway
    eps = min(max(eps, 0.0), 1.0)
    observed = {(mu, quantize_eps(eps, quantum))}
    out = []
    for cand_mu, cand_eps in ((mu + 1, eps), (mu - 1, eps),
                              (mu, eps + eps_step), (mu, eps - eps_step)):
        if cand_mu < 2 or not math.isfinite(cand_eps):
            continue
        eps_q = quantize_eps(min(max(cand_eps, 0.0), 1.0), quantum)
        if not 0.0 <= eps_q <= 1.0:
            # a quantum that doesn't divide 1 can snap the clamped value
            # back out of the domain (e.g. quantize(1.0, 0.15) = 1.05);
            # such a grid point is unservable in range — drop it
            continue
        cand = (int(cand_mu), eps_q)
        if cand not in observed and cand not in out:
            out.append(cand)
    return out
