"""Fault injection for the replicated read fleet.

A fleet is only as robust as the faults it has actually survived, so the
failure modes are first-class, seeded, and injectable instead of waiting
for production to produce them. One :class:`ChaosPolicy` instance is
shared by every replica in a fleet run (and by the writer-side corrupt
hook); all draws come from one seeded ``random.Random``, so a chaos soak
is *replayable* — a failing seed is a regression test, not an anecdote.

Faults, and where they bite:

* **replica crash** (``crash_p``) — drawn per tail-loop poll; the
  replica stops its engine mid-traffic. In-flight queries fail with
  :class:`~repro.serve.errors.EngineStopped`; the router must fail over.
* **stall** (``stall_p`` / ``stall_s``) — the tail loop sleeps without
  replaying; the replica keeps serving its last-good version while its
  ``fleet.staleness_seq`` watermark grows (graceful-degradation path).
* **slow replay** (``slow_replay_p`` / ``slow_replay_s``) — the
  ``apply_delta`` replay itself is slowed (big frontier, cold cache);
  queries must keep flushing meanwhile (replay runs off-loop).
* **torn / corrupt chain entry** (``corrupt_p``, via
  :func:`corrupt_entry`) — an on-disk entry is torn (truncated array
  file) or silently bit-flipped (payload scribble). Replicas must detect
  both — torn at :meth:`~repro.serve.store.DeltaLog.verify` time,
  scribbled at fingerprint-verify time — and **never serve** the result.
* **delayed delivery** (``delay_p`` / ``delay_s``) — a committed entry
  becomes visible to a replica only after a delay (slow NFS/object
  store), exercising the staleness accounting without any corruption.

The policy is consulted through narrow hooks (``should_crash`` /
``stall_seconds`` / …) so tests can also drive single faults
deterministically by constructing a policy with one probability at 1.0.
"""
from __future__ import annotations

import dataclasses
import os
import random
from typing import List, Optional

from repro.ckpt import checkpoint

__all__ = ["ChaosPolicy", "corrupt_entry"]


def corrupt_entry(log_directory: str, seq: int,
                  mode: str = "truncate") -> str:
    """Corrupt one committed DeltaLog entry on disk; → the damaged path.

    ``mode="truncate"`` cuts the last array file in half (a torn write:
    :meth:`DeltaLog.verify` fails, ``np.load`` would raise) —
    ``mode="scribble"`` flips payload bytes while keeping the npy header
    intact (silent bitrot: the entry *loads*, but replaying it cannot
    reproduce the recorded post-delta fingerprint). ``log_directory`` is
    the chain directory itself (``DeltaLog(...).directory``).
    """
    step = checkpoint.step_dir(log_directory, seq)
    arrs = sorted(f for f in os.listdir(step) if f.endswith(".npy"))
    if not arrs:
        raise FileNotFoundError(f"no array leaves under {step}")
    target = os.path.join(step, arrs[-1])
    size = os.path.getsize(target)
    if mode == "truncate":
        with open(target, "r+b") as f:
            f.truncate(size // 2)
    elif mode == "scribble":
        # flip bytes at the *end* of the file: the npy header stays
        # valid, so only semantic (fingerprint) verification can catch it
        with open(target, "r+b") as f:
            f.seek(max(size - 16, 0))
            tail = f.read()
            f.seek(max(size - 16, 0))
            f.write(bytes(b ^ 0xFF for b in tail))
    else:
        raise ValueError(f"unknown corrupt mode {mode!r}")
    return target


@dataclasses.dataclass
class ChaosPolicy:
    """Seeded fault schedule for a fleet run (probabilities per event)."""

    seed: int = 0
    crash_p: float = 0.0        # per tail poll, per replica
    stall_p: float = 0.0        # per tail poll, per replica
    stall_s: float = 0.05
    slow_replay_p: float = 0.0  # per replayed entry
    slow_replay_s: float = 0.02
    corrupt_p: float = 0.0      # per appended entry (writer-side hook)
    corrupt_mode: str = "truncate"
    delay_p: float = 0.0        # per (replica, entry) first sighting
    delay_s: float = 0.05
    max_crashes: int = 1        # never chaos-crash below quorum in a soak

    def __post_init__(self) -> None:
        self._rng = random.Random(self.seed)
        self._crashes = 0
        self._delayed: dict = {}   # (replica_id, seq) → release time offset

    # -- parsing (CLI / CI) --------------------------------------------
    @classmethod
    def parse(cls, spec: str, *, seed: int = 0) -> "ChaosPolicy":
        """``"crash:0.02,stall:0.05,corrupt:0.1"`` → policy. Known keys:
        crash, stall, slow, corrupt, delay (values are probabilities;
        durations/modes keep their defaults)."""
        keys = {"crash": "crash_p", "stall": "stall_p",
                "slow": "slow_replay_p", "corrupt": "corrupt_p",
                "delay": "delay_p"}
        kwargs: dict = {"seed": seed}
        for part in filter(None, spec.split(",")):
            k, _, v = part.partition(":")
            if k not in keys:
                raise ValueError(
                    f"unknown chaos fault {k!r} (know {sorted(keys)})")
            kwargs[keys[k]] = float(v) if v else 1.0
        return cls(**kwargs)

    # -- replica-side hooks --------------------------------------------
    def should_crash(self, replica_id: str) -> bool:
        if self.crash_p <= 0 or self._crashes >= self.max_crashes:
            return False
        if self._rng.random() < self.crash_p:
            self._crashes += 1
            return True
        return False

    def stall_seconds(self, replica_id: str) -> float:
        if self.stall_p > 0 and self._rng.random() < self.stall_p:
            return self.stall_s
        return 0.0

    def replay_delay(self, replica_id: str, seq: int) -> float:
        """Extra seconds to sleep inside the replay of one entry."""
        if self.slow_replay_p > 0 and self._rng.random() < self.slow_replay_p:
            return self.slow_replay_s
        return 0.0

    def delivery_delay(self, replica_id: str, seq: int) -> float:
        """Seconds this replica must keep pretending ``seq`` is not on
        disk yet (drawn once per (replica, entry))."""
        key = (replica_id, seq)
        if key not in self._delayed:
            self._delayed[key] = (
                self.delay_s
                if self.delay_p > 0 and self._rng.random() < self.delay_p
                else 0.0)
        return self._delayed[key]

    # -- writer-side hook ----------------------------------------------
    def maybe_corrupt(self, log_directory: str, seq: int) -> Optional[str]:
        """Writer-side: after committing entry ``seq``, possibly tear it
        on disk (→ damaged path, or None). The fleet harness calls this
        from its delta pipeline so corruption lands *between* the commit
        and the replicas' next poll — the worst possible moment."""
        if self.corrupt_p > 0 and self._rng.random() < self.corrupt_p:
            return corrupt_entry(log_directory, seq, self.corrupt_mode)
        return None

    # -- bookkeeping ----------------------------------------------------
    @property
    def crashes_injected(self) -> int:
        return self._crashes

    def describe(self) -> str:
        on: List[str] = []
        for k in ("crash_p", "stall_p", "slow_replay_p", "corrupt_p",
                  "delay_p"):
            v = getattr(self, k)
            if v > 0:
                on.append(f"{k}={v:g}")
        return f"chaos(seed={self.seed}, {', '.join(on) or 'off'})"
