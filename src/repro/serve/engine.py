"""Async micro-batching query engine with multi-index routing.

Concurrent clients each want one (μ, ε) answer — possibly against
*different* graphs; the device wants one big fixed-shape vmapped call per
index. The engine is the adapter: requests land on an asyncio queue tagged
with the fingerprint of the index they address, a collector coroutine
drains them until either ``max_batch`` requests are waiting or ``flush_ms``
has elapsed since the first one (classic size-or-deadline micro-batching),
then **buckets the batch by fingerprint** and answers each bucket with its
own ``query_batch`` call against that bucket's index.

Routing mechanics (one engine process, many indexes):

* **registration** — ``register(index, g)`` keys the index by its content
  fingerprint (``serve/store.py``); ``query(μ, ε, fingerprint=...)``
  routes to it. Engines constructed the classic way — one index — keep the
  old single-index API: ``query(μ, ε)`` goes to the sole registered index.
* **per-index cache partitions** — the default cache is a
  ``PartitionedResultCache``: every fingerprint gets its own LRU, so one
  hot index cannot evict another's entries, and unregistering an index
  drops its partition wholesale.
* **dedup never aliases across indexes** — the dedup/cache key is
  (fingerprint, μ, quantized ε); identical (μ, ε) against two indexes are
  distinct slots in distinct buckets.
* **failure isolation per bucket** — a failing device call rejects only
  that bucket's futures; other buckets in the same flush, and the
  collector itself, are unaffected.

Throughput mechanics (unchanged from the single-index engine):

* **dedup** — concurrent identical requests (after ε quantization) fold
  into one batch slot; every waiter gets the same result object.
* **cache** — answers are LRU-cached on (fingerprint, μ, quantized ε);
  hits resolve without touching the device.
* **fixed batch shape** — each bucket's device call is always padded to
  ``max_batch`` slots, so exactly one XLA artifact per index serves every
  traffic pattern; no recompiles mid-flight.
* **sweep-ahead warming** — padding slots are filled with the (μ±1, ε±δ)
  neighborhood of the bucket's real requests instead of dead repeats
  (``serve.cache.neighborhood``): parameter-exploring clients walk the
  grid locally, so the next request is usually already cached by the time
  it arrives. Warming changes neither the batch shape nor the call count —
  it rides slots that were previously wasted.
* **sharded execution** — ``EngineConfig(shards=k)`` runs every device
  call through :func:`repro.core.query_batch_sharded` on a k-way mesh
  (giant-graph mode: edge arrays partitioned over the ``data`` axis).

The device call runs inline on the event loop: it is the serial resource
being scheduled, and everything else the loop does (queueing, cache hits)
is microseconds. Results are host-side numpy ``ClusterResult``s. Index
*maintenance* is the opposite case — ``apply_delta`` takes tens of
milliseconds and is not the resource queries wait on — so the engine
exposes a single-worker ``offload_executor()`` that ``LiveIndexService``
uses to apply + log deltas off the loop: collector flushes proceed during
an in-flight apply, and apply latency never shows up in query tails.
"""
from __future__ import annotations

import asyncio
import dataclasses
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.core.graph import CSRGraph
from repro.core.index import ScanIndex
from repro.core.query import ClusterResult, query_batch
from repro.serve.cache import (DEFAULT_EPS_QUANTUM, PartitionedResultCache,
                               ResultCache, neighborhood, quantize_eps)
from repro.serve.store import index_fingerprint


# queue marker for drain() barriers — compared by identity, so no real
# fingerprint string can collide with it
_DRAIN = object()


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 32          # device slots per micro-batch
    flush_ms: float = 2.0        # max wait after the first queued request
    cache_capacity: int = 4096   # per index partition
    eps_quantum: float = DEFAULT_EPS_QUANTUM
    warm_ahead: bool = True      # fill padding slots with (μ, ε) neighbors
    warm_eps_step: float = 0.05  # ε stride of the warmed neighborhood
    shards: Optional[int] = None  # run device calls sharded over k devices


class MicroBatchEngine:
    """Serve one *or many* indexes to concurrent ``await engine.query(...)``.

    Single-index (classic): ``MicroBatchEngine(index, g)``.
    Multi-index (router):   ``MicroBatchEngine()`` then ``register(...)``
    per index; pass ``fingerprint=`` to ``query`` to route.
    """

    def __init__(self, index: Optional[ScanIndex] = None,
                 g: Optional[CSRGraph] = None, *,
                 fingerprint: Optional[str] = None,
                 config: EngineConfig = EngineConfig(),
                 cache=None):
        self.cfg = config
        self.cache = cache if cache is not None else PartitionedResultCache(
            config.cache_capacity, config.eps_quantum)
        self._indexes: dict[str, tuple[ScanIndex, CSRGraph]] = {}
        self._queue: asyncio.Queue = asyncio.Queue()
        self._task: Optional[asyncio.Task] = None
        self._offload: Optional[ThreadPoolExecutor] = None
        self._mesh = None
        self._shard_plans: dict = {}   # fingerprint → ShardedQueryPlan
        self.stats = {"requests": 0, "batches": 0, "device_queries": 0,
                      "cache_hits": 0, "deduped": 0, "warmed": 0,
                      "bucket_failures": 0}
        self.fingerprint: Optional[str] = None
        if index is not None:
            if g is None:
                raise ValueError("an index needs its graph")
            self.fingerprint = self.register(index, g,
                                             fingerprint=fingerprint)

    # ------------------------------------------------------------------
    # index registry
    # ------------------------------------------------------------------
    def register(self, index: ScanIndex, g: CSRGraph, *,
                 fingerprint: Optional[str] = None,
                 shard_plan=None) -> str:
        """Add an index to the router; returns its routing fingerprint.

        ``shard_plan`` seeds the sharded-execution plan for this index
        (``EngineConfig(shards=k)`` mode) — the live-update hot-swap path
        hands over a plan refreshed from its predecessor so only mutated
        partitions of the O(m) operands were re-placed on device.
        """
        fp = (fingerprint if fingerprint is not None
              else index_fingerprint(index, g))
        if fp in self._indexes:
            # hot-swap under an explicit fingerprint: the old index's
            # sharded plan and cached answers must not outlive it
            self._shard_plans.pop(fp, None)
            self.cache.invalidate(fp)
        self._indexes[fp] = (index, g)
        if shard_plan is not None:
            self._shard_plans[fp] = shard_plan
        if self.fingerprint is None:
            self.fingerprint = fp
        return fp

    def unregister(self, fingerprint: str) -> int:
        """Drop an index and its cache partition; → evicted entry count."""
        self._indexes.pop(fingerprint, None)
        self._shard_plans.pop(fingerprint, None)
        if self.fingerprint == fingerprint:
            self.fingerprint = next(iter(self._indexes), None)
        return self.cache.invalidate(fingerprint)

    def fingerprints(self) -> list[str]:
        return list(self._indexes)

    @property
    def index(self) -> Optional[ScanIndex]:
        """Default-route index (single-index back-compat accessor)."""
        pair = self._indexes.get(self.fingerprint)
        return pair[0] if pair else None

    @property
    def g(self) -> Optional[CSRGraph]:
        pair = self._indexes.get(self.fingerprint)
        return pair[1] if pair else None

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    async def start(self) -> None:
        if self._task is None:
            # fresh queue per collector: asyncio.Queue binds to the event
            # loop on first use, so an engine reused across a second
            # asyncio.run() must not hand the new collector the old loop's
            # queue (its first get() would die and strand every waiter)
            self._queue = asyncio.Queue()
            self._task = asyncio.get_running_loop().create_task(self._loop())

    async def stop(self) -> None:
        if self._task is not None:
            self._queue.put_nowait(None)
            await self._task
            self._task = None
        if self._offload is not None:
            # wait out an in-flight off-loop apply (a torn maintenance job
            # must not outlive the engine it feeds) — but wait *off* the
            # loop: a synchronous shutdown(wait=True) would freeze every
            # other coroutine for the duration of the apply
            offload, self._offload = self._offload, None
            await asyncio.get_running_loop().run_in_executor(
                None, lambda: offload.shutdown(wait=True))

    @property
    def is_running(self) -> bool:
        """Whether the collector task is alive (the engine serves queries
        and may accept maintenance work)."""
        return self._task is not None

    def offload_executor(self) -> ThreadPoolExecutor:
        """Single-worker executor for blocking index-maintenance jobs
        (``LiveIndexService`` runs ``apply_delta`` + delta logging here so
        the collector loop never stalls behind an apply). One worker keeps
        maintenance serial; the loop thread stays free for flushes, which
        is the whole point of taking applies off the event loop."""
        if not self.is_running:
            # stop() shut the previous executor down; lazily resurrecting
            # one here would leak its thread and absorb maintenance into
            # an engine whose collector will never serve the result
            raise RuntimeError(
                "engine is not running: start() it before scheduling "
                "maintenance work")
        if self._offload is None:
            self._offload = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="index-apply")
        return self._offload

    async def drain(self) -> None:
        """Resolve once every request enqueued *before* this call has been
        flushed. The queue is FIFO and the collector flushes strictly in
        order, so a marker item acts as a barrier — this is what lets a
        hot-swap retire an old index only after all in-flight traffic
        against it has been answered (readers see old or new, never a
        mix, and never a KeyError on a half-retired route)."""
        if self._task is None:
            return
        fut = asyncio.get_running_loop().create_future()
        self._queue.put_nowait((_DRAIN, 0, 0.0, fut))
        await fut

    async def __aenter__(self) -> "MicroBatchEngine":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # ------------------------------------------------------------------
    # client API
    # ------------------------------------------------------------------
    async def query(self, mu: int, eps: float,
                    fingerprint: Optional[str] = None) -> ClusterResult:
        """One SCAN query; coalesced with whatever else is in flight.

        ``fingerprint`` selects the target index; ``None`` routes to the
        engine's default (the first registered index).
        """
        fp = fingerprint if fingerprint is not None else self.fingerprint
        if fp not in self._indexes:
            raise KeyError(f"no index registered for fingerprint {fp!r}")
        if self._task is None:
            await self.start()
        self.stats["requests"] += 1
        mu = int(mu)
        eps_q = quantize_eps(eps, self.cfg.eps_quantum)
        hit = self.cache.get(fp, mu, eps_q)
        if hit is not None:
            self.stats["cache_hits"] += 1
            return hit
        fut = asyncio.get_running_loop().create_future()
        self._queue.put_nowait((fp, mu, eps_q, fut))
        return await fut

    # ------------------------------------------------------------------
    # collector loop
    # ------------------------------------------------------------------
    async def _loop(self) -> None:
        while True:
            first = await self._queue.get()
            if first is None:
                return
            batch = [first]
            deadline = asyncio.get_running_loop().time() + self.cfg.flush_ms / 1e3
            while len(batch) < self.cfg.max_batch:
                timeout = deadline - asyncio.get_running_loop().time()
                if timeout <= 0:
                    break
                try:
                    item = await asyncio.wait_for(self._queue.get(), timeout)
                except asyncio.TimeoutError:
                    break
                if item is None:
                    self._flush(batch)
                    return
                batch.append(item)
            self._flush(batch)

    def _flush(self, batch) -> None:
        """Bucket one collected batch by fingerprint and execute each bucket
        as its own device call. A failing bucket rejects only its own
        waiters — sibling buckets and the collector keep running (later
        requests must not hang on a dead loop)."""
        buckets: dict[str, list] = {}
        for item in batch:
            if item[0] is _DRAIN:
                # barrier marker: everything queued before it is in this
                # or an earlier (already flushed) batch; real items in
                # *this* batch flush below, before any awaiter of the
                # barrier future runs (the loop is single-threaded).
                # A cancelled waiter (wait_for timeout) must not kill the
                # collector with InvalidStateError.
                if not item[3].done():
                    item[3].set_result(None)
                continue
            buckets.setdefault(item[0], []).append(item)
        for bucket in buckets.values():
            try:
                self._execute(bucket)
            except Exception as e:  # noqa: BLE001
                self.stats["bucket_failures"] += 1
                for _, _, _, fut in bucket:
                    if not fut.done():
                        fut.set_exception(e)

    # ------------------------------------------------------------------
    # per-bucket execution
    # ------------------------------------------------------------------
    def _device_call(self, fp: str, index: ScanIndex, g: CSRGraph,
                     mus, epss):
        if self.cfg.shards is not None and self.cfg.shards > 1:
            from repro.core.distributed import ShardedQueryPlan, query_mesh
            if self._mesh is None:
                self._mesh = query_mesh(self.cfg.shards)
            plan = self._shard_plans.get(fp)
            if plan is None:
                # pad + shard the O(m) operands once per index, not per flush
                plan = self._shard_plans[fp] = ShardedQueryPlan(
                    index, g, self._mesh)
            return plan(mus, epss)
        return query_batch(index, g, mus, epss)

    def _execute(self, bucket) -> None:
        """One fingerprint's requests → at most one fixed-shape device call."""
        fp = bucket[0][0]
        index, g = self._indexes[fp]
        waiters: dict[tuple, list] = {}
        for _, mu, eps_q, fut in bucket:
            waiters.setdefault((mu, eps_q), []).append(fut)
        self.stats["batches"] += 1
        self.stats["deduped"] += len(bucket) - len(waiters)

        need, resolved = [], {}
        for key in waiters:
            # a twin request may have filled the cache while we queued
            hit = self.cache.peek(fp, *key)
            if hit is not None:
                self.stats["cache_hits"] += 1
                resolved[key] = hit
            else:
                need.append(key)

        if need:
            # pad to the fixed slot count: one compiled artifact forever.
            # Padding slots carry the warm-ahead neighborhood of the real
            # requests (already-cached neighbors excluded); any remainder
            # repeats the first real request.
            warm = []
            if self.cfg.warm_ahead:
                warm = self._warm_candidates(fp, need,
                                             self.cfg.max_batch - len(need))
            slots = need + warm
            slots = slots + [need[0]] * (self.cfg.max_batch - len(slots))
            mus = np.asarray([k[0] for k in slots], np.int32)
            epss = np.asarray([k[1] for k in slots], np.float32)
            res = self._device_call(fp, index, g, mus, epss)
            labels = np.asarray(res.labels)
            is_core = np.asarray(res.is_core)
            n_clusters = np.asarray(res.n_clusters)
            self.stats["device_queries"] += 1
            self.stats["warmed"] += len(warm)
            for i, key in enumerate(need + warm):
                # copy: row views would pin the whole padded batch array
                # in the cache for as long as the entry lives
                out = ClusterResult(labels=labels[i].copy(),
                                    is_core=is_core[i].copy(),
                                    n_clusters=int(n_clusters[i]))
                self.cache.put(fp, key[0], key[1], out)
                if i < len(need):
                    resolved[key] = out

        for key, futs in waiters.items():
            for fut in futs:
                if not fut.done():
                    fut.set_result(resolved[key])

    def _warm_candidates(self, fp: str, need, limit: int) -> list:
        """Neighborhood settings worth pre-computing in this bucket's
        padding slots: near an actual request, not requested themselves,
        and not already cached."""
        if limit <= 0:
            return []
        seen = set(need)
        out = []
        for mu, eps_q in need:
            for cand in neighborhood(mu, eps_q,
                                     eps_step=self.cfg.warm_eps_step,
                                     quantum=self.cfg.eps_quantum):
                if cand in seen:
                    continue
                seen.add(cand)
                if self.cache.peek(fp, *cand) is not None:
                    continue
                out.append(cand)
                if len(out) >= limit:
                    return out
        return out

    def batch_stats(self) -> dict:
        """Engine + cache counters (for the CLI / bench report)."""
        out = dict(self.stats)
        b = max(out["batches"], 1)
        out["avg_batch"] = (out["requests"] - out["cache_hits"]) / b
        out["indexes"] = len(self._indexes)
        cache_stats = {f"cache_{k}": v for k, v in self.cache.stats().items()}
        # the engine's own cache_hits (which also counts _execute peek
        # re-checks) must not be clobbered by the store-side hits counter
        cache_stats.pop("cache_hits", None)
        out.update(cache_stats)
        return out
