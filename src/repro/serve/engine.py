"""Async micro-batching query engine with multi-index routing.

Concurrent clients each want one (μ, ε) answer — possibly against
*different* graphs; the device wants one big fixed-shape vmapped call per
index. The engine is the adapter: requests land on an asyncio queue tagged
with the fingerprint of the index they address, a collector coroutine
drains them until either ``max_batch`` requests are waiting or ``flush_ms``
has elapsed since the first one (classic size-or-deadline micro-batching),
then **buckets the batch by fingerprint** and answers each bucket with its
own ``query_batch`` call against that bucket's index.

Routing mechanics (one engine process, many indexes):

* **registration** — ``register(index, g)`` keys the index by its content
  fingerprint (``serve/store.py``); ``query(μ, ε, fingerprint=...)``
  routes to it. Engines constructed the classic way — one index — keep the
  old single-index API: ``query(μ, ε)`` goes to the sole registered index.
* **per-index cache partitions** — the default cache is a
  ``PartitionedResultCache``: every fingerprint gets its own LRU, so one
  hot index cannot evict another's entries, and unregistering an index
  drops its partition wholesale.
* **dedup never aliases across indexes** — the dedup/cache key is
  (fingerprint, μ, quantized ε); identical (μ, ε) against two indexes are
  distinct slots in distinct buckets.
* **failure isolation per bucket** — a failing device call rejects only
  that bucket's futures; other buckets in the same flush, and the
  collector itself, are unaffected.

Throughput mechanics (unchanged from the single-index engine):

* **dedup** — concurrent identical requests (after ε quantization) fold
  into one batch slot; every waiter gets the same result object.
* **cache** — answers are LRU-cached on (fingerprint, μ, quantized ε);
  hits resolve without touching the device.
* **fixed batch shape** — each bucket's device call is always padded to
  ``max_batch`` slots, so exactly one XLA artifact per index serves every
  traffic pattern; no recompiles mid-flight.
* **sweep-ahead warming** — padding slots are filled with the (μ±1, ε±δ)
  neighborhood of the bucket's real requests instead of dead repeats
  (``serve.cache.neighborhood``): parameter-exploring clients walk the
  grid locally, so the next request is usually already cached by the time
  it arrives. Warming changes neither the batch shape nor the call count —
  it rides slots that were previously wasted.
* **sharded execution** — ``EngineConfig(shards=k)`` runs every device
  call through :func:`repro.core.query_batch_sharded` on a k-way mesh
  (giant-graph mode: edge arrays partitioned over the ``data`` axis).

Seed-set (local) queries are a second request kind on the same queue:
``await engine.query_seed(seed, μ, ε)`` answers "what is *this vertex's*
cluster" through :func:`repro.core.local.query_seeds` — work scales with
the output cluster, not with n. Seed requests get their **own buckets
and their own fixed batch shape** (``seed_batch`` lanes per device call,
padded the same way), so seed and global traffic never share a compiled
artifact; their dedup/cache key is (fingerprint, seed, μ, quantized ε)
in a dedicated :class:`~repro.serve.cache.SeedResultCache`, whose
entries survive live-index deltas when the seed's cluster provably
didn't change (``SeedResultCache.migrate`` — see ``serve/live.py``).
Padding slots warm the (μ±1, ε±δ) neighborhood of the *same seed*.
Seed telemetry mirrors the global taxonomy under ``engine.seed_*``:
``seed_e2e`` histogram, ``seed_queue_wait`` event, ``seed_cache_lookup``
/ ``seed_device_call`` spans, and ``seed_requests`` / ``seed_batches`` /
``seed_cache_hits`` / ``seed_deduped`` / ``seed_device_queries`` /
``seed_warmed`` / ``seed_spills`` counters.

The device call runs inline on the event loop: it is the serial resource
being scheduled, and everything else the loop does (queueing, cache hits)
is microseconds. Results are host-side numpy ``ClusterResult``s. Index
*maintenance* is the opposite case — ``apply_delta`` takes tens of
milliseconds and is not the resource queries wait on — so the engine
exposes a single-worker ``offload_executor()`` that ``LiveIndexService``
uses to apply + log deltas off the loop: collector flushes proceed during
an in-flight apply, and apply latency never shows up in query tails.

Telemetry (``repro.obs``): every engine owns a ``MetricsRegistry`` + a
``Tracer``. Per request the engine records an ``engine.cache_lookup``
span, an ``engine.queue_wait`` event (enqueue → flush pickup), and an
``engine.e2e`` histogram sample (request → resolved, cache hits
included); per flush an ``engine.batch_assembly`` event and one
``engine.device_call`` span per bucket; plus counters for every legacy
``stats`` key, an ``engine.queue_depth`` / ``engine.offload_depth``
gauge pair, and an ``engine.jit_recompiles`` counter fed by jit
cache-size deltas measured around each device call — a steady-state
engine that keeps retracing is a *measured* regression, not a silent
slowdown. ``engine.stats`` remains as a read-only mapping view over the
registry counters (the old mutable dict was updated from both the event
loop and the offload worker with no synchronization — a lost-update
bug; all mutations now go through the thread-safe registry).
"""
from __future__ import annotations

import asyncio
import contextvars
import dataclasses
import sys
import time
from collections.abc import Mapping
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.backend.policy import ExecutionPolicy
from repro.core.graph import CSRGraph
from repro.core.index import ScanIndex
from repro.core.local import SeedResult, query_seeds
from repro.core.query import ClusterResult, query_batch
from repro.obs import MetricsRegistry, Tracer
from repro.serve.admission import AdmissionConfig, AdmissionController
from repro.serve.cache import (DEFAULT_EPS_QUANTUM, PartitionedResultCache,
                               ResultCache, SeedResultCache, neighborhood,
                               quantize_eps)
from repro.serve.errors import EngineStopped
from repro.serve.store import index_fingerprint


# queue marker for drain() barriers — compared by identity, so no real
# fingerprint string can collide with it
_DRAIN = object()

# request kinds: queue items are (fp, kind, key, fut, t0); "q" keys are
# (μ, ε_q), "s" keys are (seed, μ, ε_q). Kinds bucket separately in
# _flush, so seed and global traffic never share a device call (nor a
# compiled artifact — their batch shapes differ).
_KIND_QUERY = "q"
_KIND_SEED = "s"

# legacy ``engine.stats`` keys, each backed by the registry counter
# ``engine.<key>``
_STAT_KEYS = ("requests", "batches", "device_queries", "cache_hits",
              "deduped", "warmed", "bucket_failures")


class _StatsView(Mapping):
    """Read-only mapping view of the engine's legacy counters, backed by
    the thread-safe registry. Reads are always current; writes must go
    through ``registry.inc`` (a ``stats[k] += 1`` raises TypeError, which
    is the point — the old dict was racily mutated from two threads)."""

    __slots__ = ("_registry",)

    def __init__(self, registry: MetricsRegistry) -> None:
        self._registry = registry

    def __getitem__(self, key: str) -> int:
        if key not in _STAT_KEYS:
            raise KeyError(key)
        return self._registry.counter(f"engine.{key}").value

    def __iter__(self):
        return iter(_STAT_KEYS)

    def __len__(self) -> int:
        return len(_STAT_KEYS)


def _query_jit_entries() -> int:
    """Total compiled-artifact count across the query path's jit caches
    (single-device ``query``/``query_batch`` + the sharded twin when
    loaded). The engine differences this around device calls: any growth
    after warmup is a retrace — e.g. an unhashed config field churning
    the cache key — surfaced as the ``engine.jit_recompiles`` counter."""
    import repro.core.query
    # the package re-exports ``query`` the *function*; go through
    # sys.modules for the submodule itself
    _query_mod = sys.modules["repro.core.query"]

    total = 0
    fns = [_query_mod.query, _query_mod.query_batch]
    local_mod = sys.modules.get("repro.core.local")
    if local_mod is not None:
        fns.append(local_mod.query_seeds_device)
    dist_mod = sys.modules.get("repro.core.distributed")
    if dist_mod is not None:
        fns.append(dist_mod._sharded_query_batch)
    for fn in fns:
        cache_size = getattr(fn, "_cache_size", None)
        if cache_size is not None:
            total += cache_size()
    return total


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 32          # device slots per micro-batch
    flush_ms: float = 2.0        # max wait after the first queued request
    cache_capacity: int = 4096   # per index partition
    eps_quantum: float = DEFAULT_EPS_QUANTUM
    warm_ahead: bool = True      # fill padding slots with (μ, ε) neighbors
    warm_eps_step: float = 0.05  # ε stride of the warmed neighborhood
    shards: Optional[int] = None  # run device calls sharded over k devices
    # --- seed-query lane (repro.core.local; single-device) ---
    seed_batch: int = 32          # device lanes per seed micro-batch
    seed_frontier_cap: int = 128  # member/frontier slots per lane (pow2)
    seed_window: int = 32         # NO-row ε-prefix entries per gather
    seed_border_cap: int = 512    # candidate-border slots per lane (pow2)
    # --- admission control (None = accept everything, the old behavior)
    admission: Optional[AdmissionConfig] = None
    # --- backend execution lane: None = auto-dispatch per call; one of
    # repro.backend.policy.LANES pins every kernel to that lane (the
    # REPRO_LANE env var overrides either way, per call)
    lane: Optional[str] = None


class MicroBatchEngine:
    """Serve one *or many* indexes to concurrent ``await engine.query(...)``.

    Single-index (classic): ``MicroBatchEngine(index, g)``.
    Multi-index (router):   ``MicroBatchEngine()`` then ``register(...)``
    per index; pass ``fingerprint=`` to ``query`` to route.
    """

    def __init__(self, index: Optional[ScanIndex] = None,
                 g: Optional[CSRGraph] = None, *,
                 fingerprint: Optional[str] = None,
                 config: EngineConfig = EngineConfig(),
                 cache=None,
                 registry: Optional[MetricsRegistry] = None,
                 policy: Optional[ExecutionPolicy] = None):
        self.cfg = config
        self.cache = cache if cache is not None else PartitionedResultCache(
            config.cache_capacity, config.eps_quantum)
        self.seed_cache = SeedResultCache(config.cache_capacity,
                                          config.eps_quantum)
        self._indexes: dict[str, tuple[ScanIndex, CSRGraph]] = {}
        self._queue: asyncio.Queue = asyncio.Queue()
        self._task: Optional[asyncio.Task] = None
        self._stopped = False
        self._offload: Optional[ThreadPoolExecutor] = None
        self._mesh = None
        self._shard_plans: dict = {}   # fingerprint → ShardedQueryPlan
        self._provenance: dict = {}    # fingerprint → IndexProvenance
        self.registry = registry if registry is not None else MetricsRegistry()
        # the engine owns an ExecutionPolicy so every kernel-lane decision
        # made on its behalf lands in *its* registry (backend.* counters
        # sit next to engine.* in one scrape); config.lane forces a lane
        # for all ops, REPRO_LANE still overrides per call
        self.policy = (policy if policy is not None
                       else ExecutionPolicy(forced_lane=config.lane,
                                            registry=self.registry))
        if self.policy.registry is None:
            self.policy.registry = self.registry
        self.tracer = Tracer(self.registry)
        self.stats = _StatsView(self.registry)
        self.admission = (AdmissionController(config.admission, self.registry)
                          if config.admission is not None else None)
        self.fingerprint: Optional[str] = None
        if index is not None:
            if g is None:
                raise ValueError("an index needs its graph")
            self.fingerprint = self.register(index, g,
                                             fingerprint=fingerprint)

    # ------------------------------------------------------------------
    # index registry
    # ------------------------------------------------------------------
    def register(self, index: ScanIndex, g: CSRGraph, *,
                 fingerprint: Optional[str] = None,
                 shard_plan=None, provenance=None) -> str:
        """Add an index to the router; returns its routing fingerprint.

        ``shard_plan`` seeds the sharded-execution plan for this index
        (``EngineConfig(shards=k)`` mode) — the live-update hot-swap path
        hands over a plan refreshed from its predecessor so only mutated
        partitions of the O(m) operands were re-placed on device.

        ``provenance`` (a :class:`repro.core.approx.IndexProvenance`) tags
        the route with how the index's similarities were produced —
        approximate-first registrations advertise their sketch params here
        so clients/operators can see *what* a fingerprint answers with.
        """
        fp = (fingerprint if fingerprint is not None
              else index_fingerprint(index, g))
        if fp in self._indexes:
            # hot-swap under an explicit fingerprint: the old index's
            # sharded plan and cached answers (global *and* seed) must
            # not outlive it. A refine that reproduced the served bits
            # must NOT come through here — that is relabel()'s job.
            self._shard_plans.pop(fp, None)
            self.cache.invalidate(fp)
            self.seed_cache.invalidate(fp)
        self._indexes[fp] = (index, g)
        if shard_plan is not None:
            self._shard_plans[fp] = shard_plan
        if provenance is not None:
            self._provenance[fp] = provenance
        else:
            self._provenance.pop(fp, None)
        if self.fingerprint is None:
            self.fingerprint = fp
        return fp

    def unregister(self, fingerprint: str) -> int:
        """Drop an index and its cache partitions (global + seed);
        → evicted entry count."""
        self._indexes.pop(fingerprint, None)
        self._shard_plans.pop(fingerprint, None)
        self._provenance.pop(fingerprint, None)
        if self.fingerprint == fingerprint:
            self.fingerprint = next(iter(self._indexes), None)
        return (self.cache.invalidate(fingerprint)
                + self.seed_cache.invalidate(fingerprint))

    def relabel(self, fingerprint: str, *, provenance=None) -> None:
        """Update a registered route's provenance tag *only*.

        Unlike re-:meth:`register`-ing the same fingerprint (the hot-swap
        path), this leaves the compiled shard plan and both cache
        partitions intact — the right verb when a background refine
        reproduces the served index bit-for-bit and all that changed is
        how the bits were produced. ``provenance=None`` resets the route
        to the exact-build convention."""
        if fingerprint not in self._indexes:
            raise KeyError(
                f"no index registered for fingerprint {fingerprint!r}")
        if provenance is not None:
            self._provenance[fingerprint] = provenance
        else:
            self._provenance.pop(fingerprint, None)

    def provenance(self, fingerprint: Optional[str] = None):
        """The :class:`~repro.core.approx.IndexProvenance` registered for
        a route (default route when ``fingerprint`` is None). Routes
        registered without a tag are exact builds by convention."""
        from repro.core.approx import EXACT_PROVENANCE
        fp = fingerprint if fingerprint is not None else self.fingerprint
        if fp not in self._indexes:
            raise KeyError(f"no index registered for fingerprint {fp!r}")
        return self._provenance.get(fp, EXACT_PROVENANCE)

    def fingerprints(self) -> list[str]:
        return list(self._indexes)

    @property
    def index(self) -> Optional[ScanIndex]:
        """Default-route index (single-index back-compat accessor)."""
        pair = self._indexes.get(self.fingerprint)
        return pair[0] if pair else None

    @property
    def g(self) -> Optional[CSRGraph]:
        pair = self._indexes.get(self.fingerprint)
        return pair[1] if pair else None

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    async def start(self) -> None:
        if self._task is None:
            # fresh queue per collector: asyncio.Queue binds to the event
            # loop on first use, so an engine reused across a second
            # asyncio.run() must not hand the new collector the old loop's
            # queue (its first get() would die and strand every waiter)
            self._queue = asyncio.Queue()
            self._stopped = False
            self._task = asyncio.get_running_loop().create_task(self._loop())

    async def stop(self) -> None:
        if self._task is not None:
            # flag first: a request admitted after this point fails fast
            # instead of parking a future behind the stop marker forever
            self._stopped = True
            self._queue.put_nowait(None)
            await self._task
            self._task = None
            # the collector drained on exit; sweep anything that raced in
            # between its last get() and now
            self._reject_pending()
        if self._offload is not None:
            # wait out an in-flight off-loop apply (a torn maintenance job
            # must not outlive the engine it feeds) — but wait *off* the
            # loop: a synchronous shutdown(wait=True) would freeze every
            # other coroutine for the duration of the apply
            offload, self._offload = self._offload, None
            await asyncio.get_running_loop().run_in_executor(
                None, lambda: offload.shutdown(wait=True))

    @property
    def is_running(self) -> bool:
        """Whether the collector task is alive (the engine serves queries
        and may accept maintenance work)."""
        return self._task is not None

    def offload_executor(self) -> ThreadPoolExecutor:
        """Single-worker executor for blocking index-maintenance jobs
        (``LiveIndexService`` runs ``apply_delta`` + delta logging here so
        the collector loop never stalls behind an apply). One worker keeps
        maintenance serial; the loop thread stays free for flushes, which
        is the whole point of taking applies off the event loop."""
        if not self.is_running:
            # stop() shut the previous executor down; lazily resurrecting
            # one here would leak its thread and absorb maintenance into
            # an engine whose collector will never serve the result
            raise RuntimeError(
                "engine is not running: start() it before scheduling "
                "maintenance work")
        if self._offload is None:
            self._offload = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="index-apply")
        return self._offload

    async def run_offloaded(self, fn):
        """Run ``fn()`` in the offload executor and await its result.

        Two things the raw ``loop.run_in_executor`` call site lacked:
        the ``engine.offload_depth`` gauge tracks jobs submitted but not
        finished (the single worker means depth > 1 is a queue — the
        admission-control signal the ROADMAP's fleet work needs), and
        the caller's contextvars are copied into the worker so spans the
        job opens nest under the caller's span (``run_in_executor`` drops
        context on the floor)."""
        depth = self.registry.gauge("engine.offload_depth")
        self.registry.inc("engine.offload_jobs")
        depth.add(1)
        try:
            ctx = contextvars.copy_context()
            return await asyncio.get_running_loop().run_in_executor(
                self.offload_executor(), lambda: ctx.run(fn))
        finally:
            depth.add(-1)

    async def drain(self) -> None:
        """Resolve once every request enqueued *before* this call has been
        flushed. The queue is FIFO and the collector flushes strictly in
        order, so a marker item acts as a barrier — this is what lets a
        hot-swap retire an old index only after all in-flight traffic
        against it has been answered (readers see old or new, never a
        mix, and never a KeyError on a half-retired route)."""
        if self._task is None:
            return
        fut = asyncio.get_running_loop().create_future()
        self._queue.put_nowait((_DRAIN, None, None, fut, time.monotonic()))
        await fut

    async def __aenter__(self) -> "MicroBatchEngine":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # ------------------------------------------------------------------
    # client API
    # ------------------------------------------------------------------
    async def query(self, mu: int, eps: float,
                    fingerprint: Optional[str] = None, *,
                    client: Optional[str] = None,
                    deadline_s: Optional[float] = None) -> ClusterResult:
        """One SCAN query; coalesced with whatever else is in flight.

        ``fingerprint`` selects the target index; ``None`` routes to the
        engine's default (the first registered index). ``client`` is an
        opaque id for per-client admission fairness; ``deadline_s`` lets
        admission reject immediately when the estimated queue wait
        already exceeds the client's patience (both ignored unless the
        engine was configured with ``EngineConfig(admission=...)``; a
        shed raises :class:`~repro.serve.errors.Overloaded`).
        """
        fp = self._admit(fingerprint, client=client, deadline_s=deadline_s)
        if self._task is None:
            await self.start()
        t0 = time.monotonic()
        self.registry.inc("engine.requests")
        mu = int(mu)
        eps_q = quantize_eps(eps, self.cfg.eps_quantum)
        with self.tracer.span("engine.cache_lookup", fingerprint=fp[:12]):
            hit = self.cache.get(fp, mu, eps_q)
        if hit is not None:
            self.registry.inc("engine.cache_hits")
            self.registry.observe("engine.e2e", time.monotonic() - t0)
            return hit
        fut = self._enqueue(fp, _KIND_QUERY, (mu, eps_q), t0)
        try:
            return await fut
        finally:
            # end-to-end latency includes queue wait, batch assembly, and
            # the device call — the number a client actually experiences
            self.registry.observe("engine.e2e", time.monotonic() - t0)

    async def query_seed(self, seed: int, mu: int, eps: float,
                         fingerprint: Optional[str] = None, *,
                         client: Optional[str] = None,
                         deadline_s: Optional[float] = None) -> SeedResult:
        """One seed-set (local) query: the cluster containing ``seed`` at
        (μ, ε) — label, core flag, and full member mask — coalesced with
        other in-flight seed requests into one fixed-shape
        ``query_seeds`` lane batch. Bit-identical to the seed's row of
        the full ``query()`` answer. ``client`` / ``deadline_s`` feed
        admission control exactly as in :meth:`query`."""
        fp = self._admit(fingerprint, client=client, deadline_s=deadline_s)
        index, _ = self._indexes[fp]
        seed = int(seed)
        if not 0 <= seed < index.n:
            raise ValueError(f"seed {seed} out of range for n={index.n}")
        if self._task is None:
            await self.start()
        t0 = time.monotonic()
        self.registry.inc("engine.seed_requests")
        key = (seed, int(mu), quantize_eps(eps, self.cfg.eps_quantum))
        with self.tracer.span("engine.seed_cache_lookup",
                              fingerprint=fp[:12]):
            hit = self.seed_cache.get(fp, *key)
        if hit is not None:
            self.registry.inc("engine.seed_cache_hits")
            self.registry.observe("engine.seed_e2e", time.monotonic() - t0)
            return hit
        fut = self._enqueue(fp, _KIND_SEED, key, t0)
        try:
            return await fut
        finally:
            self.registry.observe("engine.seed_e2e", time.monotonic() - t0)

    def _admit(self, fingerprint: Optional[str], *,
               client: Optional[str] = None,
               deadline_s: Optional[float] = None) -> str:
        """Resolve the route, refuse work on a stopped engine (a request
        enqueued after stop() would otherwise hold a future the dead
        collector never resolves — typed :class:`EngineStopped` so fleet
        retry logic can branch on it), and run admission control when
        configured (a shed raises typed
        :class:`~repro.serve.errors.Overloaded` with ``retry_after``
        instead of silently growing the queue)."""
        fp = fingerprint if fingerprint is not None else self.fingerprint
        if fp not in self._indexes:
            raise KeyError(f"no index registered for fingerprint {fp!r}")
        if self._stopped:
            raise EngineStopped()
        if self.admission is not None:
            self.admission.check(
                client=client, deadline_s=deadline_s,
                queue_depth=self._queue.qsize(),
                offload_depth=self.registry.gauge(
                    "engine.offload_depth").value,
                est_wait_s=self._est_wait_s())
        return fp

    def _est_wait_s(self) -> float:
        """Estimated time-to-service at the current backlog: full flushes
        ahead of a new request × (flush window + observed p50 device
        call). Deliberately a fast, conservative scalar — admission needs
        a shed threshold and a ``retry_after``, not a simulator."""
        flushes_ahead = self._queue.qsize() // max(self.cfg.max_batch, 1) + 1
        per_flush = self.cfg.flush_ms / 1e3
        hist = self.registry.histogram("engine.device_call")
        if hist.count:
            per_flush += hist.quantile(0.5)
        return flushes_ahead * per_flush

    def _enqueue(self, fp: str, kind: str, key, t0: float) -> asyncio.Future:
        # NOTE: callers reach here with no suspension point between
        # their _admit check and this put (start() never actually
        # suspends, and clears _stopped anyway), so an admitted request
        # cannot slip past both stop()'s flag and its _reject_pending()
        fut = asyncio.get_running_loop().create_future()
        self._queue.put_nowait((fp, kind, key, fut, t0))
        self.registry.gauge("engine.queue_depth").set(self._queue.qsize())
        return fut

    # ------------------------------------------------------------------
    # collector loop
    # ------------------------------------------------------------------
    async def _loop(self) -> None:
        while True:
            first = await self._queue.get()
            if first is None:
                self._reject_pending()
                return
            batch = [first]
            t_asm = time.monotonic()
            deadline = asyncio.get_running_loop().time() + self.cfg.flush_ms / 1e3
            while len(batch) < self.cfg.max_batch:
                timeout = deadline - asyncio.get_running_loop().time()
                if timeout <= 0:
                    break
                try:
                    item = await asyncio.wait_for(self._queue.get(), timeout)
                except asyncio.TimeoutError:
                    break
                if item is None:
                    self._note_assembly(t_asm, batch)
                    self._flush(batch)
                    self._reject_pending()
                    return
                batch.append(item)
            self._note_assembly(t_asm, batch)
            self._flush(batch)

    def _reject_pending(self) -> None:
        """Collector exit path: drain whatever is still queued and fail
        those futures fast. A request that raced ``stop()`` into the
        queue behind the ``None`` marker would otherwise hold a future
        nobody ever resolves (the old shutdown bug). Drain barriers
        resolve trivially — everything ahead of them has been flushed or
        rejected by the time we get here."""
        rejected = 0
        while True:
            try:
                item = self._queue.get_nowait()
            except asyncio.QueueEmpty:
                break
            if item is None:
                continue
            fut = item[3]
            if fut.done():
                continue
            if item[0] is _DRAIN:
                fut.set_result(None)
                continue
            fut.set_exception(EngineStopped())
            rejected += 1
        if rejected:
            self.registry.inc("engine.rejected_on_stop", rejected)

    def _note_assembly(self, t_asm: float, batch) -> None:
        """Record the size-or-deadline collection window as a span-shaped
        event (first item picked up → flush decision)."""
        self.tracer.event("engine.batch_assembly",
                          time.monotonic() - t_asm, t_start=t_asm,
                          batch=len(batch))

    def _flush(self, batch) -> None:
        """Bucket one collected batch by (fingerprint, kind) and execute
        each bucket as its own device call — seed and global requests
        never share a call (their batch shapes, caches, and compiled
        artifacts differ). A failing bucket rejects only its own
        waiters — sibling buckets and the collector keep running (later
        requests must not hang on a dead loop)."""
        now = time.monotonic()
        buckets: dict[tuple, list] = {}
        for item in batch:
            if item[0] is _DRAIN:
                # barrier marker: everything queued before it is in this
                # or an earlier (already flushed) batch; real items in
                # *this* batch flush below, before any awaiter of the
                # barrier future runs (the loop is single-threaded).
                # A cancelled waiter (wait_for timeout) must not kill the
                # collector with InvalidStateError.
                if not item[3].done():
                    item[3].set_result(None)
                continue
            # queue wait = enqueue → flush pickup, per request (the batch
            # deadline shows up here; tail growth means admission trouble)
            wait_name = ("engine.seed_queue_wait"
                         if item[1] == _KIND_SEED else "engine.queue_wait")
            self.tracer.event(wait_name, now - item[4],
                              t_start=item[4], fingerprint=item[0][:12])
            buckets.setdefault((item[0], item[1]), []).append(item)
        self.registry.gauge("engine.queue_depth").set(self._queue.qsize())
        for (fp, kind), bucket in buckets.items():
            try:
                if kind == _KIND_SEED:
                    self._execute_seeds(fp, bucket)
                else:
                    self._execute(fp, bucket)
            except Exception as e:  # noqa: BLE001
                self.registry.inc("engine.bucket_failures")
                for item in bucket:
                    if not item[3].done():
                        item[3].set_exception(e)

    # ------------------------------------------------------------------
    # per-bucket execution
    # ------------------------------------------------------------------
    def _device_call(self, fp: str, index: ScanIndex, g: CSRGraph,
                     mus, epss):
        if self.cfg.shards is not None and self.cfg.shards > 1:
            from repro.core.distributed import ShardedQueryPlan, query_mesh
            if self._mesh is None:
                self._mesh = query_mesh(self.cfg.shards)
            plan = self._shard_plans.get(fp)
            if plan is None:
                # pad + shard the O(m) operands once per index, not per flush
                plan = self._shard_plans[fp] = ShardedQueryPlan(
                    index, g, self._mesh, registry=self.registry)
            return plan(mus, epss)
        return query_batch(index, g, mus, epss)

    def _execute(self, fp: str, bucket) -> None:
        """One fingerprint's global requests → at most one fixed-shape
        device call."""
        index, g = self._indexes[fp]
        waiters: dict[tuple, list] = {}
        for item in bucket:
            waiters.setdefault(item[2], []).append(item[3])
        self.registry.inc("engine.batches")
        self.registry.inc("engine.deduped", len(bucket) - len(waiters))

        need, resolved = [], {}
        for key in waiters:
            # a twin request may have filled the cache while we queued
            hit = self.cache.peek(fp, *key)
            if hit is not None:
                self.registry.inc("engine.cache_hits")
                resolved[key] = hit
            else:
                need.append(key)

        if need:
            # pad to the fixed slot count: one compiled artifact forever.
            # Padding slots carry the warm-ahead neighborhood of the real
            # requests (already-cached neighbors excluded); any remainder
            # repeats the first real request.
            warm = []
            if self.cfg.warm_ahead:
                warm = self._warm_candidates(fp, need,
                                             self.cfg.max_batch - len(need))
            slots = need + warm
            slots = slots + [need[0]] * (self.cfg.max_batch - len(slots))
            mus = np.asarray([k[0] for k in slots], np.int32)
            epss = np.asarray([k[1] for k in slots], np.float32)
            jit_before = _query_jit_entries()
            lane = self.policy.lane("query")
            self.policy.note("query", lane)
            with self.tracer.span(
                    "engine.device_call", fingerprint=fp[:12],
                    need=len(need), warmed=len(warm), slots=len(slots),
                    shards=self.cfg.shards or 1, lane=lane):
                res = self._device_call(fp, index, g, mus, epss)
                # host conversion blocks on the device, so the span (and
                # the same-named histogram) covers real compute+transfer
                labels = np.asarray(res.labels)
                is_core = np.asarray(res.is_core)
                n_clusters = np.asarray(res.n_clusters)
            jit_delta = _query_jit_entries() - jit_before
            if jit_delta > 0:
                self.registry.inc("engine.jit_recompiles", jit_delta)
            self.registry.inc("engine.device_queries")
            self.registry.inc("engine.warmed", len(warm))
            for i, key in enumerate(need + warm):
                # copy: row views would pin the whole padded batch array
                # in the cache for as long as the entry lives
                out = ClusterResult(labels=labels[i].copy(),
                                    is_core=is_core[i].copy(),
                                    n_clusters=int(n_clusters[i]))
                self.cache.put(fp, key[0], key[1], out)
                if i < len(need):
                    resolved[key] = out

        for key, futs in waiters.items():
            for fut in futs:
                if not fut.done():
                    fut.set_result(resolved[key])

    def _execute_seeds(self, fp: str, bucket) -> None:
        """One fingerprint's seed requests → fixed-shape ``query_seeds``
        calls of ``seed_batch`` lanes (chunked if a flush carries more
        distinct keys than lanes; each chunk keeps the one batch shape).
        """
        index, g = self._indexes[fp]
        waiters: dict[tuple, list] = {}
        for item in bucket:
            waiters.setdefault(item[2], []).append(item[3])
        self.registry.inc("engine.seed_batches")
        self.registry.inc("engine.seed_deduped", len(bucket) - len(waiters))

        need, resolved = [], {}
        for key in waiters:
            hit = self.seed_cache.peek(fp, *key)
            if hit is not None:
                self.registry.inc("engine.seed_cache_hits")
                resolved[key] = hit
            else:
                need.append(key)

        lanes = self.cfg.seed_batch
        for lo in range(0, len(need), lanes):
            chunk = need[lo:lo + lanes]
            warm = []
            if self.cfg.warm_ahead:
                warm = self._seed_warm_candidates(fp, chunk,
                                                  lanes - len(chunk))
            slots = chunk + warm
            real = len(slots)
            slots = slots + [chunk[0]] * (lanes - real)
            seeds = np.asarray([k[0] for k in slots], np.int32)
            mus = np.asarray([k[1] for k in slots], np.int32)
            epss = np.asarray([k[2] for k in slots], np.float32)
            jit_before = _query_jit_entries()
            q_lane = self.policy.lane("query")
            self.policy.note("query", q_lane)
            with self.tracer.span(
                    "engine.seed_device_call", fingerprint=fp[:12],
                    need=len(chunk), warmed=len(warm), slots=lanes,
                    lane=q_lane):
                res = query_seeds(
                    index, g, seeds, mus, epss,
                    frontier_cap=self.cfg.seed_frontier_cap,
                    window=self.cfg.seed_window,
                    border_cap=self.cfg.seed_border_cap,
                    # spill lanes fall back through the global batch
                    # shape — the artifact the engine already compiles
                    fallback_batch=self.cfg.max_batch)
            jit_delta = _query_jit_entries() - jit_before
            if jit_delta > 0:
                self.registry.inc("engine.jit_recompiles", jit_delta)
            self.registry.inc("engine.seed_device_queries")
            self.registry.inc("engine.seed_warmed", len(warm))
            n_spill = int(np.asarray(res.spilled)[:real].sum())
            if n_spill:
                self.registry.inc("engine.seed_spills", n_spill)
            for i, key in enumerate(chunk + warm):
                out = SeedResult.from_batch_row(res, i, key[0])
                self.seed_cache.put(fp, *key, out)
                if i < len(chunk):
                    resolved[key] = out

        for key, futs in waiters.items():
            for fut in futs:
                if not fut.done():
                    fut.set_result(resolved[key])

    def _seed_warm_candidates(self, fp: str, need, limit: int) -> list:
        """Padding-slot warming for seed lanes: the same seed at its
        (μ±1, ε±δ) neighborhood — parameter-exploring users move on the
        (μ, ε) grid, not across seeds."""
        if limit <= 0:
            return []
        seen = set(need)
        out = []
        for seed, mu, eps_q in need:
            for cmu, ceps in neighborhood(mu, eps_q,
                                          eps_step=self.cfg.warm_eps_step,
                                          quantum=self.cfg.eps_quantum):
                cand = (seed, cmu, ceps)
                if cand in seen:
                    continue
                seen.add(cand)
                if self.seed_cache.peek(fp, *cand) is not None:
                    continue
                out.append(cand)
                if len(out) >= limit:
                    return out
        return out

    def _warm_candidates(self, fp: str, need, limit: int) -> list:
        """Neighborhood settings worth pre-computing in this bucket's
        padding slots: near an actual request, not requested themselves,
        and not already cached."""
        if limit <= 0:
            return []
        seen = set(need)
        out = []
        for mu, eps_q in need:
            for cand in neighborhood(mu, eps_q,
                                     eps_step=self.cfg.warm_eps_step,
                                     quantum=self.cfg.eps_quantum):
                if cand in seen:
                    continue
                seen.add(cand)
                if self.cache.peek(fp, *cand) is not None:
                    continue
                out.append(cand)
                if len(out) >= limit:
                    return out
        return out

    def batch_stats(self) -> dict:
        """Engine + cache counters (for the CLI / bench report)."""
        out = dict(self.stats)
        b = max(out["batches"], 1)
        out["avg_batch"] = (out["requests"] - out["cache_hits"]) / b
        out["indexes"] = len(self._indexes)
        out["approx_indexes"] = sum(
            1 for p in self._provenance.values()
            if getattr(p, "is_approx", False))
        out["jit_recompiles"] = self.registry.counter(
            "engine.jit_recompiles").value
        cache_stats = {f"cache_{k}": v for k, v in self.cache.stats().items()}
        # the engine's own cache_hits (which also counts _execute peek
        # re-checks) must not be clobbered by the store-side hits counter
        cache_stats.pop("cache_hits", None)
        out.update(cache_stats)
        for key in ("seed_requests", "seed_batches", "seed_cache_hits",
                    "seed_deduped", "seed_device_queries", "seed_warmed",
                    "seed_spills", "rejected_on_stop"):
            out[key] = self.registry.counter(f"engine.{key}").value
        out.update({f"seed_cache_{k}": v
                    for k, v in self.seed_cache.stats().items()
                    if k != "hits"})
        return out

    def latency_stats(self, quantiles=(0.5, 0.9, 0.99)) -> dict:
        """Queue-wait / end-to-end latency quantiles in seconds, straight
        from the registry histograms (for the CLI / bench report)."""
        out = {}
        for short, name in (("wait", "engine.queue_wait"),
                            ("e2e", "engine.e2e"),
                            ("seed_wait", "engine.seed_queue_wait"),
                            ("seed_e2e", "engine.seed_e2e")):
            hist = self.registry.histogram(name)
            out[f"{short}_n"] = hist.count
            for q in quantiles:
                out[f"{short}_p{int(q * 100)}"] = hist.quantile(q)
        return out
