"""Async micro-batching query engine.

Concurrent clients each want one (μ, ε) answer; the device wants one big
vmapped call. The engine is the adapter: requests land on an asyncio queue,
a collector coroutine drains them until either ``max_batch`` requests are
waiting or ``flush_ms`` has elapsed since the first one (classic
size-or-deadline micro-batching), then answers the whole batch with a
single ``query_batch`` call.

Throughput mechanics:

* **dedup** — concurrent identical requests (after ε quantization) fold
  into one batch slot; every waiter gets the same result object.
* **cache** — answers are LRU-cached on (fingerprint, μ, quantized ε)
  (``serve/cache.py``); hits resolve without touching the device.
* **fixed batch shape** — the device call is always padded to
  ``max_batch`` slots (unused slots repeat the first real request), so
  exactly one XLA artifact serves every traffic pattern; no recompiles
  mid-flight.

The device call runs inline on the event loop: it is the serial resource
being scheduled, and everything else the loop does (queueing, cache hits)
is microseconds. Results are host-side numpy ``ClusterResult``s.
"""
from __future__ import annotations

import asyncio
import dataclasses
from typing import Optional

import numpy as np

from repro.core.graph import CSRGraph
from repro.core.index import ScanIndex
from repro.core.query import ClusterResult, query_batch
from repro.serve.cache import DEFAULT_EPS_QUANTUM, ResultCache, quantize_eps
from repro.serve.store import index_fingerprint


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 32          # device slots per micro-batch
    flush_ms: float = 2.0        # max wait after the first queued request
    cache_capacity: int = 4096
    eps_quantum: float = DEFAULT_EPS_QUANTUM


class MicroBatchEngine:
    """Serve one index to many concurrent ``await engine.query(μ, ε)``."""

    def __init__(self, index: ScanIndex, g: CSRGraph, *,
                 fingerprint: Optional[str] = None,
                 config: EngineConfig = EngineConfig(),
                 cache: Optional[ResultCache] = None):
        self.index = index
        self.g = g
        self.cfg = config
        self.fingerprint = (fingerprint if fingerprint is not None
                            else index_fingerprint(index, g))
        self.cache = cache if cache is not None else ResultCache(
            config.cache_capacity, config.eps_quantum)
        self._queue: asyncio.Queue = asyncio.Queue()
        self._task: Optional[asyncio.Task] = None
        self.stats = {"requests": 0, "batches": 0, "device_queries": 0,
                      "cache_hits": 0, "deduped": 0}

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    async def start(self) -> None:
        if self._task is None:
            self._task = asyncio.get_running_loop().create_task(self._loop())

    async def stop(self) -> None:
        if self._task is not None:
            self._queue.put_nowait(None)
            await self._task
            self._task = None

    async def __aenter__(self) -> "MicroBatchEngine":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    # ------------------------------------------------------------------
    # client API
    # ------------------------------------------------------------------
    async def query(self, mu: int, eps: float) -> ClusterResult:
        """One SCAN query; coalesced with whatever else is in flight."""
        if self._task is None:
            await self.start()
        self.stats["requests"] += 1
        mu = int(mu)
        eps_q = quantize_eps(eps, self.cfg.eps_quantum)
        hit = self.cache.get(self.fingerprint, mu, eps_q)
        if hit is not None:
            self.stats["cache_hits"] += 1
            return hit
        fut = asyncio.get_running_loop().create_future()
        self._queue.put_nowait((mu, eps_q, fut))
        return await fut

    # ------------------------------------------------------------------
    # collector loop
    # ------------------------------------------------------------------
    async def _loop(self) -> None:
        while True:
            first = await self._queue.get()
            if first is None:
                return
            batch = [first]
            deadline = asyncio.get_running_loop().time() + self.cfg.flush_ms / 1e3
            while len(batch) < self.cfg.max_batch:
                timeout = deadline - asyncio.get_running_loop().time()
                if timeout <= 0:
                    break
                try:
                    item = await asyncio.wait_for(self._queue.get(), timeout)
                except asyncio.TimeoutError:
                    break
                if item is None:
                    self._execute_safe(batch)
                    return
                batch.append(item)
            self._execute_safe(batch)

    def _execute_safe(self, batch) -> None:
        """Run one batch; a failing device call rejects that batch's
        futures instead of killing the collector (later requests must not
        hang on a dead loop)."""
        try:
            self._execute(batch)
        except Exception as e:  # noqa: BLE001
            for _, _, fut in batch:
                if not fut.done():
                    fut.set_exception(e)

    def _execute(self, batch) -> None:
        waiters: dict[tuple, list] = {}
        for mu, eps_q, fut in batch:
            waiters.setdefault((mu, eps_q), []).append(fut)
        self.stats["batches"] += 1
        self.stats["deduped"] += len(batch) - len(waiters)

        need, resolved = [], {}
        for key in waiters:
            # a twin request may have filled the cache while we queued
            hit = self.cache.peek(self.fingerprint, *key)
            if hit is not None:
                self.stats["cache_hits"] += 1
                resolved[key] = hit
            else:
                need.append(key)

        if need:
            # pad to the fixed slot count: one compiled artifact forever
            slots = need + [need[0]] * (self.cfg.max_batch - len(need))
            mus = np.asarray([k[0] for k in slots], np.int32)
            epss = np.asarray([k[1] for k in slots], np.float32)
            res = query_batch(self.index, self.g, mus, epss)
            labels = np.asarray(res.labels)
            is_core = np.asarray(res.is_core)
            n_clusters = np.asarray(res.n_clusters)
            self.stats["device_queries"] += 1
            for i, key in enumerate(need):
                # copy: row views would pin the whole padded batch array
                # in the cache for as long as the entry lives
                out = ClusterResult(labels=labels[i].copy(),
                                    is_core=is_core[i].copy(),
                                    n_clusters=int(n_clusters[i]))
                self.cache.put(self.fingerprint, key[0], key[1], out)
                resolved[key] = out

        for key, futs in waiters.items():
            for fut in futs:
                if not fut.done():
                    fut.set_result(resolved[key])

    def batch_stats(self) -> dict:
        """Engine + cache counters (for the CLI / bench report)."""
        out = dict(self.stats)
        b = max(out["batches"], 1)
        out["avg_batch"] = (out["requests"] - out["cache_hits"]) / b
        out.update({f"cache_{k}": v for k, v in self.cache.stats().items()})
        return out
