"""Typed serve-layer exceptions.

The engine used to reject work with bare ``RuntimeError("engine
stopped")`` strings — fine for a human reading a traceback, useless for
fleet retry logic that must branch on *why* a request failed (a stopped
replica is retryable on a sibling; an overloaded one wants backoff for
``retry_after`` seconds; a divergent one must never be retried into).
Every class subclasses :class:`RuntimeError` so pre-existing
``except RuntimeError`` / ``pytest.raises(RuntimeError)`` call sites keep
working, and the legacy message strings are preserved for log back-compat.
"""
from __future__ import annotations

__all__ = ["ServeError", "EngineStopped", "Overloaded",
           "ReplicaUnavailable", "FleetExhausted"]


class ServeError(RuntimeError):
    """Base class for every typed serve-layer rejection."""


class EngineStopped(ServeError):
    """The engine's collector is (or is about to be) gone; the request
    was never executed. Retryable — on a restarted engine or, in a fleet,
    on a sibling replica."""

    def __init__(self, message: str = "engine stopped") -> None:
        super().__init__(message)


class Overloaded(ServeError):
    """Admission control shed this request instead of queueing it.

    ``retry_after`` is the server's estimate (seconds) of when capacity
    frees up — a client (or the fleet router) should back off at least
    that long before retrying *this* server; ``reason`` names which limit
    tripped (``"client_rate"`` / ``"queue_depth"`` / ``"offload_depth"``
    / ``"deadline"``)."""

    def __init__(self, retry_after: float = 0.0,
                 reason: str = "overloaded") -> None:
        super().__init__(
            f"overloaded ({reason}): retry after {retry_after:.3f}s")
        self.retry_after = float(retry_after)
        self.reason = reason


class ReplicaUnavailable(ServeError):
    """A fleet replica cannot serve (crashed, stopped, or still syncing
    with nothing restorable). Retryable on a sibling."""


class FleetExhausted(ServeError):
    """The fleet router ran out of replicas/retries for one request.
    ``attempts`` records how many replica calls were made; ``last`` the
    final per-replica failure."""

    def __init__(self, message: str, *, attempts: int = 0,
                 last: Exception | None = None) -> None:
        super().__init__(message)
        self.attempts = attempts
        self.last = last
