"""Replicated read fleet over the DeltaLog.

One writer (:class:`~repro.serve.live.LiveIndexService`) cannot be the
whole read path: its engine is one collector on one event loop, and it
is also the process that crashes when the machine under it does. The
fleet turns the write-side artifacts the repo already trusts — atomic
snapshots plus the fingerprint-verified :class:`DeltaLog` chain — into a
**replication protocol**: the log is the only channel between writer and
replicas, so anything a replica can be convinced to serve has, by
construction, survived a round-trip through crash-safe storage.

Roles:

* :class:`ReadReplica` — an independent :class:`MicroBatchEngine` (own
  registry, own caches, own compiled-artifact routes) that restores each
  named index from its latest snapshot and then *tails* the delta chain:
  poll for newer entries, :meth:`DeltaLog.verify` the bytes, replay via
  ``apply_delta`` off-loop, check the replayed content fingerprint
  against the one the writer recorded, and hot-swap behind the engine's
  ``drain()`` barrier — the same swap discipline as the writer, so
  replica clients also never see a mix. **Bit-identity is the invariant**
  (``apply_delta`` is oracle-proven identical to a rebuild): a replica
  either serves exactly the writer's bits at some sequence number, or it
  serves its *last verified* version and says so (``fleet.staleness_seq``
  gauge, max-merged across the fleet) — it never serves a divergent
  index. A torn/corrupt entry or a fingerprint mismatch halts the tail at
  the last good seq; the replica recovers by **re-syncing from the next
  snapshot** (the writer's compaction eventually publishes one past the
  damage), not by touching the writer-owned chain.
* :class:`FleetRouter` — consistent-hash routing (vnode ring keyed on
  the *index name*, which is stable across versions, so one index's
  traffic keeps hitting the same replica's caches), health checks, per
  attempt timeouts, jittered-backoff retry over ring siblings, and
  hedged failover: if the primary has not answered within
  ``hedge_after_s``, a sibling is raced and the first success wins.
  Typed failures route: :class:`EngineStopped`/timeout → failover to the
  next sibling; :class:`Overloaded` → spill to a sibling once per
  replica, else surface the shed (with its ``retry_after``) to the
  client — the router must not amplify an overload into a retry storm.
* :class:`Fleet` — the harness: one writer + N replicas + a router over
  one on-disk catalog, with the optional
  :class:`~repro.serve.chaos.ChaosPolicy` threaded through both sides
  (writer-side entry corruption lands *between* commit and the replicas'
  next poll). ``metrics_snapshot()`` folds every registry into one view
  via ``merge_snapshot`` — counters sum, staleness watermarks max.

Telemetry extends the ``repro.obs`` taxonomy under ``fleet.*``:
``fleet.replay`` / ``fleet.resync`` spans; ``fleet.replays`` /
``fleet.swaps`` / ``fleet.resyncs`` / ``fleet.corrupt_entries`` /
``fleet.fingerprint_mismatches`` / ``fleet.crashes`` / ``fleet.stalls``
/ ``fleet.delayed_entries`` counters replica-side; ``fleet.requests`` /
``fleet.retries`` / ``fleet.failovers`` / ``fleet.hedges`` /
``fleet.hedge_wins`` / ``fleet.overload_spills`` / ``fleet.exhausted``
router-side; ``fleet.staleness_seq`` / ``fleet.replicas_healthy``
gauges.
"""
from __future__ import annotations

import asyncio
import bisect
import dataclasses
import hashlib
import logging
import random
import time
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.graph import CSRGraph
from repro.core.index import ScanIndex
from repro.core.update import EdgeDelta, apply_delta
from repro.obs import MetricsRegistry, Tracer
from repro.serve.chaos import ChaosPolicy
from repro.serve.engine import EngineConfig, MicroBatchEngine
from repro.serve.errors import (EngineStopped, FleetExhausted, Overloaded,
                                ReplicaUnavailable)
from repro.serve.live import LiveIndexService
from repro.serve.store import DeltaLog, IndexCatalog, index_fingerprint

__all__ = ["ReadReplica", "FleetRouter", "Fleet", "FleetAnswer"]

_log = logging.getLogger(__name__)


@dataclasses.dataclass(frozen=True)
class FleetAnswer:
    """One routed answer plus the provenance a bit-identity oracle needs:
    *which* index version (content fingerprint + delta seq) produced it,
    and on which replica. ``result`` is a ``ClusterResult`` or
    ``SeedResult`` depending on the query kind."""

    result: object
    fingerprint: str
    seq: int
    replica: str


@dataclasses.dataclass
class _Tracked:
    """One name's tail position on one replica."""

    index: ScanIndex
    g: CSRGraph
    fp: str
    seq: int


class ReadReplica:
    """One read-only engine tailing the writer's on-disk state.

    The replica owns nothing on disk: snapshots and the delta chain are
    the writer's; this side only ever reads them. It owns its *serving*
    state — engine, caches, compiled routes — and advances it only
    through verified replay or snapshot resync.
    """

    def __init__(self, replica_id: str, root: str, *,
                 config: EngineConfig = EngineConfig(),
                 measure: str = "cosine",
                 poll_s: float = 0.02,
                 chaos: Optional[ChaosPolicy] = None):
        self.replica_id = replica_id
        self.catalog = IndexCatalog(root)
        self.engine = MicroBatchEngine(config=config)
        self.measure = measure
        self.poll_s = poll_s
        self.chaos = chaos
        self.registry = self.engine.registry
        self.tracer = self.engine.tracer
        self._tracked: Dict[str, _Tracked] = {}
        self._first_seen: Dict[Tuple[str, int], float] = {}
        self._tail_task: Optional[asyncio.Task] = None
        self._running = False
        self.crashed = False

    # -- lifecycle -----------------------------------------------------
    async def start(self) -> None:
        await self.engine.start()
        self._running = True
        self.crashed = False
        self._discover()
        self._tail_task = asyncio.get_running_loop().create_task(
            self._tail_loop())

    async def stop(self) -> None:
        self._running = False
        if self._tail_task is not None:
            task, self._tail_task = self._tail_task, None
            task.cancel()
            try:
                await task
            except asyncio.CancelledError:
                pass
        await self.engine.stop()

    async def crash(self) -> None:
        """Chaos verb: die mid-traffic. In-flight queries get
        :class:`EngineStopped`; the tail stops advancing; the router's
        health check turns negative on its next probe."""
        self.registry.inc("fleet.crashes")
        self.crashed = True
        await self.stop()

    @property
    def healthy(self) -> bool:
        return self._running and self.engine.is_running

    def names(self) -> List[str]:
        return sorted(self._tracked)

    def seq(self, name: str) -> int:
        return self._tracked[name].seq

    def fingerprint(self, name: str) -> str:
        return self._tracked[name].fp

    # -- serving -------------------------------------------------------
    async def query(self, name: str, mu: int, eps: float, *,
                    client: Optional[str] = None,
                    deadline_s: Optional[float] = None) -> FleetAnswer:
        """One global query against this replica's current version of
        ``name``; → :class:`FleetAnswer` (the fp/seq pair is resolved
        atomically here, so a concurrent tail swap gives this query
        entirely the old or entirely the new index)."""
        tr = self._route(name)
        res = await self.engine.query(mu, eps, fingerprint=tr.fp,
                                      client=client, deadline_s=deadline_s)
        return FleetAnswer(res, tr.fp, tr.seq, self.replica_id)

    async def query_seed(self, name: str, seed: int, mu: int, eps: float, *,
                         client: Optional[str] = None,
                         deadline_s: Optional[float] = None) -> FleetAnswer:
        tr = self._route(name)
        res = await self.engine.query_seed(seed, mu, eps, fingerprint=tr.fp,
                                           client=client,
                                           deadline_s=deadline_s)
        return FleetAnswer(res, tr.fp, tr.seq, self.replica_id)

    def _route(self, name: str) -> _Tracked:
        if not self.healthy:
            raise ReplicaUnavailable(
                f"replica {self.replica_id!r} is not serving")
        tr = self._tracked.get(name)
        if tr is None:
            raise KeyError(f"replica {self.replica_id!r} does not track "
                           f"index {name!r}")
        return tr

    # -- restore / resync ----------------------------------------------
    def _discover(self) -> None:
        """Pick up catalog names this replica is not tracking yet
        (indexes created after the fleet started included)."""
        for name in self.catalog.names():
            if name in self._tracked:
                continue
            try:
                self._restore(name)
            except Exception:  # noqa: BLE001 — a half-written first
                # snapshot is indistinguishable from one mid-commit;
                # leave it for the next poll instead of dying
                _log.exception("replica %s: restore of %r failed",
                               self.replica_id, name)

    def _restore(self, name: str) -> None:
        store = self.catalog.store(name)
        index, g, fp = store.load()
        seq = store.latest_version()
        old = self._tracked.get(name)
        self.engine.register(index, g, fingerprint=fp)
        self._tracked[name] = _Tracked(index=index, g=g, fp=fp, seq=seq)
        if old is not None and old.fp != fp and not self._fp_in_use(old.fp):
            self.engine.unregister(old.fp)

    def _fp_in_use(self, fp: str) -> bool:
        return any(t.fp == fp for t in self._tracked.values())

    async def _resync(self, name: str, stuck_seq: int) -> bool:
        """Recover from a damaged/pruned chain by jumping to the next
        snapshot. Only useful once the writer has published a snapshot
        *past* the stuck position — until then keep serving last-good."""
        store = self.catalog.store(name)
        latest = store.latest_version()
        if latest is None or latest <= stuck_seq:
            return False
        with self.tracer.span("fleet.resync", replica=self.replica_id,
                              index=name, at=stuck_seq, to=latest):
            # the O(m) snapshot read is disk work — off-loop, same as the
            # writer's compaction; the swap itself follows the standard
            # register → flip → drain → unregister discipline
            index, g, fp = await self.engine.run_offloaded(
                lambda: store.load(latest))
            old = self._tracked.get(name)
            self.engine.register(index, g, fingerprint=fp)
            self._tracked[name] = _Tracked(index=index, g=g, fp=fp,
                                           seq=latest)
            await self.engine.drain()
            if old is not None and old.fp != fp \
                    and not self._fp_in_use(old.fp):
                self.engine.unregister(old.fp)
        self.registry.inc("fleet.resyncs")
        return True

    # -- tailing -------------------------------------------------------
    async def _tail_loop(self) -> None:
        while self._running:
            if self.chaos is not None:
                if self.chaos.should_crash(self.replica_id):
                    # crash() awaits our own task's cancellation —
                    # detach it so the loop can die under us
                    asyncio.get_running_loop().create_task(self.crash())
                    return
                stall = self.chaos.stall_seconds(self.replica_id)
                if stall > 0:
                    self.registry.inc("fleet.stalls")
                    await asyncio.sleep(stall)
            try:
                self._discover()
                for name in list(self._tracked):
                    await self._tail_once(name)
            except asyncio.CancelledError:
                raise
            except Exception:  # noqa: BLE001 — the tail must survive
                # transient races with writer commits/prunes; the chain
                # is re-read from scratch next poll
                _log.exception("replica %s: tail iteration failed",
                               self.replica_id)
            await asyncio.sleep(self.poll_s)

    async def _tail_once(self, name: str) -> None:
        tr = self._tracked[name]
        store = self.catalog.store(name)
        log = DeltaLog(store.directory)
        pending = [s for s in log.sequences() if s > tr.seq]
        latest_snap = store.latest_version()
        target = max(pending, default=tr.seq)
        if latest_snap is not None:
            target = max(target, latest_snap)
        if (latest_snap is not None and latest_snap > tr.seq
                and (not pending or pending[0] != tr.seq + 1)):
            # the chain cannot carry us forward from here — compaction
            # pruned past us (possibly around a corrupt entry we refused)
            # or there is nothing newer on it at all — but a newer
            # snapshot can: this is the recovery exit for every stuck
            # state, and it is reached without ever touching the
            # writer-owned chain
            await self._resync(name, tr.seq)
            tr = self._tracked[name]
            pending = [s for s in log.sequences() if s > tr.seq]
        for s in pending:
            if not self._delivered(name, s):
                break  # chaos: entry not visible to this replica yet
            if s != self._tracked[name].seq + 1:
                # gap: compaction pruned entries we never saw — the only
                # way forward is the snapshot that covered them
                if not await self._resync(name, self._tracked[name].seq):
                    break
                if self._tracked[name].seq + 1 != s:
                    break  # resync jumped past (or not yet far enough)
            if not log.verify(s):
                # torn/corrupt bytes. NOT ours to truncate (the writer
                # owns the chain; for all we know this is an append still
                # racing to completion) — hold position, serve last-good,
                # and take the snapshot exit once one covers the damage.
                self.registry.inc("fleet.corrupt_entries")
                await self._resync(name, self._tracked[name].seq)
                break
            if not await self._replay(name, s):
                break
        tr = self._tracked[name]
        self.registry.gauge("fleet.staleness_seq", "max").set(
            max(target - tr.seq, 0))

    def _delivered(self, name: str, s: int) -> bool:
        if self.chaos is None:
            return True
        delay = self.chaos.delivery_delay(self.replica_id, s)
        if delay <= 0:
            return True
        key = (name, s)
        first = self._first_seen.setdefault(key, time.monotonic())
        if time.monotonic() - first < delay:
            return False
        self._first_seen.pop(key, None)
        self.registry.inc("fleet.delayed_entries")
        return True

    async def _replay(self, name: str, s: int) -> bool:
        """Replay one verified chain entry and hot-swap; → advanced?"""
        tr = self._tracked[name]
        log = DeltaLog(store_dir(self.catalog, name))

        def _absorb():
            # entry load + apply + fingerprint are all worker-side: the
            # collector keeps flushing query batches against the current
            # version for the whole replay (chaos slow-replay sleeps here
            # too, stalling the tail, never the serve path)
            delta, want = log.load(s)
            if self.chaos is not None:
                extra = self.chaos.replay_delay(self.replica_id, s)
                if extra > 0:
                    time.sleep(extra)
            new_index, new_g, _info = apply_delta(tr.index, tr.g, delta,
                                                  self.measure)
            return new_index, new_g, index_fingerprint(new_index, new_g), want

        with self.tracer.span("fleet.replay", replica=self.replica_id,
                              index=name, seq=s) as sp:
            try:
                new_index, new_g, new_fp, want_fp = \
                    await self.engine.run_offloaded(_absorb)
            except asyncio.CancelledError:
                raise
            except Exception:  # noqa: BLE001
                # an entry that passed verify() can still fail to *load*
                # semantically (e.g. a scribbled fingerprint leaf that no
                # longer decodes). Same posture as torn bytes: count it,
                # hold last-good, exit via the next covering snapshot —
                # retrying the same entry forever would be a livelock.
                self.registry.inc("fleet.corrupt_entries")
                sp.set(corrupt=True)
                _log.exception(
                    "replica %s: entry %d of %r failed to load/replay",
                    self.replica_id, s, name)
                await self._resync(name, tr.seq)
                return False
            if new_fp != want_fp:
                # the entry *loaded* but does not reproduce the writer's
                # bits (scribbled payload, or a divergent replica state).
                # Divergent bits must never swap in — hold last-good and
                # wait for a snapshot past the damage.
                self.registry.inc("fleet.fingerprint_mismatches")
                sp.set(diverged=True)
                _log.error(
                    "replica %s: entry %d of %r replayed to %s… but chain "
                    "recorded %s…; holding at seq %d", self.replica_id, s,
                    name, new_fp[:12], want_fp[:12], tr.seq)
                await self._resync(name, tr.seq)
                return False
            self.registry.inc("fleet.replays")
            if new_fp != tr.fp:
                self.engine.register(new_index, new_g, fingerprint=new_fp)
                self._tracked[name] = _Tracked(index=new_index, g=new_g,
                                               fp=new_fp, seq=s)
                await self.engine.drain()
                if not self._fp_in_use(tr.fp):
                    self.engine.unregister(tr.fp)
                self.registry.inc("fleet.swaps")
            else:
                self._tracked[name] = dataclasses.replace(tr, seq=s)
        return True


def store_dir(catalog: IndexCatalog, name: str) -> str:
    return catalog.store(name).directory


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Retry/hedging policy for one :class:`FleetRouter`."""

    vnodes: int = 32            # ring points per replica
    timeout_s: float = 2.0      # per attempt (primary + its hedge)
    retries: int = 3            # replica attempts per request
    hedge_after_s: Optional[float] = 0.25  # None disables hedging
    backoff_s: float = 0.005    # base of the jittered exponential backoff
    backoff_max_s: float = 0.1
    seed: int = 0               # jitter rng


class FleetRouter:
    """Front door over N replicas: consistent hashing, health checks,
    timeouts, jittered retry, hedged failover.

    Routing key is the **index name** — stable across versions, unlike
    the content fingerprint that changes every delta — so one index's
    traffic sticks to one replica's caches while siblings stay warm only
    through spill/hedge traffic (exactly the replicas that serve it on
    failover).
    """

    def __init__(self, replicas: Sequence[ReadReplica], *,
                 config: RouterConfig = RouterConfig(),
                 registry: Optional[MetricsRegistry] = None):
        if not replicas:
            raise ValueError("a router needs at least one replica")
        self.replicas = list(replicas)
        self.cfg = config
        self.registry = registry if registry is not None else MetricsRegistry()
        self._rng = random.Random(config.seed)
        self._ring: List[Tuple[int, ReadReplica]] = []
        for rep in self.replicas:
            for v in range(config.vnodes):
                point = int.from_bytes(hashlib.sha256(
                    f"{rep.replica_id}#{v}".encode()).digest()[:8], "big")
                self._ring.append((point, rep))
        self._ring.sort(key=lambda pr: pr[0])
        self._points = [p for p, _ in self._ring]

    # -- placement -----------------------------------------------------
    def route(self, key: str) -> List[ReadReplica]:
        """Distinct replicas in ring order starting at ``key``'s point —
        element 0 is the primary, the rest the failover/hedge order."""
        point = int.from_bytes(
            hashlib.sha256(key.encode()).digest()[:8], "big")
        start = bisect.bisect_right(self._points, point) % len(self._ring)
        order: List[ReadReplica] = []
        for i in range(len(self._ring)):
            rep = self._ring[(start + i) % len(self._ring)][1]
            if rep not in order:
                order.append(rep)
                if len(order) == len(self.replicas):
                    break
        return order

    def healthy(self) -> List[ReadReplica]:
        alive = [r for r in self.replicas if r.healthy]
        self.registry.gauge("fleet.replicas_healthy", "max").set(len(alive))
        return alive

    # -- request path ---------------------------------------------------
    async def query(self, name: str, mu: int, eps: float, *,
                    client: Optional[str] = None,
                    deadline_s: Optional[float] = None) -> FleetAnswer:
        return await self._request(
            name, lambda rep: rep.query(name, mu, eps, client=client,
                                        deadline_s=deadline_s))

    async def query_seed(self, name: str, seed: int, mu: int, eps: float, *,
                         client: Optional[str] = None,
                         deadline_s: Optional[float] = None) -> FleetAnswer:
        return await self._request(
            name, lambda rep: rep.query_seed(name, seed, mu, eps,
                                             client=client,
                                             deadline_s=deadline_s))

    async def _request(self, key: str, call) -> FleetAnswer:
        self.registry.inc("fleet.requests")
        routed = self.route(key)
        order = [r for r in routed if r.healthy]
        if order and routed[0] is not order[0]:
            # the routed owner failed its health check — serving from a
            # ring sibling is a failover even though no call was wasted
            self.registry.inc("fleet.failovers")
        self.registry.gauge("fleet.replicas_healthy", "max").set(len(order))
        if not order:
            self.registry.inc("fleet.exhausted")
            raise FleetExhausted(f"no healthy replica for {key!r}",
                                 attempts=0)
        last: Optional[Exception] = None
        attempts = 0
        for i in range(min(self.cfg.retries, len(order))):
            primary = order[i]
            hedge = order[(i + 1) % len(order)] if len(order) > 1 else None
            attempts += 1
            try:
                return await self._attempt(call, primary, hedge)
            except Overloaded as e:
                # admission did its job — spill once to each sibling, but
                # an all-shed fleet surfaces the shed (with retry_after),
                # never converts it into a retry storm
                self.registry.inc("fleet.overload_spills")
                last = e
                continue
            except (EngineStopped, ReplicaUnavailable,
                    asyncio.TimeoutError, KeyError) as e:
                # KeyError: a replica that has not discovered a freshly
                # created name yet — retryable on a sibling exactly like
                # a crashed one
                self.registry.inc("fleet.failovers")
                last = e
            if i + 1 < min(self.cfg.retries, len(order)):
                self.registry.inc("fleet.retries")
                await asyncio.sleep(self._backoff(i))
        if isinstance(last, Overloaded):
            raise last
        self.registry.inc("fleet.exhausted")
        raise FleetExhausted(
            f"no replica answered {key!r} after {attempts} attempts "
            f"(last: {last!r})", attempts=attempts, last=last)

    def _backoff(self, attempt: int) -> float:
        """Full-jitter exponential backoff: uniform in (0, base·2^n],
        capped — retries from many concurrent callers decorrelate instead
        of re-arriving in lockstep at the next replica."""
        ceil = min(self.cfg.backoff_s * (2 ** attempt),
                   self.cfg.backoff_max_s)
        return self._rng.uniform(0, ceil)

    async def _attempt(self, call, primary: ReadReplica,
                       hedge: Optional[ReadReplica]) -> FleetAnswer:
        """One timed attempt: primary, plus a hedged sibling raced in if
        the primary is still pending after ``hedge_after_s``. First
        success wins and cancels the loser; both failing raises the
        primary's error (it is the routed owner — its failure decides
        the failover)."""
        loop = asyncio.get_running_loop()
        deadline = loop.time() + self.cfg.timeout_s
        t_primary = asyncio.ensure_future(call(primary))
        tasks = [t_primary]
        hedged = False
        try:
            while True:
                timeout = deadline - loop.time()
                if (not hedged and hedge is not None
                        and self.cfg.hedge_after_s is not None):
                    timeout = min(timeout, self.cfg.hedge_after_s)
                if timeout <= 0:
                    raise asyncio.TimeoutError(
                        f"attempt on {primary.replica_id!r} timed out")
                done, pending = await asyncio.wait(
                    tasks, timeout=timeout,
                    return_when=asyncio.FIRST_COMPLETED)
                for t in done:
                    if not t.cancelled() and t.exception() is None:
                        if hedged and t is not t_primary:
                            self.registry.inc("fleet.hedge_wins")
                        return t.result()
                if done:
                    tasks = list(pending)
                    if not tasks:
                        # every racer failed; the primary's error drives
                        # the router's failover decision
                        raise t_primary.exception() or next(
                            iter(done)).exception()
                    continue
                # timeout fired with nothing done: hedge once, then let
                # the overall deadline govern
                if (not hedged and hedge is not None
                        and self.cfg.hedge_after_s is not None):
                    hedged = True
                    self.registry.inc("fleet.hedges")
                    tasks.append(asyncio.ensure_future(call(hedge)))
        finally:
            for t in tasks:
                if not t.done():
                    t.cancel()


class Fleet:
    """One writer + N read replicas + a router over one on-disk catalog.

    The single-process model is faithful to the protocol because the
    replicas genuinely share nothing with the writer but the directory
    tree: every byte a replica serves went through a committed snapshot
    or a verified chain entry. ``chaos`` (a shared seeded
    :class:`ChaosPolicy`) arms fault injection on both sides.
    """

    def __init__(self, root: str, *, n_replicas: int = 2,
                 writer_config: EngineConfig = EngineConfig(),
                 replica_config: Optional[EngineConfig] = None,
                 router_config: RouterConfig = RouterConfig(),
                 measure: str = "cosine",
                 compact_every: int = 8,
                 poll_s: float = 0.02,
                 chaos: Optional[ChaosPolicy] = None):
        if n_replicas < 1:
            raise ValueError("a fleet needs at least one replica")
        self.root = root
        self.chaos = chaos
        self.writer = LiveIndexService(root, config=writer_config,
                                       measure=measure,
                                       compact_every=compact_every)
        self.replicas = [
            ReadReplica(f"replica-{i}", root,
                        config=(replica_config if replica_config is not None
                                else writer_config),
                        measure=measure, poll_s=poll_s, chaos=chaos)
            for i in range(n_replicas)]
        self.router = FleetRouter(self.replicas, config=router_config)
        self.registry = self.router.registry

    # -- lifecycle -----------------------------------------------------
    async def __aenter__(self) -> "Fleet":
        await self.writer.__aenter__()
        for rep in self.replicas:
            await rep.start()
        return self

    async def __aexit__(self, *exc) -> None:
        for rep in self.replicas:
            await rep.stop()
        await self.writer.__aexit__(*exc)

    # -- write path (delegates to the writer) ---------------------------
    def create(self, name: str, g: CSRGraph, **kw) -> str:
        return self.writer.create(name, g, **kw)

    async def apply(self, name: str, delta: EdgeDelta):
        """Apply one delta through the writer; the committed chain entry
        is the replication event the replicas will pick up. With chaos
        armed, the freshly committed entry may be corrupted *here* —
        after commit, before any replica's next poll — which is the
        worst-ordering case the resync path exists for."""
        info = await self.writer.apply(name, delta)
        if self.chaos is not None:
            seq = self.writer._live[name].seq
            damaged = self.chaos.maybe_corrupt(
                DeltaLog(store_dir(self.writer.catalog, name)).directory,
                seq)
            if damaged:
                self.registry.inc("fleet.injected_corruptions")
                _log.warning("chaos: corrupted chain entry %d (%s)",
                             seq, damaged)
        return info

    def target_seq(self, name: str) -> int:
        """The seq replicas are converging toward (writer's applied seq)."""
        return self.writer._live[name].seq

    # -- read path ------------------------------------------------------
    async def query(self, name: str, mu: int, eps: float, **kw
                    ) -> FleetAnswer:
        return await self.router.query(name, mu, eps, **kw)

    async def query_seed(self, name: str, seed: int, mu: int, eps: float,
                         **kw) -> FleetAnswer:
        return await self.router.query_seed(name, seed, mu, eps, **kw)

    async def converged(self, name: str, *, timeout_s: float = 10.0,
                        replicas: Optional[Sequence[ReadReplica]] = None
                        ) -> bool:
        """Wait until every (healthy) replica has replayed up to the
        writer's seq for ``name``; → False on timeout (a chaos-stalled
        fleet may legitimately never converge within the window)."""
        target = self.target_seq(name)
        deadline = time.monotonic() + timeout_s
        pool = self.replicas if replicas is None else list(replicas)
        while time.monotonic() < deadline:
            live = [r for r in pool if r.healthy]
            if live and all(name in r._tracked and r.seq(name) >= target
                            for r in live):
                return True
            await asyncio.sleep(0.01)
        return False

    # -- observability ---------------------------------------------------
    def metrics_snapshot(self) -> dict:
        """One merged view over writer + every replica + router: counters
        sum (fleet totals), histograms concatenate, and max-mode gauges —
        the staleness watermark — keep the worst replica visible instead
        of averaging it away."""
        merged = MetricsRegistry()
        merged.merge_snapshot(self.registry.snapshot())
        merged.merge_snapshot(self.writer.engine.registry.snapshot())
        for rep in self.replicas:
            merged.merge_snapshot(rep.registry.snapshot())
        return merged.snapshot()
