"""Resident index construction + update + query process.

``LiveIndexService`` completes the serve story for *evolving* graphs: one
process owns the named indexes (an :class:`~repro.serve.store.IndexCatalog`
on disk), serves (μ, ε) queries through the micro-batching router, and
applies :class:`~repro.core.update.EdgeDelta` batches between engine
flushes — no cold rebuilds, no process restarts.

Approximate-first ingest (paper §5–§6.3): ``register_approximate`` builds
an LSH-sketched index (cheap — sketches + the §6.3 degree-heuristic exact
pass) and serves it *immediately*; ``refine`` then builds the exact index
on the engine's offload worker while the approximate one keeps answering,
and hot-swaps it in behind the same ``drain()`` barrier deltas use.
Every index carries an :class:`~repro.core.approx.IndexProvenance` tag
(exact vs approx + sketch params) that persists with snapshots and is
queryable per route, so a crash before the refine swap restores the
*approximate* index — the service degrades to provably-close answers,
never to downtime — and a crash after it restores exact.

Update protocol (per named index):

  1. ``apply_delta`` maintains the index incrementally (bit-identical to a
     rebuild — see ``repro.core.update``); the old (index, graph) pair is
     untouched. The apply runs **off the event loop** (the engine's
     single-worker ``offload_executor()``), so the collector keeps
     flushing query batches while the delta is being absorbed — apply
     latency never appears in query tail latency.
  2. The delta is appended to the on-disk chain
     (:class:`~repro.serve.store.DeltaLog`) *before* the swap — a crash
     after the append replays the delta on restart; a crash during it
     leaves an ignorable ``.tmp`` and the previous version restorable.
     (The append happens in the same worker job as the apply.)
  3. The new index registers with the engine under its new content
     fingerprint (in sharded mode, via ``ShardedQueryPlan.refresh`` —
     also run in the worker — so only mutated partitions of the O(m)
     operands are re-placed on device), then the name's route flips in
     one assignment *on the loop* — queries that already resolved the old
     fingerprint keep hitting the old index, new queries hit the new one,
     and *nobody* sees a mix.
  4. ``engine.drain()`` barriers until every in-flight request has been
     answered, then the old fingerprint unregisters — which also drops
     exactly its cache partition (sibling indexes keep their hit rates;
     that is the whole point of fingerprint-keyed invalidation).
  5. Recently observed (μ, ε) settings are re-issued against the new
     index, which re-warms their (μ±1, ε±δ) neighborhood through the
     engine's padding-slot warming.
  6. Every ``compact_every`` deltas the live index is saved as a full
     snapshot (version = delta seq, written in the offload worker — the
     O(m) disk write never stalls the collector either) and the covered
     chain prefix is pruned; restore = latest snapshot + replay of the
     strictly-newer tail, fingerprint-verified step by step.

Every stage of that protocol is traced through the engine's
``repro.obs`` tracer (span == same-named latency histogram): a
``live.apply`` root span wraps each delta; inside it the worker records
``live.apply_delta`` (with the ``UpdateInfo`` work counters — frontier
size, ``n_sim_groups``, ``n_plan_rows``, ``n_plan_classes`` — as span
attributes), ``live.fingerprint``, ``live.shard_refresh``, and
``live.log_append``; back on the loop ``live.swap`` (register + route
flip), ``live.drain`` (the barrier), ``live.rewarm``, and
``live.compact`` when compaction triggers. The engine's
``engine.offload_depth`` gauge exposes how many maintenance jobs are
queued behind the single worker. This is how the PR 5 claim — "apply
latency never shows in query tails" — became a measurement: apply spans
record nonzero durations while the concurrent ``engine.e2e`` histogram
keeps filling (asserted in tests/test_serve_obs.py).
"""
from __future__ import annotations

import asyncio
import dataclasses
import logging
from collections import OrderedDict
from typing import Dict, List, Optional

from repro.backend.policy import ExecutionPolicy
from repro.backend.profile import DEFAULT_PROFILE, AutotuneProfile
from repro.core.approx import (EXACT_PROVENANCE, ApproxIndexBuilder,
                               ApproxParams, IndexProvenance)
from repro.core.graph import CSRGraph
from repro.core.index import ScanIndex, build_index
from repro.core.query import ClusterResult
from repro.core.update import EdgeDelta, UpdateInfo, apply_delta
from repro.serve.cache import quantize_eps
from repro.serve.engine import EngineConfig, MicroBatchEngine
from repro.serve.store import DeltaLog, IndexCatalog, index_fingerprint


def _log_abandoned_apply(task) -> None:
    """Surface the outcome of an apply whose caller cancelled mid-commit."""
    if task.cancelled():
        return
    exc = task.exception()
    if exc is not None:
        logging.getLogger(__name__).error(
            "abandoned live-index apply failed: %r", exc)


@dataclasses.dataclass(frozen=True)
class _Live:
    """One name's resident state (replaced wholesale on every swap)."""

    index: ScanIndex
    g: CSRGraph
    fp: str
    seq: int            # last applied delta sequence number
    snapshot_seq: int   # delta seq covered by the newest full snapshot
    provenance: IndexProvenance = EXACT_PROVENANCE
    # the autotune profile the newest snapshot was persisted with; when it
    # differs from the serving policy's profile, profile_mismatch flags it
    # in status() — serving continues on the policy thresholds (lane
    # choice never moves index bits), never silently retunes
    profile: AutotuneProfile = DEFAULT_PROFILE
    profile_mismatch: bool = False


class LiveIndexService:
    """Named live indexes behind one micro-batching engine.

    ``measure`` is the structural-similarity measure every index in this
    service is built and maintained with.
    """

    def __init__(self, root: str, *,
                 config: EngineConfig = EngineConfig(),
                 measure: str = "cosine",
                 compact_every: int = 8,
                 keep_snapshots: int = 3,
                 rewarm_recent: int = 4,
                 policy: Optional[ExecutionPolicy] = None):
        self.catalog = IndexCatalog(root, keep=keep_snapshots)
        self.engine = MicroBatchEngine(config=config, policy=policy)
        self.measure = measure
        self.compact_every = compact_every
        self.rewarm_recent = rewarm_recent
        self._live: Dict[str, _Live] = {}
        self._observed: Dict[str, OrderedDict] = {}
        self._locks: Dict[str, asyncio.Lock] = {}
        self._pending: set = set()   # in-flight (possibly abandoned) applies

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    async def __aenter__(self) -> "LiveIndexService":
        await self.engine.start()
        return self

    async def __aexit__(self, *exc) -> None:
        # a cancellation-shielded apply may have outlived its caller; its
        # swap continuation must finish *before* the engine stops, or it
        # would register into (and re-warm against) a dead router
        while self._pending:
            await asyncio.gather(*tuple(self._pending),
                                 return_exceptions=True)
        await self.engine.stop()

    def names(self) -> List[str]:
        return sorted(self._live)

    def fingerprint(self, name: str) -> str:
        return self._live[name].fp

    def index(self, name: str) -> ScanIndex:
        """The currently live index for ``name``."""
        return self._live[name].index

    def graph(self, name: str) -> CSRGraph:
        """The currently live graph for ``name``."""
        return self._live[name].g

    def status(self, name: str) -> dict:
        """Version/routing state for ``name`` (fp, seq, snapshot_seq,
        provenance) plus the ``backend`` execution block: platform,
        forced lane, the lane each op resolves to right now, the active
        autotune profile — and, when the stored snapshot was persisted
        under a *different* profile, ``profile_mismatch`` with the stored
        thresholds (serving stays on the policy's; bit-identity across
        lanes makes that safe, and we never silently retune)."""
        live = self._live[name]
        backend = self.engine.policy.describe()
        backend["profile_mismatch"] = live.profile_mismatch
        if live.profile_mismatch:
            backend["stored_profile"] = dataclasses.asdict(live.profile)
        return {"fingerprint": live.fp, "seq": live.seq,
                "snapshot_seq": live.snapshot_seq,
                "n": live.g.n, "m": live.g.m,
                "provenance": live.provenance.describe(),
                "approx": live.provenance.is_approx,
                "backend": backend}

    def provenance(self, name: str) -> IndexProvenance:
        """How ``name``'s currently served similarities were produced."""
        return self._live[name].provenance

    def stats(self) -> dict:
        out = self.engine.batch_stats()
        out["live_indexes"] = len(self._live)
        out["live_seqs"] = {n: l.seq for n, l in self._live.items()}
        return out

    # ------------------------------------------------------------------
    # index creation / restore
    # ------------------------------------------------------------------
    def create(self, name: str, g: CSRGraph, *,
               index: Optional[ScanIndex] = None,
               provenance: Optional[IndexProvenance] = None) -> str:
        """Build (or adopt) an index for ``name``, persist snapshot v0,
        register it with the engine; → fingerprint. ``provenance`` tags an
        adopted index (default: exact)."""
        if name in self._live:
            raise ValueError(f"index {name!r} already live")
        if index is None:
            index = build_index(g, self.measure)
        if provenance is None:
            provenance = EXACT_PROVENANCE
        fp = index_fingerprint(index, g)
        profile = self.engine.policy.profile
        self.catalog.store(name).save(index, g, version=0,
                                      measure=self.measure,
                                      provenance=provenance,
                                      profile=profile)
        self.engine.register(index, g, fingerprint=fp,
                             provenance=provenance)
        self._live[name] = _Live(index=index, g=g, fp=fp, seq=0,
                                 snapshot_seq=0, provenance=provenance,
                                 profile=profile)
        return fp

    def register_approximate(self, name: str, g: CSRGraph, *,
                             params: ApproxParams = ApproxParams()) -> str:
        """Approximate-first ingest: build an LSH-sketched index for
        ``name`` (fast — the paper's §5/§6.3 construction), persist it as
        snapshot v0 *with its approx provenance*, and start serving from
        it immediately; → fingerprint.

        The index answers queries with σ̂ instead of σ (provably close —
        Theorems 5.2/5.3; exact on every §6.3 low-degree edge). Call
        :meth:`refine` afterwards to build the exact index in the
        background and hot-swap it in. A crash before the refine swap
        restores this approximate index from the store (its provenance
        travels with the snapshot), so the service degrades to
        approximate answers, never to downtime.
        """
        if name in self._live:
            raise ValueError(f"index {name!r} already live")
        builder = ApproxIndexBuilder(self.measure, params,
                                     policy=self.engine.policy)
        index, provenance = builder.build(g, tracer=self.engine.tracer)
        return self.create(name, g, index=index, provenance=provenance)

    def load(self, name: str) -> str:
        """Restore ``name`` from disk: latest snapshot + delta-chain tail
        (each replayed step fingerprint-verified); → fingerprint."""
        if name in self._live:
            raise ValueError(f"index {name!r} already live")
        store = self.catalog.store(name)
        index, g, fp = store.load()
        provenance = store.provenance()
        stored_profile = store.profile()
        profile_mismatch = stored_profile != self.engine.policy.profile
        if profile_mismatch:
            # surfaced in status() rather than retuned: thresholds only
            # steer lane choice, and every lane is bit-identical, so the
            # restored index serves correctly on the policy's profile
            logging.getLogger(__name__).warning(
                "index %r: snapshot autotune profile differs from the "
                "serving policy's; serving on policy thresholds", name)
        stored_measure = store.measure()
        if stored_measure is not None and stored_measure != self.measure:
            raise ValueError(
                f"index {name!r} was built with measure "
                f"{stored_measure!r}; this service maintains "
                f"{self.measure!r} — frontier σ recomputes would silently "
                "mix measures")
        snap_seq = store.latest_version()
        log = DeltaLog(store.directory)
        # a crash mid-append can leave a renamed-but-torn tail entry
        # (pre-durability writers; torn bytes). This service *owns* the
        # chain, so recovery truncates it and replay lands on the last
        # intact entry — the delta it described was never served anyway
        torn = log.truncate_torn_tail()
        if torn:
            logging.getLogger(__name__).warning(
                "index %r: truncated torn delta-chain tail %s", name, torn)
        seq = snap_seq
        for s in log.sequences():
            if s <= snap_seq:
                continue
            if s != seq + 1:
                raise ValueError(
                    f"delta chain for {name!r} has a gap: snapshot at "
                    f"{snap_seq}, next delta {s} after {seq}")
            delta, want_fp = log.load(s)
            index, g, _ = apply_delta(index, g, delta, self.measure)
            fp = index_fingerprint(index, g)
            if fp != want_fp:
                raise ValueError(
                    f"delta {s} for {name!r} replayed to fingerprint "
                    f"{fp[:12]}… but the chain recorded {want_fp[:12]}…")
            seq = s
        self.engine.register(index, g, fingerprint=fp,
                             provenance=provenance)
        self._live[name] = _Live(index=index, g=g, fp=fp, seq=seq,
                                 snapshot_seq=snap_seq,
                                 provenance=provenance,
                                 profile=stored_profile,
                                 profile_mismatch=profile_mismatch)
        return fp

    def load_all(self) -> List[str]:
        for name in self.catalog.names():
            if name not in self._live:
                self.load(name)
        return self.names()

    # ------------------------------------------------------------------
    # queries
    # ------------------------------------------------------------------
    async def query(self, name: str, mu: int, eps: float) -> ClusterResult:
        """One SCAN query by *name*; the route resolves atomically here,
        so a concurrent hot-swap gives this query entirely the old or
        entirely the new index."""
        live = self._live[name]
        self._note(name, mu, eps)
        return await self.engine.query(mu, eps, fingerprint=live.fp)

    async def query_seed(self, name: str, seed: int, mu: int, eps: float):
        """One seed-set (local) query by *name*: the cluster containing
        ``seed`` at (μ, ε) — a :class:`~repro.core.local.SeedResult`,
        bit-identical to the seed's row of the full :meth:`query` answer
        against the same live index."""
        live = self._live[name]
        return await self.engine.query_seed(seed, mu, eps,
                                            fingerprint=live.fp)

    def _note(self, name: str, mu: int, eps: float) -> None:
        obs = self._observed.setdefault(name, OrderedDict())
        key = (int(mu), quantize_eps(eps, self.engine.cfg.eps_quantum))
        obs.pop(key, None)
        obs[key] = True
        while len(obs) > self.rewarm_recent:
            obs.popitem(last=False)

    # ------------------------------------------------------------------
    # updates
    # ------------------------------------------------------------------
    async def apply(self, name: str, delta: EdgeDelta) -> UpdateInfo:
        """Apply one edit batch to ``name`` and hot-swap the result in.

        The expensive, loop-irrelevant work — ``apply_delta``, the content
        fingerprint, the crash-safe ``DeltaLog`` append, and (in sharded
        mode) the mutated-partition-only ``ShardedQueryPlan.refresh`` —
        runs in the engine's offload worker, so the collector keeps
        flushing query batches against the *old* index for the whole
        duration. Only the swap itself (register, route flip, drain,
        unregister, re-warm) runs on the event loop.

        An apply is a *commit*: the whole sequence is shielded from
        caller cancellation (e.g. ``asyncio.wait_for`` timeouts), because
        the executor job cannot be interrupted once launched — abandoning
        the coroutine mid-way would leave the on-disk delta chain one
        committed entry ahead of the served in-memory state (and a
        successor apply would silently reuse its sequence number). The
        caller still observes ``CancelledError``; the swap completes in
        the background regardless (``__aexit__`` waits for abandoned
        applies before stopping the engine).
        """
        task = asyncio.ensure_future(self._apply_locked(name, delta))
        self._pending.add(task)
        task.add_done_callback(self._pending.discard)
        try:
            return await asyncio.shield(task)
        except asyncio.CancelledError:
            # the caller walked away from a commit in flight — its
            # eventual outcome must not vanish (a failure would otherwise
            # surface only as a gc-time 'never retrieved' warning);
            # callers that kept awaiting get the exception via the shield
            # and are responsible for it themselves
            task.add_done_callback(_log_abandoned_apply)
            raise

    async def _apply_locked(self, name: str, delta: EdgeDelta) -> UpdateInfo:
        lock = self._locks.setdefault(name, asyncio.Lock())
        tracer = self.engine.tracer
        async with lock:
            live = self._live[name]
            seq = live.seq + 1
            log_dir = self.catalog.store(name).directory

            def _absorb():
                # worker-side spans nest under live.apply: run_offloaded
                # ships the caller's contextvars into the worker thread
                with tracer.span("live.apply_delta", index=name,
                                 seq=seq) as sp:
                    new_index, new_g, info = apply_delta(
                        live.index, live.g, delta, self.measure)
                    sp.set(n_inserted=info.n_inserted,
                           n_deleted=info.n_deleted,
                           n_touched=info.n_touched,
                           n_frontier=info.n_frontier,
                           n_affected_rows=info.n_affected_rows,
                           n_sim_groups=info.n_sim_groups,
                           n_plan_rows=info.n_plan_rows,
                           n_plan_classes=info.n_plan_classes)
                with tracer.span("live.fingerprint", index=name):
                    new_fp = index_fingerprint(new_index, new_g)
                shard_plan = None
                # look the predecessor plan up *here*, not before the
                # worker started: the collector may lazily build it for
                # the old fingerprint while this apply is in flight
                old_plan = self.engine._shard_plans.get(live.fp)
                if old_plan is not None and new_fp != live.fp:
                    # re-shard only the mutated partitions; the old plan
                    # stays intact for in-flight traffic until the drain
                    with tracer.span("live.shard_refresh",
                                     index=name) as sp:
                        shard_plan = old_plan.refresh(new_index, new_g)
                        sp.set(**shard_plan.last_refresh)
                # commit to the chain *last*: a failure anywhere above
                # must not leave the on-disk log ahead of served state
                # (the next apply would reuse this sequence number)
                with tracer.span("live.log_append", index=name, seq=seq):
                    DeltaLog(log_dir).append(seq, delta, new_fp)
                return new_index, new_g, info, new_fp, shard_plan

            with tracer.span("live.apply", index=name, seq=seq) as apply_sp:
                new_index, new_g, info, new_fp, shard_plan = \
                    await self.engine.run_offloaded(_absorb)
                apply_sp.set(swapped=new_fp != live.fp,
                             n_frontier=info.n_frontier)

                if new_fp != live.fp:
                    with tracer.span("live.swap", index=name):
                        # provenance carries across deltas: frontier σ is
                        # recomputed exactly, but untouched edges keep
                        # their sketched σ̂ — the index stays approximate
                        # until refine() replaces it wholesale
                        self.engine.register(new_index, new_g,
                                             fingerprint=new_fp,
                                             shard_plan=shard_plan,
                                             provenance=live.provenance)
                        # seed-cache frontier invalidation: entries whose
                        # seed *and* members all avoid the delta's stale
                        # set are bit-identical under the new index —
                        # carry them to the new fingerprint instead of
                        # recomputing; the rest are dropped here (and the
                        # old partition's remainder goes with the
                        # unregister below)
                        kept, dropped = self.engine.seed_cache.migrate(
                            live.fp, new_fp, info.stale_mask(new_g.n))
                        self.engine.registry.inc(
                            "live.seed_entries_migrated", kept)
                        self.engine.registry.inc(
                            "live.seed_entries_dropped", dropped)
                        self._live[name] = dataclasses.replace(
                            live, index=new_index, g=new_g, fp=new_fp,
                            seq=seq)
                    with tracer.span("live.drain", index=name):
                        await self.engine.drain()
                    if live.fp not in {l.fp for l in self._live.values()}:
                        self.engine.unregister(live.fp)
                    with tracer.span("live.rewarm", index=name):
                        await self._rewarm(name)
                else:
                    self._live[name] = dataclasses.replace(
                        live, index=new_index, g=new_g, fp=new_fp, seq=seq)
                if seq - self._live[name].snapshot_seq >= self.compact_every:
                    # the O(m) snapshot write is disk work on an immutable
                    # (index, graph) pair — it belongs in the worker too,
                    # not on the loop stalling the collector
                    def _compact():
                        with tracer.span("live.compact", index=name):
                            self.compact(name)
                    await self.engine.run_offloaded(_compact)
            return info

    # ------------------------------------------------------------------
    # background exact refinement (approximate-first lifecycle)
    # ------------------------------------------------------------------
    async def refine(self, name: str) -> str:
        """Replace ``name``'s approximate index with the exact build, off
        the event loop; → the fingerprint served afterwards.

        The exact ``build_index`` (the expensive part — it is exactly the
        work approximate-first ingest deferred) runs in the engine's
        single-worker ``offload_executor()``, so the collector keeps
        answering queries from the approximate index for the whole build.
        The swap then follows the same protocol as a delta hot-swap:
        register the exact index under its new fingerprint, flip the route
        in one assignment on the loop, ``drain()`` until every in-flight
        request has answered (old or new, never a mix), unregister the
        approximate fingerprint — which drops exactly its cache partition —
        and re-warm observed traffic. Finally the exact index is persisted
        as a full snapshot (off-loop), so a restart serves exact without
        re-refining.

        Failure is graceful by construction: the approximate index is not
        touched until the exact build has fully succeeded, so an exception
        in the worker leaves it serving (counted in the
        ``live.refine_failures`` registry counter) and the caller may
        retry. Refines serialize with :meth:`apply` on the per-name lock —
        a delta landing mid-build would otherwise be silently discarded by
        the swap.

        Refining an already-exact index is a no-op returning the current
        fingerprint.
        """
        lock = self._locks.setdefault(name, asyncio.Lock())
        tracer = self.engine.tracer
        async with lock:
            live = self._live[name]
            if not live.provenance.is_approx:
                return live.fp
            seq = live.seq + 1

            def _build_exact():
                with tracer.span("live.refine_build", index=name,
                                 n=live.g.n, m=live.g.m):
                    new_index = build_index(live.g, self.measure)
                with tracer.span("live.fingerprint", index=name):
                    new_fp = index_fingerprint(new_index, live.g)
                shard_plan = None
                old_plan = self.engine._shard_plans.get(live.fp)
                if old_plan is not None and new_fp != live.fp:
                    with tracer.span("live.shard_refresh", index=name) as sp:
                        shard_plan = old_plan.refresh(new_index, live.g)
                        sp.set(**shard_plan.last_refresh)
                return new_index, new_fp, shard_plan

            with tracer.span("live.refine", index=name, seq=seq) as ref_sp:
                try:
                    new_index, new_fp, shard_plan = \
                        await self.engine.run_offloaded(_build_exact)
                except Exception:
                    # graceful degradation: the approximate index was never
                    # deregistered, so traffic keeps flowing against it
                    self.engine.registry.inc("live.refine_failures")
                    ref_sp.set(failed=True)
                    raise
                ref_sp.set(swapped=new_fp != live.fp)

                if new_fp != live.fp:
                    with tracer.span("live.swap", index=name):
                        self.engine.register(new_index, live.g,
                                             fingerprint=new_fp,
                                             shard_plan=shard_plan,
                                             provenance=EXACT_PROVENANCE)
                        self._live[name] = dataclasses.replace(
                            live, index=new_index, fp=new_fp, seq=seq,
                            provenance=EXACT_PROVENANCE)
                    with tracer.span("live.drain", index=name):
                        await self.engine.drain()
                    if live.fp not in {l.fp for l in self._live.values()}:
                        self.engine.unregister(live.fp)
                    with tracer.span("live.rewarm", index=name):
                        await self._rewarm(name)
                else:
                    # sketch happened to reproduce exact σ bit-for-bit
                    # (tiny graphs / pure-heuristic edges): relabel the
                    # provenance only. Re-register()ing the same
                    # fingerprint would take the hot-swap path and throw
                    # away the route's shard plan plus two cache
                    # partitions full of answers that are — by the very
                    # premise of this branch — still bit-identical.
                    self.engine.relabel(live.fp,
                                        provenance=EXACT_PROVENANCE)
                    self._live[name] = dataclasses.replace(
                        live, seq=seq, provenance=EXACT_PROVENANCE)

                # persist the refined index as a full snapshot covering
                # ``seq`` — version numbers stay monotone with delta seqs,
                # so restore = this snapshot + strictly-newer chain tail.
                # The O(m) disk write is worker work, not loop work.
                def _snapshot():
                    with tracer.span("live.compact", index=name):
                        self.compact(name)
                await self.engine.run_offloaded(_snapshot)
            return self._live[name].fp

    async def _rewarm(self, name: str) -> None:
        """Re-issue the recently observed settings against the fresh
        index — the engine's padding-slot warming re-warms their
        (μ±1, ε±δ) neighborhood as a side effect.

        Warming is best-effort by definition: it runs *after* the
        delta/refine has committed and the route has flipped, so a
        failed warm query must neither cancel its siblings nor
        propagate — the caller's apply succeeded, and raising here would
        make a completed commit look failed. Failures land in the
        ``live.rewarm_failures`` counter instead."""
        if not self.engine.is_running:
            # engine already stopped (an abandoned apply finishing late):
            # warming would auto-start a collector on a dying loop
            return
        fp = self._live[name].fp
        obs = list(self._observed.get(name, ()))
        if obs:
            results = await asyncio.gather(
                *[self.engine.query(mu, eps, fingerprint=fp)
                  for mu, eps in obs],
                return_exceptions=True)
            failures = sum(1 for r in results
                           if isinstance(r, BaseException))
            if failures:
                self.engine.registry.inc("live.rewarm_failures", failures)

    # ------------------------------------------------------------------
    # compaction
    # ------------------------------------------------------------------
    def compact(self, name: str) -> int:
        """Save the live index as a full snapshot (version = delta seq)
        and prune the covered chain prefix; → pruned delta count."""
        live = self._live[name]
        store = self.catalog.store(name)
        profile = self.engine.policy.profile
        store.save(live.index, live.g, version=live.seq,
                   measure=self.measure, provenance=live.provenance,
                   profile=profile)
        dropped = DeltaLog(store.directory).prune_through(live.seq)
        # the fresh snapshot carries the serving policy's profile, so any
        # restored-from-an-older-profile mismatch is resolved here
        self._live[name] = dataclasses.replace(
            live, snapshot_seq=live.seq, profile=profile,
            profile_mismatch=False)
        return dropped
