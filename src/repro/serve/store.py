"""Persist / restore the SCAN index as a servable artifact.

Storage rides on :mod:`repro.ckpt.checkpoint` — the same atomic-rename
manifest format used for model checkpoints — so an index directory has the
identical crash-safety story: readers only ever see fully committed
versions, and ``keep`` old versions are retained for rollback.

Layout of one committed version (``<dir>/step_<k>/``)::

    manifest.json       leaf paths, shapes, dtypes (self-describing)
    arr_00000.npy ...   one file per array leaf

The saved tree bundles the index arrays, the graph arrays, the static
shape fields (as int32 scalars) and the content **fingerprint** (sha256
over the graph structure and edge similarities, stored as a uint8 digest
array). The fingerprint names the *content*, not the file: two indexes
built from the same graph + similarity measure fingerprint identically, so
cached query results keyed on it survive a process restart but are
invalidated the moment the underlying graph changes.

Restore is reference-free: the manifest is self-describing, so
:meth:`IndexStore.load` reconstructs ``ScanIndex``/``CSRGraph`` without a
template pytree (the static fields come out of the saved scalars).
"""
from __future__ import annotations

import hashlib
import os
import shutil
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.backend.profile import DEFAULT_PROFILE, AutotuneProfile
from repro.ckpt import checkpoint
from repro.core.approx import EXACT_PROVENANCE, IndexProvenance
from repro.core.graph import CSRGraph
from repro.core.index import ScanIndex
from repro.core.update import EdgeDelta

_INDEX_FIELDS = ("offsets_c", "no_nbrs", "no_sims", "no_self", "co_offsets",
                 "co_vertex", "co_theta", "cdeg", "edge_sims")
_GRAPH_FIELDS = ("offsets", "nbrs", "wgts", "edge_u")


def index_fingerprint(index: ScanIndex, g: CSRGraph) -> str:
    """Content hash of (graph structure, edge similarities).

    Everything else in the index is a deterministic function of these, so
    this is the minimal key that invalidates cached results exactly when
    query answers could change.
    """
    h = hashlib.sha256()
    h.update(f"n={g.n};m2={g.m2}".encode())
    for arr in (g.offsets, g.nbrs, g.wgts, index.edge_sims):
        h.update(np.ascontiguousarray(np.asarray(arr)).tobytes())
    return h.hexdigest()


def _to_tree(index: ScanIndex, g: CSRGraph, fingerprint: str,
             measure: str, provenance: IndexProvenance,
             profile: AutotuneProfile) -> dict:
    return {
        "index": {f: getattr(index, f) for f in _INDEX_FIELDS},
        "graph": {f: getattr(g, f) for f in _GRAPH_FIELDS},
        "static": {
            "n": jnp.int32(index.n),
            "m2": jnp.int32(g.m2),
            "m2c": jnp.int32(index.m2c),
            "max_cdeg": jnp.int32(index.max_cdeg),
        },
        "fingerprint": np.frombuffer(fingerprint.encode(), dtype=np.uint8),
        "measure": np.frombuffer(measure.encode(), dtype=np.uint8),
        "provenance": np.frombuffer(provenance.to_json().encode(),
                                    dtype=np.uint8),
        "backend_profile": np.frombuffer(profile.to_json().encode(),
                                         dtype=np.uint8),
    }


class IndexStore:
    """Versioned on-disk home for one graph's SCAN index."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep

    # -- write ---------------------------------------------------------
    def save(self, index: ScanIndex, g: CSRGraph, *,
             version: Optional[int] = None,
             measure: str = "cosine",
             provenance: Optional[IndexProvenance] = None,
             profile: Optional[AutotuneProfile] = None) -> str:
        """Commit a new version; returns the committed path. ``measure``
        records the similarity measure the index was built with, so a
        consumer that will *maintain* the index (incremental updates
        recompute frontier σ) can refuse a mismatched adoption.
        ``provenance`` records how the similarities were produced (exact
        vs LSH-sketched, sketch params); default exact. ``profile``
        records the backend autotune thresholds active at save time
        (default = the untuned constants) as a versioned manifest leaf."""
        latest = checkpoint.latest_step(self.directory)
        if version is None:
            version = 0 if latest is None else latest + 1
        elif latest is not None and version <= latest:
            # versions are monotone: a lower one would be garbage-collected
            # by the keep-N sweep the moment it commits
            raise ValueError(
                f"version {version} <= latest committed {latest}")
        fp = index_fingerprint(index, g)
        if provenance is None:
            provenance = EXACT_PROVENANCE
        if profile is None:
            profile = DEFAULT_PROFILE
        return checkpoint.save(self.directory, version,
                               _to_tree(index, g, fp, measure, provenance,
                                        profile),
                               keep=self.keep)

    # -- read ----------------------------------------------------------
    def latest_version(self) -> Optional[int]:
        return checkpoint.latest_step(self.directory)

    def load(self, version: Optional[int] = None
             ) -> Tuple[ScanIndex, CSRGraph, str]:
        """→ (index, graph, fingerprint) for ``version`` (default latest)."""
        if version is None:
            version = self.latest_version()
            if version is None:
                raise FileNotFoundError(
                    f"no committed index under {self.directory!r}")
        by_path = checkpoint.load_leaves(self.directory, version)

        def leaf(*parts):
            return by_path[checkpoint.leaf_key(*parts)]

        static = {k: int(leaf("static", k))
                  for k in ("n", "m2", "m2c", "max_cdeg")}
        g = CSRGraph(
            offsets=jnp.asarray(leaf("graph", "offsets")),
            nbrs=jnp.asarray(leaf("graph", "nbrs")),
            wgts=jnp.asarray(leaf("graph", "wgts")),
            edge_u=jnp.asarray(leaf("graph", "edge_u")),
            n=static["n"],
            m2=static["m2"],
        )
        index = ScanIndex(
            **{f: jnp.asarray(leaf("index", f)) for f in _INDEX_FIELDS},
            n=static["n"],
            m2c=static["m2c"],
            max_cdeg=static["max_cdeg"],
        )
        fp = bytes(leaf("fingerprint")).decode()
        return index, g, fp

    def measure(self, version: Optional[int] = None) -> Optional[str]:
        """The similarity measure recorded at save time, or ``None`` for
        checkpoints predating the measure leaf."""
        if version is None:
            version = self.latest_version()
            if version is None:
                raise FileNotFoundError(
                    f"no committed index under {self.directory!r}")
        by_path = checkpoint.load_leaves(self.directory, version)
        raw = by_path.get(checkpoint.leaf_key("measure"))
        return bytes(raw).decode() if raw is not None else None

    def provenance(self, version: Optional[int] = None) -> IndexProvenance:
        """The :class:`IndexProvenance` recorded at save time; checkpoints
        predating the provenance leaf are exact builds by construction."""
        if version is None:
            version = self.latest_version()
            if version is None:
                raise FileNotFoundError(
                    f"no committed index under {self.directory!r}")
        by_path = checkpoint.load_leaves(self.directory, version)
        raw = by_path.get(checkpoint.leaf_key("provenance"))
        if raw is None:
            return EXACT_PROVENANCE
        return IndexProvenance.from_json(bytes(raw).decode())

    def profile(self, version: Optional[int] = None) -> AutotuneProfile:
        """The :class:`AutotuneProfile` recorded at save time; checkpoints
        predating the leaf get the untuned default — bit-for-bit the
        constants the engine ran with before autotune existed."""
        if version is None:
            version = self.latest_version()
            if version is None:
                raise FileNotFoundError(
                    f"no committed index under {self.directory!r}")
        by_path = checkpoint.load_leaves(self.directory, version)
        raw = by_path.get(checkpoint.leaf_key("backend_profile"))
        if raw is None:
            return DEFAULT_PROFILE
        return AutotuneProfile.from_json(bytes(raw).decode())


class DeltaLog:
    """Versioned chain of edit batches rooted next to an index store.

    Layout: ``<index dir>/deltas/step_<seq>/…`` — one atomic checkpoint
    (same tmp-dir + rename commit as every other artifact) per applied
    :class:`~repro.core.update.EdgeDelta`. Each entry also records the
    content fingerprint the live index had *after* the delta, so restore
    can verify chain integrity step by step.

    The chain composes with the snapshot store: a compaction saves the
    live index as snapshot version ``seq`` and prunes deltas ≤ ``seq``,
    so restore = load latest snapshot + replay the (strictly newer) tail.
    A crash mid-append leaves only an ignorable ``.tmp`` directory — the
    manifest stays restorable to the last committed version.
    """

    SUBDIR = "deltas"

    def __init__(self, directory: str):
        self.directory = os.path.join(directory, self.SUBDIR)

    def append(self, seq: int, delta: EdgeDelta, fingerprint: str) -> str:
        tree = {
            "ins": {"u": delta.ins_u, "v": delta.ins_v, "w": delta.ins_w},
            "del": {"u": delta.del_u, "v": delta.del_v},
            "meta": {
                "seq": np.int64(seq),
                "fingerprint": np.frombuffer(fingerprint.encode(),
                                             dtype=np.uint8),
            },
        }
        # keep=everything: chain entries are pruned by compaction, not age
        return checkpoint.save(self.directory, seq, tree, keep=1 << 30)

    def sequences(self) -> List[int]:
        """Committed delta seqs, ascending (``.tmp`` wreckage ignored)."""
        return checkpoint.steps(self.directory)

    def verify(self, seq: int) -> bool:
        """Whether one committed chain entry is *intact* (manifest parses,
        every leaf file loads at its recorded shape). A renamed-but-torn
        entry — pre-durability power loss, bitrot, an injected chaos
        fault — fails here instead of exploding mid-replay. This checks
        *storage* integrity only; semantic integrity (did the delta
        replay to the recorded fingerprint) is the replay-time check."""
        return checkpoint.verify_step(self.directory, seq)

    def truncate_torn_tail(self) -> List[int]:
        """Drop the torn *tail* of the chain: from the first entry that
        fails :meth:`verify`, remove it and everything after (later
        entries chain off a delta that never durably committed, so they
        are unreachable by a correct replay anyway); → removed seqs.

        This is the **owner's** (writer's) crash-recovery verb — replay
        lands on the last intact entry instead of raising. Read-side
        tailers must *not* call it (the chain is shared state; a reader
        deleting the writer's in-flight append would be corruption, not
        recovery) — they treat a torn entry as not-yet-delivered and
        re-poll.
        """
        removed: List[int] = []
        torn = False
        for s in self.sequences():
            if not torn and not self.verify(s):
                torn = True
            if torn:
                shutil.rmtree(checkpoint.step_dir(self.directory, s),
                              ignore_errors=True)
                removed.append(s)
        return removed

    def load(self, seq: int) -> Tuple[EdgeDelta, str]:
        """→ (delta, post-application fingerprint) for one chain entry."""
        by_path = checkpoint.load_leaves(self.directory, seq)

        def leaf(*parts):
            return by_path[checkpoint.leaf_key(*parts)]

        delta = EdgeDelta(
            ins_u=np.asarray(leaf("ins", "u"), np.int64),
            ins_v=np.asarray(leaf("ins", "v"), np.int64),
            ins_w=np.asarray(leaf("ins", "w"), np.float32),
            del_u=np.asarray(leaf("del", "u"), np.int64),
            del_v=np.asarray(leaf("del", "v"), np.int64),
        )
        return delta, bytes(leaf("meta", "fingerprint")).decode()

    def prune_through(self, seq: int) -> int:
        """Drop chain entries ≤ ``seq`` (they are covered by a snapshot)."""
        dropped = 0
        for s in self.sequences():
            if s <= seq:
                shutil.rmtree(checkpoint.step_dir(self.directory, s),
                              ignore_errors=True)
                dropped += 1
        return dropped


class IndexCatalog:
    """A directory of named ``IndexStore``s — the on-disk side of the
    multi-index router.

    Layout: ``<root>/<name>/step_<k>/…`` — every child directory is one
    graph's versioned index store. ``load_all`` restores the latest
    committed version of every named index, returning the
    ``{fingerprint: (index, graph)}`` mapping an engine registers from
    (fingerprints, not names, key routing — two names holding identical
    content deliberately collapse to one route and one cache partition).
    """

    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep

    def store(self, name: str) -> IndexStore:
        return IndexStore(os.path.join(self.root, name), keep=self.keep)

    def names(self) -> list[str]:
        if not os.path.isdir(self.root):
            return []
        return sorted(
            d for d in os.listdir(self.root)
            if self.store(d).latest_version() is not None)

    def save(self, name: str, index: ScanIndex, g: CSRGraph, *,
             measure: str = "cosine",
             provenance: Optional[IndexProvenance] = None,
             profile: Optional[AutotuneProfile] = None) -> str:
        return self.store(name).save(index, g, measure=measure,
                                     provenance=provenance,
                                     profile=profile)

    def load_all(self) -> Dict[str, Tuple[ScanIndex, CSRGraph]]:
        out: Dict[str, Tuple[ScanIndex, CSRGraph]] = {}
        for name in self.names():
            index, g, fp = self.store(name).load()
            out[fp] = (index, g)
        return out
