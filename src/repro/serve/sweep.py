"""Vmapped (μ, ε) parameter sweeps over one index.

``query`` keeps (μ, ε) as traced scalars, so a whole batch of settings is
one ``vmap`` away: the index arrays broadcast, only the two parameter
vectors carry a batch axis, and the entire sweep is a single compiled
device call (``repro.core.query_batch``). This module adds the
exploration-workload conveniences on top:

  * :func:`sweep`       — batched queries for explicit (μ, ε) pairs;
  * :func:`grid_sweep`  — the full μ × ε cartesian grid in one call;
  * :func:`sweep_stats` — per-setting cluster count / coverage /
    modularity, the table a "which parameters should I use?" user reads.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.graph import CSRGraph
from repro.core.index import ScanIndex
from repro.core.query import ClusterResult, query_batch
from repro.core.quality import modularity


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """One row per (μ, ε) setting; arrays are host-side numpy."""

    mus: np.ndarray         # int32[B]
    epss: np.ndarray        # float32[B]
    labels: np.ndarray      # int32[B, n]
    is_core: np.ndarray     # bool[B, n]
    n_clusters: np.ndarray  # int32[B]

    def __len__(self) -> int:
        return len(self.mus)

    def result(self, i: int) -> ClusterResult:
        """The i-th setting's answer as a plain ClusterResult."""
        return ClusterResult(labels=self.labels[i], is_core=self.is_core[i],
                             n_clusters=self.n_clusters[i])


def sweep(index: ScanIndex, g: CSRGraph,
          mus: Sequence[int], epss: Sequence[float],
          *, mesh=None) -> SweepResult:
    """Batched queries for paired parameter vectors (one compiled call).

    ``mesh`` switches to the sharded query path
    (:func:`repro.core.query_batch_sharded`): edge arrays partitioned over
    the mesh's ``data`` axis, identical results — the giant-graph mode.
    """
    mus = np.asarray(mus, np.int32).reshape(-1)
    epss = np.asarray(epss, np.float32).reshape(-1)
    if mus.shape != epss.shape:
        raise ValueError(f"mus {mus.shape} and epss {epss.shape} must match")
    if mesh is not None:
        from repro.core.distributed import query_batch_sharded
        res = query_batch_sharded(index, g, mus, epss, mesh=mesh)
    else:
        res = query_batch(index, g, mus, epss)
    return SweepResult(
        mus=mus, epss=epss,
        labels=np.asarray(res.labels),
        is_core=np.asarray(res.is_core),
        n_clusters=np.asarray(res.n_clusters),
    )


def grid_sweep(index: ScanIndex, g: CSRGraph,
               mu_values: Sequence[int],
               eps_values: Sequence[float],
               *, mesh=None) -> SweepResult:
    """Full cartesian μ × ε grid, μ-major row order."""
    mu_grid, eps_grid = np.meshgrid(
        np.asarray(mu_values, np.int32),
        np.asarray(eps_values, np.float32), indexing="ij")
    return sweep(index, g, mu_grid.reshape(-1), eps_grid.reshape(-1),
                 mesh=mesh)


def sweep_stats(index: ScanIndex, g: CSRGraph,
                mu_values: Sequence[int],
                eps_values: Sequence[float],
                *, mesh=None) -> list[dict]:
    """Per-setting summary rows for parameter exploration.

    Returns dicts with ``mu, eps, n_clusters, n_cores, coverage,
    modularity`` (coverage = fraction of vertices assigned to a cluster;
    modularity follows the paper's §7.3.4 singleton convention for
    unclustered vertices).
    """
    res = grid_sweep(index, g, mu_values, eps_values, mesh=mesh)
    rows = []
    for i in range(len(res)):
        labels = res.labels[i]
        rows.append({
            "mu": int(res.mus[i]),
            "eps": float(res.epss[i]),
            "n_clusters": int(res.n_clusters[i]),
            "n_cores": int(res.is_core[i].sum()),
            "coverage": float((labels >= 0).mean()) if g.n else 0.0,
            "modularity": modularity(g, labels),
        })
    return rows
