"""Serving step factories: batched prefill and single-token decode.

``decode_step`` is the unit the decode_32k/long_500k dry-run cells lower:
one new token against a KV cache of the cell's seq_len. ``greedy_generate``
drives multi-token generation for the examples/tests (host loop around the
jitted step — cache donation keeps it allocation-stable).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as mdl


def make_prefill(cfg: ModelConfig, max_len: int):
    def prefill(params, batch):
        return mdl.prefill(cfg, params, batch, max_len)

    return prefill


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, cache, token, pos):
        return mdl.decode_step(cfg, params, cache, token, pos)

    return decode_step


def greedy_generate(cfg: ModelConfig, params, batch, steps: int,
                    max_len: int):
    """Prefill + greedy decode loop. Returns [B, steps] generated tokens."""
    prompt_len = (batch["tokens"].shape[1] if "tokens" in batch
                  else batch["embeddings"].shape[1])
    prefill = jax.jit(make_prefill(cfg, max_len))
    step = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
    logits, cache = prefill(params, batch)
    out = []
    tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)
    base = prompt_len + (cfg.meta_tokens if cfg.family == "hybrid" else 0)
    for i in range(steps):
        out.append(tok)
        logits, cache = step(params, cache, tok, base + i)
        tok = jnp.argmax(logits[:, : cfg.vocab], axis=-1).astype(jnp.int32)
    return jnp.stack(out, axis=1)
