"""Training step factory: CE (+ MoE aux) loss, microbatched gradient
accumulation, AdamW. The accumulation loop is an unrolled python loop (XLA
reuses the gradient buffers in place; unrolling keeps dry-run FLOP
accounting exact — DESIGN.md §6).

Batch layout: every array in the batch carries a leading microbatch axis
[accum, B_micro, ...]; ``accum=1`` collapses to a plain step.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as mdl
from repro.models.layers import cross_entropy
from repro.optim import adamw


def loss_fn(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, Dict]:
    if cfg.ce_chunk > 0:
        (hidden, head), aux = mdl.forward_hidden(cfg, params, batch)
        from repro.models.layers import cross_entropy_chunked
        ce = cross_entropy_chunked(hidden, head, batch["labels"], cfg.vocab,
                                   cfg.ce_chunk)
    else:
        logits, aux = mdl.forward(cfg, params, batch)
        ce = cross_entropy(logits, batch["labels"], cfg.vocab)
    loss = ce + cfg.router_aux_coef * aux
    return loss, {"ce": ce, "aux": aux}


def make_train_step(cfg: ModelConfig, hp: adamw.AdamWConfig, accum: int = 1):
    """→ train_step(params, opt_state, batch) → (params, opt_state, metrics)."""

    grad_fn = jax.grad(functools.partial(loss_fn, cfg), has_aux=True)

    def train_step(params, opt_state, batch):
        def micro(i, params_dep):
            mb = jax.tree.map(lambda x: x[i], batch)
            return grad_fn(params_dep, mb)

        grads, metrics = micro(0, params)
        for i in range(1, accum):
            # optimization_barrier chains microstep i on microstep i-1's
            # grads: the scheduler cannot overlap them, so live activation
            # memory stays one-microbatch-sized instead of accum-sized.
            params_dep, _ = jax.lax.optimization_barrier(
                (params, jax.tree.leaves(grads)[0]))
            g_i, m_i = micro(i, params_dep)
            grads = jax.tree.map(jnp.add, grads, g_i)
            metrics = jax.tree.map(jnp.add, metrics, m_i)
        if accum > 1:
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m / accum, metrics)

        new_params, new_opt, opt_metrics = adamw.update(grads, opt_state, hp)
        metrics = dict(metrics, **opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_eval_step(cfg: ModelConfig):
    def eval_step(params, batch):
        _, metrics = loss_fn(cfg, params, batch)
        return metrics

    return eval_step
