"""Shared oracle helper: array-for-array SimilarityPlan equality.

Used by the plan-maintenance unit tests (tests/test_plan_apply.py) and the
edit-script index oracle (tests/test_incremental_index.py) — the invariant
is the same in both: a maintained plan is bit-identical to a from-scratch
``SimilarityPlan.build`` on the same graph.
"""
import numpy as np


def assert_plan_equal(plan, ref, tag=""):
    """Array-for-array equality, norms compared bitwise (uint32 views)."""
    assert plan.widths == ref.widths, (tag, plan.widths, ref.widths)
    assert (plan.n, plan.m2, plan.hub_tile) == \
        (ref.n, ref.m2, ref.hub_tile), tag
    for f in ("vclass", "vrow", "vtiles", "deg"):
        a, b = getattr(plan, f), getattr(ref, f)
        assert a.dtype == b.dtype, (tag, f)
        np.testing.assert_array_equal(a, b, err_msg=f"{tag} {f}")
    for i, w in enumerate(plan.widths):
        np.testing.assert_array_equal(
            np.asarray(plan.nbr_blocks[i]), np.asarray(ref.nbr_blocks[i]),
            err_msg=f"{tag} nbr_blocks[{w}]")
        np.testing.assert_array_equal(
            np.asarray(plan.wgt_blocks[i]), np.asarray(ref.wgt_blocks[i]),
            err_msg=f"{tag} wgt_blocks[{w}]")
    np.testing.assert_array_equal(
        np.asarray(plan.norms).view(np.uint32),
        np.asarray(ref.norms).view(np.uint32), err_msg=f"{tag} norms")
    np.testing.assert_array_equal(
        np.asarray(plan.cdeg), np.asarray(ref.cdeg), err_msg=f"{tag} cdeg")
