import os

# Tests must see the real (single) CPU device — the 512-device override is
# exclusively for launch/dryrun.py (per assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, settings

    # seed-pinned fast-lane profile: derandomize makes every run replay
    # the same examples, so tier-1/CI can't flake on a rare draw; the
    # "thorough" profile re-enables exploration for local soak runs
    # (HYPOTHESIS_PROFILE=thorough pytest ...).
    settings.register_profile(
        "fast", derandomize=True, deadline=None,
        suppress_health_check=[HealthCheck.too_slow])
    settings.register_profile("thorough", deadline=None, max_examples=100)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "fast"))
except ImportError:                                    # pragma: no cover
    pass


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
