import os

# Tests must see the real (single) CPU device — the 512-device override is
# exclusively for launch/dryrun.py (per assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
