"""Admission control: token-bucket math (injected clock), shed causes,
per-client fairness, deadline-aware rejection, and the typed-exception
contract (``EngineStopped`` / ``Overloaded`` stay ``RuntimeError``
subclasses with the legacy message)."""
import asyncio

import pytest

from repro.core import random_graph, build_index
from repro.obs import MetricsRegistry
from repro.serve import (AdmissionConfig, AdmissionController, EngineConfig,
                         EngineStopped, MicroBatchEngine, Overloaded,
                         ServeError, TokenBucket)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# --------------------------------------------------------------------------
# token bucket
# --------------------------------------------------------------------------
def test_bucket_burst_then_refill_math():
    clk = FakeClock()
    b = TokenBucket(rate=2.0, burst=3, clock=clk)
    assert [b.take() for _ in range(3)] == [0.0, 0.0, 0.0]
    # empty: next token is 1/rate = 0.5s away
    assert b.take() == pytest.approx(0.5)
    clk.advance(0.25)
    # half a token accumulated → half a token short → 0.25s
    assert b.take() == pytest.approx(0.25)
    clk.advance(0.25)
    assert b.take() == 0.0
    # and it is again empty right after
    assert b.take() == pytest.approx(0.5)


def test_bucket_never_exceeds_burst():
    clk = FakeClock()
    b = TokenBucket(rate=100.0, burst=2, clock=clk)
    clk.advance(60.0)  # an hour of refill still caps at burst
    assert [b.take() for _ in range(2)] == [0.0, 0.0]
    assert b.take() > 0.0


def test_bucket_rejects_bad_params():
    with pytest.raises(ValueError):
        TokenBucket(rate=0.0, burst=1)
    with pytest.raises(ValueError):
        TokenBucket(rate=1.0, burst=0)


# --------------------------------------------------------------------------
# controller shed causes
# --------------------------------------------------------------------------
def _check(ctrl, **kw):
    kw.setdefault("client", None)
    kw.setdefault("deadline_s", None)
    kw.setdefault("queue_depth", 0)
    kw.setdefault("offload_depth", 0)
    kw.setdefault("est_wait_s", 0.01)
    ctrl.check(**kw)


def test_queue_depth_shed_reason_and_counter():
    reg = MetricsRegistry()
    ctrl = AdmissionController(AdmissionConfig(max_queue_depth=4), reg)
    _check(ctrl, queue_depth=3)
    with pytest.raises(Overloaded) as ei:
        _check(ctrl, queue_depth=4, est_wait_s=0.7)
    assert ei.value.reason == "queue_depth"
    assert ei.value.retry_after == pytest.approx(0.7)
    assert reg.counter("admission.shed_queue_depth").value == 1
    assert reg.counter("admission.admitted").value == 1


def test_offload_depth_shed():
    reg = MetricsRegistry()
    ctrl = AdmissionController(AdmissionConfig(max_offload_depth=2), reg)
    _check(ctrl, offload_depth=2)  # at the limit is still fine
    with pytest.raises(Overloaded) as ei:
        _check(ctrl, offload_depth=3)
    assert ei.value.reason == "offload_depth"
    assert reg.counter("admission.shed_offload_depth").value == 1


def test_deadline_rejection_is_immediate():
    reg = MetricsRegistry()
    ctrl = AdmissionController(AdmissionConfig(), reg)
    _check(ctrl, deadline_s=1.0, est_wait_s=0.5)
    with pytest.raises(Overloaded) as ei:
        _check(ctrl, deadline_s=0.1, est_wait_s=0.5)
    assert ei.value.reason == "deadline"
    assert ei.value.retry_after == pytest.approx(0.5)


def test_per_client_fairness():
    """A client that burns its burst is shed with the bucket's exact
    retry_after; an independent client on the same engine is untouched."""
    clk = FakeClock()
    reg = MetricsRegistry()
    ctrl = AdmissionController(
        AdmissionConfig(client_rate=1.0, client_burst=2), reg, clock=clk)
    _check(ctrl, client="greedy")
    _check(ctrl, client="greedy")
    with pytest.raises(Overloaded) as ei:
        _check(ctrl, client="greedy")
    assert ei.value.reason == "client_rate"
    assert ei.value.retry_after == pytest.approx(1.0)
    _check(ctrl, client="polite")            # unaffected
    clk.advance(1.0)
    _check(ctrl, client="greedy")            # token refilled
    assert reg.counter("admission.shed_client_rate").value == 1


def test_client_lru_cap_evicts_oldest():
    clk = FakeClock()
    ctrl = AdmissionController(
        AdmissionConfig(client_rate=1.0, client_burst=1, max_clients=2),
        MetricsRegistry(), clock=clk)
    _check(ctrl, client="a")
    _check(ctrl, client="b")
    _check(ctrl, client="c")  # evicts a's (empty) bucket
    assert set(ctrl._buckets) == {"b", "c"}
    _check(ctrl, client="a")  # returns with a fresh burst — errs permissive


def test_anonymous_traffic_skips_buckets():
    ctrl = AdmissionController(
        AdmissionConfig(client_rate=1.0, client_burst=1), MetricsRegistry())
    for _ in range(5):
        _check(ctrl, client=None)
    assert not ctrl._buckets


# --------------------------------------------------------------------------
# engine integration
# --------------------------------------------------------------------------
def _engine(**admission_kw):
    g = random_graph(40, 4.0, seed=0)
    index = build_index(g, "cosine")
    return MicroBatchEngine(index, g, config=EngineConfig(
        max_batch=4, flush_ms=20.0,
        admission=AdmissionConfig(**admission_kw)))


def test_engine_deadline_shed_is_typed():
    """est_wait ≥ one flush window, so an impossible deadline sheds at
    enqueue time — typed, with retry_after — not as a timeout later."""
    engine = _engine()

    async def main():
        async with engine:
            await engine.query(2, 0.5, deadline_s=10.0)  # plenty of time
            with pytest.raises(Overloaded) as ei:
                await engine.query(3, 0.5, deadline_s=1e-9)
            return ei.value

    e = asyncio.run(main())
    assert e.reason == "deadline" and e.retry_after > 0
    assert isinstance(e, RuntimeError)  # back-compat contract


def test_engine_client_rate_shed_and_sibling_unaffected():
    engine = _engine(client_rate=0.001, client_burst=1)

    async def main():
        async with engine:
            await engine.query(2, 0.5, client="hog")
            with pytest.raises(Overloaded):
                await engine.query(3, 0.5, client="hog")
            await engine.query(3, 0.5, client="other")

    asyncio.run(main())
    assert engine.registry.counter("admission.shed_client_rate").value == 1


def test_no_admission_config_accepts_everything():
    g = random_graph(40, 4.0, seed=0)
    engine = MicroBatchEngine(build_index(g, "cosine"), g,
                              config=EngineConfig(max_batch=4, flush_ms=2.0))

    async def main():
        async with engine:
            # client/deadline kwargs are accepted and ignored
            await engine.query(2, 0.5, client="x", deadline_s=1e-9)

    asyncio.run(main())


# --------------------------------------------------------------------------
# typed rejection back-compat
# --------------------------------------------------------------------------
def test_stopped_engine_raises_typed_engine_stopped():
    engine = _engine()

    async def main():
        async with engine:
            await engine.query(2, 0.5)
        # context manager exited → stopped
        with pytest.raises(EngineStopped):
            await engine.query(2, 0.6)
        with pytest.raises(RuntimeError, match="engine stopped"):
            await engine.query(2, 0.7)

    asyncio.run(main())


def test_typed_exception_hierarchy():
    assert issubclass(EngineStopped, ServeError)
    assert issubclass(Overloaded, ServeError)
    assert issubclass(ServeError, RuntimeError)
    assert str(EngineStopped()) == "engine stopped"
    e = Overloaded(retry_after=0.25, reason="queue_depth")
    assert "0.250" in str(e) and "queue_depth" in str(e)
