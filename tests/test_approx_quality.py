"""End-to-end quality oracle: approximate index vs exact index.

The §5 guarantees bound per-edge σ̂ error; what the serving system actually
cares about is the *clustering* the approximate index yields. This module
sweeps a (μ, ε) grid on the two structured generators (power-law with
forced hubs — the regime where the degree heuristic leaves real sketched
edges — and hub-ring) and asserts the approximate clustering stays close
to the exact one: ARI on labels plus precision/recall on the core set
(the §5 theorems are core-classification guarantees, so core fidelity is
the direct readout). Grid aggregates, not per-point minima: a borderline
(μ, ε) can legitimately flip a tiny cluster, which is exactly the
within-(ε±δ) band the theorems exclude.

The fast tests run a 3×5 grid; ``test_quality_grid_large`` widens it to
5×16 on bigger graphs and is marked ``slow`` (local soak / scheduled CI).
"""
import numpy as np
import pytest

from repro.core import (adjusted_rand_index, build_approx_index, build_index,
                        core_precision_recall, hub_ring_graph,
                        power_law_graph, query)

MUS = (2, 3, 4)
EPSS = (0.2, 0.35, 0.5, 0.65, 0.8)


def grid_quality(g, idx_exact, idx_approx, mus=MUS, epss=EPSS):
    """(mean ARI, frac of grid points with ARI ≥ 0.8, mean core precision,
    mean core recall) of approx vs exact across the (μ, ε) grid."""
    aris, precs, recs = [], [], []
    for mu in mus:
        for eps in epss:
            res_e = query(idx_exact, g, mu, float(eps))
            res_a = query(idx_approx, g, mu, float(eps))
            aris.append(adjusted_rand_index(np.asarray(res_e.labels),
                                            np.asarray(res_a.labels)))
            p, r = core_precision_recall(np.asarray(res_a.is_core),
                                         np.asarray(res_e.is_core))
            precs.append(p)
            recs.append(r)
    aris = np.asarray(aris)
    return (float(aris.mean()), float(np.mean(aris >= 0.8)),
            float(np.mean(precs)), float(np.mean(recs)))


def _graphs():
    # hub_degree > samples forces genuinely sketched hub edges, so the
    # degree heuristic cannot make the comparison trivially exact
    return (("power_law", power_law_graph(400, seed=2, hub_degree=120)),
            ("hub_ring", hub_ring_graph(150, 80, seed=3)))


def test_quality_grid_with_degree_heuristic():
    """Paper-default construction (§6.3 heuristic + simhash on hub-hub
    edges) tracks the exact clustering closely across the grid."""
    floors = {"power_law": (0.80, 0.60, 0.80, 0.90),
              "hub_ring": (0.95, 0.90, 0.95, 0.95)}
    for name, g in _graphs():
        idx_e = build_index(g, "cosine")
        idx_a, prov = build_approx_index(
            g, measure="cosine", method="simhash", samples=64, seed=0,
            degree_heuristic=True)
        assert prov.is_approx and prov.samples == 64
        ari, frac, prec, rec = grid_quality(g, idx_e, idx_a)
        f_ari, f_frac, f_prec, f_rec = floors[name]
        assert ari >= f_ari, f"{name}: mean ARI {ari:.3f} < {f_ari}"
        assert frac >= f_frac, f"{name}: ARI≥0.8 fraction {frac:.2f}"
        assert prec >= f_prec, f"{name}: core precision {prec:.3f}"
        assert rec >= f_rec, f"{name}: core recall {rec:.3f}"


def test_quality_grid_pure_sketch():
    """With the heuristic off, *every* σ is sketched — quality must still
    be usable at high sample count (this is the regime Theorems 5.2/5.3
    actually govern: recall stays high, precision degrades gracefully)."""
    floors = {"power_law": (0.55, 0.65, 0.90),
              "hub_ring": (0.70, 0.75, 0.90)}
    for name, g in _graphs():
        idx_e = build_index(g, "cosine")
        idx_a, _ = build_approx_index(
            g, measure="cosine", method="simhash", samples=1024, seed=0,
            degree_heuristic=False)
        ari, _, prec, rec = grid_quality(g, idx_e, idx_a)
        f_ari, f_prec, f_rec = floors[name]
        assert ari >= f_ari, f"{name}: mean ARI {ari:.3f} < {f_ari}"
        assert prec >= f_prec, f"{name}: core precision {prec:.3f}"
        assert rec >= f_rec, f"{name}: core recall {rec:.3f}"


@pytest.mark.slow
def test_quality_grid_large():
    """Wider (μ, ε) grid on larger graphs — the soak-lane variant."""
    mus = (2, 3, 4, 5, 8)
    epss = tuple(np.round(np.arange(0.15, 0.91, 0.05), 2))
    cases = (("power_law", power_law_graph(1000, seed=4, hub_degree=200),
              (0.85, 0.75, 0.85, 0.95)),
             ("hub_ring", hub_ring_graph(400, 150, seed=5),
              (0.95, 0.90, 0.95, 0.95)))
    for name, g, (f_ari, f_frac, f_prec, f_rec) in cases:
        idx_e = build_index(g, "cosine")
        idx_a, _ = build_approx_index(
            g, measure="cosine", method="simhash", samples=96, seed=1,
            degree_heuristic=True)
        ari, frac, prec, rec = grid_quality(g, idx_e, idx_a,
                                            mus=mus, epss=epss)
        assert ari >= f_ari, f"{name}: mean ARI {ari:.3f} < {f_ari}"
        assert frac >= f_frac, f"{name}: ARI≥0.8 fraction {frac:.2f}"
        assert prec >= f_prec, f"{name}: core precision {prec:.3f}"
        assert rec >= f_rec, f"{name}: core recall {rec:.3f}"
