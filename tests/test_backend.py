"""Execution-policy backend: lane-matrix oracle, per-call dispatch, and
the import-time-freeze regression.

The contract under test: every lane of every hot op (``ref`` pure-jnp /
``pallas-interpret`` / ``pallas-compiled``) reproduces the ``ref`` lane
bit-for-bit on unweighted σ and to ULP on weighted σ, so lane choice can
never move an index fingerprint; lane resolution (platform, ``REPRO_LANE``)
happens per call, never at import.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.backend import padding
from repro.backend.policy import (LANE_COMPILED, LANE_INTERPRET, LANE_REF,
                                  OPS, ExecutionPolicy, default_policy)
from repro.backend.profile import (DEFAULT_PROFILE, PROFILE_VERSION,
                                   AutotuneProfile, autotune)
from repro.core import compute_similarities, random_graph
from repro.kernels import ops
from repro.obs import MetricsRegistry

RNG = np.random.default_rng(0)

# on CPU the compiled lane cannot run; the matrix covers what can
_HOST_LANES = [LANE_REF, LANE_INTERPRET]


# ---------------------------------------------------------------------------
# lane-matrix oracle: every lane of every hot op vs the ref lane
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("lane", _HOST_LANES)
@pytest.mark.parametrize("measure", ["cosine", "jaccard"])
def test_lane_matrix_gram(lane, measure):
    g = random_graph(150, 6.0, seed=1)
    want = np.asarray(ops.edge_similarities_gram(g, measure, lane=LANE_REF))
    got = np.asarray(ops.edge_similarities_gram(g, measure, lane=lane))
    # unweighted graph: integer-valued dots in f32 → bit-for-bit
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("lane", _HOST_LANES)
@pytest.mark.parametrize("weighted", [False, True])
def test_lane_matrix_bucket_probe(lane, weighted):
    e, p, t, n = 64, 8, 48, 64
    ids_p = np.sort(RNG.choice(n, size=(e, p), replace=True), axis=1)
    ids_t = np.sort(RNG.choice(n, size=(e, t), replace=True), axis=1)
    if weighted:
        w_p = RNG.uniform(0.1, 1.0, size=(e, p)).astype(np.float32)
        w_t = RNG.uniform(0.1, 1.0, size=(e, t)).astype(np.float32)
    else:
        w_p = np.ones((e, p), np.float32)
        w_t = np.ones((e, t), np.float32)
    args = (jnp.asarray(ids_p, jnp.int32), jnp.asarray(w_p),
            jnp.asarray(ids_t, jnp.int32), jnp.asarray(w_t), n)
    want_dot, want_cnt = ops.bucket_probe_stats(*args, lane=LANE_REF)
    dot, cnt = ops.bucket_probe_stats(*args, lane=lane)
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(want_cnt))
    if weighted:
        np.testing.assert_allclose(np.asarray(dot), np.asarray(want_dot),
                                   rtol=1e-6, atol=1e-6)
    else:
        np.testing.assert_array_equal(np.asarray(dot), np.asarray(want_dot))


@pytest.mark.parametrize("lane", _HOST_LANES)
def test_lane_matrix_simhash_and_hamming(lane):
    g = random_graph(130, 5.0, seed=2)
    k = 96
    key = jax.random.PRNGKey(0)
    want_sk = np.asarray(ops.simhash_sketches_kernel(g, k, key,
                                                     lane=LANE_REF))
    sk = np.asarray(ops.simhash_sketches_kernel(g, k, key, lane=lane))
    np.testing.assert_array_equal(sk, want_sk)  # packed bits: exact
    want = np.asarray(ops.simhash_edge_similarity_kernel(
        jnp.asarray(sk), g.edge_u, g.nbrs, k, lane=LANE_REF))
    got = np.asarray(ops.simhash_edge_similarity_kernel(
        jnp.asarray(sk), g.edge_u, g.nbrs, k, lane=lane))
    # XOR/popcount is integer-exact; the cos epilogue is the same
    # elementwise expression → bit-for-bit
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("lane", _HOST_LANES)
def test_lane_matrix_attention(lane):
    bh, s, d = 2, 128, 64
    q, k, v = (jnp.asarray(RNG.standard_normal((bh, s, d)), jnp.float32)
               for _ in range(3))
    want = np.asarray(ops.attention(q, k, v, causal=True, lane=LANE_REF))
    got = np.asarray(ops.attention(q, k, v, causal=True, lane=lane))
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("weighted", [False, True])
def test_full_similarity_pass_lane_identity(monkeypatch, weighted):
    """The whole σ engine (plan → groups → epilogue) under a forced
    Pallas-interpret lane reproduces the default jnp engine — bit-for-bit
    on unweighted graphs, ULP-close on weighted."""
    g = random_graph(300, 8.0, seed=3, weighted=weighted)
    monkeypatch.delenv("REPRO_LANE", raising=False)
    want = np.asarray(compute_similarities(g, "cosine"))
    monkeypatch.setenv("REPRO_LANE", LANE_INTERPRET)
    got = np.asarray(compute_similarities(g, "cosine"))
    if weighted:
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    else:
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# per-call resolution: REPRO_LANE, clamping, platform
# ---------------------------------------------------------------------------
def test_env_lane_read_per_call(monkeypatch):
    pol = ExecutionPolicy()
    monkeypatch.delenv("REPRO_LANE", raising=False)
    assert pol.forced_lane() is None
    # the same policy object changes its answer when the env changes —
    # nothing is frozen at construction
    monkeypatch.setenv("REPRO_LANE", LANE_REF)
    assert pol.lane("bucket_probe", width=1 << 20) == LANE_REF
    monkeypatch.setenv("REPRO_LANE", LANE_INTERPRET)
    assert pol.lane("bucket_probe", width=1) == LANE_INTERPRET
    assert pol.kernel_lane("hamming") == LANE_INTERPRET
    monkeypatch.setenv("REPRO_LANE", "not-a-lane")
    with pytest.raises(ValueError, match="unknown lane"):
        pol.lane("bucket_probe")


def test_forced_lane_clamps_to_registered_lanes(monkeypatch):
    """Ops with only a ref lane stay on it under any forced lane — the
    (μ, ε) query path honestly reports ref, never pretends."""
    monkeypatch.setenv("REPRO_LANE", LANE_COMPILED)
    pol = ExecutionPolicy()
    assert OPS["query"] == (LANE_REF,)
    assert pol.lane("query") == LANE_REF
    assert pol.kernel_lane("query") == LANE_REF


def test_constructor_lane_beaten_by_env(monkeypatch):
    monkeypatch.delenv("REPRO_LANE", raising=False)
    pol = ExecutionPolicy(forced_lane=LANE_REF)
    assert pol.lane("bucket_probe") == LANE_REF
    monkeypatch.setenv("REPRO_LANE", LANE_INTERPRET)
    assert pol.lane("bucket_probe") == LANE_INTERPRET


def test_lane_counters_flow(monkeypatch):
    monkeypatch.delenv("REPRO_LANE", raising=False)
    reg = MetricsRegistry()
    pol = ExecutionPolicy(forced_lane=LANE_INTERPRET, registry=reg)
    g = random_graph(120, 5.0, seed=4)
    ops.edge_similarities_gram(g, "cosine", policy=pol)
    ops.simhash_sketches_kernel(g, 64, jax.random.PRNGKey(0), policy=pol)
    snap = reg.snapshot()["counters"]
    assert snap[f"backend.lane.triangle_count.{LANE_INTERPRET}"] == 1
    assert snap[f"backend.lane.simhash.{LANE_INTERPRET}"] == 1


def test_describe_block(monkeypatch):
    monkeypatch.delenv("REPRO_LANE", raising=False)
    desc = ExecutionPolicy(forced_lane=LANE_REF).describe()
    assert desc["forced_lane"] == LANE_REF
    assert desc["platform"] == jax.default_backend()
    assert set(desc["lanes"]) == set(OPS)
    assert desc["profile"]["hub_tile"] == DEFAULT_PROFILE.hub_tile


def test_no_import_time_backend_freeze():
    """Importing the kernel wrappers must neither initialize a jax backend
    nor freeze the platform decision — the regression that motivated this
    subsystem (`_ON_TPU`/`_INTERPRET` module constants captured at import,
    so `JAX_PLATFORMS` set afterwards was silently ignored)."""
    code = """
import repro.kernels.ops, repro.core.similarity
from jax._src import xla_bridge as xb
assert not xb._backends, "importing kernel wrappers initialized jax"

from unittest import mock
from repro.backend.policy import (LANE_COMPILED, LANE_INTERPRET, LANE_REF,
                                  ExecutionPolicy)
pol = ExecutionPolicy()
with mock.patch("jax.default_backend", return_value="tpu"):
    assert pol.kernel_lane("bucket_probe") == LANE_COMPILED
    assert pol.lane("bucket_probe", width=1 << 20) == LANE_COMPILED
with mock.patch("jax.default_backend", return_value="cpu"):
    assert pol.kernel_lane("bucket_probe") == LANE_INTERPRET
    assert pol.lane("bucket_probe", width=1 << 20) == LANE_REF
print("OK")
"""
    env = dict(os.environ)
    env.pop("REPRO_LANE", None)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


def test_no_module_level_backend_constant():
    """No module may capture platform state at import again."""
    import inspect

    import repro.core.similarity as sim_mod
    src = inspect.getsource(ops) + inspect.getsource(sim_mod)
    for frozen in ("_ON_TPU", "_INTERPRET ="):
        assert frozen not in src


# ---------------------------------------------------------------------------
# padding helpers (deterministic; the hypothesis property lives in
# test_backend_property.py)
# ---------------------------------------------------------------------------
def test_padding_helpers():
    assert [padding.pow2ceil(x) for x in (1, 2, 3, 5, 8, 1000)] == \
        [1, 2, 4, 8, 8, 1024]
    assert padding.pow2ceil(0, floor=8) == 8
    assert padding.pow2_bucket(100, floor=64) == 128
    assert padding.pow2_bucket(64, floor=64) == 64
    np.testing.assert_array_equal(
        padding.np_pow2ceil(np.array([1, 3, 4, 9])), [1, 4, 4, 16])
    np.testing.assert_array_equal(
        padding.np_log2(np.array([1, 2, 8, 1024])), [0, 1, 3, 10])
    a = padding.pad1(np.arange(3, dtype=np.int32), 2, -1)
    np.testing.assert_array_equal(a, [0, 1, 2, -1, -1])
    x = padding.pad_to(jnp.ones((3, 5)), 4, (0, 1))
    assert x.shape == (4, 8)
    assert float(x.sum()) == 15.0


def test_similarity_reexports_padding_helpers():
    """core.similarity keeps the old underscore names as aliases of the
    shared module — one definition, not two."""
    import repro.core.similarity as sim_mod
    assert sim_mod._pow2ceil is padding.pow2ceil
    assert sim_mod._pow2_bucket is padding.pow2_bucket
    assert sim_mod._pad1 is padding.pad1


# ---------------------------------------------------------------------------
# autotune: profile round-trip, observability, default behavior
# ---------------------------------------------------------------------------
def test_profile_json_roundtrip():
    prof = AutotuneProfile(platform="cpu", gram_block=64, probe_be=128)
    back = AutotuneProfile.from_json(prof.to_json())
    assert back == prof
    # unknown keys from a future profile version are ignored, not fatal
    import json
    payload = json.loads(prof.to_json())
    payload["some_future_knob"] = 7
    assert AutotuneProfile.from_json(json.dumps(payload)) == prof


def test_default_profile_is_legacy_constants():
    from repro.core import similarity as sim_mod
    assert DEFAULT_PROFILE.hub_tile == sim_mod.HUB_TILE == 2048
    assert DEFAULT_PROFILE.version == PROFILE_VERSION
    assert DEFAULT_PROFILE.platform == "default"


def test_autotune_produces_profile_under_span(monkeypatch):
    monkeypatch.delenv("REPRO_LANE", raising=False)
    reg = MetricsRegistry()
    pol = ExecutionPolicy(registry=reg)
    # two-candidate hamming grid: cheap to time in interpret mode; the
    # rest single-valued (taken without timing)
    prof = autotune(pol, candidates={
        "gram_block": (128,), "probe_block": ((256, 256),),
        "hamming_block": (512, 1024), "simhash_block": (128,),
        "hub_tile": (2048,)}, trials=1)
    assert prof.platform == jax.default_backend()
    assert prof.hamming_block in (512, 1024)
    assert reg.histogram("backend.autotune").count == 1
    assert reg.counter("backend.autotune_runs").value == 1
    assert reg.counter("backend.autotune_candidates_timed").value == 2


def test_autotune_ref_lane_skips_timing(monkeypatch):
    """A ref-forced policy has nothing to tune — the sweep returns the
    incoming thresholds without running a single kernel."""
    monkeypatch.setenv("REPRO_LANE", LANE_REF)
    reg = MetricsRegistry()
    prof = autotune(ExecutionPolicy(registry=reg), trials=1)
    assert prof.hamming_block == DEFAULT_PROFILE.hamming_block
    assert prof.gram_block == DEFAULT_PROFILE.gram_block
    assert reg.counter("backend.autotune_candidates_timed").value == 0


def test_policy_profile_steers_plan_default(monkeypatch):
    """plan_for's hub_tile default resolves through the process policy's
    profile, not a frozen module constant."""
    from repro.backend.policy import set_default_policy
    from repro.core import similarity as sim_mod
    g = random_graph(200, 6.0, seed=5)
    try:
        set_default_policy(ExecutionPolicy(
            profile=AutotuneProfile(hub_tile=512)))
        assert sim_mod.plan_for(g).hub_tile == 512
    finally:
        set_default_policy(None)
    assert sim_mod.plan_for(g).hub_tile == 2048


def test_default_policy_singleton():
    pol = default_policy()
    assert default_policy() is pol
    assert pol.registry is not None
