"""Autotune-profile persistence: the ``backend_profile`` manifest leaf.

Three guarantees: a profile round-trips through ``IndexStore`` save/load;
checkpoints written *before* the leaf existed restore to the untuned
default — bit-for-bit today's constants, so old indexes behave exactly as
they always did; and a snapshot saved under a different profile than the
serving policy's surfaces as ``profile_mismatch`` in
``LiveIndexService.status()`` instead of silently retuning.
"""
import asyncio

import pytest

from repro.backend.policy import ExecutionPolicy
from repro.core import EdgeDelta
from repro.backend.profile import DEFAULT_PROFILE, AutotuneProfile
from repro.core import build_index, random_graph
from repro.serve import EngineConfig, IndexStore, LiveIndexService
from repro.serve import store as store_mod


def _graph(n=60, deg=6.0, seed=1):
    return random_graph(n, deg, seed=seed)


def test_profile_roundtrips_through_store(tmp_path):
    g = _graph()
    index = build_index(g, "cosine")
    tuned = AutotuneProfile(platform="cpu", gram_block=64, probe_be=128,
                            hamming_block=512)
    store = IndexStore(str(tmp_path))
    store.save(index, g, profile=tuned)
    assert store.profile() == tuned
    # a later version may carry different thresholds; each reads back its own
    store.save(index, g, profile=DEFAULT_PROFILE)
    assert store.profile() == DEFAULT_PROFILE
    assert store.profile(version=0) == tuned


def test_save_without_profile_persists_default(tmp_path):
    g = _graph(seed=2)
    index = build_index(g, "cosine")
    store = IndexStore(str(tmp_path))
    store.save(index, g)
    assert store.profile() == DEFAULT_PROFILE


def test_old_checkpoint_without_leaf_defaults(tmp_path, monkeypatch):
    """A checkpoint written before the leaf existed (simulated by dropping
    it from the tree) restores to the untuned default — the exact
    constants the engine ran with before autotune existed."""
    real_to_tree = store_mod._to_tree

    def legacy_to_tree(*args, **kw):
        tree = real_to_tree(*args, **kw)
        tree.pop("backend_profile")
        return tree

    monkeypatch.setattr(store_mod, "_to_tree", legacy_to_tree)
    g = _graph(seed=3)
    index = build_index(g, "cosine")
    store = IndexStore(str(tmp_path))
    store.save(index, g, profile=AutotuneProfile(gram_block=64))
    monkeypatch.undo()
    prof = store.profile()
    assert prof == DEFAULT_PROFILE
    assert prof.to_json() == DEFAULT_PROFILE.to_json()   # bit-for-bit
    # and the index itself still loads
    index2, g2, _ = store.load()
    assert index2.n == index.n


def test_profile_mismatch_surfaces_in_status(tmp_path):
    """Restore under a policy tuned differently than the snapshot: the
    service flags the mismatch in status(), keeps serving on the policy's
    thresholds, and the next compaction (which re-persists under the
    serving profile) clears it."""
    g = _graph(seed=4)
    cfg = EngineConfig(max_batch=8, flush_ms=5.0)

    saved_profile = AutotuneProfile(platform="cpu", hamming_block=512)
    svc1 = LiveIndexService(
        str(tmp_path), config=cfg,
        policy=ExecutionPolicy(profile=saved_profile))
    svc1.create("web", g)
    assert svc1.status("web")["backend"]["profile_mismatch"] is False

    serving_profile = AutotuneProfile(platform="cpu", hamming_block=1024,
                                      gram_block=64)
    svc2 = LiveIndexService(
        str(tmp_path), config=cfg,
        policy=ExecutionPolicy(profile=serving_profile))
    svc2.load("web")
    backend = svc2.status("web")["backend"]
    assert backend["profile_mismatch"] is True
    assert backend["stored_profile"]["hamming_block"] == 512
    # serving continues on the policy's thresholds, not the stored ones
    assert backend["profile"]["hamming_block"] == 1024

    async def main():
        async with svc2:
            res = await svc2.query("web", 2, 0.5)
            assert res.n_clusters >= 0
            # advance past snapshot v0 (versions are monotone), then
            # compact: the fresh snapshot carries the serving profile
            await svc2.apply("web", EdgeDelta.make(
                inserts=[(0, 30)], weights=[0.9]))
            svc2.compact("web")
            assert svc2.status("web")["backend"]["profile_mismatch"] is False

    asyncio.run(main())
    # and a fresh restore now agrees with the serving policy
    svc3 = LiveIndexService(
        str(tmp_path), config=cfg,
        policy=ExecutionPolicy(profile=serving_profile))
    svc3.load("web")
    assert svc3.status("web")["backend"]["profile_mismatch"] is False


def test_status_backend_block_shape(tmp_path, monkeypatch):
    # the env var beats EngineConfig(lane=...) by design; clear it so the
    # config-lane assertion below sees the config, not the CI matrix lane
    monkeypatch.delenv("REPRO_LANE", raising=False)
    svc = LiveIndexService(str(tmp_path),
                           config=EngineConfig(max_batch=8, flush_ms=5.0))
    svc.create("web", _graph(seed=5))
    backend = svc.status("web")["backend"]
    assert set(backend) >= {"platform", "forced_lane", "lanes", "profile",
                            "profile_mismatch"}
    assert "bucket_probe" in backend["lanes"]
    # engine config lane flows into the policy the block describes
    svc2 = LiveIndexService(
        str(tmp_path) + "_b",
        config=EngineConfig(max_batch=8, flush_ms=5.0, lane="ref"))
    svc2.create("web", _graph(seed=6))
    assert svc2.status("web")["backend"]["forced_lane"] == "ref"


def test_engine_lane_counters_in_registry(tmp_path):
    """backend.lane.* counters land in the engine's own registry — one
    scrape covers engine.* and backend.* alike."""
    svc = LiveIndexService(str(tmp_path),
                           config=EngineConfig(max_batch=8, flush_ms=5.0))
    svc.create("web", _graph(seed=7))

    async def main():
        async with svc:
            await svc.query("web", 2, 0.5)

    asyncio.run(main())
    counters = svc.engine.registry.snapshot()["counters"]
    assert counters.get("backend.lane.query.ref", 0) >= 1
