"""Hypothesis properties for the shared padding/pow2 helpers
(``repro.backend.padding``) — the invariants every fixed-shape trick in
the repo leans on. Deterministic unit coverage lives in
``test_backend.py``; this module explores the input space when hypothesis
is installed (profiles in ``conftest.py``) and skips cleanly otherwise.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.backend import padding  # noqa: E402


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 1 << 30), st.sampled_from([1, 2, 8, 64]))
def test_pow2ceil_properties(x, floor):
    p = padding.pow2ceil(x, floor=floor)
    assert p >= max(x, floor)
    assert p & (p - 1) == 0                    # a power of two
    assert p == 1 or p // 2 < max(x, floor, 1)  # the *smallest* one


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 1 << 30), st.sampled_from([1, 64, 128]))
def test_pow2_bucket_matches_pow2ceil(total, floor):
    assert padding.pow2_bucket(total, floor=floor) == \
        padding.pow2ceil(total, floor=floor)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(1, 1 << 30), min_size=1, max_size=64))
def test_np_pow2ceil_elementwise(xs):
    arr = np.asarray(xs, np.int64)
    out = padding.np_pow2ceil(arr)
    want = np.asarray([padding.pow2ceil(int(x)) for x in xs], np.int64)
    np.testing.assert_array_equal(out, want)
    # np_log2 is its exact inverse on power-of-two inputs (round trip)
    np.testing.assert_array_equal(1 << padding.np_log2(out), out)


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(-1000, 1000), min_size=0, max_size=64),
       st.integers(0, 32), st.integers(-5, 5))
def test_pad1_roundtrip(xs, pad, fill):
    a = np.asarray(xs, np.int32)
    out = padding.pad1(a, pad, fill)
    assert out.shape == (len(xs) + pad,)
    np.testing.assert_array_equal(out[:len(xs)], a)      # prefix preserved
    assert (out[len(xs):] == fill).all()


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 50), st.integers(1, 50),
       st.sampled_from([1, 4, 8, 32]))
def test_pad_to_roundtrip(r, c, mult):
    import jax.numpy as jnp
    x = jnp.arange(r * c, dtype=jnp.float32).reshape(r, c)
    out = padding.pad_to(x, mult, (0, 1))
    assert out.shape[0] % mult == 0 and out.shape[1] % mult == 0
    assert out.shape[0] - r < mult and out.shape[1] - c < mult
    np.testing.assert_array_equal(np.asarray(out[:r, :c]),
                                  np.asarray(x))          # slice-back exact
    assert float(out.sum()) == float(x.sum())             # zero padding
