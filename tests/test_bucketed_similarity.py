"""Degree-bucketed similarity engine vs the dense oracle.

Precision contract (asserted here):

* **unweighted** graphs (both measures): every intermediate — shared
  counts, degrees, norms² — is a small integer, exact in float32 under any
  reduction order, so the bucketed engine is **bit-identical** to
  ``compute_similarities_dense`` whatever the degree classes, hub tiling,
  or chunking do to the reduction tree;
* **weighted** cosine: float sums are reduction-order-sensitive, so
  engine-vs-oracle agreement is to float32 resolution (≤ ~deg·ulp), while
  the engine itself stays bit-deterministic (subset ≡ full slice, chunked
  ≡ unchunked) — the property the incremental-update oracle relies on.
"""
import jax
import numpy as np
import pytest

from repro.core import (
    EdgeDelta,
    apply_delta,
    build_index,
    compute_similarities,
    compute_similarities_dense,
    compute_similarities_densepad,
    edge_similarities_subset,
    from_edge_list,
    hub_ring_graph,
    plan_for,
    power_law_graph,
    random_graph,
    triangle_counts,
)
from repro.core import similarity as sim_mod
from repro.core.similarity import SimilarityPlan, densepad_operand_bytes

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    hypothesis = None


def assert_matches_oracle(g, measure):
    got = np.asarray(compute_similarities(g, measure))
    want = np.asarray(compute_similarities_dense(g, measure))
    if np.all(np.asarray(g.wgts) == 1.0):
        np.testing.assert_array_equal(got, want)       # bitwise, unweighted
    else:
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-6)


CASES = [
    (random_graph(40, 5.0, seed=1), "cosine"),
    (random_graph(40, 5.0, seed=1), "jaccard"),
    (random_graph(64, 7.0, seed=2, weighted=True), "cosine"),
    (hub_ring_graph(60, 45), "cosine"),
    (hub_ring_graph(60, 45), "jaccard"),
    (power_law_graph(150, 2.1, seed=3, hub_degree=64), "jaccard"),
    (power_law_graph(150, 2.1, seed=4, weighted=True, hub_degree=64),
     "cosine"),
]


@pytest.mark.parametrize("g,measure", CASES)
def test_bucketed_matches_dense_oracle(g, measure):
    assert_matches_oracle(g, measure)


def test_forced_hub_tiling_exact():
    """A deg ≫ median hub forced through multi-tile rows (tiny hub_tile)
    stays bit-identical to the oracle AND to the untiled plan (unweighted:
    tile-order partial sums are integer-exact)."""
    g = hub_ring_graph(80, 60)
    assert int(np.asarray(g.degrees()).max()) == 60      # hub dominates
    assert int(np.median(np.asarray(g.degrees()))) <= 3
    tiled = plan_for(g, hub_tile=16)
    assert int(tiled.vtiles.max()) > 1                   # splitting engaged
    s_tiled = np.asarray(tiled.edge_sims(g.edge_u, g.nbrs, g.wgts, "cosine"))
    s_flat = np.asarray(compute_similarities(g, "cosine"))
    s_oracle = np.asarray(compute_similarities_dense(g, "cosine"))
    np.testing.assert_array_equal(s_tiled, s_oracle)
    np.testing.assert_array_equal(s_flat, s_oracle)


def test_subset_bit_identical_to_full_pass():
    """The frontier-recompute path: any edge subset must reproduce the
    full pass bit-for-bit (this is what lets apply_delta carry σ)."""
    g = power_law_graph(120, 2.1, seed=5, weighted=True, hub_degree=40)
    full = np.asarray(compute_similarities(g, "cosine"))
    eu, ev, w = np.asarray(g.edge_u), np.asarray(g.nbrs), np.asarray(g.wgts)
    rng = np.random.default_rng(0)
    idx = rng.choice(g.m2, size=g.m2 // 3, replace=False)
    sub = np.asarray(edge_similarities_subset(
        g, eu[idx], ev[idx], w[idx], "cosine"))
    np.testing.assert_array_equal(sub, full[idx])


def test_chunked_bit_identical():
    g = power_law_graph(100, 2.1, seed=6, weighted=True, hub_degree=30)
    a = np.asarray(compute_similarities(g, "cosine", chunk=64))
    b = np.asarray(compute_similarities(g, "cosine", chunk=1 << 16))
    np.testing.assert_array_equal(a, b)


def test_triangle_counts_exact():
    g = power_law_graph(90, 2.1, seed=7, hub_degree=40)
    import jax.numpy as jnp
    a = np.asarray(jnp.zeros((g.n, g.n)).at[g.edge_u, g.nbrs].set(1.0))
    ref = (a @ a)[np.asarray(g.edge_u), np.asarray(g.nbrs)].astype(np.int32)
    np.testing.assert_array_equal(np.asarray(triangle_counts(g)), ref)


def test_operand_memory_beats_dense_padding():
    """On a hub graph the bucketed operands are ≥10× smaller than the
    O(n·Δ) dense-padded matrices (the acceptance bar; on real power-law
    graphs the gap grows with n·Δ/m)."""
    g = hub_ring_graph(2048, 512)
    plan = plan_for(g)
    dense_bytes = densepad_operand_bytes(g)
    assert dense_bytes >= 10 * plan.operand_bytes(), (
        dense_bytes, plan.operand_bytes())
    # and the bucketed layout stays O(m + n): blocks ≤ 2·m2 + floor·n slots
    slots = sum(int(np.prod(b.shape)) for b in plan.nbr_blocks)
    assert slots <= 2 * g.m2 + sim_mod.BUCKET_FLOOR * g.n + 2 * len(
        plan.widths) * sim_mod.HUB_TILE


def test_densepad_legacy_path_agrees():
    g = power_law_graph(120, 2.1, seed=8, weighted=True, hub_degree=48)
    a = np.asarray(compute_similarities(g, "cosine"))
    b = np.asarray(compute_similarities_densepad(g, "cosine"))
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=1e-6)


def test_jit_cache_hoisted_across_apply_delta_batches():
    """Repeated apply_delta batches at the same pow2 subset size must reuse
    one compiled kernel per degree-class pair: the bucketed chunk kernel's
    jit cache stops growing after the first batch warms it."""
    g = random_graph(64, 6.0, seed=10)
    idx = build_index(g, "cosine")
    # absent edges to insert and then remove again: every batch is the same
    # pow2 subset size and the same degree-class pairs, so after one warm
    # insert+delete cycle no new kernel shape may appear
    eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
    present = {(int(u), int(v)) for u, v in zip(eu, ev)}
    absent = [(u, v) for u in range(g.n) for v in range(u + 1, g.n)
              if (u, v) not in present][:4]
    ins = EdgeDelta.make(inserts=absent)
    dels = EdgeDelta.make(deletes=absent)

    idx, g, _ = apply_delta(idx, g, ins)          # warm the caches
    idx, g, _ = apply_delta(idx, g, dels)
    warm = sim_mod._bucket_sims_chunk._cache_size()
    for _ in range(3):
        idx, g, info = apply_delta(idx, g, ins)
        assert info.n_frontier > 0
        idx, g, info = apply_delta(idx, g, dels)
        assert info.n_frontier > 0
    assert sim_mod._bucket_sims_chunk._cache_size() == warm


def test_plan_cache_reuses_per_graph_object():
    g = random_graph(30, 4.0, seed=11)
    assert plan_for(g) is plan_for(g)
    p = SimilarityPlan.build(g)
    assert p is not plan_for(g)


def test_isolated_vertices_and_empty_graph():
    g = from_edge_list(12, [(0, 1), (1, 2)])       # vertices 3..11 isolated
    assert_matches_oracle(g, "cosine")
    assert_matches_oracle(g, "jaccard")
    g0 = from_edge_list(6, np.zeros((0, 2), np.int64))
    assert compute_similarities(g0).shape == (0,)
    assert triangle_counts(g0).shape == (0,)


def test_pallas_probe_matches_engine_stats():
    """The Pallas bucket-probe kernel (interpret mode) reproduces the jnp
    engine's shared dot/count on real plan-gathered rows, including a
    tiled hub target (the streaming k-axis)."""
    from repro.kernels import ops as kops

    g = hub_ring_graph(48, 30, weighted=True, seed=2)
    plan = plan_for(g, hub_tile=16)
    eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
    pu, pv, _ = plan.route(eu.astype(np.int64), ev.astype(np.int64))
    # gather full-width rows host-side (tiles concatenated, pad id = n)
    def gather(v):
        c = int(plan.vclass[v])
        blk_n = np.asarray(plan.nbr_blocks[c])
        blk_w = np.asarray(plan.wgt_blocks[c])
        r0, t = int(plan.vrow[v]), int(plan.vtiles[v])
        return blk_n[r0:r0 + t].reshape(-1), blk_w[r0:r0 + t].reshape(-1)

    wmax = max(len(gather(v)[0]) for v in range(g.n))
    rp = np.full((g.m2, wmax), g.n, np.int32)
    wp = np.zeros((g.m2, wmax), np.float32)
    rt = np.full((g.m2, wmax), g.n, np.int32)
    wt = np.zeros((g.m2, wmax), np.float32)
    for e in range(g.m2):
        a, b = gather(pu[e])
        rp[e, :len(a)], wp[e, :len(a)] = a, b
        a, b = gather(pv[e])
        rt[e, :len(a)], wt[e, :len(a)] = a, b
    dot, cnt = kops.bucket_probe_stats(
        jax.numpy.asarray(rp), jax.numpy.asarray(wp),
        jax.numpy.asarray(rt), jax.numpy.asarray(wt), g.n, be=32, bt=16)
    # numpy reference: sorted-set intersection per edge
    w_lut = {}
    for u, v, w in zip(eu, ev, np.asarray(g.wgts)):
        w_lut[(int(u), int(v))] = float(w)
    for e in range(g.m2):
        u, v = int(pu[e]), int(pv[e])
        nu = rp[e][rp[e] < g.n]
        nv = rt[e][rt[e] < g.n]
        shared = np.intersect1d(nu, nv)
        want_cnt = len(shared)
        want_dot = sum(w_lut[(u, int(x))] * w_lut[(v, int(x))]
                       for x in shared)
        assert int(cnt[e]) == want_cnt
        np.testing.assert_allclose(float(dot[e]), want_dot, rtol=1e-5,
                                   atol=1e-6)


# --------------------------------------------------------------------------
# hypothesis property: bucketed ≡ dense oracle
# --------------------------------------------------------------------------
if hypothesis is not None:

    @st.composite
    def graphs(draw):
        n = draw(st.integers(6, 24))
        m = draw(st.integers(0, 2 * n))
        pairs = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        pairs = [(u, v) for u, v in pairs if u != v]
        if draw(st.booleans()):                        # force a hub at 0
            pairs += [(0, v) for v in range(1, n)]
        weighted = draw(st.booleans())
        if not pairs:
            pairs = [(0, 1)]
        w = (draw(st.lists(st.floats(0.1, 1.0, allow_nan=False,
                                     width=32),
                           min_size=len(pairs), max_size=len(pairs)))
             if weighted else None)
        g = from_edge_list(n, np.asarray(pairs, np.int64),
                           np.asarray(w, np.float32) if w else None)
        measure = draw(st.sampled_from(
            ["cosine"] if weighted else ["cosine", "jaccard"]))
        return g, measure

    @settings(max_examples=40, deadline=None)
    @given(graphs())
    def test_hypothesis_bucketed_vs_dense_oracle(case):
        g, measure = case
        assert_matches_oracle(g, measure)
        # engine self-consistency is always bitwise, weighted or not
        a = np.asarray(compute_similarities(g, measure, chunk=32))
        b = np.asarray(compute_similarities(g, measure))
        np.testing.assert_array_equal(a, b)
