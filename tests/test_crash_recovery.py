"""Crash-recovery coverage for the durable write path.

A byte-truncation sweep over a persisted delta chain (every file of the
tail entry, truncated at the start, middle, and last byte) asserts that
restore always lands on the last *intact* entry with the correct
fingerprint; a kill-mid-snapshot test confirms ``.tmp`` wreckage is
ignored and the prior version restores. These are the satellites of the
fsync durability fix in ``repro.ckpt.checkpoint`` — os.rename used to be
the only "commit", which survives a process crash but not a power cut.
"""
import asyncio
import os
import shutil

import numpy as np
import pytest

from repro.ckpt import checkpoint
from repro.core import random_graph
from repro.serve import DeltaLog, EngineConfig, LiveIndexService
from repro.core.update import random_delta


def _service(root, **kw):
    kw.setdefault("config", EngineConfig(max_batch=8, flush_ms=2.0))
    kw.setdefault("compact_every", 100)  # keep the whole chain around
    return LiveIndexService(str(root), **kw)


def _build_chain(root, n_deltas=3, n=40, deg=4.0, k=4):
    """Create an index + apply ``n_deltas`` deltas; → {seq: fingerprint}
    (seq 0 = the snapshot) with the chain fully on disk under root."""
    rng = np.random.default_rng(7)
    svc = _service(root)
    fps = {}

    async def main():
        async with svc:
            svc.create("g", random_graph(n, deg, seed=3, weighted=True))
            fps[0] = svc.fingerprint("g")
            for _ in range(n_deltas):
                await svc.apply("g", random_delta(svc.graph("g"), k, rng))
                fps[svc._live["g"].seq] = svc.fingerprint("g")

    asyncio.run(main())
    return fps


def _entry_files(log_dir, seq):
    step = checkpoint.step_dir(log_dir, seq)
    return sorted(os.path.join(step, f) for f in os.listdir(step))


# --------------------------------------------------------------------------
# byte-truncation sweep over the chain tail
# --------------------------------------------------------------------------
def test_truncation_sweep_restores_last_intact_entry(tmp_path):
    """Tear the tail entry at every file boundary and mid-file; recovery
    must always land exactly one entry back, never crash, never serve a
    half-applied delta."""
    fps = _build_chain(tmp_path / "orig", n_deltas=3)
    index_dir = tmp_path / "orig" / "g"
    log = DeltaLog(str(index_dir))
    last = max(log.sequences())
    assert last == 3

    variants = []
    for path in _entry_files(log.directory, last):
        size = os.path.getsize(path)
        # boundary (empty file), mid-entry, and one byte short
        for cut in sorted({0, size // 2, max(size - 1, 0)}):
            variants.append((os.path.basename(path), cut))

    for fname, cut in variants:
        work = tmp_path / f"case_{fname}_{cut}"
        shutil.copytree(index_dir, work / "g")
        wlog = DeltaLog(str(work / "g"))
        victim = os.path.join(checkpoint.step_dir(wlog.directory, last),
                              fname)
        with open(victim, "r+b") as f:
            f.truncate(cut)

        case = f"{fname} truncated at {cut}"
        assert not wlog.verify(last), case
        removed = wlog.truncate_torn_tail()
        assert removed == [last], case
        assert wlog.sequences() == [1, 2], case
        # the surviving tip still carries the fingerprint the writer
        # recorded for it — the restore target is exact, not approximate
        _, tip_fp = wlog.load(2)
        assert tip_fp == fps[2], case


def test_truncated_manifest_is_torn_too(tmp_path):
    """The manifest itself torn (not just an array leaf) must also read
    as a damaged entry, not a parse crash."""
    _build_chain(tmp_path, n_deltas=1)
    log = DeltaLog(str(tmp_path / "g"))
    man = os.path.join(checkpoint.step_dir(log.directory, 1),
                       "manifest.json")
    with open(man, "r+b") as f:
        f.truncate(os.path.getsize(man) // 2)
    assert not log.verify(1)
    assert log.truncate_torn_tail() == [1]


def test_mid_chain_damage_drops_everything_after(tmp_path):
    """A torn entry strands every later entry (they chain off a delta
    that never durably committed): the whole suffix goes."""
    _build_chain(tmp_path, n_deltas=3)
    log = DeltaLog(str(tmp_path / "g"))
    files = _entry_files(log.directory, 2)
    npys = [f for f in files if f.endswith(".npy")]
    with open(npys[0], "r+b") as f:
        f.truncate(1)
    assert log.truncate_torn_tail() == [2, 3]
    assert log.sequences() == [1]


def test_service_restore_after_torn_tail_serves_verified_state(tmp_path):
    """End to end: a service restarted over a torn chain truncates the
    tail (it owns the chain), replays the intact prefix, and serves the
    fingerprint recorded at the surviving tip."""
    fps = _build_chain(tmp_path, n_deltas=2)
    log = DeltaLog(str(tmp_path / "g"))
    files = [f for f in _entry_files(log.directory, 2)
             if f.endswith(".npy")]
    with open(files[-1], "r+b") as f:
        f.truncate(os.path.getsize(files[-1]) // 2)

    svc = _service(tmp_path)

    async def main():
        async with svc:
            fp = svc.load("g")
            res = await svc.query("g", 2, 0.5)
            return fp, svc._live["g"].seq, res

    fp, seq, res = asyncio.run(main())
    assert seq == 1
    assert fp == fps[1]
    assert res.n_clusters >= 0  # it actually serves


# --------------------------------------------------------------------------
# kill mid-snapshot
# --------------------------------------------------------------------------
def test_tmp_wreckage_ignored_and_prior_version_restores(tmp_path):
    """A crash mid-``save`` leaves a ``.tmp`` directory that must be
    invisible to every reader: latest_step skips it, restore serves the
    previous committed version, and the next commit reuses the slot."""
    fps = _build_chain(tmp_path, n_deltas=1)
    store_dir = tmp_path / "g"
    # fake a writer dying halfway through snapshot version 1
    import pathlib
    wreck = pathlib.Path(checkpoint.step_dir(str(store_dir), 1) + ".tmp")
    wreck.mkdir()
    (wreck / "manifest.json").write_text('{"truncated', encoding="utf-8")
    (wreck / "arr_00000.npy").write_bytes(b"\x93NUMPY garbage")

    assert checkpoint.latest_step(str(store_dir)) == 0

    svc = _service(tmp_path)

    async def main():
        async with svc:
            return svc.load("g"), svc._live["g"].seq

    fp, seq = asyncio.run(main())
    assert seq == 1          # snapshot v0 + the one intact chain entry
    assert fp == fps[1]


def test_verify_step_detects_shape_lies(tmp_path):
    """verify_step is byte-level *and* shape-level: a leaf that loads but
    with the wrong shape (swapped files, partial overwrite) fails."""
    tree = {"a": np.arange(6, dtype=np.int64),
            "b": np.zeros((2, 2), dtype=np.float32)}
    checkpoint.save(str(tmp_path), 0, tree)
    assert checkpoint.verify_step(str(tmp_path), 0)
    step = checkpoint.step_dir(str(tmp_path), 0)
    files = sorted(f for f in os.listdir(step) if f.endswith(".npy"))
    # overwrite one leaf with a differently-shaped valid npy
    np.save(os.path.join(step, files[0]), np.arange(2, dtype=np.int64))
    assert not checkpoint.verify_step(str(tmp_path), 0)


def test_fsync_helpers_roundtrip(tmp_path):
    """fsync_file_then_dir is a no-op semantically — contents unchanged,
    durability only — and works on fresh files in fresh directories."""
    p = tmp_path / "sub" / "f.bin"
    p.parent.mkdir()
    p.write_bytes(b"payload")
    checkpoint.fsync_file_then_dir(str(p))
    checkpoint.fsync_dir(str(tmp_path))
    assert p.read_bytes() == b"payload"
