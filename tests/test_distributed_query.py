"""Sharded `query_batch` ≡ single-device `query_batch`, bit-exactly.

The sharded path (``repro.core.query_batch_sharded``) partitions the
half-edge and CO-slot arrays over a mesh ``data`` axis and finishes with
all-reduced label propagation; every merge is an associative min/max, so
results must equal the single-device path *exactly* — same labels, same
core mask, same cluster count, for every (μ, ε) including the extremes.

Multi-device cases run in a subprocess with
``--xla_force_host_platform_device_count=8`` (the parent process must keep
its real single-device view; see tests/test_distribution.py for the same
pattern).
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import numpy as np
    import jax
    from repro.core import (build_index, from_edge_list, query_batch,
                            query_batch_sharded, query_mesh, random_graph)

    assert jax.device_count() == 8, jax.device_count()

    # μ sweeps past max_cdeg, ε hits both extremes (0 ⇒ every edge similar,
    # 1 ⇒ only σ=1 edges), plus interior settings.
    MUS  = np.asarray([2, 3, 4, 5, 2,   2,   10_000], np.int32)
    EPSS = np.asarray([0.0, 0.3, 0.5, 0.7, 1.0, 0.9, 0.5], np.float32)

    def check(g, mesh, tag):
        idx = build_index(g, "cosine")
        ref = query_batch(idx, g, MUS, EPSS)
        out = query_batch_sharded(idx, g, MUS, EPSS, mesh=mesh)
        for field in ("labels", "is_core", "n_clusters"):
            np.testing.assert_array_equal(
                np.asarray(getattr(out, field)),
                np.asarray(getattr(ref, field)),
                err_msg=f"{tag}:{field}")
        print("CASE_OK", tag, "n=", g.n, "m2=", g.m2,
              "ragged=", g.m2 % mesh.devices.size)

    mesh8 = query_mesh(8)

    # ragged edge count — padding to the axis size is exercised
    g = random_graph(97, 5.0, seed=3)
    assert g.m2 % 8 != 0, g.m2
    check(g, mesh8, "ragged-sparse")

    # weighted graph with planted structure
    g = random_graph(120, 8.0, seed=1, weighted=True, planted_clusters=4)
    check(g, mesh8, "weighted-planted")

    # isolated vertices + fewer edges than shards (every shard mostly pad)
    g = from_edge_list(10, [(0, 1), (1, 2), (7, 8)])
    assert g.m2 < 8, g.m2
    check(g, mesh8, "tiny-isolated")

    # a mesh that uses a strict subset of devices, with non-dividing size
    mesh3 = query_mesh(3)
    g = random_graph(64, 6.0, seed=9)
    check(g, mesh3, "three-way")

    print("ALL_OK")
""")


def _run_subprocess(prog: str) -> subprocess.CompletedProcess:
    return subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": os.environ.get("HOME", "/root")},
        cwd=_REPO, timeout=600)


@pytest.mark.slow
def test_sharded_query_batch_bit_exact_8way():
    """Acceptance criterion: the sharded query path matches the
    single-device path exactly on an 8-way forced host mesh, including
    ragged edge counts that need padding to the axis size."""
    r = _run_subprocess(_PROG)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ALL_OK" in r.stdout
    assert r.stdout.count("CASE_OK") == 4


def test_sharded_query_single_device_degenerate():
    """k=1 mesh in-process: the sharded code path (shard_map, collectives,
    padding) must already be exact with one shard — the cheap always-on
    guard; the 8-way proof lives in the slow lane. Also exercises
    ShardedQueryPlan reuse (pad once, query many — the engine's pattern)."""
    from repro.core import (ShardedQueryPlan, build_index, query_batch,
                            query_batch_sharded, query_mesh, random_graph)

    g = random_graph(60, 5.0, seed=7)
    idx = build_index(g, "cosine")
    mus = np.asarray([2, 3, 9999], np.int32)
    epss = np.asarray([0.0, 0.5, 1.0], np.float32)
    ref = query_batch(idx, g, mus, epss)
    out = query_batch_sharded(idx, g, mus, epss, mesh=query_mesh(1))
    np.testing.assert_array_equal(np.asarray(out.labels),
                                  np.asarray(ref.labels))
    np.testing.assert_array_equal(np.asarray(out.is_core),
                                  np.asarray(ref.is_core))
    np.testing.assert_array_equal(np.asarray(out.n_clusters),
                                  np.asarray(ref.n_clusters))

    plan = ShardedQueryPlan(idx, g, query_mesh(1))
    for _ in range(2):                       # same plan, repeated calls
        out2 = plan(mus, epss)
        np.testing.assert_array_equal(np.asarray(out2.labels),
                                      np.asarray(ref.labels))


def test_query_mesh_rejects_oversubscription():
    from repro.core import query_mesh

    with pytest.raises(ValueError, match="devices"):
        query_mesh(4096)
