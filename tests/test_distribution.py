"""Distribution tests that need multiple devices run in a subprocess with
--xla_force_host_platform_device_count (tests must not pollute the parent
process's device count). In-process tests cover the spec rules themselves.
"""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.configs.base import get_config, all_arch_ids
from repro.models import model as mdl


def _leaf_shapes(cfg):
    import jax.numpy as jnp
    return jax.eval_shape(lambda k: mdl.init_params(cfg, k),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


@pytest.mark.parametrize("arch", all_arch_ids())
def test_param_specs_divisible(arch):
    """Every sharded dim must divide by its mesh-axis size for the 16x16
    production mesh — the rule the fallback chain exists to guarantee."""
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    from repro.dist.sharding import Sharder
    cfg = get_config(arch)
    sharder = Sharder.__new__(Sharder)
    sharder.mesh = FakeMesh()
    sharder.cfg = cfg
    sharder.tp = 16
    sharder.dp_axes = ("data",)
    sharder.dp = 16
    shapes = _leaf_shapes(cfg)
    specs = sharder.param_specs(shapes)

    def check(path, leaf, spec):
        for dim, part in enumerate(spec):
            if part is None:
                continue
            size = 16 if not isinstance(part, tuple) else 16 ** len(part)
            assert leaf.shape[dim] % size == 0, (arch, path, leaf.shape, spec)

    flat_shapes, _ = jax.tree_util.tree_flatten_with_path(shapes)
    flat_specs = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_shapes) == len(flat_specs)
    for (path, leaf), spec in zip(flat_shapes, flat_specs):
        check(path, leaf, spec)


def test_big_params_are_sharded():
    """No tensor > 64M elements may stay fully replicated on the 16-way TP
    mesh (memory posture)."""
    from jax.sharding import PartitionSpec as P
    from repro.dist.sharding import Sharder

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    for arch in all_arch_ids():
        cfg = get_config(arch)
        sharder = Sharder.__new__(Sharder)
        sharder.mesh = FakeMesh()
        sharder.cfg = cfg
        sharder.tp = 16
        sharder.dp_axes = ("data",)
        sharder.dp = 16
        shapes = _leaf_shapes(cfg)
        specs = sharder.param_specs(shapes)
        flat_shapes, _ = jax.tree_util.tree_flatten_with_path(shapes)
        flat_specs = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        for (path, leaf), spec in zip(flat_shapes, flat_specs):
            if int(np.prod(leaf.shape)) >= (1 << 26):
                assert any(p is not None for p in spec), (arch, path, leaf.shape)


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs.base import get_config
    from repro.models import model as mdl
    from repro.dist import ep as ep_mod
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = get_config("deepseek-v2-lite-16b").scaled(
        n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, head_dim=8,
        n_experts=8, top_k=2, d_ff=16, d_ff_dense=64, first_dense_layers=1,
        kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=8, v_head_dim=8,
        vocab=128, dtype="float32", capacity_factor=4.0, q_chunk=16)
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)

    with mesh:
        logits_pjit, aux1 = jax.jit(
            lambda p, t: mdl.forward(cfg, p, {"tokens": t}))(params, tokens)
        ep_mod.set_ep_mesh(mesh, ("data",), "model")
        cfg_ep = cfg.scaled(moe_impl="ep")
        logits_ep, aux2 = jax.jit(
            lambda p, t: mdl.forward(cfg_ep, p, {"tokens": t}))(params, tokens)
        # EP gradients flow
        def loss(p, t):
            lg, aux = mdl.forward(cfg_ep, p, {"tokens": t})
            return jnp.mean(lg ** 2) + aux
        g = jax.jit(jax.grad(loss))(params, tokens)
        gnorm = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    err = float(jnp.max(jnp.abs(logits_pjit - logits_ep)))
    assert err < 2e-4, f"EP vs pjit mismatch: {err}"
    assert abs(float(aux1) - float(aux2)) < 1e-5
    assert np.isfinite(gnorm) and gnorm > 0
    print("EP_OK", err, gnorm)

    # sharded SCAN similarity pass (edge-parallel shard_map over the
    # degree-bucketed groups; class blocks replicated, ragged group sizes
    # padded to the axis size internally)
    from repro.core import random_graph, power_law_graph, compute_similarities
    from repro.core.similarity import plan_for
    from repro.core.distributed import sharded_edge_similarities
    for g2 in (random_graph(48, 6.0, seed=3),
               power_law_graph(64, 2.1, seed=4, hub_degree=24)):
        with mesh:
            sims_sharded = sharded_edge_similarities(g2, plan_for(g2), mesh)
        sims_ref = compute_similarities(g2)
        err2 = float(jnp.max(jnp.abs(sims_sharded - sims_ref)))
        assert err2 < 1e-5, err2
    print("SCAN_SHARD_OK", err2)
""")


@pytest.mark.slow
def test_ep_and_sharded_scan_multidevice():
    """shard_map EP MoE ≡ pjit MoE, and edge-sharded SCAN similarity ≡
    single-device — on an 8-device (2×4) host-platform mesh."""
    r = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"},
                       cwd="/root/repo", timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "EP_OK" in r.stdout and "SCAN_SHARD_OK" in r.stdout


@pytest.mark.slow
def test_dryrun_one_cell_subprocess():
    """Integration: the actual dry-run driver on the cheapest cell (512
    host devices, single-pod mesh) — proves the assignment's entry point."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-780m",
         "--shape", "decode_32k", "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd="/root/repo", timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "[dryrun] OK" in r.stdout
    rec = json.load(open("/tmp/dryrun_test/pod16x16/mamba2-780m__decode_32k.json"))
    assert rec["status"] == "ok"
    assert rec["devices"] == 256
    assert rec["flops_per_device"] > 0
    assert rec["roofline"]["dominant"] in ("compute_s", "memory_s",
                                           "collective_s")
