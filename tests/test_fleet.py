"""Replicated read fleet: tail convergence with bit-identity, consistent
hash affinity, crash failover, corrupt-entry detection + snapshot resync,
graceful staleness, hedged requests, and the chaos acceptance run (3
replicas, mixed global+seed traffic, one killed mid-stream, zero
bit-divergent answers against a side-replayed oracle)."""
import asyncio
import time

import numpy as np
import pytest

from repro.core import (EdgeDelta, apply_delta, build_index, query,
                        random_graph)
from repro.core.local import query_seeds
from repro.core.update import random_delta
from repro.serve import (ChaosPolicy, DeltaLog, EngineConfig, Fleet,
                         FleetAnswer, FleetExhausted, FleetRouter,
                         LiveIndexService, Overloaded, ReadReplica,
                         RouterConfig, corrupt_entry)

CFG = EngineConfig(max_batch=8, flush_ms=2.0)


def _graph(n=50, deg=5.0, seed=2):
    return random_graph(n, deg, seed=seed, weighted=True)


def _fleet(root, **kw):
    kw.setdefault("writer_config", CFG)
    kw.setdefault("poll_s", 0.01)
    kw.setdefault("router_config", RouterConfig(timeout_s=5.0,
                                                hedge_after_s=1.0))
    return Fleet(str(root), **kw)


# --------------------------------------------------------------------------
# replication basics
# --------------------------------------------------------------------------
def test_replicas_converge_and_answers_are_bit_identical(tmp_path):
    """Every replica tails the chain to the writer's seq, and a routed
    answer equals the writer's own engine answer bit for bit."""
    rng = np.random.default_rng(0)

    async def main():
        async with _fleet(tmp_path, n_replicas=2) as fleet:
            fleet.create("g", _graph())
            for _ in range(3):
                await fleet.apply("g", random_delta(fleet.writer.graph("g"),
                                                    6, rng))
            assert await fleet.converged("g", timeout_s=20)
            ans = await fleet.query("g", 3, 0.4)
            ref = await fleet.writer.query("g", 3, 0.4)
            assert ans.seq == fleet.target_seq("g")
            assert ans.fingerprint == fleet.writer.fingerprint("g")
            np.testing.assert_array_equal(np.asarray(ans.result.labels),
                                          np.asarray(ref.labels))
            np.testing.assert_array_equal(np.asarray(ans.result.is_core),
                                          np.asarray(ref.is_core))
            snap = fleet.metrics_snapshot()
            # both replicas replayed all 3 entries and hot-swapped
            assert snap["counters"]["fleet.replays"] == 6
            assert snap["counters"]["fleet.swaps"] == 6
            assert snap["gauges"]["fleet.staleness_seq"] == 0.0
            assert snap["gauge_modes"]["fleet.staleness_seq"] == "max"

    asyncio.run(main())


def test_seed_queries_route_through_fleet(tmp_path):
    async def main():
        async with _fleet(tmp_path, n_replicas=2) as fleet:
            g = _graph()
            fleet.create("g", g)
            assert await fleet.converged("g", timeout_s=20)
            full = await fleet.writer.query("g", 2, 0.5)
            for seed in (0, 7, 23):
                ans = await fleet.query_seed("g", seed, 2, 0.5)
                assert isinstance(ans, FleetAnswer)
                assert ans.result.label == int(
                    np.asarray(full.labels)[seed])

    asyncio.run(main())


def test_hash_affinity_is_stable(tmp_path):
    """One name's traffic sticks to one replica (cache affinity); the
    routed order is deterministic for a given replica set."""
    async def main():
        async with _fleet(tmp_path, n_replicas=3) as fleet:
            fleet.create("g", _graph())
            assert await fleet.converged("g", timeout_s=20)
            order = fleet.router.route("g")
            assert [r.replica_id for r in fleet.router.route("g")] == \
                [r.replica_id for r in order]
            served = {(await fleet.query("g", 2, 0.5)).replica
                      for _ in range(6)}
            assert served == {order[0].replica_id}
            # distinct keys spread over the ring (not all on one node)
            firsts = {fleet.router.route(f"key-{i}")[0].replica_id
                      for i in range(32)}
            assert len(firsts) > 1

    asyncio.run(main())


# --------------------------------------------------------------------------
# failure handling
# --------------------------------------------------------------------------
def test_crash_failover_keeps_answering(tmp_path):
    async def main():
        async with _fleet(tmp_path, n_replicas=2) as fleet:
            fleet.create("g", _graph())
            assert await fleet.converged("g", timeout_s=20)
            primary = fleet.router.route("g")[0]
            await primary.crash()
            for _ in range(4):
                ans = await fleet.query("g", 3, 0.4)
                assert ans.replica != primary.replica_id
            snap = fleet.metrics_snapshot()
            assert snap["counters"]["fleet.crashes"] == 1
            assert snap["counters"]["fleet.failovers"] >= 1
            # all replicas down → typed exhaustion, not a hang
            await fleet.router.route("g")[0].crash()
            for rep in fleet.replicas:
                if rep.healthy:
                    await rep.stop()
            with pytest.raises(FleetExhausted):
                await fleet.query("g", 3, 0.4)

    asyncio.run(main())


def test_corrupt_entry_detected_and_never_served(tmp_path):
    """The acceptance property for corruption: a damaged chain entry —
    whether it fails storage verification or loads-but-diverges — is
    refused; the replica keeps serving its last verified version (stale,
    consistent, counted). The replica starts *after* the damage so
    detection is deterministic, not a poll race."""
    rng = np.random.default_rng(3)
    root = tmp_path

    async def write_side():
        svc = LiveIndexService(str(root), config=CFG, compact_every=100)
        async with svc:
            svc.create("g", _graph())
            for _ in range(2):
                await svc.apply("g", random_delta(svc.graph("g"), 6, rng))
            return svc.fingerprint("g")

    final_fp = asyncio.run(write_side())
    # damage entry 2 on disk: depending on which leaf the scribble hits,
    # this reads as torn storage or as loads-fine-wrong-bits — the
    # replica must refuse it either way
    log = DeltaLog(str(root / "g"))
    corrupt_entry(log.directory, 2, mode="scribble")

    async def read_side():
        rep = ReadReplica("r0", str(root), config=CFG, poll_s=0.01)
        await rep.start()
        try:
            deadline = time.monotonic() + 10
            while time.monotonic() < deadline and rep.seq("g") < 1:
                await asyncio.sleep(0.01)
            await asyncio.sleep(0.1)
            assert rep.seq("g") == 1       # entry 2 refused, held position
            ans = await rep.query("g", 2, 0.5)
            assert ans.seq == 1
            assert ans.fingerprint != final_fp
            c = rep.registry
            assert (c.counter("fleet.fingerprint_mismatches").value
                    + c.counter("fleet.corrupt_entries").value) >= 1
            assert c.gauge("fleet.staleness_seq").value >= 1
            # the chain is the writer's: the reader never truncated it
            assert log.sequences() == [1, 2]
        finally:
            await rep.stop()

    asyncio.run(read_side())


def test_corrupt_entry_recovery_via_snapshot_resync(tmp_path):
    """A replica stuck behind a torn entry recovers the moment the
    writer's compaction publishes a snapshot past the damage — through
    the resync path, never by touching the chain. Chaos delayed delivery
    pins the replica behind the entry long enough to corrupt it
    deterministically."""
    rng = np.random.default_rng(13)
    chaos = ChaosPolicy(delay_p=1.0, delay_s=0.4)

    async def main():
        svc = LiveIndexService(str(tmp_path), config=CFG,
                               compact_every=100)
        async with svc:
            svc.create("g", _graph())
            rep = ReadReplica("r0", str(tmp_path), config=CFG,
                              poll_s=0.01, chaos=chaos)
            await rep.start()
            try:
                await svc.apply("g", random_delta(svc.graph("g"), 6, rng))
                await svc.apply("g", random_delta(svc.graph("g"), 6, rng))
                # the replica will not look at entry 2 for delay_s yet —
                # a deterministic window to tear it on disk
                log = DeltaLog(str(tmp_path / "g"))
                corrupt_entry(log.directory, 2, mode="truncate")
                deadline = time.monotonic() + 10
                while time.monotonic() < deadline and rep.seq("g") < 1:
                    await asyncio.sleep(0.01)
                await asyncio.sleep(0.6)
                assert rep.seq("g") == 1   # stuck behind the torn entry
                assert rep.registry.counter(
                    "fleet.corrupt_entries").value >= 1

                # the writer still holds seq 2 in memory: compaction
                # snapshots v2 and prunes the (damaged) chain prefix
                svc.compact("g")
                deadline = time.monotonic() + 10
                while time.monotonic() < deadline and rep.seq("g") < 2:
                    await asyncio.sleep(0.01)
                assert rep.seq("g") == 2
                assert rep.registry.counter("fleet.resyncs").value >= 1
                ans = await rep.query("g", 2, 0.5)
                ref = await svc.query("g", 2, 0.5)
                assert ans.seq == 2
                np.testing.assert_array_equal(np.asarray(ans.result.labels),
                                              np.asarray(ref.labels))
            finally:
                await rep.stop()

    asyncio.run(main())


def test_torn_entry_holds_position_without_touching_chain(tmp_path):
    """A *truncated* entry fails storage verification; the replica holds
    at last-good and — critically — does not truncate the writer-owned
    chain (the writer may still be mid-append)."""
    rng = np.random.default_rng(4)

    async def write_side():
        svc = LiveIndexService(str(tmp_path), config=CFG,
                               compact_every=100)
        async with svc:
            svc.create("g", _graph())
            await svc.apply("g", random_delta(svc.graph("g"), 6, rng))

    asyncio.run(write_side())
    log = DeltaLog(str(tmp_path / "g"))
    corrupt_entry(log.directory, 1, mode="truncate")
    assert not log.verify(1)

    async def read_side():
        rep = ReadReplica("r0", str(tmp_path), config=CFG, poll_s=0.01)
        await rep.start()
        try:
            await asyncio.sleep(0.15)
            assert rep.seq("g") == 0
            assert rep.registry.counter("fleet.corrupt_entries").value >= 1
            # the chain entry is still there — reader never deleted it
            assert log.sequences() == [1]
            ans = await rep.query("g", 2, 0.5)
            assert ans.seq == 0
        finally:
            await rep.stop()

    asyncio.run(read_side())


def test_delayed_delivery_serves_stale_then_catches_up(tmp_path):
    """Chaos delayed delivery: the replica answers from its last-good
    version while the entry is 'in flight', then converges."""
    rng = np.random.default_rng(5)
    chaos = ChaosPolicy(delay_p=1.0, delay_s=0.3)

    async def main():
        async with _fleet(tmp_path, n_replicas=1, chaos=chaos) as fleet:
            fleet.create("g", _graph())
            await asyncio.sleep(0.05)
            await fleet.apply("g", random_delta(fleet.writer.graph("g"),
                                                6, rng))
            ans = await fleet.query("g", 2, 0.5)
            assert ans.seq == 0  # stale, but served
            assert await fleet.converged("g", timeout_s=20)
            ans2 = await fleet.query("g", 2, 0.5)
            assert ans2.seq == 1
            snap = fleet.metrics_snapshot()
            assert snap["counters"]["fleet.delayed_entries"] >= 1

    asyncio.run(main())


def test_hedged_request_wins_on_slow_primary(tmp_path):
    """If the primary sits on a request past hedge_after_s, the sibling
    is raced in and its (identical) answer wins."""
    async def main():
        async with _fleet(tmp_path, n_replicas=2,
                          router_config=RouterConfig(
                              timeout_s=5.0, hedge_after_s=0.05)) as fleet:
            fleet.create("g", _graph())
            assert await fleet.converged("g", timeout_s=20)
            primary = fleet.router.route("g")[0]
            real = primary.query

            async def slow_query(*a, **kw):
                await asyncio.sleep(0.5)
                return await real(*a, **kw)

            primary.query = slow_query
            try:
                t0 = time.monotonic()
                ans = await fleet.query("g", 3, 0.4)
                elapsed = time.monotonic() - t0
            finally:
                primary.query = real
            assert ans.replica != primary.replica_id
            assert elapsed < 0.5
            snap = fleet.metrics_snapshot()
            assert snap["counters"]["fleet.hedges"] >= 1
            assert snap["counters"]["fleet.hedge_wins"] >= 1

    asyncio.run(main())


def test_overload_spills_then_surfaces_typed(tmp_path):
    """An Overloaded primary spills to a sibling; an all-shed fleet
    surfaces the Overloaded (with retry_after) instead of exhausting."""
    async def main():
        async with _fleet(tmp_path, n_replicas=2) as fleet:
            fleet.create("g", _graph())
            assert await fleet.converged("g", timeout_s=20)

            def shedding(rep):
                async def f(*a, **kw):
                    raise Overloaded(retry_after=0.5, reason="queue_depth")
                return f

            order = fleet.router.route("g")
            real0 = order[0].query
            order[0].query = shedding(order[0])
            try:
                ans = await fleet.query("g", 3, 0.4)
                assert ans.replica == order[1].replica_id
                real1 = order[1].query
                order[1].query = shedding(order[1])
                try:
                    with pytest.raises(Overloaded) as ei:
                        await fleet.query("g", 3, 0.4)
                    assert ei.value.retry_after == pytest.approx(0.5)
                finally:
                    order[1].query = real1
            finally:
                order[0].query = real0
            snap = fleet.metrics_snapshot()
            assert snap["counters"]["fleet.overload_spills"] >= 2

    asyncio.run(main())


# --------------------------------------------------------------------------
# chaos acceptance
# --------------------------------------------------------------------------
def test_chaos_acceptance_crash_under_mixed_traffic(tmp_path):
    """The PR's acceptance bar: 3 replicas under mixed global+seed
    traffic with live deltas; one replica is killed mid-stream. The
    router must keep answering (bounded typed-error rate) and every
    answer must be bit-identical to a single-engine oracle replaying the
    same chain — staleness is allowed, divergence is not."""
    rng = np.random.default_rng(11)
    settings = [(2, 0.3), (3, 0.5), (2, 0.7), (4, 0.4)]
    seeds = [0, 5, 17, 31]

    async def main():
        async with _fleet(tmp_path, n_replicas=3) as fleet:
            g0 = _graph(n=60, deg=6.0, seed=9)
            fleet.create("g", g0)
            assert await fleet.converged("g", timeout_s=30)

            # side oracle: seq → (index, graph), replayed independently
            oracle = {0: (fleet.writer.index("g"), fleet.writer.graph("g"))}
            answers, errors = [], []

            async def traffic(k):
                for j, (mu, eps) in enumerate(settings):
                    try:
                        a = await fleet.query("g", mu, eps,
                                              client=f"c{k % 3}")
                        answers.append((a, mu, eps, None))
                        s = seeds[(k + j) % len(seeds)]
                        a2 = await fleet.query_seed("g", s, mu, eps,
                                                    client=f"c{k % 3}")
                        answers.append((a2, mu, eps, s))
                    except (Overloaded, FleetExhausted) as e:
                        errors.append(e)
                    await asyncio.sleep(0.002)

            victim = fleet.router.route("g")[0]
            for wave in range(3):
                if wave == 1:
                    await victim.crash()      # mid-stream
                delta = random_delta(fleet.writer.graph("g"), 6, rng)
                await fleet.apply("g", delta)
                seq = fleet.target_seq("g")
                idx, gg = oracle[seq - 1]
                oracle[seq] = apply_delta(idx, gg, delta, "cosine")[:2]
                await asyncio.gather(*[traffic(k) for k in range(4)])

            survivors = [r for r in fleet.replicas if r.healthy]
            assert len(survivors) == 2
            assert await fleet.converged("g", timeout_s=30)

            # zero bit-divergence: every answer matches the oracle AT THE
            # SEQ IT WAS SERVED FROM (stale-but-consistent is legal)
            checked = 0
            for a, mu, eps, seed in answers:
                idx, gg = oracle[a.seq]
                ref = query(idx, gg, mu, eps)
                if seed is None:
                    np.testing.assert_array_equal(
                        np.asarray(a.result.labels), np.asarray(ref.labels))
                else:
                    assert a.result.label == int(
                        np.asarray(ref.labels)[seed])
                    assert a.result.is_core == bool(
                        np.asarray(ref.is_core)[seed])
                checked += 1
            assert checked >= 48  # traffic actually flowed

            # bounded typed-error rate: the crash may shed a few requests
            # as typed failures, never more than a sliver of the stream
            assert len(errors) <= checked // 4
            snap = fleet.metrics_snapshot()
            assert snap["counters"]["fleet.crashes"] == 1
            assert snap["counters"]["fleet.requests"] >= checked

    asyncio.run(main())


def test_chaos_policy_is_seeded_and_parseable():
    p = ChaosPolicy.parse("crash:0.02,stall:0.05,corrupt:0.1", seed=42)
    assert (p.crash_p, p.stall_p, p.corrupt_p) == (0.02, 0.05, 0.1)
    assert p.seed == 42
    with pytest.raises(ValueError):
        ChaosPolicy.parse("meteor:1.0")
    # same seed → same draw sequence (replayable soaks)
    a = ChaosPolicy(seed=1, stall_p=0.5)
    b = ChaosPolicy(seed=1, stall_p=0.5)
    assert [a.stall_seconds("r") for _ in range(16)] == \
        [b.stall_seconds("r") for _ in range(16)]
    # crash budget: never below max_crashes
    c = ChaosPolicy(seed=0, crash_p=1.0, max_crashes=1)
    assert c.should_crash("r0") is True
    assert c.should_crash("r1") is False
    assert c.crashes_injected == 1
