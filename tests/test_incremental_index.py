"""Edit-script oracle for incremental GS*-Index maintenance.

The invariant under test: after ANY sequence of edge insert/delete batches,
the incrementally maintained index (``repro.core.update.apply_delta``) is
**bit-identical** to ``build_index`` run from scratch on the resulting edge
set — every array, every dtype, every static — and ``query_batch`` answers
are identical across a (μ, ε) grid.

Two generators drive the oracle:

  * deterministic seeded scripts (always run, no external deps) covering
    the adversarial edit classes: weighted edges, weight overwrites,
    isolated-vertex creation (deleting a vertex's last edge) and removal
    (re-attaching it), re-inserting a deleted edge, delete+insert of the
    same edge in one batch, emptying the graph, and repopulating it;
  * hypothesis-generated random scripts (run when hypothesis is installed
    — CI's fast lane, with the seed-pinned profile from conftest).
"""
import gc

import numpy as np
import pytest

from repro.core import (EdgeDelta, apply_delta, build_index, from_edge_list,
                        query_batch, random_graph)
from repro.core import similarity as sim_mod
from repro.core.similarity import SimilarityPlan

from _plan_oracle import assert_plan_equal

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    hypothesis = None

INDEX_FIELDS = ("offsets_c", "no_nbrs", "no_sims", "no_self", "co_offsets",
                "co_vertex", "co_theta", "cdeg", "edge_sims")
GRAPH_FIELDS = ("offsets", "nbrs", "wgts", "edge_u")


def canonical_edges(g):
    eu, ev, w = np.asarray(g.edge_u), np.asarray(g.nbrs), np.asarray(g.wgts)
    m = eu < ev
    return np.stack([eu[m], ev[m]], axis=1), w[m]


def rebuild(g, measure="cosine"):
    """From-scratch reference: new graph + new index off the edge list."""
    edges, w = canonical_edges(g)
    g_ref = from_edge_list(g.n, edges, w)
    return build_index(g_ref, measure), g_ref


def assert_bit_identical(idx, g, idx_ref, g_ref, tag=""):
    for f in GRAPH_FIELDS:
        a, b = np.asarray(getattr(g, f)), np.asarray(getattr(g_ref, f))
        assert a.dtype == b.dtype, (tag, f)
        np.testing.assert_array_equal(a, b, err_msg=f"{tag} graph.{f}")
    assert (g.n, g.m2) == (g_ref.n, g_ref.m2), tag
    for f in INDEX_FIELDS:
        a, b = np.asarray(getattr(idx, f)), np.asarray(getattr(idx_ref, f))
        assert a.dtype == b.dtype, (tag, f, a.dtype, b.dtype)
        assert a.shape == b.shape, (tag, f, a.shape, b.shape)
        np.testing.assert_array_equal(a, b, err_msg=f"{tag} index.{f}")
    assert (idx.n, idx.m2c, idx.max_cdeg) == \
        (idx_ref.n, idx_ref.m2c, idx_ref.max_cdeg), tag
    # the incrementally maintained similarity plan (seeded into the cache
    # by apply_delta) must equal a from-scratch build array-for-array too —
    # blocks, routing tables, norms, every bit
    maintained = sim_mod.cached_plan(g)
    assert maintained is not None, (tag, "apply_delta must seed the plan")
    assert_plan_equal(maintained, SimilarityPlan.build(g), f"{tag} plan")


def assert_queries_identical(idx, g, idx_ref, g_ref, tag=""):
    mus = np.asarray([2, 2, 3, 4, 5], np.int32)
    epss = np.asarray([0.05, 0.5, 0.3, 0.7, 0.95], np.float32)
    got = query_batch(idx, g, mus, epss)
    ref = query_batch(idx_ref, g_ref, mus, epss)
    for f in ("labels", "is_core", "n_clusters"):
        np.testing.assert_array_equal(
            np.asarray(getattr(got, f)), np.asarray(getattr(ref, f)),
            err_msg=f"{tag} query.{f}")


# --------------------------------------------------------------------------
# deterministic edit-script oracle (always runs)
# --------------------------------------------------------------------------
def test_scripted_edit_classes_bit_identical():
    """One long script through every adversarial edit class, asserting
    bit-identity after every step and query equality at checkpoints."""
    g = random_graph(48, 5.0, seed=2, weighted=True)
    idx = build_index(g, "cosine")

    def step(delta, tag, queries=False):
        nonlocal idx, g
        idx, g, info = apply_delta(idx, g, delta)
        idx_ref, g_ref = rebuild(g)
        assert_bit_identical(idx, g, idx_ref, g_ref, tag)
        if queries:
            assert_queries_identical(idx, g, idx_ref, g_ref, tag)
        return info

    # weighted inserts (incl. one weight overwrite of an existing edge)
    eu0, ev0 = int(np.asarray(g.edge_u)[0]), int(np.asarray(g.nbrs)[0])
    info = step(EdgeDelta.make(
        inserts=[(1, 40), (2, 33), (min(eu0, ev0), max(eu0, ev0))],
        weights=[0.25, 0.75, 0.5]), "weighted-insert", queries=True)
    assert info.n_inserted == 3 and info.n_deleted == 0

    # delete a vertex's last edges → isolated-vertex creation
    eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
    v_iso = int(eu[0])
    last = [(int(u), int(v)) for u, v in zip(eu, ev) if u == v_iso]
    info = step(EdgeDelta.make(deletes=last), "isolate")
    assert np.asarray(g.degrees())[v_iso] == 0
    assert info.n_deleted == len(last)

    # re-attach the isolated vertex (isolated-vertex removal) and
    # re-insert one previously deleted edge
    back = last[0]
    info = step(EdgeDelta.make(inserts=[(v_iso, (v_iso + 7) % g.n), back],
                               weights=[1.0, 0.9]),
                "reattach", queries=True)
    assert info.n_inserted == 2

    # delete + insert the same edge in one batch (reinsert-with-new-weight)
    info = step(EdgeDelta.make(inserts=[back], weights=[0.1],
                               deletes=[back]), "del+ins-same-batch")
    assert info.n_deleted == 1 and info.n_inserted == 1

    # no-op batch: delete an absent edge, re-insert an identical edge
    w_now = None
    eu, ev, wn = (np.asarray(g.edge_u), np.asarray(g.nbrs),
                  np.asarray(g.wgts))
    for u, v, w in zip(eu, ev, wn):
        if u < v:
            w_now = (int(u), int(v), float(w))
            break
    info = step(EdgeDelta.make(inserts=[w_now[:2]], weights=[w_now[2]],
                               deletes=[(0, g.n - 1)
                                        if not _has_edge(g, 0, g.n - 1)
                                        else (1, g.n - 1)]), "noop")
    assert info.n_inserted == 0 and info.n_deleted == 0
    assert info.n_frontier == 0 and info.n_affected_rows == 0

    # empty the graph entirely, then repopulate from nothing
    edges, _ = canonical_edges(g)
    step(EdgeDelta.make(deletes=edges), "empty")
    assert g.m2 == 0
    step(EdgeDelta.make(inserts=[(0, 1), (1, 2), (0, 2), (5, 9)],
                        weights=[0.3, 0.6, 0.9, 1.0]),
         "repopulate", queries=True)


def _has_edge(g, u, v):
    eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
    return bool(np.any((eu == u) & (ev == v)))


def test_random_scripts_bit_identical():
    """Seeded random scripts over a few graph shapes: every step must stay
    bit-identical; queries checked on the final state of each script."""
    for seed, n, deg, weighted in ((0, 30, 4.0, False), (1, 44, 6.0, True)):
        rng = np.random.default_rng(seed)
        g = random_graph(n, deg, seed=seed, weighted=weighted)
        idx = build_index(g, "cosine")
        for step in range(4):
            k_ins = int(rng.integers(0, 6))
            k_del = int(rng.integers(0, 6))
            ins = rng.integers(0, n, size=(k_ins, 2))
            w = rng.uniform(0.1, 1.0, size=k_ins).astype(np.float32)
            edges, _ = canonical_edges(g)
            if len(edges) and k_del:
                dels = edges[rng.integers(0, len(edges), size=k_del)]
            else:
                dels = rng.integers(0, n, size=(k_del, 2))
            idx, g, _ = apply_delta(
                idx, g, EdgeDelta.make(inserts=ins, weights=w, deletes=dels))
            idx_ref, g_ref = rebuild(g)
            assert_bit_identical(idx, g, idx_ref, g_ref,
                                 f"seed={seed} step={step}")
        assert_queries_identical(idx, g, idx_ref, g_ref, f"seed={seed}")


@pytest.mark.slow
def test_random_scripts_thorough():
    """Slow-lane soak: bigger graphs, longer scripts, larger batches, and
    query-grid equality after EVERY step (the fast lane checks queries at
    script checkpoints only)."""
    base = sim_mod.plan_cache_size()   # other modules' live graphs cache too
    for seed in range(3):
        n = 80 + 40 * seed
        rng = np.random.default_rng(100 + seed)
        g = random_graph(n, 8.0, seed=seed, weighted=(seed % 2 == 0))
        idx = build_index(g, "cosine")
        for step in range(6):
            k_ins = int(rng.integers(0, 16))
            k_del = int(rng.integers(0, 16))
            ins = rng.integers(0, n, size=(k_ins, 2))
            w = rng.uniform(0.1, 1.0, size=k_ins).astype(np.float32)
            edges, _ = canonical_edges(g)
            dels = (edges[rng.integers(0, len(edges), size=k_del)]
                    if len(edges) and k_del
                    else rng.integers(0, n, size=(k_del, 2)))
            idx, g, _ = apply_delta(
                idx, g, EdgeDelta.make(inserts=ins, weights=w, deletes=dels))
            idx_ref, g_ref = rebuild(g)
            tag = f"thorough seed={seed} step={step}"
            assert_bit_identical(idx, g, idx_ref, g_ref, tag)
            assert_queries_identical(idx, g, idx_ref, g_ref, tag)
            # soak guard: repeated deltas must not regrow device memory —
            # dead graphs' plan-cache entries die with their graphs, so
            # beyond the pre-test baseline only the live graph and this
            # step's rebuild reference may remain
            gc.collect()
            assert sim_mod.plan_cache_size() <= base + 2, \
                f"{tag}: plan cache regrew to {sim_mod.plan_cache_size()}"


def test_degree_growth_never_triggers_full_resim():
    """Regression for the old dense-padded fallback: growing one vertex
    across several power-of-two degree classes must re-run ONLY the touched
    degree classes (frontier edges), never the whole graph — and the result
    must still be bit-identical to a rebuild at every step."""
    g = random_graph(40, 3.0, seed=4)
    idx = build_index(g, "cosine")
    hub = 7
    deg0 = int(np.asarray(g.degrees())[hub])
    targets = [v for v in range(g.n)
               if v != hub and not _has_edge(g, hub, v)]
    for chunk in range(0, len(targets), 6):
        ins = [(hub, v) for v in targets[chunk: chunk + 6]]
        idx, g, info = apply_delta(idx, g, EdgeDelta.make(inserts=ins))
        # frontier = edges incident to touched endpoints only — the old
        # engine recomputed all m2 σ whenever the global width bucket moved
        assert info.n_frontier < g.m2, f"full re-sim at chunk {chunk}"
        assert info.n_sim_groups >= 1
        idx_ref, g_ref = rebuild(g)
        assert_bit_identical(idx, g, idx_ref, g_ref, f"hub-chunk {chunk}")
    # the hub crossed multiple pow2 classes (deg 3ish → ~39)
    assert int(np.asarray(g.degrees())[hub]) == deg0 + len(targets)
    assert_queries_identical(idx, g, idx_ref, g_ref, "hub-final")


def test_power_law_scripts_bit_identical():
    """apply_delta on a power-law graph with a forced hub: the bucketed
    engine's frontier-only recompute stays bit-identical to rebuild, with
    hub-incident inserts touching only the hub's and spokes' classes."""
    from repro.core import power_law_graph

    g = power_law_graph(96, 2.1, seed=9, weighted=True, hub_degree=48)
    idx = build_index(g, "cosine")
    rng = np.random.default_rng(5)
    for step in range(3):
        # half the inserts pile onto the hub (vertex 0), half are random
        k = 6
        hub_ins = np.stack([np.zeros(k // 2, np.int64),
                            rng.integers(1, g.n, size=k // 2)], axis=1)
        rnd_ins = rng.integers(0, g.n, size=(k - k // 2, 2))
        ins = np.concatenate([hub_ins, rnd_ins])
        w = rng.uniform(0.1, 1.0, size=len(ins)).astype(np.float32)
        edges, _ = canonical_edges(g)
        dels = edges[rng.integers(0, len(edges), size=2)]
        idx, g, info = apply_delta(
            idx, g, EdgeDelta.make(inserts=ins, weights=w, deletes=dels))
        assert info.n_frontier < g.m2
        idx_ref, g_ref = rebuild(g)
        assert_bit_identical(idx, g, idx_ref, g_ref, f"powerlaw step={step}")
    assert_queries_identical(idx, g, idx_ref, g_ref, "powerlaw-final")


def test_delta_canonicalization():
    d = EdgeDelta.make(inserts=[(3, 1), (1, 3), (2, 2), (4, 5)],
                       weights=[0.2, 0.9, 0.5, 0.4],
                       deletes=[(7, 6), (6, 7), (8, 8)])
    # self-loops dropped, duplicates collapsed (last insert weight wins)
    assert len(d.ins_u) == 2 and len(d.del_u) == 1
    i = int(np.flatnonzero((d.ins_u == 1) & (d.ins_v == 3))[0])
    assert d.ins_w[i] == np.float32(0.9)
    assert (int(d.del_u[0]), int(d.del_v[0])) == (6, 7)
    assert len(d) == 3


def test_out_of_range_endpoints_rejected():
    g = random_graph(10, 2.0, seed=0)
    idx = build_index(g, "cosine")
    with pytest.raises(ValueError):
        apply_delta(idx, g, EdgeDelta.make(inserts=[(0, 10)]))
    with pytest.raises(ValueError):
        apply_delta(idx, g, EdgeDelta.make(deletes=[(3, 99)]))
    # negative ids must raise up front, not crash deep inside a kernel
    with pytest.raises(ValueError):
        apply_delta(idx, g, EdgeDelta.make(inserts=[(-1, 5)]))
    with pytest.raises(ValueError):
        apply_delta(idx, g, EdgeDelta.make(deletes=[(-2, 4)]))


def test_vertex_ids_beyond_31_bits_rejected():
    """Regression: ids past 31 bits silently collided the packed (u, v)
    merge keys (u << 32 | v in one int64) and corrupted the CO merge —
    they must be rejected with a clear error at delta/graph creation."""
    with pytest.raises(ValueError, match="31 bits"):
        EdgeDelta.make(inserts=[(0, 2 ** 31)])
    with pytest.raises(ValueError, match="31 bits"):
        EdgeDelta.make(deletes=[(2 ** 31 + 5, 3)])
    with pytest.raises(ValueError, match="31 bits"):
        from_edge_list(2 ** 31 + 2, [(0, 1)])
    # the widest representable id is fine (no allocation at this size —
    # validation only; the delta never meets a graph here)
    d = EdgeDelta.make(inserts=[(0, 2 ** 31 - 1)])
    assert len(d) == 1


# --------------------------------------------------------------------------
# hypothesis edit-script oracle (CI fast lane; seed-pinned profile)
# --------------------------------------------------------------------------
if hypothesis is not None:

    @st.composite
    def edit_scripts(draw):
        """(initial graph, [EdgeDelta, ...]) with ops biased toward the
        nasty cases: deleting existing edges (incl. a vertex's last edge)
        and re-inserting recently deleted ones."""
        n = draw(st.integers(6, 20))
        m = draw(st.integers(1, 2 * n))
        pairs = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        pairs = [(u, v) for u, v in pairs if u != v] or [(0, 1)]
        weighted = draw(st.booleans())
        weights = (draw(st.lists(st.floats(0.1, 1.0, allow_nan=False),
                                 min_size=len(pairs), max_size=len(pairs)))
                   if weighted else None)
        g0 = from_edge_list(n, np.asarray(pairs, np.int64),
                            np.asarray(weights, np.float32)
                            if weights else None)
        n_steps = draw(st.integers(1, 3))
        steps = []
        for _ in range(n_steps):
            k_ins = draw(st.integers(0, 4))
            k_del = draw(st.integers(0, 4))
            ins = draw(st.lists(
                st.tuples(st.integers(0, n - 1), st.integers(0, n - 1),
                          st.floats(0.1, 1.0, allow_nan=False)),
                min_size=k_ins, max_size=k_ins))
            dels = draw(st.lists(
                st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
                min_size=k_del, max_size=k_del))
            steps.append((ins, dels))
        return g0, steps

    @settings(max_examples=12, deadline=None)
    @given(edit_scripts())
    def test_hypothesis_scripts_bit_identical(script):
        g0, steps = script
        idx, g = build_index(g0, "cosine"), g0
        for i, (ins, dels) in enumerate(steps):
            # bias deletions toward edges that actually exist
            edges, _ = canonical_edges(g)
            real_dels = list(dels)
            if len(edges) and dels:
                real_dels += [tuple(edges[(u * 7 + v) % len(edges)])
                              for u, v in dels[:2]]
            delta = EdgeDelta.make(
                inserts=[(u, v) for u, v, _ in ins],
                weights=[w for _, _, w in ins],
                deletes=real_dels)
            idx, g, _ = apply_delta(idx, g, delta)
            idx_ref, g_ref = rebuild(g)
            assert_bit_identical(idx, g, idx_ref, g_ref, f"step {i}")
        assert_queries_identical(idx, g, idx_ref, g_ref, "final")
