"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as kref
from repro.kernels import ops
from repro.kernels.bucket_probe import bucket_probe
from repro.kernels.triangle_count import masked_gram
from repro.kernels.simhash import simhash_pack
from repro.kernels.hamming import hamming_cosine
from repro.kernels.flash_attention import flash_attention

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("n,block", [(128, 128), (256, 128), (256, 64),
                                     (384, 128)])
def test_masked_gram_sweep(n, block):
    w = RNG.standard_normal((n, n)).astype(np.float32)
    m = (RNG.random((n, n)) < 0.15).astype(np.float32)
    out = masked_gram(jnp.asarray(w), jnp.asarray(m), bm=block, bn=block,
                      bk=block, interpret=True)
    want = kref.masked_gram_ref(jnp.asarray(w), jnp.asarray(m))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4,
                               atol=2e-3)


@pytest.mark.parametrize("e,p,t,be,bt", [(64, 8, 32, 32, 16),
                                         (128, 16, 64, 64, 64),
                                         (32, 8, 128, 32, 32)])
def test_bucket_probe_sweep(e, p, t, be, bt):
    """Degree-bucketed probe kernel vs the pure-jnp oracle, including the
    tiled target axis (t > bt streams the row through multiple grid steps,
    the hub-row splitting path)."""
    n_ids = 64
    ids_p = np.sort(RNG.choice(n_ids, size=(e, p)), axis=1).astype(np.int32)
    ids_t = np.sort(RNG.choice(n_ids, size=(e, t)), axis=1).astype(np.int32)
    # sanitize duplicates away (simple-graph invariant) and pad some tails
    for row in (ids_p, ids_t):
        for i in range(e):
            u = np.unique(row[i])
            pad = -1 if row is ids_p else -2
            row[i] = np.concatenate(
                [u, np.full(row.shape[1] - len(u), pad, np.int32)]
            ) if len(u) < row.shape[1] else row[i]
    w_p = RNG.uniform(0.1, 1.0, size=(e, p)).astype(np.float32)
    w_t = RNG.uniform(0.1, 1.0, size=(e, t)).astype(np.float32)
    dot, cnt = bucket_probe(jnp.asarray(ids_p), jnp.asarray(w_p),
                            jnp.asarray(ids_t), jnp.asarray(w_t),
                            be=be, bt=bt, interpret=True)
    want_dot, want_cnt = kref.bucket_probe_ref(
        jnp.asarray(ids_p), jnp.asarray(w_p),
        jnp.asarray(ids_t), jnp.asarray(w_t))
    np.testing.assert_array_equal(np.asarray(cnt), np.asarray(want_cnt))
    np.testing.assert_allclose(np.asarray(dot), np.asarray(want_dot),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n,k", [(128, 128), (256, 256), (128, 384)])
def test_simhash_pack_sweep(n, k):
    w = RNG.standard_normal((n, n)).astype(np.float32)
    r = RNG.standard_normal((n, k)).astype(np.float32)
    out = simhash_pack(jnp.asarray(w), jnp.asarray(r), interpret=True)
    want = kref.simhash_pack_ref(jnp.asarray(w), jnp.asarray(r))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


@pytest.mark.parametrize("e,words,k", [(1024, 4, 128), (2048, 8, 256),
                                       (1024, 1, 32)])
def test_hamming_sweep(e, words, k):
    su = RNG.integers(0, 2**32, size=(e, words), dtype=np.uint32)
    sv = RNG.integers(0, 2**32, size=(e, words), dtype=np.uint32)
    out = hamming_cosine(jnp.asarray(su), jnp.asarray(sv), samples=k,
                         be=512, interpret=True)
    want = kref.hamming_cosine_ref(jnp.asarray(su), jnp.asarray(sv), k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128),
                                           (False, 0)])
def test_flash_attention_sweep(dtype, causal, window):
    bh, s, d = 3, 256, 128
    q = RNG.standard_normal((bh, s, d)).astype(np.float32)
    k = RNG.standard_normal((bh, s, d)).astype(np.float32)
    v = RNG.standard_normal((bh, s, d)).astype(np.float32)
    qq, kk, vv = (jnp.asarray(x).astype(dtype) for x in (q, k, v))
    out = flash_attention(qq, kk, vv, causal=causal, window=window,
                          interpret=True)
    want = kref.flash_attention_ref(qq, kk, vv, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol)


def test_flash_vs_model_attention():
    """Pallas serving kernel ≡ the model's jnp attention (same semantics)."""
    from repro.models import layers as L
    b, s, h, d = 2, 256, 4, 64
    q = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, s, h, d)), jnp.float32)
    model_out = L.attention(q, k, v, causal=True, impl="dense")
    # kernel path: fold heads into batch
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, d)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * h, s, d)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * h, s, d)
    kern = ops.attention(qf, kf, vf, causal=True)
    kern = jnp.moveaxis(kern.reshape(b, h, s, d), 1, 2)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(model_out),
                               atol=2e-5)


def test_kernel_simhash_statistically_sound():
    """Kernel-produced sketches estimate cosine within O(1/√k)."""
    from repro.core import random_graph, compute_similarities
    g = random_graph(200, 8.0, seed=31)
    k = 512
    sk = ops.simhash_sketches_kernel(g, k, jax.random.PRNGKey(0))
    est = np.asarray(ops.simhash_edge_similarity_kernel(
        sk, g.edge_u, g.nbrs, k))
    exact = np.asarray(compute_similarities(g, "cosine"))
    assert np.mean(np.abs(est - exact)) < 0.06
