"""LiveIndexService: resident update+query process.

Covers the update protocol end to end — atomic hot-swaps under concurrent
traffic, delta-chain persistence (crash mid-delta, snapshot + tail replay,
chain-integrity verification), compaction fingerprint equivalence, and the
mutated-partition-only shard-plan refresh."""
import asyncio
import os
import shutil
import threading

import numpy as np
import pytest

from repro.core import (ApproxParams, EdgeDelta, ShardedQueryPlan,
                        apply_delta, build_index, query, query_batch,
                        query_mesh, random_graph)
from repro.serve import (DeltaLog, EngineConfig, LiveIndexService,
                         index_fingerprint)


def _service(tmp_path, **kw):
    kw.setdefault("config", EngineConfig(max_batch=8, flush_ms=5.0))
    return LiveIndexService(str(tmp_path), **kw)


def _graph(n=60, deg=6.0, seed=1):
    return random_graph(n, deg, seed=seed, weighted=True)


# --------------------------------------------------------------------------
# hot-swap semantics
# --------------------------------------------------------------------------
def test_hot_swap_serves_old_or_new_never_mixed(tmp_path):
    """Queries racing an update must each match the old index's answer or
    the new index's answer exactly — no torn reads, no routing errors."""
    svc = _service(tmp_path)
    g = _graph()
    svc.create("web", g)
    old = svc._live["web"]
    delta = EdgeDelta.make(inserts=[(0, 30), (1, 45), (2, 50)],
                           weights=[0.9, 0.8, 0.7])
    new_index, new_g, _ = apply_delta(old.index, old.g, delta)
    settings = [(2, 0.3), (3, 0.5), (2, 0.7), (4, 0.4)]
    refs = {}
    for mu, eps in settings:
        refs[(mu, eps)] = (
            np.asarray(query(old.index, old.g, mu, eps).labels),
            np.asarray(query(new_index, new_g, mu, eps).labels))

    async def main():
        async with svc:
            tasks = []
            apply_task = None
            for round_ in range(6):
                for mu, eps in settings:
                    tasks.append(asyncio.ensure_future(
                        svc.query("web", mu, eps)))
                if round_ == 2:
                    apply_task = asyncio.ensure_future(
                        svc.apply("web", delta))
                await asyncio.sleep(0)
            racing = await asyncio.gather(*tasks)
            await apply_task
            # the apply runs off the event loop now, so racing queries may
            # all have resolved against the old index; queries issued after
            # the awaited swap must see the new one
            post = await asyncio.gather(
                *[svc.query("web", mu, eps) for mu, eps in settings])
            return racing, post

    racing, post = asyncio.run(main())
    n_old = n_new = 0
    for qi, out in enumerate(racing):
        mu, eps = settings[qi % len(settings)]
        old_ref, new_ref = refs[(mu, eps)]
        got = np.asarray(out.labels)
        if np.array_equal(got, old_ref):
            n_old += 1
        elif np.array_equal(got, new_ref):
            n_new += 1
        else:
            raise AssertionError(
                f"({mu}, {eps}) matched neither old nor new index")
    assert n_old + n_new == len(racing)
    for (mu, eps), out in zip(settings, post):
        np.testing.assert_array_equal(
            np.asarray(out.labels), refs[(mu, eps)][1],
            err_msg=f"post-swap ({mu}, {eps}) must see the new index")


def test_noop_delta_keeps_fingerprint_and_cache(tmp_path):
    """An ineffective batch (absent delete) must not swap, not invalidate
    the cache, and not advance to a new fingerprint."""
    svc = _service(tmp_path)
    g = _graph(n=40, deg=4.0)
    fp = svc.create("web", g)
    absent = (0, 39) if not np.any(
        (np.asarray(g.edge_u) == 0) & (np.asarray(g.nbrs) == 39)) else (1, 39)

    async def main():
        async with svc:
            await svc.query("web", 2, 0.5)
            hits0 = svc.engine.stats["cache_hits"]
            info = await svc.apply("web", EdgeDelta.make(deletes=[absent]))
            assert info.n_deleted == 0 and info.n_inserted == 0
            await svc.query("web", 2, 0.5)
            assert svc.engine.stats["cache_hits"] == hits0 + 1

    asyncio.run(main())
    assert svc.fingerprint("web") == fp
    assert svc._live["web"].seq == 1       # the delta still logs


def test_cancelled_drain_waiter_does_not_kill_collector(tmp_path):
    """A drain() waiter cancelled by a timeout must not crash the
    collector with InvalidStateError when the marker is flushed — later
    queries would hang forever on a dead loop."""
    svc = _service(tmp_path)
    g = _graph(n=40, deg=4.0)
    svc.create("web", g)

    async def main():
        async with svc:
            drain = asyncio.ensure_future(svc.engine.drain())
            await asyncio.sleep(0)         # marker enqueued, not flushed
            drain.cancel()
            try:
                await drain
            except asyncio.CancelledError:
                pass
            # collector must still answer real traffic
            out = await asyncio.wait_for(svc.query("web", 2, 0.5), 10)
            return out

    out = asyncio.run(main())
    live = svc._live["web"]
    ref = query(live.index, live.g, 2, 0.5)
    np.testing.assert_array_equal(out.labels, np.asarray(ref.labels))


def test_collector_flushes_during_in_flight_apply(tmp_path, monkeypatch):
    """The tentpole property of off-loop application: while an apply is
    blocked in the worker, the collector must keep answering queries on
    the event loop — apply latency never appears in query tails."""
    import repro.serve.live as live_mod

    svc = _service(tmp_path)
    g = _graph()
    svc.create("web", g)
    entered = threading.Event()
    gate = threading.Event()
    real_apply = live_mod.apply_delta

    def gated_apply(*args, **kwargs):
        entered.set()
        assert gate.wait(30), "test gate never opened"
        return real_apply(*args, **kwargs)

    monkeypatch.setattr(live_mod, "apply_delta", gated_apply)
    delta = EdgeDelta.make(inserts=[(0, 30), (1, 45)], weights=[0.9, 0.8])

    async def main():
        async with svc:
            apply_task = asyncio.ensure_future(svc.apply("web", delta))
            while not entered.is_set():        # worker holds the apply now
                await asyncio.sleep(0.005)
            # queries must flush while the apply is parked in the worker
            answers = []
            for mu, eps in ((2, 0.3), (3, 0.5), (2, 0.7)):
                answers.append(await asyncio.wait_for(
                    svc.query("web", mu, eps), timeout=10))
            assert not apply_task.done(), \
                "apply finished before the gate opened — it ran inline"
            gate.set()
            info = await apply_task
            return answers, info

    answers, info = asyncio.run(main())
    assert info.n_inserted == 2
    # the queries that raced the apply answered against the old index
    for (mu, eps), out in zip(((2, 0.3), (3, 0.5), (2, 0.7)), answers):
        ref = query(build_index(g, "cosine"), g, mu, eps)
        np.testing.assert_array_equal(out.labels, np.asarray(ref.labels))
    # and the swap completed once the worker finished
    live = svc._live["web"]
    assert live.seq == 1
    assert live.g.m == g.m + 2


def test_cancelled_apply_still_commits_consistently(tmp_path, monkeypatch):
    """An apply is a commit: cancelling the caller (wait_for timeout)
    while the worker holds the delta must not leave the on-disk chain one
    entry ahead of the served state — the shielded swap completes in the
    background and the next apply gets the next sequence number."""
    import repro.serve.live as live_mod

    svc = _service(tmp_path)
    g = _graph()
    svc.create("web", g)
    entered = threading.Event()
    gate = threading.Event()
    real_apply = live_mod.apply_delta

    def gated_apply(*args, **kwargs):
        entered.set()
        assert gate.wait(30), "test gate never opened"
        return real_apply(*args, **kwargs)

    monkeypatch.setattr(live_mod, "apply_delta", gated_apply)
    eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
    pair = next((0, v) for v in range(1, g.n)
                if not np.any((eu == 0) & (ev == v)))
    delta = EdgeDelta.make(inserts=[pair], weights=[0.9])

    async def main():
        async with svc:
            task = asyncio.ensure_future(svc.apply("web", delta))
            while not entered.is_set():
                await asyncio.sleep(0.005)
            task.cancel()                  # caller gives up mid-worker
            with pytest.raises(asyncio.CancelledError):
                await task
            gate.set()
            # exit immediately: __aexit__ must wait out the abandoned
            # apply before stopping the engine (no swap against a dead
            # router, no resurrected collector)
        assert svc._live["web"].seq == 1

    asyncio.run(main())

    async def followup():
        async with svc:                    # engine restarts cleanly
            monkeypatch.setattr(live_mod, "apply_delta", real_apply)
            await svc.apply("web", EdgeDelta.make(deletes=[pair]))

    asyncio.run(followup())
    # served state and chain agree: two committed entries, no seq reuse
    log = DeltaLog(os.path.join(str(tmp_path), "web"))
    assert log.sequences() == [1, 2]
    assert svc._live["web"].seq == 2
    assert svc._live["web"].g.m == g.m     # insert then delete → back


def test_measure_mismatch_rejected_on_load(tmp_path):
    """Adopting a jaccard-built index into a cosine-maintaining service
    would silently mix measures on the first frontier recompute."""
    svc = _service(tmp_path, measure="jaccard")
    svc.create("web", random_graph(40, 4.0, seed=1))
    svc2 = _service(tmp_path)              # default measure: cosine
    with pytest.raises(ValueError, match="measure"):
        svc2.load("web")
    svc3 = _service(tmp_path, measure="jaccard")
    assert svc3.load("web") == svc.fingerprint("web")


# --------------------------------------------------------------------------
# delta-chain persistence
# --------------------------------------------------------------------------
def test_restore_replays_delta_tail(tmp_path):
    svc = _service(tmp_path, compact_every=100)   # never compacts
    g = _graph()
    svc.create("web", g)

    async def main():
        async with svc:
            await svc.apply("web", EdgeDelta.make(
                inserts=[(0, 30), (2, 41)], weights=[0.9, 0.4]))
            await svc.apply("web", EdgeDelta.make(deletes=[(0, 30)]))

    asyncio.run(main())
    live = svc._live["web"]
    assert live.seq == 2 and live.snapshot_seq == 0

    svc2 = _service(tmp_path)
    assert svc2.load_all() == ["web"]
    assert svc2.fingerprint("web") == live.fp
    restored = svc2._live["web"]
    np.testing.assert_array_equal(np.asarray(restored.index.no_sims),
                                  np.asarray(live.index.no_sims))
    res = query_batch(restored.index, restored.g, [2, 3], [0.4, 0.6])
    ref = query_batch(live.index, live.g, [2, 3], [0.4, 0.6])
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(ref.labels))


def test_crash_mid_delta_restores_last_consistent_version(tmp_path):
    """A torn (uncommitted) delta write — the crash window is the .tmp
    directory before the atomic rename — must be invisible to restore."""
    svc = _service(tmp_path, compact_every=100)
    g = _graph(n=40, deg=4.0)
    svc.create("web", g)

    async def main():
        async with svc:
            await svc.apply("web", EdgeDelta.make(inserts=[(0, 20)]))

    asyncio.run(main())
    fp_committed = svc.fingerprint("web")

    # simulate a crash mid-append: partially written step dir, no rename
    log_dir = os.path.join(str(tmp_path), "web", DeltaLog.SUBDIR)
    torn = os.path.join(log_dir, "step_00000002.tmp")
    os.makedirs(torn)
    with open(os.path.join(torn, "arr_00000.npy"), "wb") as f:
        f.write(b"\x93NUMPY garbage")

    svc2 = _service(tmp_path)
    assert svc2.load("web") == fp_committed
    assert svc2._live["web"].seq == 1


def test_chain_integrity_verification_catches_divergence(tmp_path):
    """A chain entry whose recorded fingerprint disagrees with the replay
    is corruption — restore must refuse, not serve wrong clusters."""
    svc = _service(tmp_path, compact_every=100)
    g = _graph(n=40, deg=4.0)
    svc.create("web", g)

    async def main():
        async with svc:
            await svc.apply("web", EdgeDelta.make(inserts=[(0, 20)]))

    asyncio.run(main())
    # overwrite entry 1 with a delta that replays to a different graph
    log = DeltaLog(os.path.join(str(tmp_path), "web"))
    shutil.rmtree(os.path.join(log.directory, "step_00000001"))
    log.append(1, EdgeDelta.make(inserts=[(3, 30)]), "0" * 64)

    svc2 = _service(tmp_path)
    with pytest.raises(ValueError, match="fingerprint"):
        svc2.load("web")


def test_gap_in_delta_chain_is_rejected(tmp_path):
    svc = _service(tmp_path, compact_every=100)
    svc.create("web", _graph(n=30, deg=3.0))

    async def main():
        async with svc:
            for k in range(3):
                await svc.apply("web", EdgeDelta.make(inserts=[(k, k + 10)]))

    asyncio.run(main())
    shutil.rmtree(os.path.join(str(tmp_path), "web", DeltaLog.SUBDIR,
                               "step_00000002"))
    svc2 = _service(tmp_path)
    with pytest.raises(ValueError, match="gap"):
        svc2.load("web")


# --------------------------------------------------------------------------
# compaction
# --------------------------------------------------------------------------
def test_compaction_snapshot_fingerprint_equals_live(tmp_path):
    """The compacted snapshot must fingerprint identically to the
    incrementally maintained index (and to a from-scratch rebuild)."""
    svc = _service(tmp_path, compact_every=3)
    g = _graph()
    svc.create("web", g)

    async def main():
        async with svc:
            await svc.apply("web", EdgeDelta.make(
                inserts=[(0, 30)], weights=[0.5]))
            await svc.apply("web", EdgeDelta.make(deletes=[(0, 30)]))
            await svc.apply("web", EdgeDelta.make(
                inserts=[(7, 40), (8, 41)], weights=[0.2, 0.9]))

    asyncio.run(main())
    live = svc._live["web"]
    assert live.snapshot_seq == 3
    store = svc.catalog.store("web")
    assert store.latest_version() == 3
    snap_index, snap_g, snap_fp = store.load()
    assert snap_fp == live.fp
    assert snap_fp == index_fingerprint(snap_index, snap_g)
    # chain prefix pruned: nothing older than the snapshot remains
    assert DeltaLog(store.directory).sequences() == []
    # rebuild-from-scratch agrees (bit-identity invariant)
    rebuilt = build_index(snap_g, "cosine")
    np.testing.assert_array_equal(np.asarray(rebuilt.no_sims),
                                  np.asarray(snap_index.no_sims))
    assert index_fingerprint(rebuilt, snap_g) == snap_fp
    # a fresh load takes the snapshot fast-path (no replay) to the same fp
    svc2 = _service(tmp_path)
    assert svc2.load("web") == live.fp
    assert svc2._live["web"].snapshot_seq == 3


# --------------------------------------------------------------------------
# sharded plan refresh (k=1 degenerate mesh in-process; the multi-shard
# behavior of the same code path is covered by the chunk-diff test below)
# --------------------------------------------------------------------------
def test_shard_plan_refresh_matches_and_reuses_chunks():
    g = random_graph(80, 6.0, seed=3)
    idx = build_index(g, "cosine")
    mesh = query_mesh(1)
    plan = ShardedQueryPlan(idx, g, mesh)
    assert plan.last_refresh["reused"] == 0

    idx2, g2, _ = apply_delta(idx, g, EdgeDelta.make(inserts=[(0, 40)]))
    plan2 = plan.refresh(idx2, g2)
    mus = np.asarray([2, 3], np.int32)
    epss = np.asarray([0.4, 0.6], np.float32)
    out = plan2(mus, epss)
    ref = query_batch(idx2, g2, mus, epss)
    for f in ("labels", "is_core", "n_clusters"):
        np.testing.assert_array_equal(np.asarray(getattr(out, f)),
                                      np.asarray(getattr(ref, f)))
    # old plan still answers for the *old* index (hot-swap window)
    out_old = plan(mus, epss)
    ref_old = query_batch(idx, g, mus, epss)
    np.testing.assert_array_equal(np.asarray(out_old.labels),
                                  np.asarray(ref_old.labels))


def test_shard_plan_refresh_noop_reuses_everything():
    """Identical content → every chunk adopted, zero re-placements."""
    g = random_graph(50, 5.0, seed=4)
    idx = build_index(g, "cosine")
    mesh = query_mesh(1)
    plan = ShardedQueryPlan(idx, g, mesh)
    plan2 = plan.refresh(idx, g)
    assert plan2.last_refresh["placed"] == 0
    assert plan2.last_refresh["reused"] == plan2.last_refresh["chunks"]


# --------------------------------------------------------------------------
# approximate-first lifecycle: register_approximate → serve → refine
# --------------------------------------------------------------------------
APPROX = ApproxParams(method="simhash", samples=32, seed=7,
                      degree_heuristic=False)  # force genuinely-sketched σ̂


def test_refine_serves_approx_during_build_then_bit_identical(
        tmp_path, monkeypatch):
    """The acceptance property of approximate-first ingest: while the
    exact build is parked in the worker, queries keep answering from the
    approximate index (never an error, never a mix); after the swap,
    results are bit-identical to a cold from-scratch ``build_index``."""
    import repro.serve.live as live_mod

    svc = _service(tmp_path)
    g = _graph(n=70, deg=7.0, seed=9)
    entered = threading.Event()
    gate = threading.Event()
    real_build = live_mod.build_index

    def gated_build(*args, **kwargs):
        entered.set()
        assert gate.wait(30), "test gate never opened"
        return real_build(*args, **kwargs)

    monkeypatch.setattr(live_mod, "build_index", gated_build)
    fp_a = svc.register_approximate("web", g, params=APPROX)
    idx_approx = svc.index("web")
    assert svc.provenance("web").is_approx
    assert svc.engine.provenance(fp_a).is_approx
    assert svc.engine.batch_stats()["approx_indexes"] == 1
    settings = ((2, 0.3), (3, 0.5), (2, 0.7))

    async def main():
        async with svc:
            refine_task = asyncio.ensure_future(svc.refine("web"))
            while not entered.is_set():    # worker holds the exact build
                await asyncio.sleep(0.005)
            during = []
            for mu, eps in settings:
                during.append(await asyncio.wait_for(
                    svc.query("web", mu, eps), timeout=10))
            assert not refine_task.done(), \
                "refine finished before the gate opened — it ran inline"
            gate.set()
            fp_exact = await refine_task
            post = [await svc.query("web", mu, eps)
                    for mu, eps in settings]
            return during, post, fp_exact

    during, post, fp_exact = asyncio.run(main())
    # mid-refine queries answered from the approximate index, exactly
    for (mu, eps), out in zip(settings, during):
        ref = query(idx_approx, g, mu, eps)
        np.testing.assert_array_equal(out.labels, np.asarray(ref.labels))
    # post-swap results are bit-identical to a cold exact build
    cold = build_index(g, "cosine")
    assert fp_exact == index_fingerprint(cold, g)
    for (mu, eps), out in zip(settings, post):
        ref = query(cold, g, mu, eps)
        np.testing.assert_array_equal(out.labels, np.asarray(ref.labels))
        np.testing.assert_array_equal(out.is_core, np.asarray(ref.is_core))
    st = svc.status("web")
    assert not st["approx"] and st["provenance"] == "exact"
    assert not svc.engine.provenance(fp_exact).is_approx
    assert svc.engine.batch_stats()["approx_indexes"] == 0
    with pytest.raises(KeyError):
        svc.engine.provenance(fp_a)        # approx route fully retired


def test_refine_failure_leaves_approx_serving(tmp_path, monkeypatch):
    """Graceful degradation: a failed exact build must leave the
    approximate index registered and answering, count one refine
    failure, and stay retryable."""
    import repro.serve.live as live_mod

    svc = _service(tmp_path)
    g = _graph(n=50, deg=5.0, seed=11)
    real_build = live_mod.build_index
    monkeypatch.setattr(live_mod, "build_index", None)  # guard create()
    fp_a = svc.register_approximate("web", g, params=APPROX)

    calls = {"n": 0}

    def failing_build(*args, **kwargs):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("simulated OOM in exact build")
        return real_build(*args, **kwargs)

    monkeypatch.setattr(live_mod, "build_index", failing_build)

    async def main():
        async with svc:
            with pytest.raises(RuntimeError, match="simulated OOM"):
                await svc.refine("web")
            # still serving the approximate index
            out = await svc.query("web", 2, 0.5)
            assert svc.fingerprint("web") == fp_a
            assert svc.provenance("web").is_approx
            # retry succeeds
            fp_exact = await svc.refine("web")
            return out, fp_exact

    out, fp_exact = asyncio.run(main())
    idx_a, _, _ = svc.catalog.store("web").load(version=0)
    ref = query(idx_a, g, 2, 0.5)
    np.testing.assert_array_equal(out.labels, np.asarray(ref.labels))
    assert svc.engine.registry.counter("live.refine_failures").value == 1
    assert fp_exact == index_fingerprint(build_index(g, "cosine"), g)
    assert not svc.provenance("web").is_approx


def test_crash_before_refine_swap_restores_approx(tmp_path):
    """A restart before refine completes must restore the *approximate*
    index from the store — provenance and fingerprint intact — and the
    restored service must still be able to refine to exact."""
    svc = _service(tmp_path)
    g = _graph(n=60, deg=6.0, seed=13)
    fp_a = svc.register_approximate("web", g, params=APPROX)
    # crash: the service never ran refine; only snapshot v0 is on disk
    svc2 = _service(tmp_path)
    assert svc2.load("web") == fp_a
    restored = svc2.provenance("web")
    assert restored.is_approx and restored.method == "simhash"
    assert restored.samples == APPROX.samples
    assert svc2.engine.provenance(fp_a).is_approx

    async def main():
        async with svc2:
            return await svc2.refine("web")

    fp_exact = asyncio.run(main())
    assert fp_exact == index_fingerprint(build_index(g, "cosine"), g)
    # a third restart restores *exact* from the refine snapshot
    svc3 = _service(tmp_path)
    assert svc3.load("web") == fp_exact
    assert not svc3.provenance("web").is_approx
    assert svc3._live["web"].seq == svc3._live["web"].snapshot_seq == 1


def test_delta_after_refine_keeps_chain_consistent(tmp_path):
    """Refine bumps the sequence without a chain entry (the snapshot
    covers it); a delta applied afterwards must extend the chain from the
    refined snapshot and restore bit-identically."""
    svc = _service(tmp_path, compact_every=100)
    g = _graph(n=50, deg=5.0, seed=17)
    svc.register_approximate("web", g, params=APPROX)

    async def main():
        async with svc:
            await svc.refine("web")
            await svc.apply("web", EdgeDelta.make(
                inserts=[(0, 25), (1, 30)], weights=[0.9, 0.4]))

    asyncio.run(main())
    live = svc._live["web"]
    assert live.seq == 2 and live.snapshot_seq == 1
    assert DeltaLog(svc.catalog.store("web").directory).sequences() == [2]
    svc2 = _service(tmp_path)
    assert svc2.load("web") == live.fp
    assert not svc2.provenance("web").is_approx
    np.testing.assert_array_equal(
        np.asarray(svc2._live["web"].index.no_sims),
        np.asarray(live.index.no_sims))


def test_refine_already_exact_is_noop(tmp_path):
    svc = _service(tmp_path)
    g = _graph(n=40, deg=4.0, seed=19)
    fp = svc.create("web", g)

    async def main():
        async with svc:
            assert await svc.refine("web") == fp

    asyncio.run(main())
    assert svc._live["web"].seq == 0       # no-op: no version bump


def test_shard_plan_chunk_diff_updates_only_mutated_partitions():
    """Host-side chunk diffing: with a forced 4-way split of the padded
    operands, an edit touching one region re-places only the chunks whose
    content moved (the emask/eu/ev/co_i identity chunks are reused)."""
    g = random_graph(64, 6.0, seed=5)
    idx = build_index(g, "cosine")
    mesh = query_mesh(1)
    plan = ShardedQueryPlan(idx, g, mesh)
    # same-shape successor: weight tweak on one existing edge keeps every
    # array length identical, so the diff path (not the rebuild path) runs
    eu, ev, w = (np.asarray(g.edge_u), np.asarray(g.nbrs),
                 np.asarray(g.wgts))
    i = int(np.flatnonzero(eu < ev)[0])
    idx2, g2, info = apply_delta(idx, g, EdgeDelta.make(
        inserts=[(int(eu[i]), int(ev[i]))],
        weights=[float(w[i]) + 0.25]))
    assert g2.m2 == g.m2
    plan2 = plan.refresh(idx2, g2)
    st = plan2.last_refresh
    # structure arrays (emask, eu, ev, co_i) are unchanged → reused
    assert st["reused"] >= 4
    assert st["placed"] >= 1               # esim/no change must land
    assert st["reused"] + st["placed"] == st["chunks"]
