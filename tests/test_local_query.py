"""Seed-set local query kernel (``repro.core.local``) vs the full-query
oracle.

The contract under test: for every (seed, μ, ε), ``query_seeds`` must be
bit-identical to running the full ``query`` and extracting the seed's
row — same label, same core bit, same member set — whether the lane was
answered by the fixed-shape frontier expansion or spilled to the
``query_batch`` fallback.
"""
import numpy as np
import pytest

from repro.core import (
    build_index,
    from_edge_list,
    power_law_graph,
    query,
    query_seeds,
    random_graph,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    HAVE_HYPOTHESIS = False


def expected_rows(index, g, seeds, mus, epss):
    """Oracle: full ``query`` per distinct (μ, ε), row-extracted."""
    out = []
    for s, m, e in zip(seeds, mus, epss):
        res = query(index, g, int(m), float(e))
        labels = np.asarray(res.labels)
        lab = int(labels[s])
        mask = (labels == lab) if lab >= 0 else np.zeros(g.n, bool)
        out.append((lab, bool(np.asarray(res.is_core)[s]), mask))
    return out


def check_identity(index, g, seeds, mus, epss, **kw):
    res = query_seeds(index, g, seeds, mus, epss, **kw)
    for i, (lab, core, mask) in enumerate(
            expected_rows(index, g, seeds, mus, epss)):
        assert int(res.labels[i]) == lab, (seeds[i], mus[i], epss[i])
        assert bool(res.is_core[i]) == core
        np.testing.assert_array_equal(res.member_mask[i], mask)
        assert int(res.n_members[i]) == int(mask.sum())
    return res


def all_vertex_sweep(index, g, mu, eps, **kw):
    seeds = np.arange(g.n, dtype=np.int32)
    return check_identity(index, g, seeds,
                          np.full(g.n, mu, np.int32),
                          np.full(g.n, eps, np.float32), **kw)


def test_isolated_seed():
    # vertices 6..9 have no edges at all: not core, no cluster, empty mask
    edges = np.array([[0, 1], [1, 2], [0, 2], [2, 3], [3, 4], [4, 5]])
    g = from_edge_list(10, edges)
    index = build_index(g, "cosine")
    res = all_vertex_sweep(index, g, 2, 0.3)
    assert int(res.labels[7]) == -1
    assert not bool(res.is_core[7])
    assert int(res.n_members[7]) == 0


def test_border_seed_not_core():
    # planted clusters at a mid ε leave border vertices: attached to a
    # cluster (label >= 0) without being cores themselves — the seed path
    # must reproduce the full query's deterministic attachment rule
    g = random_graph(120, 6.0, seed=3, planted_clusters=4)
    index = build_index(g, "cosine")
    full = query(index, g, 3, 0.5)
    labels = np.asarray(full.labels)
    border = np.flatnonzero((labels >= 0) & ~np.asarray(full.is_core))
    assert border.size > 0, "fixture must produce border vertices"
    seeds = border.astype(np.int32)
    check_identity(index, g, seeds,
                   np.full(seeds.size, 3, np.int32),
                   np.full(seeds.size, 0.5, np.float32))


def test_mu_above_max_closed_degree():
    g = random_graph(60, 4.0, seed=1)
    index = build_index(g, "cosine")
    res = all_vertex_sweep(index, g, 1000, 0.2)
    assert not res.is_core.any()
    assert (res.labels == -1).all()
    assert not res.spilled.any()        # nothing to expand, nothing spills


def test_hub_spanning_cluster():
    # power-law graph with a forced hub: the hub's cluster at low ε pulls
    # in a large fraction of the graph; with default caps this is exactly
    # the lane that must spill to the full-query fallback and still match
    g = power_law_graph(n=512, alpha=2.1, avg_degree=8.0, seed=7,
                        hub_degree=128)
    index = build_index(g, "cosine")
    hub = int(np.argmax(np.diff(np.asarray(g.offsets))))
    seeds = np.asarray([hub, 0, 1, 2], np.int32)
    for mu, eps in ((2, 0.2), (2, 0.5), (3, 0.4)):
        check_identity(index, g, seeds,
                       np.full(seeds.size, mu, np.int32),
                       np.full(seeds.size, eps, np.float32))


def test_spill_fallback_bit_identical():
    # tiny static caps force frontier/border/window spills on a graph
    # whose ε=0.2 clusters are far larger than 8 members; spilled lanes
    # are re-answered by query_batch and must stay bit-identical
    g = random_graph(200, 8.0, seed=5, planted_clusters=2)
    index = build_index(g, "cosine")
    res = all_vertex_sweep(index, g, 2, 0.2,
                           frontier_cap=8, window=4, border_cap=8)
    assert res.spilled.any(), "fixture must exercise the spill path"


def test_scalar_broadcast_and_validation():
    g = random_graph(50, 4.0, seed=2)
    index = build_index(g, "cosine")
    res = query_seeds(index, g, np.arange(10), 2, 0.4)
    assert res.labels.shape == (10,)
    with pytest.raises(ValueError):
        query_seeds(index, g, [g.n], 2, 0.4)        # out of range
    with pytest.raises(ValueError):
        query_seeds(index, g, [-1], 2, 0.4)
    with pytest.raises(ValueError):
        query_seeds(index, g, [0], 2, 0.4, frontier_cap=100)  # not pow2
    empty = query_seeds(index, g, np.asarray([], np.int32), 2, 0.4)
    assert empty.labels.shape == (0,)


def test_random_sweep_matches_full_query_rows():
    """Deterministic stand-in for the hypothesis property: random graphs
    × a (μ, ε) grid, every vertex as a seed, small caps so both the
    expanded and fallback paths are exercised."""
    rng = np.random.default_rng(11)
    for trial in range(4):
        n = int(rng.integers(8, 28))
        m = int(rng.integers(1, 3 * n))
        pairs = rng.integers(0, n, size=(m, 2))
        pairs = pairs[pairs[:, 0] != pairs[:, 1]]
        if pairs.size == 0:
            pairs = np.array([[0, 1]])
        g = from_edge_list(n, pairs.astype(np.int64))
        index = build_index(g, "cosine")
        for mu in (2, 3, 5):
            for eps in (0.1, 0.5, 0.9):
                all_vertex_sweep(index, g, mu, eps,
                                 frontier_cap=16, window=8, border_cap=16)


if HAVE_HYPOTHESIS:
    @st.composite
    def small_graphs(draw):
        n = draw(st.integers(5, 24))
        m = draw(st.integers(1, 3 * n))
        pairs = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        pairs = [(u, v) for u, v in pairs if u != v] or [(0, 1)]
        return from_edge_list(n, np.asarray(pairs, dtype=np.int64))

    @settings(max_examples=20, deadline=None)
    @given(small_graphs(), st.integers(2, 5), st.floats(0.05, 0.95))
    def test_property_matches_full_query_rows(g, mu, eps):
        index = build_index(g, "cosine")
        # small caps keep compilation cheap and make spills likely, so
        # both the expanded and fallback paths run across examples
        all_vertex_sweep(index, g, mu, eps,
                         frontier_cap=16, window=8, border_cap=16)
