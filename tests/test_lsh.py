"""LSH approximation: Theorems 5.2/5.3 classification guarantees + §6.3
degree heuristic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    approximate_similarities,
    build_index,
    compute_similarities,
    minhash_sketches,
    minhash_edge_similarity,
    kpartition_sketches,
    kpartition_edge_similarity,
    simhash_sketches,
    simhash_edge_similarity,
    random_graph,
    query,
)
from repro.core.quality import adjusted_rand_index


def test_simhash_classification_bound():
    """Theorem 5.2: with k ≥ π²·ln(nm)/(2δ²), every edge with exact cosine
    outside (ε−δ, ε+√(1−ε²)δ) is classified correctly w.h.p."""
    g = random_graph(60, 8.0, seed=21)
    eps, delta = 0.5, 0.25
    n, m = g.n, g.m
    k = int(np.ceil(np.pi**2 * np.log(n * m) / (2 * delta**2)))
    exact = np.asarray(compute_similarities(g, "cosine"))
    sk = simhash_sketches(g, k, jax.random.PRNGKey(0))
    approx = np.asarray(simhash_edge_similarity(sk, g.edge_u, g.nbrs, k))
    lo, hi = eps - delta, eps + np.sqrt(1 - eps**2) * delta
    outside = (exact <= lo) | (exact >= hi)
    misclassified = ((exact >= eps) != (approx >= eps)) & outside
    assert misclassified.sum() == 0, \
        f"{misclassified.sum()} edges misclassified outside the band"


def test_minhash_classification_bound():
    """Theorem 5.3: k ≥ ln(nm)/(2δ²) ⇒ edges outside (ε−δ, ε+δ) classified
    correctly w.h.p."""
    g = random_graph(60, 8.0, seed=22)
    eps, delta = 0.4, 0.2
    k = int(np.ceil(np.log(g.n * g.m) / (2 * delta**2)))
    exact = np.asarray(compute_similarities(g, "jaccard"))
    sk = minhash_sketches(g, k, jax.random.PRNGKey(1))
    approx = np.asarray(minhash_edge_similarity(sk, g.edge_u, g.nbrs))
    outside = (exact <= eps - delta) | (exact >= eps + delta)
    mis = ((exact >= eps) != (approx >= eps)) & outside
    assert mis.sum() == 0


def test_minhash_unbiased():
    """MinHash match probability equals the Jaccard similarity."""
    g = random_graph(30, 6.0, seed=23)
    exact = np.asarray(compute_similarities(g, "jaccard"))
    ests = []
    for trial in range(6):
        sk = minhash_sketches(g, 128, jax.random.PRNGKey(100 + trial))
        ests.append(np.asarray(minhash_edge_similarity(sk, g.edge_u, g.nbrs)))
    mean_est = np.mean(ests, axis=0)
    assert np.max(np.abs(mean_est - exact)) < 0.12


def test_kpartition_reasonable():
    """k-partition MinHash (no tail bound — paper §6.3) is still a usable
    estimator: mean abs error small at moderate k."""
    g = random_graph(80, 10.0, seed=24)
    exact = np.asarray(compute_similarities(g, "jaccard"))
    sk = kpartition_sketches(g, 128, jax.random.PRNGKey(2))
    approx = np.asarray(kpartition_edge_similarity(sk, g.edge_u, g.nbrs))
    assert np.mean(np.abs(approx - exact)) < 0.12


def test_degree_heuristic_exact_for_low_degree():
    """§6.3: edges with a low-degree endpoint get *exact* similarities."""
    g = random_graph(50, 4.0, seed=25)
    k = 64   # threshold k ⇒ every vertex here is low-degree
    exact = np.asarray(compute_similarities(g, "cosine"))
    approx = np.asarray(approximate_similarities(
        g, measure="cosine", method="simhash", samples=k,
        key=jax.random.PRNGKey(3), degree_heuristic=True))
    np.testing.assert_allclose(approx, exact, atol=1e-5)


def test_approx_clustering_quality():
    """Clusterings from approximate σ recover the exact-σ clustering on a
    planted-partition graph (paper §7.3.4 ARI experiment, miniature)."""
    g = random_graph(120, 10.0, seed=26, planted_clusters=5)
    idx_exact = build_index(g, "cosine")
    res_exact = query(idx_exact, g, 3, 0.4)
    idx_approx = build_index(g, "cosine", approx="simhash", samples=512,
                             key=jax.random.PRNGKey(4))
    res_approx = query(idx_approx, g, 3, 0.4)
    ari = adjusted_rand_index(np.asarray(res_exact.labels),
                              np.asarray(res_approx.labels))
    assert ari > 0.8, f"ARI {ari}"


def test_sketches_deterministic():
    g = random_graph(40, 5.0, seed=27)
    k = jax.random.PRNGKey(9)
    a = np.asarray(simhash_sketches(g, 96, k))
    b = np.asarray(simhash_sketches(g, 96, k))
    np.testing.assert_array_equal(a, b)
