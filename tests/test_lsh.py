"""LSH approximation: Theorems 5.2/5.3 classification guarantees + §6.3
degree heuristic."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    approximate_similarities,
    build_index,
    compute_similarities,
    minhash_sketches,
    minhash_edge_similarity,
    kpartition_sketches,
    kpartition_edge_similarity,
    simhash_sketches,
    simhash_edge_similarity,
    random_graph,
    query,
)
from repro.core.quality import adjusted_rand_index


def test_simhash_classification_bound():
    """Theorem 5.2: with k ≥ π²·ln(nm)/(2δ²), every edge with exact cosine
    outside (ε−δ, ε+√(1−ε²)δ) is classified correctly w.h.p."""
    g = random_graph(60, 8.0, seed=21)
    eps, delta = 0.5, 0.25
    n, m = g.n, g.m
    k = int(np.ceil(np.pi**2 * np.log(n * m) / (2 * delta**2)))
    exact = np.asarray(compute_similarities(g, "cosine"))
    sk = simhash_sketches(g, k, jax.random.PRNGKey(0))
    approx = np.asarray(simhash_edge_similarity(sk, g.edge_u, g.nbrs, k))
    lo, hi = eps - delta, eps + np.sqrt(1 - eps**2) * delta
    outside = (exact <= lo) | (exact >= hi)
    misclassified = ((exact >= eps) != (approx >= eps)) & outside
    assert misclassified.sum() == 0, \
        f"{misclassified.sum()} edges misclassified outside the band"


def test_minhash_classification_bound():
    """Theorem 5.3: k ≥ ln(nm)/(2δ²) ⇒ edges outside (ε−δ, ε+δ) classified
    correctly w.h.p."""
    g = random_graph(60, 8.0, seed=22)
    eps, delta = 0.4, 0.2
    k = int(np.ceil(np.log(g.n * g.m) / (2 * delta**2)))
    exact = np.asarray(compute_similarities(g, "jaccard"))
    sk = minhash_sketches(g, k, jax.random.PRNGKey(1))
    approx = np.asarray(minhash_edge_similarity(sk, g.edge_u, g.nbrs))
    outside = (exact <= eps - delta) | (exact >= eps + delta)
    mis = ((exact >= eps) != (approx >= eps)) & outside
    assert mis.sum() == 0


def test_minhash_unbiased():
    """MinHash match probability equals the Jaccard similarity."""
    g = random_graph(30, 6.0, seed=23)
    exact = np.asarray(compute_similarities(g, "jaccard"))
    ests = []
    for trial in range(6):
        sk = minhash_sketches(g, 128, jax.random.PRNGKey(100 + trial))
        ests.append(np.asarray(minhash_edge_similarity(sk, g.edge_u, g.nbrs)))
    mean_est = np.mean(ests, axis=0)
    assert np.max(np.abs(mean_est - exact)) < 0.12


def test_kpartition_reasonable():
    """k-partition MinHash (no tail bound — paper §6.3) is still a usable
    estimator: mean abs error small at moderate k."""
    g = random_graph(80, 10.0, seed=24)
    exact = np.asarray(compute_similarities(g, "jaccard"))
    sk = kpartition_sketches(g, 128, jax.random.PRNGKey(2))
    approx = np.asarray(kpartition_edge_similarity(sk, g.edge_u, g.nbrs))
    assert np.mean(np.abs(approx - exact)) < 0.12


def test_degree_heuristic_exact_for_low_degree():
    """§6.3: edges with a low-degree endpoint get *exact* similarities."""
    g = random_graph(50, 4.0, seed=25)
    k = 64   # threshold k ⇒ every vertex here is low-degree
    exact = np.asarray(compute_similarities(g, "cosine"))
    approx = np.asarray(approximate_similarities(
        g, measure="cosine", method="simhash", samples=k,
        key=jax.random.PRNGKey(3), degree_heuristic=True))
    np.testing.assert_allclose(approx, exact, atol=1e-5)


def test_approx_clustering_quality():
    """Clusterings from approximate σ recover the exact-σ clustering on a
    planted-partition graph (paper §7.3.4 ARI experiment, miniature)."""
    g = random_graph(120, 10.0, seed=26, planted_clusters=5)
    idx_exact = build_index(g, "cosine")
    res_exact = query(idx_exact, g, 3, 0.4)
    idx_approx = build_index(g, "cosine", approx="simhash", samples=512,
                             key=jax.random.PRNGKey(4))
    res_approx = query(idx_approx, g, 3, 0.4)
    ari = adjusted_rand_index(np.asarray(res_exact.labels),
                              np.asarray(res_approx.labels))
    assert ari > 0.8, f"ARI {ari}"


def test_sketches_deterministic():
    g = random_graph(40, 5.0, seed=27)
    k = jax.random.PRNGKey(9)
    a = np.asarray(simhash_sketches(g, 96, k))
    b = np.asarray(simhash_sketches(g, 96, k))
    np.testing.assert_array_equal(a, b)


# --------------------------------------------------------------------------
# chunk invariance (regression): the chunk width is a *memory* knob — it
# must never change a sketch bit. The old per-chunk fold_in keyed the
# gaussian projections on the chunk boundary, so chunk=512 vs chunk=64
# produced different sketches, different σ̂, and therefore different index
# fingerprints for identical (graph, params).
# --------------------------------------------------------------------------
def test_simhash_chunk_invariance_regression():
    g = random_graph(50, 6.0, seed=31, weighted=True)
    key = jax.random.PRNGKey(13)
    samples = 600                       # spans the default 512-wide chunk
    ref = np.asarray(simhash_sketches(g, samples, key, chunk=512))
    for chunk in (64, 32, 640):
        got = np.asarray(simhash_sketches(g, samples, key, chunk=chunk))
        np.testing.assert_array_equal(
            got, ref, err_msg=f"chunk={chunk} changed simhash sketch bits")
    # and therefore σ̂ is chunking-invariant too
    s_ref = np.asarray(simhash_edge_similarity(
        jnp.asarray(ref), g.edge_u, g.nbrs, samples))
    s_64 = np.asarray(simhash_edge_similarity(
        simhash_sketches(g, samples, key, chunk=64),
        g.edge_u, g.nbrs, samples))
    np.testing.assert_array_equal(s_64, s_ref)
    with pytest.raises(ValueError, match="multiple of 32"):
        simhash_sketches(g, samples, key, chunk=48)


def test_minhash_chunk_invariance_regression():
    g = random_graph(40, 5.0, seed=32)
    key = jax.random.PRNGKey(14)
    ref = np.asarray(minhash_sketches(g, 100, key, chunk=64))
    for chunk in (7, 100, 256):
        got = np.asarray(minhash_sketches(g, 100, key, chunk=chunk))
        np.testing.assert_array_equal(
            got, ref, err_msg=f"chunk={chunk} changed minhash sketches")


# --------------------------------------------------------------------------
# §5 guarantees as properties (hypothesis; seed-pinned fast profile)
# --------------------------------------------------------------------------
try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    hypothesis = None

if hypothesis is not None:

    def _bound(nm, k, eta=0.01):
        """Hoeffding half-width: P(max-edge error > bound) < eta after a
        union bound over the nm (vertex, edge) pairs the paper uses."""
        return np.sqrt(np.log(2 * nm / eta) / (2 * k))

    @settings(max_examples=8, deadline=None)
    @given(gseed=st.integers(0, 40), skseed=st.integers(0, 1000))
    def test_hypothesis_simhash_error_concentrates(gseed, skseed):
        """Theorem 5.2: max |θ̂−θ| ≤ π·√(ln(2nm/η)/(2k)) w.h.p., and the
        error contracts as the sample count grows."""
        g = random_graph(64, 8.0, seed=gseed)
        theta = np.arccos(np.clip(
            np.asarray(compute_similarities(g, "cosine")), -1.0, 1.0))
        errs = {}
        for k in (32, 512):
            sk = simhash_sketches(g, k, jax.random.PRNGKey(skseed))
            sig = np.asarray(simhash_edge_similarity(
                sk, g.edge_u, g.nbrs, k))
            theta_hat = np.arccos(np.clip(sig, -1.0, 1.0))
            errs[k] = np.max(np.abs(theta_hat - theta))
            assert errs[k] <= np.pi * _bound(g.n * g.m, k), \
                f"k={k}: θ error {errs[k]:.4f} breaks the 5.2 bound"
        assert errs[512] <= 0.75 * errs[32] + 1e-6, \
            "16× samples did not concentrate the θ estimate"

    @settings(max_examples=8, deadline=None)
    @given(gseed=st.integers(0, 40), skseed=st.integers(0, 1000))
    def test_hypothesis_minhash_error_concentrates(gseed, skseed):
        """Theorem 5.3 (Hoeffding): max |σ̂−σ| ≤ √(ln(2nm/η)/(2k)) w.h.p.,
        contracting with the sample count."""
        g = random_graph(64, 8.0, seed=gseed)
        exact = np.asarray(compute_similarities(g, "jaccard"))
        errs = {}
        for k in (32, 512):
            sk = minhash_sketches(g, k, jax.random.PRNGKey(skseed))
            est = np.asarray(minhash_edge_similarity(sk, g.edge_u, g.nbrs))
            errs[k] = np.max(np.abs(est - exact))
            assert errs[k] <= _bound(g.n * g.m, k), \
                f"k={k}: σ̂ error {errs[k]:.4f} breaks the 5.3 bound"
        assert errs[512] <= 0.8 * errs[32] + 1e-6, \
            "16× samples did not concentrate the σ̂ estimate"

    @settings(max_examples=12, deadline=None)
    @given(method=st.sampled_from(["simhash", "minhash", "kpartition"]),
           samples=st.integers(8, 96),
           skseed=st.integers(0, 1000),
           gseed=st.integers(0, 40))
    def test_hypothesis_degree_heuristic_bit_exact(method, samples,
                                                   skseed, gseed):
        """§6.3: every edge with a low-degree endpoint gets *bit-exact* σ
        — equal to the exact engine's floats, regardless of method,
        sample count, or sketch seed. (All draws compare equal to the
        same exact reference, so the low-degree σ is also invariant
        across sketch params by transitivity.)"""
        from repro.core.graph import power_law_graph

        g = power_law_graph(200, seed=gseed)
        measure = "cosine" if method == "simhash" else "jaccard"
        exact = np.asarray(compute_similarities(g, measure))
        approx = np.asarray(approximate_similarities(
            g, measure=measure, method=method, samples=samples,
            key=jax.random.PRNGKey(skseed), degree_heuristic=True))
        thr = samples if measure == "cosine" else (3 * samples) // 2
        cdeg = np.asarray(g.closed_degrees())
        eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
        low = ~((cdeg[eu] > thr) & (cdeg[ev] > thr))
        assert low.any(), "degenerate draw: no low-degree edge to check"
        np.testing.assert_array_equal(
            approx[low], exact[low],
            err_msg=f"{method} k={samples} seed={skseed}: low-degree σ "
                    "not bit-exact")
