"""Per-architecture smoke tests: reduced config of the same family, one
forward + one train step on CPU, asserting shapes and no NaNs (assignment
requirement). Full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, all_arch_ids
from repro.models import model as mdl
from repro.optim import adamw
from repro.train.train_step import make_train_step, loss_fn

# one forward + train step per architecture ≈ 2 minutes of XLA compiles:
# slow lane (tier-1 runs `-m "not slow"`; CI has a dedicated slow job)
pytestmark = pytest.mark.slow

# reduced-config overrides per family: small layers/width/experts/tables
REDUCE = dict(
    n_layers=2, d_model=64, d_ff=128, vocab=251, dtype="float32",
    q_chunk=32, attn_impl="auto",
)


def reduce_cfg(arch):
    cfg = get_config(arch)
    over = dict(REDUCE)
    if cfg.family == "dense" or cfg.family == "encdec":
        over.update(n_heads=4, n_kv_heads=2 if cfg.n_kv_heads > 1 else 1,
                    head_dim=16)
    if cfg.family == "encdec":
        over.update(n_enc_layers=2, n_frames=12)
    if cfg.family == "moe":
        over.update(n_heads=4, n_kv_heads=4, head_dim=16, n_experts=8,
                    top_k=2, d_ff=48, d_ff_dense=96,
                    capacity_factor=4.0)
        if cfg.use_mla:
            over.update(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                        v_head_dim=16)
    if cfg.family == "ssm":
        over.update(ssm_state=8, ssm_head_dim=16, ssm_chunk=8)
    if cfg.family == "hybrid":
        over.update(n_heads=4, n_kv_heads=2, head_dim=16, ssm_state=8,
                    ssm_head_dim=16, ssm_chunk=8, global_layers=(0,),
                    window=16, meta_tokens=8)
    return cfg.scaled(**over)


def make_batch(cfg, b=2, s=32, key=0):
    kk = jax.random.PRNGKey(key)
    tokens = jax.random.randint(kk, (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend == "vision_stub":
        batch = {"embeddings": jax.random.normal(kk, (b, s, cfg.d_model)),
                 "labels": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(kk, (b, cfg.n_frames, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", all_arch_ids())
def test_smoke_forward_and_train_step(arch):
    cfg = reduce_cfg(arch)
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = mdl.forward(cfg, params, batch)
    b = batch["labels"].shape[0]
    assert logits.shape == (b, 32, cfg.vocab_padded)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN logits"

    # one full train step (grads + AdamW) — finite loss and updates
    hp = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = adamw.init(params)
    step = make_train_step(cfg, hp, accum=2)
    batch2 = jax.tree.map(
        lambda x: jnp.stack([x, x]), batch)   # accum axis
    p2, o2, metrics = jax.jit(step)(params, opt, batch2)
    assert np.isfinite(float(metrics["ce"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually moved
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", all_arch_ids())
def test_smoke_serve_path(arch):
    cfg = reduce_cfg(arch)
    if cfg.frontend == "vision_stub":
        pytest.skip("vlm decode starts from prefill embeddings (covered by "
                    "dense family decode tests)")
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens[:, :16]}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.n_frames, cfg.d_model))
    full_batch = dict(batch, tokens=tokens, labels=tokens)
    logits, _ = mdl.forward(cfg, params, full_batch)
    lg, cache = mdl.prefill(cfg, params, batch, max_len=s)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, 15]),
                               atol=2e-4)
    pos0 = 16 + (cfg.meta_tokens if cfg.family == "hybrid" else 0)
    for i in range(16, s):
        lg, cache = mdl.decode_step(cfg, params, cache, tokens[:, i],
                                    pos0 + (i - 16))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, i]),
                                   atol=2e-4, err_msg=f"{arch} step {i}")


def test_accum_equivalence():
    """accum=2 over a split batch ≡ accum=1 over the full batch."""
    cfg = reduce_cfg("granite-8b")
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, b=4)
    hp = adamw.AdamWConfig(lr=1e-3, grad_clip=0.0, warmup_steps=1,
                           total_steps=10)
    opt = adamw.init(params)
    b1 = jax.tree.map(lambda x: x[None], batch)
    p1, _, m1 = make_train_step(cfg, hp, accum=1)(params, opt, b1)
    b2 = jax.tree.map(lambda x: x.reshape(2, 2, *x.shape[1:]), batch)
    p2, _, m2 = make_train_step(cfg, hp, accum=2)(params, opt, b2)
    assert abs(float(m1["ce"]) - float(m2["ce"])) < 1e-5
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)


def test_loss_decreases_quick():
    """~40 steps on learnable synthetic data: loss visibly decreases."""
    from repro.data.pipeline import SyntheticLM
    cfg = reduce_cfg("granite-8b").scaled(n_layers=2, d_model=64, vocab=64)
    params = mdl.init_params(cfg, jax.random.PRNGKey(0))
    hp = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    opt = adamw.init(params)
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=8, accum=1)
    step = jax.jit(make_train_step(cfg, hp, accum=1))
    losses = []
    for i in range(40):
        batch = jax.tree.map(
            jnp.asarray, {k: v[None] for k, v in data.batch(i).items()})
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["ce"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses
