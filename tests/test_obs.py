"""Unit tests for the observability subsystem (``repro.obs``):
metrics primitives, span tracing, and the export surfaces.

Property-style randomized coverage of the histogram invariants lives in
``test_obs_property.py`` (hypothesis); this module is the deterministic
fast lane that always runs.
"""
import asyncio
import io
import json
import math
import threading

import numpy as np
import pytest

from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry, Tracer,
                       dump_loop, hist_delta, hist_quantile, render_line,
                       to_prometheus, write_json)


# ---------------------------------------------------------------- metrics
def test_counter_and_gauge_basics():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.value == 5
    g = Gauge()
    g.set(3.0)
    g.add(-1.5)
    assert g.value == 1.5


def test_histogram_edges_strictly_increasing():
    h = Histogram(lo=1e-6, hi=100.0, buckets_per_decade=8)
    assert all(a < b for a, b in zip(h.edges, h.edges[1:]))
    assert h.edges[0] == 1e-6 and h.edges[-1] == 100.0
    # one counts slot per edge + the overflow bucket
    assert len(h.counts) == len(h.edges) + 1


def test_histogram_bucket_boundaries():
    h = Histogram(lo=1e-3, hi=10.0, buckets_per_decade=4)
    # underflow: everything ≤ lo, including 0
    assert h.bucket_index(0.0) == 0
    assert h.bucket_index(1e-3) == 0
    # upper edges are inclusive: a value equal to edges[i] lands in i
    for i, e in enumerate(h.edges):
        assert h.bucket_index(e) == i
    # overflow: everything ≥ hi beyond the last edge
    assert h.bucket_index(11.0) == len(h.edges)


def test_histogram_record_and_quantile_semantics():
    h = Histogram(lo=1e-3, hi=10.0, buckets_per_decade=4)
    for v in (0.0, 0.002, 0.02, 0.2, 2.0, 50.0):
        h.record(v)
    assert h.count == 6
    assert h.sum == pytest.approx(52.222)
    assert h.min == 0.0 and h.max == 50.0
    assert h.mean == pytest.approx(52.222 / 6)
    # q=0 → rank 1 → the underflow bucket reports lo
    assert h.quantile(0.0) == h.edges[0]
    # q=1 → rank 6 → the overflow bucket reports the observed max
    assert h.quantile(1.0) == 50.0
    # every finite estimate is an actual bucket upper edge bounding the
    # order statistic from above, within one bucket
    q50 = h.quantile(0.5)
    assert q50 in h.edges and q50 >= 0.02


def test_histogram_quantile_matches_numpy_rank_oracle():
    """Estimate == upper edge of the bucket holding numpy's
    ``inverted_cdf`` order statistic (same ``ceil(q·n)`` rank)."""
    rng = np.random.default_rng(42)
    vals = 10.0 ** rng.uniform(-5, 1.5, size=500)     # spans the range
    h = Histogram(lo=1e-6, hi=100.0, buckets_per_decade=8)
    for v in vals:
        h.record(float(v))
    for q in (0.01, 0.25, 0.5, 0.9, 0.99):
        oracle = float(np.quantile(vals, q, method="inverted_cdf"))
        est = h.quantile(q)
        assert est == h.edges[h.bucket_index(oracle)]
        # multiplicative one-bucket error bound
        assert oracle <= est <= oracle * 10 ** (1 / 8) * (1 + 1e-9)


def test_histogram_empty_and_bad_quantile():
    h = Histogram()
    assert h.quantile(0.5) == 0.0
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_merge_conserves_counts():
    a = Histogram(lo=1e-3, hi=1.0, buckets_per_decade=4)
    b = Histogram(lo=1e-3, hi=1.0, buckets_per_decade=4)
    for v in (0.01, 0.1, 5.0):
        a.record(v)
    for v in (0.0001, 0.02, 0.2):
        b.record(v)
    pre = [x + y for x, y in zip(a.counts, b.counts)]
    a.merge(b)
    assert a.counts == pre
    assert a.count == 6
    assert a.sum == pytest.approx(0.01 + 0.1 + 5.0 + 0.0001 + 0.02 + 0.2)
    assert a.min == 0.0001 and a.max == 5.0


def test_histogram_merge_rejects_mismatched_edges():
    a = Histogram(lo=1e-3, hi=1.0)
    b = Histogram(lo=1e-3, hi=10.0)
    with pytest.raises(ValueError):
        a.merge(b)
    with pytest.raises(ValueError):
        hist_delta(a.snapshot(), b.snapshot())


def test_histogram_snapshot_json_round_trip():
    h = Histogram(lo=1e-4, hi=10.0, buckets_per_decade=4)
    for v in (0.001, 0.05, 0.5, 20.0):
        h.record(v)
    snap = h.snapshot()
    back = Histogram.from_snapshot(json.loads(json.dumps(snap)))
    assert back.snapshot() == snap
    for q in (0.1, 0.5, 0.9):
        assert back.quantile(q) == h.quantile(q)


def test_hist_delta_isolates_a_wave():
    h = Histogram(lo=1e-3, hi=1.0)
    for v in (0.01, 0.02):
        h.record(v)
    before = h.snapshot()
    for v in (0.1, 0.2, 0.4):
        h.record(v)
    wave = hist_delta(h.snapshot(), before)
    assert wave["count"] == 3
    assert wave["sum"] == pytest.approx(0.7)
    assert sum(wave["counts"]) == 3
    # the wave's median comes from the wave, not the cumulative history
    assert hist_quantile(wave, 0.5) >= 0.1


def test_registry_get_or_create_and_snapshot():
    reg = MetricsRegistry()
    reg.inc("a.count")
    reg.inc("a.count", 2)
    reg.gauge("a.depth").set(7)
    reg.observe("a.lat", 0.01)
    assert reg.counter("a.count") is reg.counter("a.count")
    snap = reg.snapshot()
    assert snap["counters"] == {"a.count": 3}
    assert snap["gauges"] == {"a.depth": 7.0}
    assert snap["histograms"]["a.lat"]["count"] == 1
    assert reg.names() == ["a.count", "a.depth", "a.lat"]
    json.dumps(snap)                     # JSON-serializable end to end


def test_registry_merge_snapshot_fleet_semantics():
    a, b = MetricsRegistry(), MetricsRegistry()
    for reg, k in ((a, 2), (b, 5)):
        reg.inc("req", k)
        reg.gauge("depth").set(k)
        for i in range(k):
            reg.observe("lat", 0.01 * (i + 1))
    a.merge_snapshot(b.snapshot())
    snap = a.snapshot()
    assert snap["counters"]["req"] == 7
    assert snap["gauges"]["depth"] == 7.0
    assert snap["histograms"]["lat"]["count"] == 7


def test_gauge_max_mode_merges_as_watermark():
    """Summing is wrong for watermarks: three replicas each 2 entries
    stale is a fleet 2 entries stale, not 6. Max-mode gauges keep the
    worst replica visible through merge_snapshot."""
    merged = MetricsRegistry()
    for stale in (2.0, 0.0, 2.0):
        r = MetricsRegistry()
        r.gauge("fleet.staleness_seq", "max").set(stale)
        r.gauge("depth").set(stale)          # default sum-mode sibling
        merged.merge_snapshot(r.snapshot())
    snap = merged.snapshot()
    assert snap["gauges"]["fleet.staleness_seq"] == 2.0
    assert snap["gauges"]["depth"] == 4.0
    assert snap["gauge_modes"] == {"fleet.staleness_seq": "max"}


def test_gauge_mode_conflict_rejected():
    reg = MetricsRegistry()
    reg.gauge("g", "max")
    assert reg.gauge("g").mode == "max"      # None = whatever exists
    with pytest.raises(ValueError):
        reg.gauge("g", "sum")
    with pytest.raises(ValueError):
        MetricsRegistry().gauge("h", "median")


def test_counter_is_thread_safe_under_contention():
    """The regression the registry exists for: concurrent increments from
    many threads must not lose updates (the old ``stats[k] += 1`` dict
    did, across the event loop + offload worker)."""
    reg = MetricsRegistry()
    n_threads, n_incs = 8, 2000

    def hammer():
        for _ in range(n_incs):
            reg.inc("hot")
            reg.observe("lat", 1e-4)

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert reg.counter("hot").value == n_threads * n_incs
    assert reg.histogram("lat").count == n_threads * n_incs


# ----------------------------------------------------------------- trace
def test_span_nesting_and_registry_backing():
    reg = MetricsRegistry()
    tr = Tracer(reg)
    with tr.span("outer", who="a") as outer:
        with tr.span("inner") as inner:
            inner.set(rows=3)
        assert inner.parent_id == outer.span_id
    evs = tr.events()
    assert [e["name"] for e in evs] == ["inner", "outer"]
    assert evs[0]["parent_id"] == evs[1]["span_id"]
    assert evs[1]["parent_id"] is None
    assert evs[0]["attrs"] == {"rows": 3}
    assert evs[1]["attrs"] == {"who": "a"}
    assert all(e["duration_s"] >= 0 for e in evs)
    assert reg.histogram("outer").count == 1
    assert reg.histogram("inner").count == 1


def test_span_nesting_isolated_across_asyncio_tasks():
    tr = Tracer()

    async def task(name):
        with tr.span(name):
            await asyncio.sleep(0.01)
            with tr.span(name + ".child"):
                await asyncio.sleep(0.01)

    async def main():
        await asyncio.gather(task("a"), task("b"))

    asyncio.run(main())
    by_id = {e["span_id"]: e for e in tr.events()}
    for ev in tr.events():
        if ev["name"].endswith(".child"):
            # each child is parented under ITS OWN task's root span
            assert by_id[ev["parent_id"]]["name"] == ev["name"][:-6]


def test_tracer_event_records_retro_duration():
    reg = MetricsRegistry()
    tr = Tracer(reg)
    tr.event("queue_wait", 0.25, fp="abc")
    (ev,) = tr.events("queue_wait")
    assert ev["duration_s"] == 0.25
    assert ev["attrs"] == {"fp": "abc"}
    snap = reg.histogram("queue_wait").snapshot()
    assert snap["count"] == 1 and snap["sum"] == pytest.approx(0.25)


def test_tracer_ring_buffer_bounds_memory():
    tr = Tracer(max_events=4)
    for i in range(10):
        tr.event("e", 0.0, i=i)
    evs = tr.events()
    assert len(evs) == 4
    assert [e["attrs"]["i"] for e in evs] == [6, 7, 8, 9]


# ---------------------------------------------------------------- export
def _sample_registry():
    reg = MetricsRegistry()
    reg.inc("engine.requests", 3)
    reg.gauge("engine.queue_depth").set(2)
    for v in (0.001, 0.01, 0.1):
        reg.observe("engine.e2e", v)
    return reg


def test_to_prometheus_cumulative_buckets():
    text = to_prometheus(_sample_registry().snapshot())
    assert "# TYPE repro_engine_requests counter" in text
    assert "repro_engine_requests 3" in text
    assert "repro_engine_queue_depth 2" in text
    assert '# TYPE repro_engine_e2e histogram' in text
    assert 'repro_engine_e2e_bucket{le="+Inf"} 3' in text
    assert "repro_engine_e2e_count 3" in text
    # bucket series must be cumulative (monotone nondecreasing)
    counts = [int(ln.rsplit(" ", 1)[1]) for ln in text.splitlines()
              if ln.startswith("repro_engine_e2e_bucket")]
    assert counts == sorted(counts)


def test_write_json_round_trips(tmp_path):
    reg = _sample_registry()
    path = tmp_path / "metrics.json"
    write_json(reg.snapshot(), str(path))
    assert json.loads(path.read_text()) == reg.snapshot()


def test_render_line_mentions_everything():
    line = render_line(_sample_registry().snapshot())
    assert line.startswith("stats: ")
    assert "engine.requests=3" in line
    assert "engine.e2e[n=3," in line and "ms]" in line


def test_dump_loop_emits_and_stops():
    reg = _sample_registry()
    seen = []

    async def main():
        await dump_loop(reg, 0.01, emit=seen.append, max_dumps=3)

    asyncio.run(main())
    assert len(seen) == 3
    assert all(s.startswith("stats: ") for s in seen)
