"""Hypothesis property tests for ``repro.obs`` histogram invariants:
monotone bucket bounds, count conservation under merge, quantile error
bounded by one bucket against the numpy order-statistic oracle, and
lossless JSON snapshot round-trips.

Deterministic unit coverage of the same surfaces lives in
``test_obs.py``; this module explores the input space when hypothesis is
installed (profiles in ``conftest.py``) and skips cleanly otherwise.
"""
import json
import math

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.obs import Histogram, MetricsRegistry, hist_delta  # noqa: E402

# values spanning underflow, every finite decade, and overflow
values = st.floats(min_value=0.0, max_value=1e4,
                   allow_nan=False, allow_infinity=False)
value_lists = st.lists(values, min_size=1, max_size=200)

hist_params = st.tuples(
    st.floats(1e-7, 1e-2), st.floats(1e-1, 1e3), st.integers(1, 16))


@settings(max_examples=50, deadline=None)
@given(hist_params)
def test_edges_strictly_increasing_and_anchored(params):
    lo, hi, bpd = params
    h = Histogram(lo=lo, hi=hi, buckets_per_decade=bpd)
    assert all(a < b for a, b in zip(h.edges, h.edges[1:]))
    assert h.edges[0] == lo and h.edges[-1] == hi
    assert len(h.counts) == len(h.edges) + 1


@settings(max_examples=50, deadline=None)
@given(value_lists)
def test_record_conserves_count_and_sum(vals):
    h = Histogram(lo=1e-6, hi=100.0, buckets_per_decade=8)
    for v in vals:
        h.record(v)
    assert h.count == len(vals)
    assert sum(h.counts) == len(vals)
    assert h.sum == pytest.approx(sum(vals))
    assert h.min == min(vals) and h.max == max(vals)


@settings(max_examples=50, deadline=None)
@given(value_lists, value_lists)
def test_merge_conserves_bucketwise_counts(a_vals, b_vals):
    a = Histogram(lo=1e-6, hi=100.0, buckets_per_decade=8)
    b = Histogram(lo=1e-6, hi=100.0, buckets_per_decade=8)
    for v in a_vals:
        a.record(v)
    for v in b_vals:
        b.record(v)
    expect = [x + y for x, y in zip(a.counts, b.counts)]
    a.merge(b)
    assert a.counts == expect
    assert a.count == len(a_vals) + len(b_vals)
    assert a.sum == pytest.approx(sum(a_vals) + sum(b_vals))


@settings(max_examples=50, deadline=None)
@given(value_lists, st.floats(0.0, 1.0))
def test_quantile_within_one_bucket_of_numpy_oracle(vals, q):
    h = Histogram(lo=1e-6, hi=100.0, buckets_per_decade=8)
    for v in vals:
        h.record(v)
    oracle = float(np.quantile(np.asarray(vals), q, method="inverted_cdf"))
    est = h.quantile(q)
    i = h.bucket_index(oracle)
    if i >= len(h.edges):
        # oracle overflows → estimate is the observed max ≥ oracle
        assert est == h.max and est >= oracle
    else:
        # estimate is the upper edge of the oracle's bucket: bounded
        # above by one multiplicative bucket width (underflow reports lo)
        assert est == h.edges[i]
        assert oracle <= est * (1 + 1e-9)


@settings(max_examples=50, deadline=None)
@given(value_lists)
def test_snapshot_json_round_trip_lossless(vals):
    h = Histogram(lo=1e-6, hi=100.0, buckets_per_decade=8)
    for v in vals:
        h.record(v)
    snap = h.snapshot()
    back = Histogram.from_snapshot(json.loads(json.dumps(snap)))
    assert back.snapshot() == snap
    for q in (0.0, 0.5, 0.99, 1.0):
        assert back.quantile(q) == h.quantile(q)


@settings(max_examples=25, deadline=None)
@given(value_lists, value_lists)
def test_hist_delta_recovers_second_wave(first, second):
    h = Histogram(lo=1e-6, hi=100.0, buckets_per_decade=8)
    for v in first:
        h.record(v)
    before = h.snapshot()
    for v in second:
        h.record(v)
    wave = hist_delta(h.snapshot(), before)
    assert wave["count"] == len(second)
    assert sum(wave["counts"]) == len(second)
    assert wave["sum"] == pytest.approx(sum(second), abs=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["a", "b", "c"]), values),
                min_size=1, max_size=60))
def test_registry_merge_snapshot_equals_single_registry(obs):
    """Recording split across two registries then merged == recording
    everything into one registry (fleet aggregation is lossless)."""
    one, left, right = (MetricsRegistry() for _ in range(3))
    for i, (name, v) in enumerate(obs):
        one.observe(name, v)
        one.inc("n." + name)
        (left if i % 2 == 0 else right).observe(name, v)
        (left if i % 2 == 0 else right).inc("n." + name)
    left.merge_snapshot(right.snapshot())
    merged, direct = left.snapshot(), one.snapshot()
    assert merged["counters"] == direct["counters"]
    for name in direct["histograms"]:
        m, d = merged["histograms"][name], direct["histograms"][name]
        assert m["counts"] == d["counts"]
        assert m["count"] == d["count"]
        assert m["sum"] == pytest.approx(d["sum"])


# --------------------------------------------------------------------------
# gauge merge modes (fleet aggregation of levels vs watermarks)
# --------------------------------------------------------------------------
gauge_vals = st.floats(min_value=-1e6, max_value=1e6,
                       allow_nan=False, allow_infinity=False)


@settings(max_examples=50, deadline=None)
@given(st.lists(gauge_vals, min_size=1, max_size=20),
       st.lists(gauge_vals, min_size=1, max_size=20))
def test_gauge_merge_modes_against_oracle(a_vals, b_vals):
    """Folding per-replica snapshots must equal the plain-python oracle:
    sum-mode gauges add their final levels, max-mode gauges keep the
    fleet-wide worst watermark."""
    regs = []
    for vals in (a_vals, b_vals):
        r = MetricsRegistry()
        for v in vals:
            r.gauge("level").set(v)
            r.gauge("watermark", "max").set(v)
        regs.append(r)
    merged = MetricsRegistry()
    for r in regs:
        merged.merge_snapshot(r.snapshot())
    snap = merged.snapshot()
    assert snap["gauges"]["level"] == pytest.approx(
        a_vals[-1] + b_vals[-1])
    assert snap["gauges"]["watermark"] == max(a_vals[-1], b_vals[-1])
    # only the non-default mode travels in the snapshot (back-compat:
    # pre-mode snapshots merge exactly as before)
    assert snap["gauge_modes"] == {"watermark": "max"}


@settings(max_examples=30, deadline=None)
@given(st.lists(st.lists(gauge_vals, min_size=1, max_size=8),
                min_size=1, max_size=6))
def test_gauge_max_merge_is_order_independent(replica_vals):
    """Max-mode folding is associative/commutative: any merge order
    yields the same fleet watermark (sum-mode likewise, by addition)."""
    finals = [vals[-1] for vals in replica_vals]
    snaps = []
    for vals in replica_vals:
        r = MetricsRegistry()
        for v in vals:
            r.gauge("hw", "max").set(v)
        snaps.append(r.snapshot())
    fwd, rev = MetricsRegistry(), MetricsRegistry()
    for s in snaps:
        fwd.merge_snapshot(s)
    for s in reversed(snaps):
        rev.merge_snapshot(s)
    assert fwd.snapshot()["gauges"]["hw"] == max(finals)
    assert rev.snapshot()["gauges"]["hw"] == max(finals)


@settings(max_examples=30, deadline=None)
@given(st.lists(gauge_vals, min_size=1, max_size=20))
def test_modeless_snapshot_merges_as_sum(vals):
    """A snapshot with no gauge_modes key (old format) merges every
    gauge additively — the pre-mode behavior, bit for bit."""
    r = MetricsRegistry()
    for v in vals:
        r.gauge("g").set(v)
    snap = r.snapshot()
    assert "gauge_modes" not in snap
    merged = MetricsRegistry()
    merged.merge_snapshot(snap)
    merged.merge_snapshot(snap)
    assert merged.snapshot()["gauges"]["g"] == pytest.approx(2 * vals[-1])
