"""Incremental SimilarityPlan maintenance vs build-from-scratch.

The invariant: ``plan.apply(g2, touched)`` is **bit-identical** to
``SimilarityPlan.build(g2, plan.hub_tile)`` — every block, routing table,
norm bit — while doing work proportional to the *touched* rows/classes
(asserted via the ``last_apply`` counters). Covered edit classes:

  * layout-stable row re-packs (same degree class, content change);
  * pow2 class migration (a vertex moving between exactly two blocks);
  * hub tile-row splits and merges under the ``HUB_TILE`` rule;
  * class birth (a width with no predecessor block) and death;
  * emptying the graph and repopulating it.

Plus the plan-cache lifetime regression (entries must die with their
graph, not linger until the next miss sweeps them).
"""
import gc

import numpy as np
import pytest

from repro.core import (EdgeDelta, apply_delta, build_index, from_edge_list,
                        hub_ring_graph, power_law_graph, random_graph)
from repro.core import similarity as sim_mod
from repro.core.similarity import SimilarityPlan, plan_for
from repro.core.update import _edit_edge_set

from _plan_oracle import assert_plan_equal

try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    hypothesis = None


def edit(g, plan, delta, tag):
    """One maintained step: returns (g2, successor, build reference)."""
    new_lo, new_hi, new_w, touched, _, _ = _edit_edge_set(g, delta)
    g2 = from_edge_list(
        g.n, np.stack([new_lo, new_hi], axis=1)
        if len(new_lo) else np.zeros((0, 2), np.int64), new_w)
    plan2 = plan.apply(g2, touched)
    ref = SimilarityPlan.build(g2, plan.hub_tile)
    assert_plan_equal(plan2, ref, tag)
    return g2, plan2


def test_stable_rows_repack_in_place():
    """A small edit between low-degree vertices: touched rows rewrite,
    every untouched class block is adopted by identity (same device
    array), and the work counter stays proportional to the edit."""
    g = random_graph(120, 6.0, seed=1, weighted=True)
    plan = SimilarityPlan.build(g)
    # endpoints strictly inside their pow2 class (deg+1 keeps the width),
    # so the insert re-packs two rows without migrating anybody
    deg = plan.deg
    inside = [v for v in range(g.n)
              if 2 <= deg[v] and (deg[v] < 8 or deg[v] & (deg[v] - 1))]
    u, v = None, None
    eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
    for a in inside:
        for b in inside:
            if a < b and not np.any((eu == a) & (ev == b)):
                u, v = a, b
                break
        if u is not None:
            break
    g2, plan2 = edit(g, plan, EdgeDelta.make(
        inserts=[(u, v)], weights=[0.7]), "stable")
    stats = plan2.last_apply
    assert stats["built"] == 0
    # two endpoints → at most their own classes re-pack; everything else
    # must be reused *by identity* (no device copy, no host re-pack)
    assert stats["patched"] + stats["remapped"] <= 2
    assert stats["rows_written"] <= 2
    old_by_width = dict(zip(plan.widths, plan.nbr_blocks))
    reused = sum(plan2.nbr_blocks[i] is old_by_width.get(w)
                 for i, w in enumerate(plan2.widths))
    assert reused == stats["reused"] >= len(plan2.widths) - 2


def test_class_migration_moves_between_two_blocks():
    """Growing a vertex across a pow2 boundary must migrate it between
    exactly its two classes (plus its neighbors' row re-packs)."""
    g = random_graph(100, 4.0, seed=2)
    plan = plan_for(g)
    v = 5
    deg0 = int(plan.deg[v])
    w0 = int(plan.widths[plan.vclass[v]])
    targets = [u for u in range(g.n)
               if u != v and not np.any(
                   (np.asarray(g.edge_u) == v) & (np.asarray(g.nbrs) == u))]
    grow = targets[: w0 - deg0 + 1]          # strictly past the class width
    g2, plan2 = edit(g, plan, EdgeDelta.make(
        inserts=[(v, u) for u in grow]), "migrate")
    assert int(plan2.widths[plan2.vclass[v]]) == 2 * w0
    stats = plan2.last_apply
    assert stats["remapped"] + stats["built"] >= 1    # v's new class
    assert stats["rows_written"] < sum(
        b.shape[0] for b in plan2.nbr_blocks)


def test_hub_tile_split_and_merge():
    """With a tiny hub_tile, growing the hub adds tile rows (split) and
    shrinking it removes them (merge) — both bit-identical to build."""
    g = hub_ring_graph(90, 40, seed=3, weighted=True)
    plan = SimilarityPlan.build(g, hub_tile=16)
    assert int(plan.vtiles[0]) == 3                   # ⌈40/16⌉
    spokes = set(np.asarray(g.nbrs)[np.asarray(g.edge_u) == 0].tolist())
    free = [v for v in range(1, g.n) if v not in spokes]
    g2, plan2 = edit(g, plan, EdgeDelta.make(
        inserts=[(0, v) for v in free[:20]]), "split")
    assert int(plan2.vtiles[0]) == 4                  # ⌈60/16⌉ — split
    hub_nbrs = np.asarray(g2.nbrs)[np.asarray(g2.edge_u) == 0]
    g3, plan3 = edit(g2, plan2, EdgeDelta.make(
        deletes=[(0, int(v)) for v in hub_nbrs[:40]]), "merge")
    assert int(plan3.vtiles[0]) < int(plan2.vtiles[0])


def test_class_birth_and_death():
    """An edit that creates a width no block existed for (all members
    touched → packed fresh), then removes it again."""
    g = from_edge_list(40, [(i, (i + 1) % 8) for i in range(8)])
    plan = SimilarityPlan.build(g)
    assert plan.widths == (8,)
    ins = [(20, v) for v in range(21, 21 + 12)]       # degree 12 → width 16
    g2, plan2 = edit(g, plan, EdgeDelta.make(inserts=ins), "birth")
    assert 16 in plan2.widths
    assert plan2.last_apply["built"] == 1
    g3, plan3 = edit(g2, plan2, EdgeDelta.make(deletes=ins), "death")
    assert plan3.widths == (8,)


def test_empty_and_repopulate():
    g = random_graph(24, 3.0, seed=4, weighted=True)
    plan = SimilarityPlan.build(g)
    eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
    g2, plan2 = edit(g, plan, EdgeDelta.make(
        deletes=[(int(u), int(v)) for u, v in zip(eu, ev) if u < v]),
        "empty")
    assert g2.m2 == 0
    g3, plan3 = edit(g2, plan2, EdgeDelta.make(
        inserts=[(0, 1), (1, 2), (5, 9)], weights=[0.2, 0.5, 0.9]),
        "repopulate")
    assert g3.m2 == 6


def test_noop_apply_reuses_every_block():
    g = random_graph(60, 5.0, seed=5)
    plan = SimilarityPlan.build(g)
    plan2 = plan.apply(g, np.zeros(0, np.int64))
    stats = plan2.last_apply
    assert stats["reused"] == stats["classes"]
    assert stats["rows_written"] == 0
    assert all(a is b for a, b in zip(plan2.nbr_blocks, plan.nbr_blocks))
    assert plan2.norms is plan.norms


def test_vertex_count_change_rejected():
    g = random_graph(20, 3.0, seed=6)
    g_bigger = random_graph(21, 3.0, seed=6)
    with pytest.raises(ValueError, match="vertex count"):
        SimilarityPlan.build(g).apply(g_bigger, np.zeros(0, np.int64))


def test_maintained_plan_serves_sigma():
    """The successor plan is a fully functional engine: σ off the
    maintained blocks matches the dense oracle bitwise (unweighted)."""
    from repro.core.similarity import compute_similarities_dense

    g = power_law_graph(100, 2.1, seed=7, hub_degree=30)
    plan = SimilarityPlan.build(g)
    rng = np.random.default_rng(0)
    for step in range(3):
        delta = EdgeDelta.make(
            inserts=rng.integers(0, g.n, size=(4, 2)),
            deletes=[(int(u), int(v)) for u, v in zip(
                *[a[:2] for a in (np.asarray(g.edge_u), np.asarray(g.nbrs))])])
        g, plan = edit(g, plan, delta, f"serve step={step}")
        got = np.asarray(plan.edge_sims(g.edge_u, g.nbrs, g.wgts, "cosine"))
        want = np.asarray(compute_similarities_dense(g, "cosine"))
        np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# plan-cache lifetime (bugfix regression)
# --------------------------------------------------------------------------
def test_plan_cache_evicts_on_graph_death():
    """A dead graph's O(m + n) device blocks must leave the cache the
    moment the graph is collected — not at the next cache miss."""
    before = sim_mod.plan_cache_size()
    g = random_graph(50, 4.0, seed=8)
    plan_for(g)
    assert sim_mod.plan_cache_size() == before + 1
    del g
    gc.collect()
    assert sim_mod.plan_cache_size() == before


def test_repeated_deltas_do_not_regrow_plan_cache():
    """The resident-update loop: every apply_delta adopts a plan for the
    new graph and the predecessor's entry dies with its graph."""
    g = random_graph(60, 5.0, seed=9)
    idx = build_index(g, "cosine")
    rng = np.random.default_rng(0)
    base = sim_mod.plan_cache_size()
    for k in range(6):
        ins = rng.integers(0, g.n, size=(3, 2))
        idx, g, _ = apply_delta(idx, g, EdgeDelta.make(inserts=ins))
        gc.collect()
        assert sim_mod.plan_cache_size() <= base + 2, \
            f"plan cache regrew at step {k}"
    assert sim_mod.cached_plan(g) is not None          # live graph cached


def test_adopted_plan_is_served_from_cache():
    """apply_delta must seed the cache so the post-edit graph never pays
    an O(m) plan rebuild (the whole point of incremental maintenance)."""
    g = random_graph(60, 5.0, seed=10)
    idx = build_index(g, "cosine")
    idx2, g2, info = apply_delta(idx, g, EdgeDelta.make(inserts=[(0, 30)]))
    maintained = sim_mod.cached_plan(g2)
    assert maintained is not None
    assert maintained.last_apply is not None           # patched, not built
    assert plan_for(g2) is maintained
    assert info.n_plan_rows >= 1
    assert info.n_plan_classes >= 1


# --------------------------------------------------------------------------
# hypothesis property: apply ≡ build across migration / split / merge
# --------------------------------------------------------------------------
if hypothesis is not None:

    @st.composite
    def plan_edit_scripts(draw):
        """(graph, [EdgeDelta ...], hub_tile) biased toward class
        migrations (hub-heavy inserts/deletes) and tile splits/merges
        (hub_tile small enough that the forced hub is multi-tile)."""
        n = draw(st.integers(8, 28))
        m = draw(st.integers(1, 2 * n))
        pairs = draw(st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m))
        pairs = [(u, v) for u, v in pairs if u != v] or [(0, 1)]
        if draw(st.booleans()):                        # force a hub at 0
            pairs += [(0, v) for v in range(1, n)]
        weighted = draw(st.booleans())
        w = (draw(st.lists(st.floats(0.1, 1.0, allow_nan=False, width=32),
                           min_size=len(pairs), max_size=len(pairs)))
             if weighted else None)
        g = from_edge_list(n, np.asarray(pairs, np.int64),
                           np.asarray(w, np.float32) if w else None)
        hub_tile = draw(st.sampled_from([8, 16, 2048]))
        steps = []
        for _ in range(draw(st.integers(1, 3))):
            k_ins = draw(st.integers(0, 5))
            k_del = draw(st.integers(0, 5))
            ins = draw(st.lists(
                st.tuples(st.integers(0, n - 1), st.integers(0, n - 1),
                          st.floats(0.1, 1.0, allow_nan=False)),
                min_size=k_ins, max_size=k_ins))
            if draw(st.booleans()):                    # pile onto the hub
                ins += [(0, draw(st.integers(1, n - 1)), 1.0)]
            dels = draw(st.lists(
                st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
                min_size=k_del, max_size=k_del))
            steps.append((ins, dels))
        return g, steps, hub_tile

    @settings(max_examples=20, deadline=None)
    @given(plan_edit_scripts())
    def test_hypothesis_plan_apply_equals_build(case):
        g, steps, hub_tile = case
        plan = SimilarityPlan.build(g, hub_tile)
        for i, (ins, dels) in enumerate(steps):
            # bias deletions toward edges that actually exist
            eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
            canon = [(int(u), int(v)) for u, v in zip(eu, ev) if u < v]
            real_dels = list(dels)
            if canon and dels:
                real_dels += [canon[(u * 7 + v) % len(canon)]
                              for u, v in dels[:2]]
            delta = EdgeDelta.make(
                inserts=[(u, v) for u, v, _ in ins],
                weights=[w for _, _, w in ins],
                deletes=real_dels)
            g, plan = edit(g, plan, delta, f"step {i}")
