"""Hypothesis property tests for SCAN invariants (paper §3.1 definitions)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    build_index,
    compute_similarities,
    from_edge_list,
    query,
)
from repro.core.scan_ref import scan_ref


@st.composite
def graphs(draw):
    n = draw(st.integers(5, 28))
    max_edges = n * (n - 1) // 2
    m = draw(st.integers(1, min(max_edges, 3 * n)))
    pairs = draw(
        st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            min_size=m, max_size=m,
        )
    )
    pairs = [(u, v) for u, v in pairs if u != v]
    if not pairs:
        pairs = [(0, 1 % n)] if n > 1 else []
    return from_edge_list(n, np.asarray(pairs, dtype=np.int64))


@settings(max_examples=25, deadline=None)
@given(graphs(), st.integers(2, 5), st.floats(0.05, 0.95))
def test_parallel_matches_oracle(g, mu, eps):
    sims = compute_similarities(g, "cosine")
    idx = build_index(g, "cosine", sims=sims)
    res = query(idx, g, mu, float(eps))
    ref = scan_ref(g, mu, float(eps), "cosine", sims=np.asarray(sims))
    np.testing.assert_array_equal(np.asarray(res.is_core), ref["is_core"])
    np.testing.assert_array_equal(np.asarray(res.labels), ref["labels"])


@settings(max_examples=25, deadline=None)
@given(graphs(), st.integers(2, 5), st.floats(0.05, 0.95))
def test_structural_invariants(g, mu, eps):
    """Definitional invariants, checked directly (not via the oracle):
    1. every clustered core's ε-similar core neighbors share its cluster
       (maximality);
    2. every clustered non-core (border) has an ε-similar core neighbor in
       its cluster;
    3. unclustered vertices are exactly those that are neither cores nor
       ε-similar to a core."""
    eps = float(eps)
    sims = np.asarray(compute_similarities(g, "cosine"))
    idx = build_index(g, "cosine", sims=sims)
    res = query(idx, g, mu, eps)
    labels = np.asarray(res.labels)
    core = np.asarray(res.is_core)
    eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
    f32 = np.float32
    simok = sims.astype(f32) >= f32(eps)

    # (1) maximality over core-core similar edges
    for i in range(g.m2):
        u, v = eu[i], ev[i]
        if core[u] and core[v] and simok[i]:
            assert labels[u] == labels[v]
    # (2)+(3)
    for v in range(g.n):
        if core[v]:
            assert labels[v] >= 0
            continue
        nbr_core_sim = [
            (labels[eu[i]], sims[i]) for i in range(g.m2)
            if ev[i] == v and core[eu[i]] and simok[i]
        ]
        if labels[v] >= 0:
            assert any(l == labels[v] for l, _ in nbr_core_sim)
        else:
            assert not nbr_core_sim


@settings(max_examples=15, deadline=None)
@given(graphs())
def test_index_structure(g):
    """NO rows are σ-descending with the self slot first; CO segments are
    θ-descending (the sorted-prefix properties queries depend on)."""
    idx = build_index(g, "cosine")
    off = np.asarray(idx.offsets_c)
    sims = np.asarray(idx.no_sims)
    selfs = np.asarray(idx.no_self)
    for v in range(g.n):
        row = sims[off[v]: off[v + 1]]
        assert np.all(np.diff(row) <= 1e-6)
        assert selfs[off[v]]
    co_off = np.asarray(idx.co_offsets)
    theta = np.asarray(idx.co_theta)
    for mu in range(2, idx.max_cdeg + 1):
        seg = theta[co_off[mu]: co_off[mu + 1]]
        assert np.all(np.diff(seg) <= 1e-6)
