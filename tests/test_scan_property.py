"""Hypothesis property tests for SCAN invariants (paper §3.1 definitions)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (
    build_index,
    compute_similarities,
    from_edge_list,
    query,
    query_batch,
)
from repro.core.scan_ref import scan_ref
from repro.serve import grid_sweep


@st.composite
def graphs(draw, weighted=False, isolate=False):
    """Random small graphs. ``weighted`` draws per-edge weights;
    ``isolate`` confines edges to the low half of the id space so the high
    half is guaranteed-isolated vertices (degree 0)."""
    n = draw(st.integers(5, 28))
    hi = max(1, n // 2 - 1) if isolate else n - 1
    max_edges = (hi + 1) * hi // 2
    m = draw(st.integers(1, max(1, min(max_edges, 3 * n))))
    pairs = draw(
        st.lists(
            st.tuples(st.integers(0, hi), st.integers(0, hi)),
            min_size=m, max_size=m,
        )
    )
    pairs = [(u, v) for u, v in pairs if u != v]
    if not pairs:
        pairs = [(0, 1 % (hi + 1))] if hi > 0 else [(0, 1)]
    weights = None
    if weighted:
        weights = draw(
            st.lists(st.floats(0.1, 1.0, allow_nan=False),
                     min_size=len(pairs), max_size=len(pairs))
        )
        weights = np.asarray(weights, dtype=np.float32)
    return from_edge_list(n, np.asarray(pairs, dtype=np.int64), weights)


@settings(max_examples=25, deadline=None)
@given(graphs(), st.integers(2, 5), st.floats(0.05, 0.95))
def test_parallel_matches_oracle(g, mu, eps):
    sims = compute_similarities(g, "cosine")
    idx = build_index(g, "cosine", sims=sims)
    res = query(idx, g, mu, float(eps))
    ref = scan_ref(g, mu, float(eps), "cosine", sims=np.asarray(sims))
    np.testing.assert_array_equal(np.asarray(res.is_core), ref["is_core"])
    np.testing.assert_array_equal(np.asarray(res.labels), ref["labels"])


@settings(max_examples=25, deadline=None)
@given(graphs(), st.integers(2, 5), st.floats(0.05, 0.95))
def test_structural_invariants(g, mu, eps):
    """Definitional invariants, checked directly (not via the oracle):
    1. every clustered core's ε-similar core neighbors share its cluster
       (maximality);
    2. every clustered non-core (border) has an ε-similar core neighbor in
       its cluster;
    3. unclustered vertices are exactly those that are neither cores nor
       ε-similar to a core."""
    eps = float(eps)
    sims = np.asarray(compute_similarities(g, "cosine"))
    idx = build_index(g, "cosine", sims=sims)
    res = query(idx, g, mu, eps)
    labels = np.asarray(res.labels)
    core = np.asarray(res.is_core)
    eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
    f32 = np.float32
    simok = sims.astype(f32) >= f32(eps)

    # (1) maximality over core-core similar edges
    for i in range(g.m2):
        u, v = eu[i], ev[i]
        if core[u] and core[v] and simok[i]:
            assert labels[u] == labels[v]
    # (2)+(3)
    for v in range(g.n):
        if core[v]:
            assert labels[v] >= 0
            continue
        nbr_core_sim = [
            (labels[eu[i]], sims[i]) for i in range(g.m2)
            if ev[i] == v and core[eu[i]] and simok[i]
        ]
        if labels[v] >= 0:
            assert any(l == labels[v] for l, _ in nbr_core_sim)
        else:
            assert not nbr_core_sim


def _assert_matches_oracle(g, sims, res_labels, res_core, mu, eps, tag=""):
    ref = scan_ref(g, mu, eps, "cosine", sims=np.asarray(sims))
    np.testing.assert_array_equal(
        np.asarray(res_core), ref["is_core"], err_msg=f"{tag} is_core")
    np.testing.assert_array_equal(
        np.asarray(res_labels), ref["labels"], err_msg=f"{tag} labels")


@settings(max_examples=10, deadline=None)
@given(graphs(), st.data())
def test_query_batch_matches_oracle_per_setting(g, data):
    """Every row of one vmapped ``query_batch`` call equals the sequential
    oracle for that (μ, ε) — including both ε extremes (0 admits every
    edge, 1 only σ=1 edges) and a μ beyond every closed degree (no cores,
    nothing clustered)."""
    sims = compute_similarities(g, "cosine")
    idx = build_index(g, "cosine", sims=sims)
    settings_ = [
        (data.draw(st.integers(2, 5)), data.draw(st.floats(0.05, 0.95))),
        (2, 0.0),                       # ε = 0: σ ≥ 0 everywhere
        (2, 1.0),                       # ε = 1: only exact-1 similarities
        (idx.max_cdeg + 1 + data.draw(st.integers(0, 3)), 0.5),  # μ too big
    ]
    mus = np.asarray([m for m, _ in settings_], np.int32)
    epss = np.asarray([e for _, e in settings_], np.float32)
    res = query_batch(idx, g, mus, epss)
    for i, (mu, eps) in enumerate(settings_):
        _assert_matches_oracle(g, sims, res.labels[i], res.is_core[i],
                               int(mu), float(eps), tag=f"setting {i}")
    # μ > max closed degree ⇒ no cores, nothing clustered
    assert not np.asarray(res.is_core[3]).any()
    assert (np.asarray(res.labels[3]) == -1).all()
    assert int(res.n_clusters[3]) == 0


@settings(max_examples=10, deadline=None)
@given(graphs(weighted=True), st.integers(2, 5), st.floats(0.05, 0.95))
def test_weighted_query_batch_matches_oracle(g, mu, eps):
    """Weighted graphs: the weighted-cosine σ flows through the index and
    the batched query exactly as the oracle's explicit intersection."""
    sims = compute_similarities(g, "cosine")
    idx = build_index(g, "cosine", sims=sims)
    res = query_batch(idx, g, [mu], [float(eps)])
    _assert_matches_oracle(g, sims, res.labels[0], res.is_core[0],
                           mu, float(eps))


@settings(max_examples=10, deadline=None)
@given(graphs(isolate=True), st.floats(0.05, 0.95))
def test_isolated_vertices_stay_unclustered(g, eps):
    """Isolated vertices (closed degree 1): never cores for μ ≥ 2, never
    borders (no edges), always label -1 — and the oracle agrees."""
    sims = compute_similarities(g, "cosine")
    idx = build_index(g, "cosine", sims=sims)
    res = query_batch(idx, g, [2], [float(eps)])
    _assert_matches_oracle(g, sims, res.labels[0], res.is_core[0],
                           2, float(eps))
    deg = np.diff(np.asarray(g.offsets))
    isolated = deg == 0
    assert isolated.any(), "strategy must generate isolated vertices"
    assert not np.asarray(res.is_core[0])[isolated].any()
    assert (np.asarray(res.labels[0])[isolated] == -1).all()


@settings(max_examples=8, deadline=None)
@given(graphs(), st.lists(st.integers(2, 5), min_size=2, max_size=3,
                          unique=True))
def test_grid_sweep_matches_oracle(g, mu_values):
    """grid_sweep (μ-major cartesian product) row-for-row equals the
    oracle; covers the serve layer's batched entry point end to end."""
    eps_values = [0.0, 0.45, 1.0]
    sims = compute_similarities(g, "cosine")
    idx = build_index(g, "cosine", sims=sims)
    res = grid_sweep(idx, g, mu_values, eps_values)
    assert len(res) == len(mu_values) * len(eps_values)
    k = 0
    for mu in mu_values:
        for eps in eps_values:
            assert (res.mus[k], res.epss[k]) == (mu, np.float32(eps))
            _assert_matches_oracle(g, sims, res.labels[k], res.is_core[k],
                                   int(mu), float(eps), tag=f"row {k}")
            k += 1


@settings(max_examples=15, deadline=None)
@given(graphs())
def test_index_structure(g):
    """NO rows are σ-descending with the self slot first; CO segments are
    θ-descending (the sorted-prefix properties queries depend on)."""
    idx = build_index(g, "cosine")
    off = np.asarray(idx.offsets_c)
    sims = np.asarray(idx.no_sims)
    selfs = np.asarray(idx.no_self)
    for v in range(g.n):
        row = sims[off[v]: off[v + 1]]
        assert np.all(np.diff(row) <= 1e-6)
        assert selfs[off[v]]
    co_off = np.asarray(idx.co_offsets)
    theta = np.asarray(idx.co_theta)
    for mu in range(2, idx.max_cdeg + 1):
        seg = theta[co_off[mu]: co_off[mu + 1]]
        assert np.all(np.diff(seg) <= 1e-6)
