"""Cluster queries vs the sequential SCAN oracle (paper §4.2-4.3)."""
import numpy as np
import pytest

from repro.core import (
    build_index,
    compute_similarities,
    from_edge_list,
    get_cores,
    hubs_outliers,
    query,
    random_graph,
)
from repro.core.scan_ref import scan_ref

GRAPHS = [
    (random_graph(60, 5.0, seed=11), "cosine"),
    (random_graph(60, 5.0, seed=11), "jaccard"),
    (random_graph(90, 7.0, seed=12, weighted=True), "cosine"),
    (random_graph(45, 3.0, seed=13, planted_clusters=4), "jaccard"),
]
PARAMS = [(2, 0.3), (2, 0.7), (3, 0.5), (5, 0.2), (5, 0.6), (4, 0.9)]


@pytest.mark.parametrize("g,measure", GRAPHS)
def test_query_matches_oracle(g, measure):
    sims = compute_similarities(g, measure)
    idx = build_index(g, measure, sims=sims)
    for mu, eps in PARAMS:
        res = query(idx, g, mu, eps)
        ref = scan_ref(g, mu, eps, measure, sims=np.asarray(sims))
        np.testing.assert_array_equal(np.asarray(res.is_core), ref["is_core"])
        np.testing.assert_array_equal(np.asarray(res.labels), ref["labels"])
        hub, outl = hubs_outliers(g, res.labels)
        np.testing.assert_array_equal(np.asarray(hub), ref["is_hub"])
        np.testing.assert_array_equal(np.asarray(outl), ref["is_outlier"])


def test_paper_figure1_clustering():
    """Paper Fig. 1: (μ=3, ε=.6) → clusters {1,2,3,4} and {6,7,8,11},
    vertex 5 a hub."""
    edges = [(1, 2), (1, 3), (1, 4), (2, 3), (2, 4), (3, 4), (4, 5), (5, 6),
             (6, 7), (6, 8), (7, 8), (7, 11), (8, 11), (7, 9), (8, 10)]
    g = from_edge_list(11, [(u - 1, v - 1) for u, v in edges])
    idx = build_index(g, "cosine")
    res = query(idx, g, 3, 0.6)
    lab = np.asarray(res.labels)
    assert len({lab[0], lab[1], lab[2], lab[3]}) == 1 and lab[0] >= 0
    assert len({lab[5], lab[6], lab[7], lab[10]}) == 1 and lab[5] >= 0
    assert lab[0] != lab[5]
    assert lab[4] == -1
    hub, _ = hubs_outliers(g, res.labels)
    assert bool(hub[4])


def test_core_mask_via_direct_threshold():
    """get_cores (CO-prefix path) ≡ direct θ(v,μ) ≥ ε check."""
    g = random_graph(70, 6.0, seed=14)
    sims = compute_similarities(g, "cosine")
    idx = build_index(g, "cosine", sims=sims)
    for mu in (2, 3, 7):
        for eps in (0.1, 0.5, 0.8):
            a = np.asarray(get_cores(idx, mu, eps))
            theta = np.asarray(idx.core_threshold(mu))
            b = theta >= np.float32(eps)
            np.testing.assert_array_equal(a, b)


def test_query_monotonicity():
    """Raising ε or μ never grows the core set (SCAN definition)."""
    g = random_graph(80, 6.0, seed=15)
    idx = build_index(g, "cosine")
    prev = None
    for eps in (0.2, 0.4, 0.6, 0.8):
        cores = np.asarray(get_cores(idx, 3, eps))
        if prev is not None:
            assert np.all(prev | ~cores)   # cores ⊆ prev
        prev = cores
    prev = None
    for mu in (2, 3, 5, 9):
        cores = np.asarray(get_cores(idx, mu, 0.4))
        if prev is not None:
            assert np.all(prev | ~cores)
        prev = cores


def test_empty_and_degenerate():
    g = from_edge_list(4, [(0, 1)])
    idx = build_index(g, "cosine")
    res = query(idx, g, 2, 0.1)
    ref = scan_ref(g, 2, 0.1, "cosine")
    np.testing.assert_array_equal(np.asarray(res.labels), ref["labels"])
    # μ beyond max degree → no cores
    res = query(idx, g, 10, 0.1)
    assert int(res.n_clusters) == 0
    assert np.all(np.asarray(res.labels) == -1)
