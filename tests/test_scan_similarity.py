"""Exact similarity computation vs the sequential oracle (paper §4.1.1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    compute_similarities,
    compute_similarities_dense,
    from_edge_list,
    random_graph,
)
from repro.core.scan_ref import similarities_ref
from repro.kernels import ops as kops

CASES = [
    (random_graph(40, 5.0, seed=1), "cosine"),
    (random_graph(40, 5.0, seed=1), "jaccard"),
    (random_graph(64, 7.0, seed=2, weighted=True), "cosine"),
    (random_graph(150, 3.0, seed=3), "jaccard"),
    (random_graph(150, 9.0, seed=4, weighted=True), "cosine"),
]


@pytest.mark.parametrize("g,measure", CASES)
def test_matches_sequential_oracle(g, measure):
    got = np.asarray(compute_similarities(g, measure))
    want = similarities_ref(g, measure)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("g,measure", CASES)
def test_dense_path_matches(g, measure):
    a = np.asarray(compute_similarities(g, measure))
    b = np.asarray(compute_similarities_dense(g, measure))
    np.testing.assert_allclose(a, b, atol=1e-5)


@pytest.mark.parametrize("g,measure", CASES)
def test_pallas_gram_path_matches(g, measure):
    a = np.asarray(compute_similarities(g, measure))
    b = np.asarray(kops.edge_similarities_gram(g, measure))
    np.testing.assert_allclose(a, b, atol=1e-4)


def test_paper_figure1_value():
    """σ(5,6) = 2/√12 ≈ .577 from the paper's worked example."""
    edges = [(1, 2), (1, 3), (1, 4), (2, 3), (2, 4), (3, 4), (4, 5), (5, 6),
             (6, 7), (6, 8), (7, 8), (7, 11), (8, 11), (7, 9), (8, 10)]
    g = from_edge_list(11, [(u - 1, v - 1) for u, v in edges])
    sims = np.asarray(compute_similarities(g, "cosine"))
    eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
    i = np.nonzero((eu == 4) & (ev == 5))[0][0]
    assert abs(sims[i] - 2 / np.sqrt(12)) < 1e-6


def test_chunked_equals_unchunked():
    g = random_graph(80, 6.0, seed=5)
    a = np.asarray(compute_similarities(g, "cosine", chunk=64))
    b = np.asarray(compute_similarities(g, "cosine", chunk=1 << 16))
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_similarity_range_and_symmetry():
    g = random_graph(100, 8.0, seed=6, weighted=True)
    sims = np.asarray(compute_similarities(g, "cosine"))
    assert np.all(sims >= -1e-6) and np.all(sims <= 1 + 1e-6)
    # symmetric: σ(u,v) == σ(v,u)
    eu, ev = np.asarray(g.edge_u), np.asarray(g.nbrs)
    lut = {(u, v): s for u, v, s in zip(eu, ev, sims)}
    for (u, v), s in lut.items():
        assert abs(lut[(v, u)] - s) < 1e-6
