"""Serve-subsystem tests: index persistence round-trip, vmapped sweeps,
the LRU result cache, and the async micro-batching engine."""
import asyncio

import numpy as np

from repro.core import (build_index, compute_similarities, query,
                        query_batch, random_graph)
from repro.core.scan_ref import scan_ref
from repro.serve import (EngineConfig, IndexStore, MicroBatchEngine,
                         ResultCache, grid_sweep, index_fingerprint,
                         quantize_eps, sweep, sweep_stats)


def _graph_and_index(n=120, deg=8.0, seed=0):
    g = random_graph(n, deg, seed=seed)
    sims = compute_similarities(g, "cosine")
    return g, build_index(g, "cosine", sims=sims), sims


# --------------------------------------------------------------------------
# persistence
# --------------------------------------------------------------------------
def test_store_roundtrip_preserves_everything(tmp_path):
    g, idx, _ = _graph_and_index()
    store = IndexStore(str(tmp_path))
    store.save(idx, g)
    idx2, g2, fp = store.load()

    for f in ("offsets_c", "no_nbrs", "no_sims", "no_self", "co_offsets",
              "co_vertex", "co_theta", "cdeg", "edge_sims"):
        np.testing.assert_array_equal(np.asarray(getattr(idx, f)),
                                      np.asarray(getattr(idx2, f)), err_msg=f)
    for f in ("offsets", "nbrs", "wgts", "edge_u"):
        np.testing.assert_array_equal(np.asarray(getattr(g, f)),
                                      np.asarray(getattr(g2, f)), err_msg=f)
    assert (idx2.n, idx2.m2c, idx2.max_cdeg) == (idx.n, idx.m2c, idx.max_cdeg)
    assert (g2.n, g2.m2) == (g.n, g.m2)
    assert fp == index_fingerprint(idx, g)


def test_restored_index_queries_match_oracle(tmp_path):
    g, idx, sims = _graph_and_index(n=80, deg=6.0, seed=3)
    IndexStore(str(tmp_path)).save(idx, g)
    idx2, g2, _ = IndexStore(str(tmp_path)).load()
    for mu, eps in ((2, 0.3), (3, 0.5), (4, 0.7)):
        res = query(idx2, g2, mu, eps)
        ref = scan_ref(g, mu, eps, "cosine", sims=np.asarray(sims))
        np.testing.assert_array_equal(np.asarray(res.is_core), ref["is_core"])
        np.testing.assert_array_equal(np.asarray(res.labels), ref["labels"])


def test_store_versioning_and_latest(tmp_path):
    g, idx, _ = _graph_and_index(n=40, deg=4.0)
    store = IndexStore(str(tmp_path), keep=2)
    store.save(idx, g)
    store.save(idx, g)
    assert store.latest_version() == 1
    idx2, g2, _ = store.load(version=0)
    assert g2.n == g.n
    # non-monotone explicit versions are rejected (they'd be GC'd on commit)
    try:
        store.save(idx, g, version=0)
    except ValueError:
        pass
    else:
        raise AssertionError("expected ValueError")


def test_fingerprint_tracks_content():
    g, idx, _ = _graph_and_index(n=60, deg=6.0, seed=1)
    g_b, idx_b, _ = _graph_and_index(n=60, deg=6.0, seed=1)
    assert index_fingerprint(idx, g) == index_fingerprint(idx_b, g_b)
    g_c, idx_c, _ = _graph_and_index(n=60, deg=6.0, seed=2)
    assert index_fingerprint(idx, g) != index_fingerprint(idx_c, g_c)


# --------------------------------------------------------------------------
# vmapped sweeps
# --------------------------------------------------------------------------
def test_query_batch_matches_sequential_queries():
    """Acceptance criterion: a vmapped sweep over ≥ 16 (μ, ε) settings is
    identical to sequential single queries."""
    g, idx, _ = _graph_and_index(n=150, deg=10.0, seed=5)
    mus = np.asarray([2, 3, 4, 5] * 5, np.int32)
    epss = np.linspace(0.05, 0.95, 20).astype(np.float32)
    assert len(mus) >= 16
    batched = query_batch(idx, g, mus, epss)
    for i, (mu, eps) in enumerate(zip(mus, epss)):
        one = query(idx, g, int(mu), float(eps))
        np.testing.assert_array_equal(np.asarray(batched.labels[i]),
                                      np.asarray(one.labels))
        np.testing.assert_array_equal(np.asarray(batched.is_core[i]),
                                      np.asarray(one.is_core))
        assert int(batched.n_clusters[i]) == int(one.n_clusters)


def test_grid_sweep_covers_cartesian_product():
    g, idx, _ = _graph_and_index(n=60, deg=6.0)
    res = grid_sweep(idx, g, [2, 3], [0.2, 0.5, 0.8])
    assert len(res) == 6
    assert res.labels.shape == (6, g.n)
    # μ-major ordering
    np.testing.assert_array_equal(res.mus, [2, 2, 2, 3, 3, 3])
    np.testing.assert_allclose(res.epss, [0.2, 0.5, 0.8] * 2, rtol=1e-6)
    one = query(idx, g, 3, 0.5)
    np.testing.assert_array_equal(res.result(4).labels, np.asarray(one.labels))


def test_sweep_stats_rows():
    g, idx, _ = _graph_and_index(n=60, deg=8.0)
    rows = sweep_stats(idx, g, [2, 3], [0.1, 0.3])
    assert len(rows) == 4
    for r in rows:
        assert 0.0 <= r["coverage"] <= 1.0
        assert r["n_clusters"] <= max(r["n_cores"], 1)
        assert -1.0 <= r["modularity"] <= 1.0
    # cores are monotone non-increasing in ε at fixed μ
    by_mu = {(r["mu"], round(r["eps"], 3)): r["n_cores"] for r in rows}
    assert by_mu[(2, 0.3)] <= by_mu[(2, 0.1)]


def test_sweep_rejects_mismatched_shapes():
    g, idx, _ = _graph_and_index(n=30, deg=4.0)
    try:
        sweep(idx, g, [2, 3], [0.5])
    except ValueError:
        pass
    else:
        raise AssertionError("expected ValueError")


# --------------------------------------------------------------------------
# result cache
# --------------------------------------------------------------------------
def test_cache_lru_eviction_and_stats():
    c = ResultCache(capacity=2)
    c.put("fp", 2, 0.5, "a")
    c.put("fp", 3, 0.5, "b")
    assert c.get("fp", 2, 0.5) == "a"      # 2 is now most-recent
    c.put("fp", 4, 0.5, "c")               # evicts 3
    assert c.get("fp", 3, 0.5) is None
    assert c.get("fp", 2, 0.5) == "a"
    st = c.stats()
    assert st["evictions"] == 1 and st["hits"] == 2 and st["misses"] == 1


def test_cache_eps_quantization_aliases_near_identical():
    c = ResultCache(capacity=8, eps_quantum=1e-4)
    c.put("fp", 2, 0.6, "x")
    assert c.get("fp", 2, 0.60000002) == "x"
    assert c.get("fp", 2, 0.6002) is None
    assert quantize_eps(0.60004999) == 0.6
    assert quantize_eps(0.6001) == 0.6001


def test_eps_quantization_grid_edge_aliasing():
    """Regression for the ε-boundary bug class: values straddling a 1e-4
    grid edge within half a quantum alias to the same cell (round-half-even
    at exact midpoints); values a full quantum away never do. Documented in
    serve/cache.py."""
    # both sides of the 0.3 grid edge, within half a quantum → alias
    assert quantize_eps(0.29995) == 0.3      # midpoint rounds to even cell
    assert quantize_eps(0.29996) == 0.3
    assert quantize_eps(0.30004) == 0.3
    c = ResultCache(capacity=8)
    c.put("fp", 2, 0.29995, "cell-0.3")
    assert c.get("fp", 2, 0.30004) == "cell-0.3"
    assert c.get("fp", 2, 0.3) == "cell-0.3"
    # one full quantum away → distinct cells, no aliasing
    assert quantize_eps(0.2999) == 0.2999
    assert quantize_eps(0.3001) == 0.3001
    assert c.get("fp", 2, 0.2999) is None
    assert c.get("fp", 2, 0.3001) is None
    # the snap never moves ε by more than half a quantum (+ float slack),
    # and re-quantizing is a fixed point (grid values snap to themselves)
    for e in (0.0, 0.00005, 0.00015, 0.123456, 0.29995, 0.5, 0.99995, 1.0):
        q = quantize_eps(e)
        assert abs(q - e) <= 0.5e-4 + 1e-12, e
        assert quantize_eps(q) == q, e


def test_engine_executes_quantized_eps_not_raw():
    """Quantization must gate *execution*, not just the cache key: the
    device call receives the snapped ε, so a cached answer and a computed
    answer for the same cell can never disagree."""
    g, idx, _ = _graph_and_index(n=50, deg=5.0, seed=6)
    engine = MicroBatchEngine(idx, g, config=EngineConfig(
        max_batch=4, flush_ms=5.0, warm_ahead=False))
    seen = []
    real_call = engine._device_call

    def spy(fp, index, graph, mus, epss):
        seen.append(np.asarray(epss).copy())
        return real_call(fp, index, graph, mus, epss)

    engine._device_call = spy

    async def main():
        async with engine:
            a, b = await asyncio.gather(engine.query(2, 0.29995),
                                        engine.query(2, 0.30004))
            return a, b

    a, b = asyncio.run(main())
    # both straddling requests fold into ONE executed slot at exactly 0.3
    assert engine.stats["deduped"] == 1
    assert engine.stats["device_queries"] == 1
    assert all(np.all(e == np.float32(0.3)) for e in seen)
    ref = query(idx, g, 2, 0.3)
    np.testing.assert_array_equal(a.labels, np.asarray(ref.labels))
    np.testing.assert_array_equal(b.labels, np.asarray(ref.labels))


def test_cache_fingerprint_invalidation():
    c = ResultCache(capacity=8)
    c.put("fp1", 2, 0.5, "a")
    c.put("fp1", 3, 0.5, "b")
    c.put("fp2", 2, 0.5, "c")
    assert c.invalidate("fp1") == 2
    assert c.get("fp2", 2, 0.5) == "c"
    assert c.get("fp1", 2, 0.5) is None


# --------------------------------------------------------------------------
# micro-batching engine
# --------------------------------------------------------------------------
def test_engine_concurrent_queries_match_direct():
    g, idx, _ = _graph_and_index(n=100, deg=8.0, seed=9)
    cfg = EngineConfig(max_batch=8, flush_ms=20.0)
    engine = MicroBatchEngine(idx, g, config=cfg)
    reqs = [(mu, eps) for mu in (2, 3, 4) for eps in (0.2, 0.4, 0.6, 0.8)]

    async def main():
        async with engine:
            return await asyncio.gather(
                *[engine.query(mu, eps) for mu, eps in reqs])

    outs = asyncio.run(main())
    for (mu, eps), out in zip(reqs, outs):
        ref = query(idx, g, mu, eps)
        np.testing.assert_array_equal(out.labels, np.asarray(ref.labels))
        assert int(out.n_clusters) == int(ref.n_clusters)
    st = engine.batch_stats()
    # 12 concurrent requests with max_batch=8 → at most a handful of
    # device calls, strictly fewer than one per request
    assert st["device_queries"] < len(reqs)
    assert st["requests"] == len(reqs)


def test_engine_caches_and_dedupes():
    g, idx, _ = _graph_and_index(n=60, deg=6.0, seed=4)
    engine = MicroBatchEngine(idx, g,
                              config=EngineConfig(max_batch=4, flush_ms=20.0))

    async def main():
        async with engine:
            a, b = await asyncio.gather(engine.query(2, 0.5),
                                        engine.query(2, 0.5))
            calls_after_first = engine.stats["device_queries"]
            c = await engine.query(2, 0.5)          # served from cache
            return a, b, c, calls_after_first

    a, b, c, calls = asyncio.run(main())
    assert calls == 1
    assert engine.stats["device_queries"] == 1
    assert engine.stats["cache_hits"] >= 1
    assert engine.stats["deduped"] >= 1
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(a.labels, c.labels)


def test_engine_survives_device_failure():
    """A failing device call rejects that batch's waiters; the collector
    stays alive and answers the next request."""
    g, idx, _ = _graph_and_index(n=40, deg=4.0, seed=11)
    engine = MicroBatchEngine(idx, g,
                              config=EngineConfig(max_batch=4, flush_ms=5.0))
    real_execute = engine._execute
    calls = {"n": 0}

    def flaky(fp, batch):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("injected device failure")
        return real_execute(fp, batch)

    engine._execute = flaky

    async def main():
        async with engine:
            try:
                await engine.query(2, 0.5)
            except RuntimeError as e:
                assert "injected" in str(e)
            else:
                raise AssertionError("expected RuntimeError")
            return await engine.query(2, 0.5)   # loop must still be alive

    out = asyncio.run(main())
    ref = query(idx, g, 2, 0.5)
    np.testing.assert_array_equal(out.labels, np.asarray(ref.labels))


def test_engine_cached_results_do_not_pin_batch_arrays():
    """Cached rows must be copies, not views of the padded [max_batch, n]
    device output (a view pins max_batch× the memory per entry)."""
    g, idx, _ = _graph_and_index(n=40, deg=4.0, seed=12)
    engine = MicroBatchEngine(idx, g,
                              config=EngineConfig(max_batch=8, flush_ms=5.0))

    async def main():
        async with engine:
            return await engine.query(2, 0.5)

    out = asyncio.run(main())
    assert out.labels.base is None
    assert out.is_core.base is None
    assert out.labels.shape == (g.n,)


def test_engine_invalidates_on_new_fingerprint(tmp_path):
    """A rebuilt identical index keeps cache hits (same fingerprint);
    a different graph's engine never sees them (different key space)."""
    g, idx, _ = _graph_and_index(n=50, deg=6.0, seed=7)
    cache = ResultCache(capacity=64)
    e1 = MicroBatchEngine(idx, g, cache=cache)
    g2, idx2, _ = _graph_and_index(n=50, deg=6.0, seed=8)
    e2 = MicroBatchEngine(idx2, g2, cache=cache)
    assert e1.fingerprint != e2.fingerprint

    async def main():
        async with e1:
            await e1.query(2, 0.5)
        async with e2:
            await e2.query(2, 0.5)

    asyncio.run(main())
    assert e2.stats["cache_hits"] == 0
    # each engine's answer lives under its own fingerprint (no aliasing);
    # warm-ahead entries may add more keys, also fingerprint-scoped
    assert cache.peek(e1.fingerprint, 2, 0.5) is not None
    assert cache.peek(e2.fingerprint, 2, 0.5) is not None
