"""Serve-layer observability: the engine's registry-backed stats view,
per-request span taxonomy, the steady-state no-retrace guard, exact
counters under loop+offload concurrency, and the live-apply pipeline
spans recording alongside query latency histograms.
"""
import asyncio
import threading

import numpy as np
import pytest

from repro.core import (EdgeDelta, ShardedQueryPlan, build_index,
                        query_mesh, random_graph)
from repro.obs import MetricsRegistry, hist_delta
from repro.serve import EngineConfig, LiveIndexService, MicroBatchEngine


def _graph_and_index(n=80, deg=6.0, seed=0):
    g = random_graph(n, deg, seed=seed)
    return g, build_index(g, "cosine")


# --------------------------------------------------------------------------
# legacy stats compat shim
# --------------------------------------------------------------------------
def test_stats_view_is_read_only_registry_mapping():
    g, idx = _graph_and_index(n=40, deg=4.0)
    engine = MicroBatchEngine(idx, g)
    assert engine.stats["requests"] == 0
    assert set(engine.stats) == {"requests", "batches", "device_queries",
                                 "cache_hits", "deduped", "warmed",
                                 "bucket_failures"}
    assert len(engine.stats) == 7
    assert dict(engine.stats)["warmed"] == 0
    with pytest.raises(KeyError):
        engine.stats["no_such_counter"]
    with pytest.raises(TypeError):
        engine.stats["requests"] = 5       # the old racy dict is gone
    # the view reads the registry live
    engine.registry.inc("engine.requests", 3)
    assert engine.stats["requests"] == 3


def test_external_registry_is_adopted():
    reg = MetricsRegistry()
    g, idx = _graph_and_index(n=40, deg=4.0)
    engine = MicroBatchEngine(idx, g, registry=reg)
    assert engine.registry is reg
    assert engine.tracer.registry is reg


# --------------------------------------------------------------------------
# per-request span taxonomy + latency histograms
# --------------------------------------------------------------------------
def test_request_spans_and_latency_histograms_populate():
    g, idx = _graph_and_index()
    # warm_ahead off so every distinct setting truly enqueues (warming
    # would turn the neighbors of the first query into cache hits)
    engine = MicroBatchEngine(idx, g, config=EngineConfig(
        max_batch=4, flush_ms=1.0, warm_ahead=False))

    async def main():
        async with engine:
            await engine.query(2, 0.3)
            await asyncio.gather(*[engine.query(2 + i % 2, 0.35 + 0.1 * i)
                                   for i in range(4)])
            await engine.query(2, 0.3)     # cache hit

    asyncio.run(main())
    tr = engine.tracer
    # one cache_lookup per request, queue_wait per enqueued request,
    # batch_assembly + device_call per flush that reached the device
    assert len(tr.events("engine.cache_lookup")) == 6
    assert len(tr.events("engine.queue_wait")) >= 5
    assert len(tr.events("engine.batch_assembly")) >= 1
    dev = tr.events("engine.device_call")
    assert dev and all(e["duration_s"] > 0 for e in dev)
    assert all("fingerprint" in e["attrs"] and "need" in e["attrs"]
               for e in dev)
    snap = engine.registry.snapshot()["histograms"]
    # every request lands in e2e (cache hits included)
    assert snap["engine.e2e"]["count"] == 6
    assert snap["engine.queue_wait"]["count"] >= 5
    st = engine.latency_stats()
    assert st["e2e_n"] == 6 and st["wait_n"] >= 5
    assert 0 < st["e2e_p50"] <= st["e2e_p90"] <= st["e2e_p99"]
    assert st["e2e_p99"] <= snap["engine.e2e"]["max"] * 10 ** (1 / 8)


def test_batch_stats_reports_jit_recompiles():
    g, idx = _graph_and_index(n=40, deg=4.0)
    engine = MicroBatchEngine(idx, g)

    async def main():
        async with engine:
            await engine.query(2, 0.5)

    asyncio.run(main())
    st = engine.batch_stats()
    assert "jit_recompiles" in st
    assert st["jit_recompiles"] >= 0
    assert st["device_queries"] == 1


# --------------------------------------------------------------------------
# steady-state no-retrace guard
# --------------------------------------------------------------------------
def test_warmed_engine_never_retraces_on_same_shape_flushes():
    """After warmup, repeated flushes with fresh (μ, ε) settings (cache
    misses, so every wave reaches the device) must not grow the jit
    cache: the recompile counter stays flat while device calls climb.
    A padding or cache-key regression that retraced per flush would
    trip this immediately."""
    g, idx = _graph_and_index()
    engine = MicroBatchEngine(idx, g, config=EngineConfig(
        max_batch=4, flush_ms=1.0))

    async def main():
        async with engine:
            await engine.query(2, 0.30)    # warmup: first trace happens here
            await engine.query(3, 0.35)
            warmed = engine.batch_stats()
            for i, eps in enumerate((0.42, 0.47, 0.52, 0.57, 0.62, 0.67)):
                await engine.query(2 + i % 3, eps)
            return warmed, engine.batch_stats()

    warmed, final = asyncio.run(main())
    assert final["device_queries"] > warmed["device_queries"]
    assert final["jit_recompiles"] == warmed["jit_recompiles"], \
        "steady-state flushes retraced the query kernel"


# --------------------------------------------------------------------------
# loop + offload-worker concurrency (the lost-update regression)
# --------------------------------------------------------------------------
def test_counters_exact_under_loop_and_offload_mutation():
    """The old ``stats`` dict was mutated from the event loop and the
    offload worker without synchronization; the registry must count
    exactly under that same split."""
    g, idx = _graph_and_index(n=40, deg=4.0)
    engine = MicroBatchEngine(idx, g)
    n_jobs, per_job, per_loop = 20, 500, 5000

    def worker_job():
        for _ in range(per_job):
            engine.registry.inc("engine.shared_test")

    async def main():
        async with engine:
            jobs = [asyncio.ensure_future(engine.run_offloaded(worker_job))
                    for _ in range(n_jobs)]
            for _ in range(per_loop):      # loop-side writer, interleaved
                engine.registry.inc("engine.shared_test")
            await asyncio.gather(*jobs)

    asyncio.run(main())
    expect = n_jobs * per_job + per_loop
    assert engine.registry.counter("engine.shared_test").value == expect
    assert engine.registry.counter("engine.offload_jobs").value == n_jobs
    assert engine.registry.gauge("engine.offload_depth").value == 0


# --------------------------------------------------------------------------
# live-apply pipeline spans (acceptance)
# --------------------------------------------------------------------------
def test_apply_spans_record_while_query_latency_populates(tmp_path,
                                                         monkeypatch):
    """Acceptance: a ``LiveIndexService.apply`` records nonzero-duration
    ``live.apply``/``live.apply_delta`` spans (with the UpdateInfo work
    counters as attributes) while the concurrent query path keeps
    populating the engine's latency histograms."""
    import repro.serve.live as live_mod

    svc = LiveIndexService(str(tmp_path),
                           config=EngineConfig(max_batch=8, flush_ms=5.0))
    g = random_graph(60, 6.0, seed=1, weighted=True)
    svc.create("web", g)
    entered = threading.Event()
    gate = threading.Event()
    real_apply = live_mod.apply_delta

    def gated_apply(*args, **kwargs):
        entered.set()
        assert gate.wait(30), "test gate never opened"
        return real_apply(*args, **kwargs)

    monkeypatch.setattr(live_mod, "apply_delta", gated_apply)
    delta = EdgeDelta.make(inserts=[(0, 30), (1, 45)], weights=[0.9, 0.8])

    async def main():
        async with svc:
            e2e_before = svc.engine.registry.histogram(
                "engine.e2e").snapshot()
            apply_task = asyncio.ensure_future(svc.apply("web", delta))
            while not entered.is_set():
                await asyncio.sleep(0.005)
            # queries answered while the apply is parked in the worker
            for mu, eps in ((2, 0.3), (3, 0.5), (2, 0.7)):
                await asyncio.wait_for(svc.query("web", mu, eps), timeout=10)
            e2e_during = hist_delta(
                svc.engine.registry.histogram("engine.e2e").snapshot(),
                e2e_before)
            gate.set()
            info = await apply_task
            return info, e2e_during

    info, e2e_during = asyncio.run(main())
    assert info.n_inserted == 2
    # query latency kept flowing while the apply was in flight
    assert e2e_during["count"] >= 3 and e2e_during["sum"] > 0

    tr = svc.engine.tracer
    (apply_ev,) = tr.events("live.apply")
    (delta_ev,) = tr.events("live.apply_delta")
    assert apply_ev["duration_s"] > 0
    assert delta_ev["duration_s"] > 0
    assert apply_ev["duration_s"] >= delta_ev["duration_s"]
    # UpdateInfo work counters ride on the apply_delta span
    assert delta_ev["attrs"]["n_inserted"] == 2
    assert delta_ev["attrs"]["n_frontier"] == info.n_frontier
    assert apply_ev["attrs"]["swapped"] is True
    # the worker-side span nests under live.apply (contextvars shipped
    # into the offload executor by run_offloaded)
    assert delta_ev["parent_id"] == apply_ev["span_id"]
    # the swap pipeline traced end to end
    for name in ("live.fingerprint", "live.log_append", "live.swap",
                 "live.drain", "live.rewarm"):
        evs = tr.events(name)
        assert evs, f"missing span {name}"
    reg_hists = svc.engine.registry.snapshot()["histograms"]
    assert reg_hists["live.apply"]["count"] == 1
    assert reg_hists["live.apply"]["sum"] > 0


# --------------------------------------------------------------------------
# sharded plan placement metrics
# --------------------------------------------------------------------------
def test_sharded_plan_records_placement_metrics():
    g, idx = _graph_and_index(n=64, deg=6.0, seed=5)
    reg = MetricsRegistry()
    plan = ShardedQueryPlan(idx, g, query_mesh(1), registry=reg)
    snap = reg.snapshot()
    assert snap["counters"]["sharded.chunks_placed"] > 0
    assert snap["histograms"]["sharded.plan_build"]["count"] == 1
    assert snap["histograms"]["sharded.place_full"]["count"] > 0

    # a refresh through _reuse_from inherits the registry and counts
    # adopted chunks
    plan2 = plan.refresh(idx, g)
    assert plan2._registry is reg
    snap2 = reg.snapshot()
    assert snap2["counters"]["sharded.chunks_reused"] == \
        plan2.last_refresh["reused"]
    assert snap2["histograms"]["sharded.plan_build"]["count"] == 2
