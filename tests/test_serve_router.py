"""Multi-index router stress tests: mixed-fingerprint traffic through one
engine, dedup that never aliases across indexes, per-index cache
partitions/invalidation, per-bucket failure isolation, sweep-ahead warming
of the (μ, ε) neighborhood, and live-update integration (an update batch
invalidates exactly the mutated index's partition and re-warms observed
traffic post-swap; the old-or-new-never-a-mix hot-swap property itself is
covered in tests/test_live_service.py)."""
import asyncio

import numpy as np
import pytest

from repro.core import (EdgeDelta, build_index, compute_similarities, query,
                        random_graph)
from repro.serve import (EngineConfig, IndexCatalog, LiveIndexService,
                         MicroBatchEngine, PartitionedResultCache,
                         neighborhood)


def _graph_and_index(n=80, deg=6.0, seed=0):
    g = random_graph(n, deg, seed=seed)
    sims = compute_similarities(g, "cosine")
    return g, build_index(g, "cosine", sims=sims)


def _two_index_engine(config=None, seeds=(1, 2), n=80):
    """One engine serving two same-shaped but different graphs."""
    cfg = config or EngineConfig(max_batch=8, flush_ms=20.0)
    engine = MicroBatchEngine(config=cfg)
    pairs = {}
    for seed in seeds:
        g, idx = _graph_and_index(n=n, seed=seed)
        fp = engine.register(idx, g)
        pairs[fp] = (idx, g)
    return engine, pairs


# --------------------------------------------------------------------------
# routing correctness
# --------------------------------------------------------------------------
def test_mixed_fingerprint_traffic_routes_correctly():
    """Concurrent traffic against two indexes through one engine: every
    answer must match a direct query against the right index."""
    engine, pairs = _two_index_engine()
    fps = list(pairs)
    pool = [(mu, eps) for mu in (2, 3, 4) for eps in (0.2, 0.5, 0.8)]
    rng = np.random.default_rng(0)
    reqs = [(fps[int(rng.integers(2))], *pool[int(rng.integers(len(pool)))])
            for _ in range(40)]

    async def main():
        async with engine:
            return await asyncio.gather(
                *[engine.query(mu, eps, fingerprint=fp)
                  for fp, mu, eps in reqs])

    outs = asyncio.run(main())
    for (fp, mu, eps), out in zip(reqs, outs):
        idx, g = pairs[fp]
        ref = query(idx, g, mu, eps)
        np.testing.assert_array_equal(out.labels, np.asarray(ref.labels))
        np.testing.assert_array_equal(out.is_core, np.asarray(ref.is_core))
    st = engine.batch_stats()
    assert st["requests"] == len(reqs)
    assert st["indexes"] == 2
    # coalescing still happens per bucket: far fewer device calls than
    # requests, but at least one per fingerprint
    assert 2 <= st["device_queries"] < len(reqs)


def test_dedup_does_not_alias_across_indexes():
    """The same (μ, ε) fired concurrently at two different indexes must
    dedup within each index but never fold across them."""
    engine, pairs = _two_index_engine()
    (fp_a, (idx_a, g_a)), (fp_b, (idx_b, g_b)) = pairs.items()

    async def main():
        async with engine:
            return await asyncio.gather(
                engine.query(2, 0.5, fingerprint=fp_a),
                engine.query(2, 0.5, fingerprint=fp_a),
                engine.query(2, 0.5, fingerprint=fp_b),
                engine.query(2, 0.5, fingerprint=fp_b),
            )

    a1, a2, b1, b2 = asyncio.run(main())
    # within-index dedup: both waiters observed, one slot each
    assert engine.stats["deduped"] == 2
    assert engine.stats["device_queries"] == 2      # one call per bucket
    np.testing.assert_array_equal(a1.labels, a2.labels)
    np.testing.assert_array_equal(b1.labels, b2.labels)
    # across indexes the answers are the *right* ones, not shared ones
    np.testing.assert_array_equal(
        a1.labels, np.asarray(query(idx_a, g_a, 2, 0.5).labels))
    np.testing.assert_array_equal(
        b1.labels, np.asarray(query(idx_b, g_b, 2, 0.5).labels))
    assert not np.array_equal(a1.labels, b1.labels), \
        "seed-1 and seed-2 graphs should cluster differently"


# --------------------------------------------------------------------------
# cache partitions
# --------------------------------------------------------------------------
def test_per_index_cache_invalidation_on_unregister():
    engine, pairs = _two_index_engine()
    fp_a, fp_b = pairs

    async def main():
        async with engine:
            await engine.query(2, 0.5, fingerprint=fp_a)
            await engine.query(2, 0.5, fingerprint=fp_b)

    asyncio.run(main())
    assert engine.unregister(fp_b) >= 1          # partition dropped whole

    async def after():
        async with engine:
            hits0 = engine.stats["cache_hits"]
            await engine.query(2, 0.5, fingerprint=fp_a)   # still cached
            assert engine.stats["cache_hits"] == hits0 + 1
            with pytest.raises(KeyError):
                await engine.query(2, 0.5, fingerprint=fp_b)

    asyncio.run(after())


def test_partitioned_cache_isolates_eviction_pressure():
    """A hot index hammering its partition must not evict a cold index's
    entries (the failure mode of one flat LRU)."""
    c = PartitionedResultCache(capacity=4)
    c.put("cold", 2, 0.5, "keep-me")
    for i in range(100):                 # 25× the capacity, all one index
        c.put("hot", 2 + i, 0.5, i)
    assert c.peek("cold", 2, 0.5) == "keep-me"
    assert len(c) == 4 + 1
    st = c.stats()
    assert st["partitions"] == 2
    assert st["evictions"] == 96
    assert c.invalidate("hot") == 4
    assert c.peek("cold", 2, 0.5) == "keep-me"


# --------------------------------------------------------------------------
# failure isolation
# --------------------------------------------------------------------------
def test_bucket_failure_isolated_per_index():
    """A device failure for one index's bucket rejects only that bucket's
    waiters; the sibling bucket in the same flush succeeds and the
    collector answers later traffic for *both* indexes."""
    engine, pairs = _two_index_engine()
    fp_ok, fp_bad = pairs
    idx_bad = pairs[fp_bad][0]
    real_call = engine._device_call
    state = {"armed": True}

    def flaky(fp, index, g, mus, epss):
        if state["armed"] and index is idx_bad:
            raise RuntimeError("injected device failure")
        return real_call(fp, index, g, mus, epss)

    engine._device_call = flaky

    async def main():
        async with engine:
            good, bad = await asyncio.gather(
                engine.query(2, 0.5, fingerprint=fp_ok),
                engine.query(2, 0.5, fingerprint=fp_bad),
                return_exceptions=True)
            assert isinstance(bad, RuntimeError) and "injected" in str(bad)
            assert not isinstance(good, Exception)
            idx, g = pairs[fp_ok]
            np.testing.assert_array_equal(
                good.labels, np.asarray(query(idx, g, 2, 0.5).labels))
            # collector survives; the failed index recovers once healthy
            state["armed"] = False
            retry = await engine.query(2, 0.5, fingerprint=fp_bad)
            return retry

    retry = asyncio.run(main())
    idx, g = pairs[fp_bad]
    np.testing.assert_array_equal(
        retry.labels, np.asarray(query(idx, g, 2, 0.5).labels))
    assert engine.stats["bucket_failures"] == 1


def test_register_hot_swap_drops_stale_state():
    """Re-registering under an existing fingerprint (hot-swap) must drop
    the old index's cached answers — otherwise the swapped-in index keeps
    serving its predecessor's clusters."""
    g1, idx1 = _graph_and_index(n=50, deg=5.0, seed=1)
    g2, idx2 = _graph_and_index(n=50, deg=5.0, seed=2)
    engine = MicroBatchEngine(config=EngineConfig(max_batch=4, flush_ms=5.0))
    engine.register(idx1, g1, fingerprint="route")

    async def ask():
        async with engine:
            return await engine.query(2, 0.5, fingerprint="route")

    before = asyncio.run(ask())
    engine.register(idx2, g2, fingerprint="route")
    after = asyncio.run(ask())
    np.testing.assert_array_equal(
        before.labels, np.asarray(query(idx1, g1, 2, 0.5).labels))
    np.testing.assert_array_equal(
        after.labels, np.asarray(query(idx2, g2, 2, 0.5).labels))
    assert not np.array_equal(before.labels, after.labels)


def test_engine_survives_second_event_loop():
    """An engine reused across two asyncio.run() calls must serve cache
    *misses* in the second loop: the collector's queue is per-loop
    (asyncio.Queue binds to the loop that first awaits it), so a stale
    queue would silently kill the new collector and strand every waiter."""
    g, idx = _graph_and_index(n=40, deg=4.0, seed=3)
    engine = MicroBatchEngine(idx, g, config=EngineConfig(
        max_batch=4, flush_ms=5.0, warm_ahead=False))

    async def one(mu, eps):
        async with engine:
            return await engine.query(mu, eps)

    first = asyncio.run(one(2, 0.5))
    second = asyncio.run(one(3, 0.7))      # distinct setting: a real miss
    assert engine.stats["device_queries"] == 2
    for (mu, eps), out in (((2, 0.5), first), ((3, 0.7), second)):
        np.testing.assert_array_equal(
            out.labels, np.asarray(query(idx, g, mu, eps).labels))


# --------------------------------------------------------------------------
# sweep-ahead warming
# --------------------------------------------------------------------------
def test_neighborhood_candidates():
    cands = neighborhood(3, 0.5, eps_step=0.05)
    assert (4, 0.5) in cands and (2, 0.5) in cands
    assert (3, 0.55) in cands and (3, 0.45) in cands
    # μ < 2 and ε outside [0, 1] never proposed
    assert all(mu >= 2 for mu, _ in neighborhood(2, 0.0))
    assert all(0.0 <= e <= 1.0 for _, e in neighborhood(2, 1.0))


def test_neighborhood_clamps_and_dedups():
    """Regression: every candidate must land inside the valid query domain
    (μ ≥ 2, ε ∈ [0, 1]) and be unique *after* clamping — out-of-range or
    colliding candidates would burn warming slots on queries no client
    can issue."""
    for mu, eps, step in ((2, 0.0, 0.05), (2, 1.0, 0.05), (5, 0.98, 0.05),
                          (3, 0.02, 0.05), (2, 0.5, 0.9), (4, 1.5, 0.05),
                          (7, -0.3, 0.05), (2, 0.5, 2.0)):
        cands = neighborhood(mu, eps, eps_step=step)
        assert all(m >= 2 for m, _ in cands), (mu, eps, step, cands)
        assert all(0.0 <= e <= 1.0 for _, e in cands), (mu, eps, step, cands)
        assert len(cands) == len(set(cands)), (mu, eps, step, cands)
    # a big step clamps both ε neighbors onto the boundary pair — they must
    # collapse to single candidates, not duplicate entries
    cands = neighborhood(3, 0.5, eps_step=0.9)
    assert sorted(cands) == [(2, 0.5), (3, 0.0), (3, 1.0), (4, 0.5)]
    # an out-of-domain observed ε still yields clamped, deduped candidates
    # that exclude the observed setting's clamp (the real request computes
    # and caches its own key)
    cands = neighborhood(4, 1.5, eps_step=0.05)
    assert (4, 1.0) not in cands and len(cands) == len(set(cands))
    # non-finite ε cannot produce candidates (NaN survives min/max clamps)
    assert neighborhood(3, float("nan")) == []
    assert neighborhood(3, float("inf")) == []
    assert neighborhood(3, float("-inf")) == []
    # huge-but-finite ε must not overflow quantization (ε/quantum → inf
    # inside round()); it anchors at the domain edge like any clamp
    cands = neighborhood(3, 1.7e308, eps_step=0.05, quantum=1e-9)
    assert cands and all(0.0 <= e <= 1.0 for _, e in cands), cands
    # a quantum that doesn't divide 1 must not snap a clamped candidate
    # back out of the domain (quantize(1.0, 0.15) = 1.05 — dropped)
    cands = neighborhood(3, 0.95, eps_step=0.1, quantum=0.15)
    assert cands and all(0.0 <= e <= 1.0 for _, e in cands), cands


def test_warming_turns_neighbor_queries_into_cache_hits():
    """Padding slots precompute the (μ±1, ε±δ) neighborhood, so a client
    walking the parameter grid gets its next answer without a device call."""
    g, idx = _graph_and_index(seed=5)
    engine = MicroBatchEngine(idx, g, config=EngineConfig(
        max_batch=8, flush_ms=5.0, warm_ahead=True, warm_eps_step=0.05))

    async def main():
        async with engine:
            await engine.query(3, 0.5)
            assert engine.stats["device_queries"] == 1
            assert engine.stats["warmed"] >= 4
            # grid-walk: all four neighbors are already cached
            for mu, eps in ((4, 0.5), (2, 0.5), (3, 0.55), (3, 0.45)):
                out = await engine.query(mu, eps)
                ref = query(idx, g, mu, eps)
                np.testing.assert_array_equal(out.labels,
                                              np.asarray(ref.labels))
            assert engine.stats["device_queries"] == 1
            assert engine.stats["cache_hits"] == 4

    asyncio.run(main())


def test_warming_disabled_pads_with_repeats():
    g, idx = _graph_and_index(seed=5)
    engine = MicroBatchEngine(idx, g, config=EngineConfig(
        max_batch=8, flush_ms=5.0, warm_ahead=False))

    async def main():
        async with engine:
            await engine.query(3, 0.5)
            assert engine.stats["warmed"] == 0
            await engine.query(4, 0.5)           # neighbor NOT prewarmed
            assert engine.stats["device_queries"] == 2

    asyncio.run(main())


# --------------------------------------------------------------------------
# live updates through the router
# --------------------------------------------------------------------------
def test_update_invalidates_only_mutated_index_partition(tmp_path):
    """An update batch against index A must drop exactly A's cache
    partition: B's partition keeps its entries and hit counters, while A
    re-answers from the *new* index (never the stale cache)."""
    svc = LiveIndexService(str(tmp_path), config=EngineConfig(
        max_batch=8, flush_ms=5.0, warm_ahead=False))
    svc.create("a", random_graph(60, 6.0, seed=1, weighted=True))
    svc.create("b", random_graph(60, 6.0, seed=2, weighted=True))
    fp_b = svc.fingerprint("b")

    async def main():
        async with svc:
            await svc.query("a", 2, 0.5)
            await svc.query("b", 2, 0.5)
            part_b = svc.engine.cache.partition(fp_b)
            hits_b0 = part_b.hits

            old_fp_a = svc.fingerprint("a")
            await svc.apply("a", EdgeDelta.make(
                inserts=[(0, 30), (1, 40)], weights=[0.9, 0.8]))
            new_fp_a = svc.fingerprint("a")
            assert new_fp_a != old_fp_a
            # A's old partition is gone with its fingerprint
            assert old_fp_a not in svc.engine.fingerprints()
            assert svc.engine.cache.peek(old_fp_a, 2, 0.5) is None

            # B survives untouched: same partition object, a real hit
            assert svc.engine.cache.partition(fp_b) is part_b
            await svc.query("b", 2, 0.5)
            assert part_b.hits == hits_b0 + 1

            # A's answer now comes from the new index
            out = await svc.query("a", 2, 0.5)
            live = svc._live["a"]
            ref = query(live.index, live.g, 2, 0.5)
            np.testing.assert_array_equal(out.labels, np.asarray(ref.labels))

    asyncio.run(main())


def test_observed_neighborhood_rewarmed_after_swap(tmp_path):
    """Post-swap, the service re-issues recently observed settings, whose
    padding-slot warming re-warms the (μ±1, ε±δ) neighborhood — so a
    grid-walking client's next step is a cache hit on the NEW index."""
    svc = LiveIndexService(str(tmp_path), config=EngineConfig(
        max_batch=8, flush_ms=5.0, warm_ahead=True, warm_eps_step=0.05))
    svc.create("a", random_graph(70, 6.0, seed=3, weighted=True))

    async def main():
        async with svc:
            await svc.query("a", 3, 0.5)
            await svc.apply("a", EdgeDelta.make(
                inserts=[(0, 35), (2, 44)], weights=[0.7, 0.6]))
            calls = svc.engine.stats["device_queries"]
            live = svc._live["a"]
            # the observed setting and its whole neighborhood are warm
            for mu, eps in ((3, 0.5), (4, 0.5), (2, 0.5),
                            (3, 0.55), (3, 0.45)):
                out = await svc.query("a", mu, eps)
                ref = query(live.index, live.g, mu, eps)
                np.testing.assert_array_equal(out.labels,
                                              np.asarray(ref.labels))
            assert svc.engine.stats["device_queries"] == calls

    asyncio.run(main())


# --------------------------------------------------------------------------
# catalog → router wiring
# --------------------------------------------------------------------------
def test_index_catalog_feeds_router(tmp_path):
    cat = IndexCatalog(str(tmp_path))
    built = {}
    for name, seed in (("web", 1), ("social", 2)):
        g, idx = _graph_and_index(n=40, deg=4.0, seed=seed)
        cat.save(name, idx, g)
        built[name] = (idx, g)
    assert cat.names() == ["social", "web"]

    engine = MicroBatchEngine(config=EngineConfig(max_batch=4, flush_ms=5.0))
    loaded = cat.load_all()
    assert len(loaded) == 2
    for fp, (idx, g) in loaded.items():
        assert engine.register(idx, g, fingerprint=fp) == fp

    async def main():
        async with engine:
            for fp, (idx, g) in loaded.items():
                out = await engine.query(2, 0.4, fingerprint=fp)
                ref = query(idx, g, 2, 0.4)
                np.testing.assert_array_equal(out.labels,
                                              np.asarray(ref.labels))

    asyncio.run(main())
