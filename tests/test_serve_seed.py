"""Seed-set queries through the serve stack, plus the serve-layer bugfix
regressions that ride along:

  * ``engine.query_seed`` concurrency / caching / bucketing vs the full
    ``query`` oracle;
  * stop-under-load semantics (no stranded waiters, fail-fast afterwards);
  * the ``refine`` no-swap branch relabels provenance without hot-swapping
    away the shard plan or either cache partition;
  * ``_rewarm`` failures are counted, never raised out of a completed
    apply;
  * seed-cache frontier invalidation is conservative-exact under an
    edit-script oracle.
"""
import asyncio

import numpy as np
import pytest

from repro.core import (
    ApproxParams,
    EdgeDelta,
    build_index,
    query,
    random_graph,
)
from repro.serve import EngineConfig, LiveIndexService, MicroBatchEngine


def expected_row(index, g, seed, mu, eps):
    res = query(index, g, int(mu), float(eps))
    labels = np.asarray(res.labels)
    lab = int(labels[seed])
    mask = (labels == lab) if lab >= 0 else np.zeros(g.n, bool)
    return lab, bool(np.asarray(res.is_core)[seed]), mask


def check_row(seed_res, index, g, seed, mu, eps):
    lab, core, mask = expected_row(index, g, seed, mu, eps)
    assert seed_res.label == lab
    assert seed_res.is_core == core
    np.testing.assert_array_equal(seed_res.member_mask, mask)


@pytest.fixture(scope="module")
def small():
    g = random_graph(120, 5.0, seed=4, planted_clusters=4)
    return build_index(g, "cosine"), g


def test_engine_query_seed_matches_oracle(small):
    index, g = small
    cfg = EngineConfig(max_batch=8, flush_ms=1.0, seed_batch=8)
    settings = [(2, 0.3), (3, 0.5), (2, 0.7)]

    async def run():
        engine = MicroBatchEngine(index, g, config=cfg)
        async with engine:
            reqs = [(s, *settings[s % len(settings)])
                    for s in range(0, g.n, 3)]
            outs = await asyncio.gather(
                *[engine.query_seed(s, m, e) for s, m, e in reqs])
        return reqs, outs

    reqs, outs = asyncio.run(run())
    for (s, m, e), out in zip(reqs, outs):
        check_row(out, index, g, s, m, e)


def test_seed_cache_hit_skips_device(small):
    index, g = small

    async def run():
        engine = MicroBatchEngine(index, g, config=EngineConfig(
            flush_ms=1.0, seed_batch=8, warm_ahead=False))
        async with engine:
            a = await engine.query_seed(7, 2, 0.5)
            calls = engine.registry.counter(
                "engine.seed_device_queries").value
            b = await engine.query_seed(7, 2, 0.5)
            calls2 = engine.registry.counter(
                "engine.seed_device_queries").value
            hits = engine.registry.counter("engine.seed_cache_hits").value
        return a, b, calls, calls2, hits

    a, b, calls, calls2, hits = asyncio.run(run())
    assert calls2 == calls       # answered from the seed cache
    assert hits >= 1
    assert a.label == b.label
    np.testing.assert_array_equal(a.member_mask, b.member_mask)


def test_seed_and_global_traffic_bucket_separately(small):
    index, g = small

    async def run():
        engine = MicroBatchEngine(index, g, config=EngineConfig(
            flush_ms=1.0, seed_batch=8))
        async with engine:
            seed_res, full_res = await asyncio.gather(
                engine.query_seed(3, 2, 0.5), engine.query(2, 0.5))
        return seed_res, full_res, engine.batch_stats()

    seed_res, full_res, st = asyncio.run(run())
    # one flush, two kinds → each kind got its own bucket + device call
    assert st["seed_batches"] >= 1
    assert st["batches"] >= 1
    check_row(seed_res, index, g, 3, 2, 0.5)
    labels = np.asarray(full_res.labels)
    lab = int(labels[3])
    np.testing.assert_array_equal(
        seed_res.member_mask,
        (labels == lab) if lab >= 0 else np.zeros(g.n, bool))


def test_stop_under_load_strands_no_waiter(small):
    index, g = small

    async def run():
        engine = MicroBatchEngine(index, g, config=EngineConfig(
            flush_ms=50.0, seed_batch=8))   # slow flush: requests pend
        await engine.start()
        tasks = [asyncio.create_task(engine.query_seed(i % g.n, 2, 0.5))
                 for i in range(12)]
        tasks += [asyncio.create_task(engine.query(2, 0.4))
                  for _ in range(4)]
        await asyncio.sleep(0)              # let every request enqueue
        await engine.stop()
        # every waiter must resolve promptly — an answer or the explicit
        # rejection — never hang on a dead collector
        results = await asyncio.wait_for(
            asyncio.gather(*tasks, return_exceptions=True), timeout=10)
        with pytest.raises(RuntimeError, match="engine stopped"):
            await engine.query(2, 0.5)
        with pytest.raises(RuntimeError, match="engine stopped"):
            await engine.query_seed(0, 2, 0.5)
        return results

    for r in asyncio.run(run()):
        if isinstance(r, BaseException):
            assert isinstance(r, RuntimeError)
            assert "engine stopped" in str(r)


def test_stop_rejects_item_stranded_behind_marker(small):
    # white-box regression for the old shutdown bug: a request whose
    # queue item lands behind the stop marker used to hold a future
    # nobody resolved. The collector's exit path must drain and reject.
    import time as _time

    index, g = small

    async def run():
        engine = MicroBatchEngine(index, g, config=EngineConfig(
            flush_ms=1.0))
        fp = engine.fingerprint
        await engine.start()
        loop = asyncio.get_running_loop()
        stranded = loop.create_future()
        engine._stopped = True              # simulate the lost race:
        engine._queue.put_nowait(None)      # marker first, item behind it
        engine._queue.put_nowait(
            (fp, "q", (2, 0.5), stranded, _time.monotonic()))
        await engine.stop()
        assert stranded.done()
        with pytest.raises(RuntimeError, match="engine stopped"):
            stranded.result()
        return engine.registry.counter("engine.rejected_on_stop").value

    assert asyncio.run(run()) == 1


def test_refine_noswap_relabels_without_hotswap(tmp_path):
    # every closed degree ≤ the sketch width ⇒ the §6.3 degree heuristic
    # computes every edge exactly ⇒ the approximate index is bit-identical
    # to the exact build and refine() must take the relabel branch
    g = random_graph(60, 4.0, seed=6)
    svc = LiveIndexService(tmp_path, config=EngineConfig(
        flush_ms=1.0, seed_batch=8), measure="cosine")

    async def run():
        async with svc:
            fp = svc.register_approximate(
                "a", g, params=ApproxParams.parse("simhash:64"))
            assert svc.provenance("a").is_approx
            await svc.query("a", 2, 0.5)
            for s in (0, 1, 2):
                await svc.query_seed("a", s, 2, 0.5)
            engine = svc.engine
            n_cache, n_seed = len(engine.cache), len(engine.seed_cache)
            assert n_cache > 0 and n_seed > 0
            marker = object()               # sentinel shard plan: a
            engine._shard_plans[fp] = marker  # hot-swap would drop it
            fp2 = await svc.refine("a")
            assert fp2 == fp, "premise: sketch must reproduce exact bits"
            # the no-swap branch must keep route state byte-for-byte:
            assert engine._shard_plans[fp] is marker
            assert len(engine.cache) == n_cache
            assert len(engine.seed_cache) == n_seed
            # ... while still flipping the provenance tag everywhere
            assert not svc.provenance("a").is_approx
            assert not engine._provenance[fp].is_approx
            assert svc.status("a")["provenance"] == "exact"
            del engine._shard_plans[fp]     # drop the sentinel again

    asyncio.run(run())


def test_rewarm_failures_counted_not_raised(tmp_path):
    g = random_graph(100, 4.0, seed=8)
    svc = LiveIndexService(tmp_path, config=EngineConfig(
        flush_ms=1.0), measure="cosine")

    async def run():
        async with svc:
            svc.create("live", g)
            await svc.query("live", 2, 0.5)     # observed traffic to warm

            async def boom(*a, **kw):
                raise RuntimeError("synthetic warm failure")

            svc.engine.query = boom
            delta = EdgeDelta.make(inserts=[(0, 50)])
            info = await svc.apply("live", delta)   # must NOT raise
            assert info is not None
        return svc.engine.registry.counter("live.rewarm_failures").value

    failures = asyncio.run(run())
    assert failures > 0


def test_seed_cache_invalidation_exact_under_edit_oracle(tmp_path):
    # sparse planted graph: the 2-hop stale closure stays local, so a
    # single edge edit must drop only frontier-adjacent entries while
    # untouched seeds keep answering from cache — and every post-delta
    # answer (cached or recomputed) must match the new graph's oracle
    g = random_graph(400, 3.0, seed=9, planted_clusters=8)
    mu, eps = 2, 0.6
    svc = LiveIndexService(tmp_path, config=EngineConfig(
        flush_ms=1.0, seed_batch=16, warm_ahead=False), measure="cosine")

    async def run():
        async with svc:
            svc.create("live", g)
            engine = svc.engine
            for s in range(g.n):
                await svc.query_seed("live", s, mu, eps)
            fp0 = svc.status("live")["fingerprint"]
            assert len(engine.seed_cache) == g.n

            off = np.asarray(g.offsets)
            u = int(np.argmax(np.diff(off) > 0))    # first vertex w/ edges
            v = int(np.asarray(g.nbrs)[off[u]])
            delta = EdgeDelta.make(deletes=[(min(u, v), max(u, v))])
            info = await svc.apply("live", delta)
            fp1 = svc.status("live")["fingerprint"]
            assert fp1 != fp0
            new_g = svc.graph("live")
            stale = info.stale_mask(new_g.n)
            assert stale.any() and not stale.all()

            migrated = engine.registry.counter(
                "live.seed_entries_migrated").value
            dropped = engine.registry.counter(
                "live.seed_entries_dropped").value
            assert migrated > 0 and dropped > 0
            assert migrated + dropped == g.n

            # exactness of the keep/drop split, per entry:
            new_index = svc.index("live")
            kept = sum(
                engine.seed_cache.peek(fp1, s, mu, eps) is not None
                for s in range(g.n))
            assert kept == migrated
            for s in np.flatnonzero(stale):
                # any seed in the closure lost its entry
                assert engine.seed_cache.peek(fp1, int(s), mu, eps) is None

            # untouched seeds answer from cache (no new device batches) …
            survivors = [s for s in range(g.n) if engine.seed_cache.peek(
                fp1, s, mu, eps) is not None]
            calls = engine.registry.counter(
                "engine.seed_device_queries").value
            for s in survivors[:32]:
                res = await svc.query_seed("live", s, mu, eps)
                check_row(res, new_index, new_g, s, mu, eps)
            assert engine.registry.counter(
                "engine.seed_device_queries").value == calls

            # … and every seed, cached or not, matches the new oracle
            for s in list(np.flatnonzero(stale))[:24]:
                res = await svc.query_seed("live", int(s), mu, eps)
                check_row(res, new_index, new_g, int(s), mu, eps)

    asyncio.run(run())
